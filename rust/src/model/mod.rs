//! Model geometry: the unimodal building blocks of Table 1 and the
//! MLLM compositions evaluated in §6.
//!
//! Geometry (layers, hidden, ffn, heads) is what pipeline balance depends
//! on; absolute parameter counts only matter for memory accounting. The
//! numbers mirror the paper's Table 1 exactly.

/// Transformer geometry of one unimodal model.
#[derive(Clone, Debug, PartialEq)]
pub struct ModuleGeom {
    pub name: String,
    pub n_layers: usize,
    pub hidden: usize,
    pub d_ff: usize,
    pub n_heads: usize,
}

impl ModuleGeom {
    pub fn new(name: &str, n_layers: usize, hidden: usize) -> Self {
        ModuleGeom {
            name: name.to_string(),
            n_layers,
            hidden,
            d_ff: 4 * hidden,
            n_heads: (hidden / 128).max(1),
        }
    }

    /// Approximate parameter count (dense transformer):
    /// per layer 4h² (attn) + 2·h·ff (mlp).
    pub fn params(&self) -> u64 {
        let h = self.hidden as u64;
        let f = self.d_ff as u64;
        self.n_layers as u64 * (4 * h * h + 2 * h * f)
    }
}

/// Model size classes of Table 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Size {
    S,
    M,
    L,
}

impl Size {
    pub const ALL: [Size; 3] = [Size::S, Size::M, Size::L];

    pub fn letter(&self) -> &'static str {
        match self {
            Size::S => "S",
            Size::M => "M",
            Size::L => "L",
        }
    }

    pub fn parse(s: &str) -> Option<Size> {
        match s {
            "S" | "s" => Some(Size::S),
            "M" | "m" => Some(Size::M),
            "L" | "l" => Some(Size::L),
            _ => None,
        }
    }
}

/// Llama-3.1 LLM rows of Table 1 (16/2048 ≈ 1.2b, 32/4096 ≈ 8b,
/// 64/5120 ≈ 32b).
pub fn llama(size: Size) -> ModuleGeom {
    match size {
        Size::S => ModuleGeom::new("Llama3.1-S", 16, 2048),
        Size::M => ModuleGeom::new("Llama3.1-M", 32, 4096),
        Size::L => ModuleGeom::new("Llama3.1-L", 64, 5120),
    }
}

/// EVA-CLIP vision encoder rows (40/1408 ≈ 1b, 32/4096 ≈ 8b, 48/5120 ≈ 18b).
pub fn eva_clip(size: Size) -> ModuleGeom {
    match size {
        Size::S => ModuleGeom::new("EVA-CLIP-S", 40, 1408),
        Size::M => ModuleGeom::new("EVA-CLIP-M", 32, 4096),
        Size::L => ModuleGeom::new("EVA-CLIP-L", 48, 5120),
    }
}

/// Whisper audio encoder rows (32/1920 ≈ 1.4b, 40/3840 ≈ 7b, 48/5120 ≈ 15b).
pub fn whisper(size: Size) -> ModuleGeom {
    match size {
        Size::S => ModuleGeom::new("Whisper-S", 32, 1920),
        Size::M => ModuleGeom::new("Whisper-M", 40, 3840),
        Size::L => ModuleGeom::new("Whisper-L", 48, 5120),
    }
}

/// Per-sample token counts of the synthetic dataset (§6.1: 1k text tokens,
/// a 1280×720 image, a 30 s audio clip; 1.5k–4k total after projection).
#[derive(Clone, Copy, Debug)]
pub struct TokenCounts {
    pub text: usize,
    pub vision: usize,
    pub audio: usize,
}

impl TokenCounts {
    pub fn paper() -> Self {
        // 1280x720 / 14px patches ≈ 4,700 raw -> pooled ~1024; Whisper 30 s
        // -> 1500 frames -> 750 post-conv tokens. Totals land in the
        // paper's 1.5k–4k band.
        TokenCounts { text: 1000, vision: 1024, audio: 750 }
    }

    pub fn llm_total(&self, has_vision: bool, has_audio: bool) -> usize {
        self.text
            + if has_vision { self.vision } else { 0 }
            + if has_audio { self.audio } else { 0 }
    }
}

/// An MLLM composition under test: `VLM-x`, `ALM-x`, or `VALM-xy` with a
/// separately-sized LLM (§6.1 naming).
#[derive(Clone, Debug)]
pub struct MllmSpec {
    pub llm: ModuleGeom,
    pub vision: Option<ModuleGeom>,
    pub audio: Option<ModuleGeom>,
    pub tokens: TokenCounts,
}

impl MllmSpec {
    pub fn vlm(llm_size: Size, enc_size: Size) -> Self {
        MllmSpec {
            llm: llama(llm_size),
            vision: Some(eva_clip(enc_size)),
            audio: None,
            tokens: TokenCounts::paper(),
        }
    }

    pub fn alm(llm_size: Size, enc_size: Size) -> Self {
        MllmSpec {
            llm: llama(llm_size),
            vision: None,
            audio: Some(whisper(enc_size)),
            tokens: TokenCounts::paper(),
        }
    }

    pub fn valm(llm_size: Size, vis_size: Size, aud_size: Size) -> Self {
        MllmSpec {
            llm: llama(llm_size),
            vision: Some(eva_clip(vis_size)),
            audio: Some(whisper(aud_size)),
            tokens: TokenCounts::paper(),
        }
    }

    /// Parse a composition name (`VLM-M`, `ALM-S`, `VALM-ML`; the
    /// inverse of [`MllmSpec::name`]) with an explicit LLM size. The
    /// single parser behind the CLI's `<mllm>` argument and the serve
    /// protocol's `mllm` field; the error is a ready-to-print message.
    pub fn parse_name(name: &str, llm: Size) -> Result<MllmSpec, String> {
        let (kind, sizes) = name.split_once('-').ok_or_else(|| {
            format!("bad MLLM name {name:?} (e.g. VLM-M, VALM-SL)")
        })?;
        let parse1 = |s: &str| {
            Size::parse(s)
                .ok_or_else(|| format!("bad size {s:?} in {name:?}"))
        };
        Ok(match kind {
            "VLM" => MllmSpec::vlm(llm, parse1(sizes)?),
            "ALM" => MllmSpec::alm(llm, parse1(sizes)?),
            "VALM" => {
                if sizes.len() != 2 {
                    return Err(
                        "VALM wants two sizes (e.g. VALM-ML)".to_string()
                    );
                }
                MllmSpec::valm(
                    llm,
                    parse1(&sizes[0..1])?,
                    parse1(&sizes[1..2])?,
                )
            }
            _ => return Err(format!("unknown MLLM kind {kind:?}")),
        })
    }

    pub fn name(&self) -> String {
        match (&self.vision, &self.audio) {
            (Some(v), Some(a)) => format!(
                "VALM-{}{}",
                size_of(v).letter(),
                size_of(a).letter()
            ),
            (Some(v), None) => format!("VLM-{}", size_of(v).letter()),
            (None, Some(a)) => format!("ALM-{}", size_of(a).letter()),
            (None, None) => "LLM".to_string(),
        }
    }

    pub fn llm_tokens(&self) -> usize {
        self.tokens
            .llm_total(self.vision.is_some(), self.audio.is_some())
    }
}

fn size_of(g: &ModuleGeom) -> Size {
    if g.name.ends_with("-S") {
        Size::S
    } else if g.name.ends_with("-M") {
        Size::M
    } else {
        Size::L
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_param_counts_are_in_band() {
        // Paper: Llama S/M/L = 1.2b/8b/32b; EVA-CLIP 1b/8b/18b;
        // Whisper 1.4b/7b/15b. Dense estimate should land within ~35%.
        let cases: Vec<(ModuleGeom, f64)> = vec![
            (llama(Size::S), 1.2e9),
            (llama(Size::M), 8e9),
            (llama(Size::L), 32e9),
            (eva_clip(Size::S), 1e9),
            (eva_clip(Size::M), 8e9),
            (eva_clip(Size::L), 18e9),
            (whisper(Size::S), 1.4e9),
            (whisper(Size::M), 7e9),
            (whisper(Size::L), 15e9),
        ];
        for (g, want) in cases {
            let got = g.params() as f64;
            let ratio = got / want;
            assert!(
                (0.55..1.8).contains(&ratio),
                "{}: {got:.2e} vs paper {want:.2e} (ratio {ratio:.2})",
                g.name
            );
        }
    }

    #[test]
    fn token_counts_in_paper_band() {
        let t = TokenCounts::paper();
        let total_valm = t.llm_total(true, true);
        assert!((1500..=4000).contains(&total_valm), "{total_valm}");
        assert!((1500..=4000).contains(&t.llm_total(true, false)));
    }

    #[test]
    fn names_follow_paper_convention() {
        assert_eq!(MllmSpec::vlm(Size::M, Size::L).name(), "VLM-L");
        assert_eq!(MllmSpec::valm(Size::S, Size::M, Size::L).name(), "VALM-ML");
        assert_eq!(MllmSpec::alm(Size::L, Size::S).name(), "ALM-S");
    }

    #[test]
    fn size_parse_roundtrip() {
        for s in Size::ALL {
            assert_eq!(Size::parse(s.letter()), Some(s));
        }
        assert_eq!(Size::parse("x"), None);
    }
}
