//! Pipeline parallelism: frozen-status-aware stage partitioning (§4.2)
//! and 1F1B schedule construction over modality-parallel stage DAGs (§4.1).

pub mod partition;
pub mod schedule;

pub use partition::{partition_min_max, stage_sums, LayerCost};
pub use schedule::{
    onef1b_tasks, StageGraph, StageNode, TaskKind, TaskSpec,
};

/// Cost of one pipeline stage for one microbatch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StageCost {
    pub fwd_ms: f64,
    pub bwd_ms: f64,
}

impl StageCost {
    pub fn total(&self) -> f64 {
        self.fwd_ms + self.bwd_ms
    }
}
