//! Min-max contiguous partitioning of layers into pipeline stages.
//!
//! The §4.2 observation: partition by *fwd + bwd* time where bwd follows
//! the frozen rule — not by fwd time with the classic "bwd = 2×fwd"
//! assumption. Both policies are expressed by choosing which per-layer
//! cost vector to feed the same partitioner:
//!
//! * frozen-aware:  `cost[l] = fwd[l] + bwd[l]` (bwd from [`crate::cost::GradFlow`])
//! * frozen-unaware: `cost[l] = fwd[l]` (equivalently `3×fwd`, a constant
//!   scale that does not change the argmin)

use super::StageCost;
use crate::cost::GradFlow;

/// One layer's costs and grad-flow classification.
#[derive(Clone, Copy, Debug)]
pub struct LayerCost {
    pub fwd_ms: f64,
    pub flow: GradFlow,
}

impl LayerCost {
    pub fn bwd_ms(&self, grad_ckpt: bool) -> f64 {
        self.flow.bwd_ms(self.fwd_ms, grad_ckpt)
    }
}

/// Partition `costs` into `s` contiguous non-empty segments minimizing the
/// maximum segment sum (exact DP, O(s·L²) — L ≤ 64 layers in every model
/// of Table 1, so this is microseconds). Returns the segment boundaries as
/// `s+1` indices (`bounds[k]..bounds[k+1]` is stage k). Ties are broken
/// toward earlier split points, which yields the even split for uniform
/// costs.
pub fn partition_min_max(costs: &[f64], s: usize) -> Vec<usize> {
    assert!(s > 0, "need at least one stage");
    assert!(
        costs.len() >= s,
        "cannot split {} layers into {s} non-empty stages",
        costs.len()
    );
    assert!(costs.iter().all(|&c| c >= 0.0));
    let n = costs.len();
    // prefix[i] = sum of costs[0..i]
    let mut prefix = vec![0.0f64; n + 1];
    for i in 0..n {
        prefix[i + 1] = prefix[i] + costs[i];
    }
    let seg = |a: usize, b: usize| prefix[b] - prefix[a]; // costs[a..b]

    // dp[k][i]: min over splits of costs[0..i] into k non-empty segments of
    // the max segment sum; choice[k][i]: start of the last segment.
    let inf = f64::INFINITY;
    let mut dp = vec![vec![inf; n + 1]; s + 1];
    let mut choice = vec![vec![0usize; n + 1]; s + 1];
    dp[0][0] = 0.0;
    for k in 1..=s {
        for i in k..=n {
            // last segment is costs[j..i] with j >= k-1 (room for k-1 segs)
            let mut best = inf;
            let mut best_j = k - 1;
            for j in (k - 1)..i {
                if dp[k - 1][j].is_finite() {
                    let cand = dp[k - 1][j].max(seg(j, i));
                    // strict < keeps the earliest split on ties, and since
                    // seg(j,i) decreases as j grows, earliest-j ties give
                    // balanced (even) splits for uniform costs.
                    if cand < best - 1e-12 {
                        best = cand;
                        best_j = j;
                    }
                }
            }
            dp[k][i] = best;
            choice[k][i] = best_j;
        }
    }
    // Recover boundaries.
    let mut bounds = vec![n];
    let mut i = n;
    for k in (1..=s).rev() {
        i = choice[k][i];
        bounds.push(i);
    }
    bounds.reverse();
    debug_assert_eq!(bounds.len(), s + 1);
    debug_assert_eq!(bounds[0], 0);
    bounds
}

/// Per-stage fwd/bwd sums for a set of boundaries.
pub fn stage_sums(
    layers: &[LayerCost],
    bounds: &[usize],
    grad_ckpt: bool,
) -> Vec<StageCost> {
    bounds
        .windows(2)
        .map(|w| {
            let seg = &layers[w[0]..w[1]];
            StageCost {
                fwd_ms: seg.iter().map(|l| l.fwd_ms).sum(),
                bwd_ms: seg.iter().map(|l| l.bwd_ms(grad_ckpt)).sum(),
            }
        })
        .collect()
}

/// Convenience: build the per-layer costs of a frozen module that must
/// propagate gradients (`upstream_trainable`) or not, or a trainable one.
pub fn uniform_layers(
    n: usize,
    fwd_ms: f64,
    trainable: bool,
    upstream_trainable: bool,
) -> Vec<LayerCost> {
    (0..n)
        .map(|_| LayerCost {
            fwd_ms,
            flow: GradFlow { trainable, upstream_trainable },
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    fn max_seg(costs: &[f64], bounds: &[usize]) -> f64 {
        bounds
            .windows(2)
            .map(|w| costs[w[0]..w[1]].iter().sum::<f64>())
            .fold(0.0, f64::max)
    }

    #[test]
    fn equal_layers_split_evenly() {
        let costs = vec![1.0; 8];
        let b = partition_min_max(&costs, 4);
        assert_eq!(b, vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn heavy_head_gets_own_stage() {
        let costs = vec![10.0, 1.0, 1.0, 1.0];
        let b = partition_min_max(&costs, 2);
        assert_eq!(b, vec![0, 1, 4]);
        assert!((max_seg(&costs, &b) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn boundaries_are_monotone_and_cover() {
        check("partition covers all layers", 50, |g| {
            let n = g.usize(1, 60);
            let s = g.usize(1, n + 1);
            let costs: Vec<f64> =
                (0..n).map(|_| g.rng.f64() * 10.0 + 0.01).collect();
            let b = partition_min_max(&costs, s);
            assert_eq!(b.len(), s + 1);
            assert_eq!(b[0], 0);
            assert_eq!(*b.last().unwrap(), n);
            assert!(b.windows(2).all(|w| w[0] < w[1]), "{b:?}");
        });
    }

    #[test]
    fn optimal_within_bound_of_lower_bound() {
        check("min-max within layer granularity of LB", 40, |g| {
            let n = g.usize(2, 40);
            let s = g.usize(1, n + 1);
            let costs: Vec<f64> =
                (0..n).map(|_| g.rng.f64() * 5.0 + 0.01).collect();
            let b = partition_min_max(&costs, s);
            let got = max_seg(&costs, &b);
            let lb = (costs.iter().sum::<f64>() / s as f64)
                .max(costs.iter().cloned().fold(0.0, f64::max));
            let max_layer = costs.iter().cloned().fold(0.0, f64::max);
            assert!(
                got <= lb + max_layer + 1e-9,
                "got {got} lb {lb} max_layer {max_layer}"
            );
        });
    }

    #[test]
    fn frozen_aware_shifts_boundary_toward_encoder() {
        // Paper Figure 7: frozen encoder (bwd 0) + frozen LLM (bwd 1x).
        // Frozen-aware partitioning gives the encoder FEWER stages (more
        // fwd per encoder stage) than fwd-balanced partitioning.
        let mut layers = uniform_layers(8, 10.0, false, false); // encoder
        layers.extend(uniform_layers(8, 10.0, false, true)); // llm
        let s = 4;
        // frozen-aware costs: fwd+bwd
        let aware: Vec<f64> =
            layers.iter().map(|l| l.fwd_ms + l.bwd_ms(false)).collect();
        // unaware: balanced by fwd only
        let unaware: Vec<f64> = layers.iter().map(|l| l.fwd_ms).collect();
        let b_aware = partition_min_max(&aware, s);
        let b_unaware = partition_min_max(&unaware, s);
        // encoder layers are 0..8; count layers of stage 0+1 that are
        // encoder layers — aware should pack more encoder layers early.
        let enc_layers_in_first_two =
            |b: &Vec<usize>| b[2].min(8);
        assert!(
            enc_layers_in_first_two(&b_aware)
                >= enc_layers_in_first_two(&b_unaware),
            "aware {b_aware:?} unaware {b_unaware:?}"
        );
        // fwd+bwd balance must be better under aware partitioning
        let spread = |b: &Vec<usize>| {
            let sums = stage_sums(&layers, b, false);
            let tot: Vec<f64> = sums.iter().map(|s| s.total()).collect();
            crate::util::stats::imbalance(&tot)
        };
        assert!(spread(&b_aware) <= spread(&b_unaware) + 1e-9);
    }

    #[test]
    fn boundaries_are_invariant_under_uniform_scaling() {
        // The invariant heterogeneous device assignment relies on: a
        // module's per-layer costs all scale by the same factor when the
        // chain moves to a faster/slower device group, and the min-max
        // split of uniformly scaled costs is the same split — so the
        // partition depends only on the module's *shape*, never on which
        // group it was assigned to.
        check("partition invariant under cost scaling", 40, |g| {
            let n = g.usize(2, 40);
            let s = g.usize(1, n + 1);
            let costs: Vec<f64> =
                (0..n).map(|_| g.rng.f64() * 5.0 + 0.01).collect();
            // e.g. A40 -> A100: ~0.58x; also try slower devices
            let scale = g.rng.f64() * 3.0 + 0.1;
            let scaled: Vec<f64> = costs.iter().map(|c| c * scale).collect();
            assert_eq!(
                partition_min_max(&costs, s),
                partition_min_max(&scaled, s),
                "scale {scale} moved a boundary"
            );
        });
    }

    #[test]
    fn stage_sums_add_up() {
        let layers = uniform_layers(6, 2.0, true, true);
        let sums = stage_sums(&layers, &[0, 3, 6], true);
        assert_eq!(sums.len(), 2);
        assert!((sums[0].fwd_ms - 6.0).abs() < 1e-12);
        // trainable with ckpt: bwd = 2x + 1x recompute = 3x fwd
        assert!((sums[0].bwd_ms - 18.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_more_stages_than_layers() {
        partition_min_max(&[1.0, 2.0], 3);
    }
}
