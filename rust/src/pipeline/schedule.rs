//! 1F1B schedule construction over a *stage DAG* (modality parallelism).
//!
//! Classic 1F1B assumes a linear chain of stages. Modality parallelism
//! (§4.1) generalizes the pipeline to a DAG: independent encoder chains
//! feed the LLM chain's first stage; the LLM's first stage's backward
//! fans out to every encoder chain. We emit a dependency task graph that
//! the discrete-event simulator ([`crate::sim`]) executes with per-device
//! greedy 1F1B priorities:
//!
//! * `Fwd(s, m)` depends on `Fwd(p, m)` for each predecessor stage `p`,
//!   and on `Bwd(s, m - limit(s))` — the activation-memory token that
//!   creates the 1F1B steady state, where `limit(s)` is the longest
//!   stage-path from `s` to the sink (classic 1F1B in-flight bound,
//!   generalized to DAGs).
//! * `Bwd(s, m)` depends on `Fwd(s, m)` and on `Bwd(q, m)` for each
//!   successor stage `q`.

use super::StageCost;

/// One pipeline stage placed on a device.
#[derive(Clone, Debug)]
pub struct StageNode {
    pub name: String,
    pub cost: StageCost,
    /// Device (GPU group) index; stages sharing a device serialize.
    pub device: usize,
    /// Predecessor stage indices (forward-flow).
    pub preds: Vec<usize>,
}

/// A pipeline stage DAG (encoder chains + LLM chain).
#[derive(Clone, Debug, Default)]
pub struct StageGraph {
    pub nodes: Vec<StageNode>,
    /// ms added to every cross-device dependency (activation transfer)
    /// when no per-device link cost is recorded — the homogeneous
    /// single-link model every pre-hetero plan used.
    pub comm_ms: f64,
    /// Per-device link cost (ms per hop), indexed by [`StageNode::device`].
    /// When filled, a cross-device hop between `a` and `b` pays the
    /// *slower* of the two links (the bottleneck of a heterogeneous
    /// pool); when empty, every hop pays [`StageGraph::comm_ms`].
    pub device_link_ms: Vec<f64>,
}

impl StageGraph {
    /// Append a linear chain; returns the node ids. `feeds` connects the
    /// chain's first stage to existing nodes (their outputs are its
    /// inputs).
    pub fn add_chain(
        &mut self,
        name: &str,
        costs: &[StageCost],
        first_device: usize,
        feeds_from: &[usize],
    ) -> Vec<usize> {
        let mut ids = Vec::with_capacity(costs.len());
        for (i, &c) in costs.iter().enumerate() {
            let preds = if i == 0 {
                feeds_from.to_vec()
            } else {
                vec![ids[i - 1]]
            };
            self.nodes.push(StageNode {
                name: format!("{name}[{i}]"),
                cost: c,
                device: first_device + i,
                preds,
            });
            ids.push(self.nodes.len() - 1);
        }
        ids
    }

    pub fn n_devices(&self) -> usize {
        self.nodes.iter().map(|n| n.device + 1).max().unwrap_or(0)
    }

    /// Comm cost (ms) of a dependency hop from device `a` to device `b`:
    /// 0 on-device, the bottleneck (max) of the two recorded link costs
    /// across devices, or the flat [`StageGraph::comm_ms`] when no
    /// per-device links are recorded.
    pub fn hop_ms(&self, a: usize, b: usize) -> f64 {
        if a == b {
            return 0.0;
        }
        match (self.device_link_ms.get(a), self.device_link_ms.get(b)) {
            (Some(&la), Some(&lb)) => la.max(lb),
            _ => self.comm_ms,
        }
    }

    pub fn successors(&self) -> Vec<Vec<usize>> {
        let mut succ = vec![Vec::new(); self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for &p in &n.preds {
                succ[p].push(i);
            }
        }
        succ
    }

    /// Longest path (in stages, inclusive) from each node to any sink —
    /// the generalized 1F1B in-flight limit.
    pub fn depth_to_sink(&self) -> Vec<usize> {
        let succ = self.successors();
        let n = self.nodes.len();
        let mut depth = vec![0usize; n];
        // Nodes are topologically ordered by construction (preds < id);
        // walk backwards.
        for i in (0..n).rev() {
            depth[i] = 1 + succ[i].iter().map(|&s| depth[s]).max().unwrap_or(0);
        }
        depth
    }
}

/// Task kind in the emitted graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum TaskKind {
    Fwd,
    Bwd,
}

/// A schedulable unit handed to the simulator.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    /// (kind, stage, microbatch) — unique.
    pub kind: TaskKind,
    pub stage: usize,
    pub microbatch: usize,
    pub device: usize,
    pub dur_ms: f64,
    /// Indices into the task vector this task waits for, with edge
    /// latency ms.
    pub deps: Vec<(usize, f64)>,
    /// Device-local scheduling priority (smaller runs first when several
    /// tasks are ready): 1F1B prefers backward in steady state and lower
    /// microbatch indices.
    pub priority: (u8, usize),
}

/// Emit the full 1F1B task graph for `m` microbatches over `g`.
pub fn onef1b_tasks(g: &StageGraph, m: usize) -> Vec<TaskSpec> {
    assert!(m > 0);
    let n = g.nodes.len();
    let succ = g.successors();
    let depth = g.depth_to_sink();
    let fwd_id = |s: usize, mb: usize| mb * n + s;
    let bwd_id = |s: usize, mb: usize| m * n + mb * n + s;
    let mut tasks = Vec::with_capacity(2 * m * n);
    // forward tasks
    for mb in 0..m {
        for s in 0..n {
            let node = &g.nodes[s];
            let mut deps: Vec<(usize, f64)> = node
                .preds
                .iter()
                .map(|&p| {
                    (fwd_id(p, mb), g.hop_ms(g.nodes[p].device, node.device))
                })
                .collect();
            // 1F1B memory token: at most depth(s) microbatches in flight.
            if mb >= depth[s] {
                deps.push((bwd_id(s, mb - depth[s]), 0.0));
            }
            tasks.push(TaskSpec {
                kind: TaskKind::Fwd,
                stage: s,
                microbatch: mb,
                device: node.device,
                dur_ms: node.cost.fwd_ms,
                deps,
                priority: (1, mb),
            });
        }
    }
    // backward tasks
    for mb in 0..m {
        for s in 0..n {
            let node = &g.nodes[s];
            let mut deps: Vec<(usize, f64)> = vec![(fwd_id(s, mb), 0.0)];
            for &q in &succ[s] {
                let lat = g.hop_ms(g.nodes[q].device, node.device);
                deps.push((bwd_id(q, mb), lat));
            }
            tasks.push(TaskSpec {
                kind: TaskKind::Bwd,
                stage: s,
                microbatch: mb,
                device: node.device,
                dur_ms: node.cost.bwd_ms,
                deps,
                priority: (0, mb), // backward first (1F1B steady state)
            });
        }
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(fwd: f64, bwd: f64, n: usize) -> Vec<StageCost> {
        vec![StageCost { fwd_ms: fwd, bwd_ms: bwd }; n]
    }

    #[test]
    fn chain_depths() {
        let mut g = StageGraph::default();
        g.add_chain("llm", &chain(1.0, 2.0, 4), 0, &[]);
        assert_eq!(g.depth_to_sink(), vec![4, 3, 2, 1]);
    }

    #[test]
    fn dag_depths_take_longest_path() {
        let mut g = StageGraph::default();
        let v = g.add_chain("vision", &chain(1.0, 0.0, 2), 0, &[]);
        let a = g.add_chain("audio", &chain(1.0, 0.0, 1), 2, &[]);
        let llm =
            g.add_chain("llm", &chain(1.0, 1.0, 3), 3, &[v[1], a[0]]);
        let d = g.depth_to_sink();
        assert_eq!(d[v[0]], 5); // vision[0] -> vision[1] -> llm x3
        assert_eq!(d[a[0]], 4);
        assert_eq!(d[llm[2]], 1);
    }

    #[test]
    fn task_count_and_ids() {
        let mut g = StageGraph::default();
        g.add_chain("llm", &chain(1.0, 2.0, 3), 0, &[]);
        let tasks = onef1b_tasks(&g, 4);
        assert_eq!(tasks.len(), 2 * 3 * 4);
        // every dep index is in range and refers to an earlier-created or
        // later-created task but always a valid one
        for t in &tasks {
            for &(d, _) in &t.deps {
                assert!(d < tasks.len());
            }
        }
    }

    #[test]
    fn memory_token_creates_inflight_bound() {
        let mut g = StageGraph::default();
        g.add_chain("llm", &chain(1.0, 2.0, 3), 0, &[]);
        let tasks = onef1b_tasks(&g, 6);
        // stage 0 has depth 3: fwd of microbatch 3 must depend on bwd of
        // microbatch 0 at stage 0.
        let f30 = tasks
            .iter()
            .find(|t| {
                t.kind == TaskKind::Fwd && t.stage == 0 && t.microbatch == 3
            })
            .unwrap();
        let bwd0_idx = 6 * 3 + 0 * 3 + 0; // m*n + mb*n + s
        assert!(f30.deps.iter().any(|&(d, _)| d == bwd0_idx));
    }

    #[test]
    fn cross_device_deps_carry_comm_latency() {
        let mut g = StageGraph::default();
        g.comm_ms = 0.5;
        g.add_chain("llm", &chain(1.0, 2.0, 2), 0, &[]);
        let tasks = onef1b_tasks(&g, 1);
        let f_s1 = tasks
            .iter()
            .find(|t| t.kind == TaskKind::Fwd && t.stage == 1)
            .unwrap();
        assert_eq!(f_s1.deps[0].1, 0.5);
    }

    #[test]
    fn per_device_links_price_the_bottleneck() {
        let mut g = StageGraph::default();
        g.comm_ms = 0.5;
        g.add_chain("llm", &chain(1.0, 2.0, 3), 0, &[]);
        // without link costs, every cross-device hop pays the flat rate
        assert_eq!(g.hop_ms(0, 1), 0.5);
        assert_eq!(g.hop_ms(1, 1), 0.0);
        // devices 0..1 on a slow-linked group, device 2 on a fast one:
        // the crossing hop pays the slower link
        g.device_link_ms = vec![0.5, 0.5, 0.05];
        assert_eq!(g.hop_ms(0, 1), 0.5);
        assert_eq!(g.hop_ms(1, 2), 0.5);
        assert_eq!(g.hop_ms(2, 2), 0.0);
        // the emitted task graph carries the per-edge price
        let tasks = onef1b_tasks(&g, 1);
        let f_s2 = tasks
            .iter()
            .find(|t| t.kind == TaskKind::Fwd && t.stage == 2)
            .unwrap();
        assert_eq!(f_s2.deps[0].1, 0.5);
        // a fast-fast hop would price at the fast link
        g.device_link_ms = vec![0.05, 0.05, 0.05];
        let tasks = onef1b_tasks(&g, 1);
        let f_s2 = tasks
            .iter()
            .find(|t| t.kind == TaskKind::Fwd && t.stage == 2)
            .unwrap();
        assert_eq!(f_s2.deps[0].1, 0.05);
    }

    #[test]
    fn encoder_bwd_waits_for_llm_first_stage_bwd() {
        let mut g = StageGraph::default();
        let v = g.add_chain("vision", &chain(1.0, 0.5, 1), 0, &[]);
        let llm = g.add_chain("llm", &chain(1.0, 1.0, 2), 1, &[v[0]]);
        let tasks = onef1b_tasks(&g, 1);
        let bwd_v = tasks
            .iter()
            .find(|t| t.kind == TaskKind::Bwd && t.stage == v[0])
            .unwrap();
        let bwd_llm0_idx = 1 * 3 + 0 * 3 + llm[0];
        assert!(bwd_v.deps.iter().any(|&(d, _)| d == bwd_llm0_idx));
    }
}
