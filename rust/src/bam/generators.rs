//! BAM mask generators for the three layouts of the paper's Figure 11:
//!
//! * **EP** — encoder outputs prepended: `[mod_1 .. mod_k, text]`;
//! * **EE** — encoder outputs embedded: modality segments spliced between
//!   text runs (LLaVA-Next / Qwen2-VL style);
//! * **MP** — multimodal packing: several independent samples packed in
//!   one sequence, each with its own text stream and modality segments
//!   (tokens of one sample never attend another sample).
//!
//! Plus randomized variants used by Table 4 / Figure 12 ("an attention
//! mask is randomly generated for every run").

use super::{Bam, TEXT_BIT};
use crate::util::rng::Rng;

/// Declarative mask description (mirrors `ref.make_bits_*`).
#[derive(Clone, Debug)]
pub enum MaskSpec {
    /// (text_len, modality segment lengths)
    Ep(usize, Vec<usize>),
    /// (text run lengths [k+1], modality segment lengths [k])
    Ee(Vec<usize>, Vec<usize>),
    /// packed samples: (text_len, modality segment lengths) each
    Mp(Vec<(usize, Vec<usize>)>),
}

impl MaskSpec {
    pub fn build(&self) -> Bam {
        match self {
            MaskSpec::Ep(t, segs) => ep(*t, segs),
            MaskSpec::Ee(texts, segs) => ee(texts, segs),
            MaskSpec::Mp(samples) => mp(samples),
        }
    }
}

/// Encoder outputs prepended (Figure 11a).
pub fn ep(text_len: usize, seg_lens: &[usize]) -> Bam {
    let mut bits = Vec::with_capacity(text_len + seg_lens.iter().sum::<usize>());
    let mut text_bits = TEXT_BIT;
    for (m, &l) in seg_lens.iter().enumerate() {
        let b = 1u64 << (m + 1);
        text_bits |= b;
        bits.extend(std::iter::repeat(b).take(l));
    }
    bits.extend(std::iter::repeat(text_bits).take(text_len));
    Bam::new(bits, TEXT_BIT)
}

/// Encoder outputs embedded (Figure 11b). `text_lens.len() ==
/// seg_lens.len() + 1`.
pub fn ee(text_lens: &[usize], seg_lens: &[usize]) -> Bam {
    assert_eq!(text_lens.len(), seg_lens.len() + 1, "EE layout shape");
    let mut text_bits = TEXT_BIT;
    for m in 0..seg_lens.len() {
        text_bits |= 1u64 << (m + 1);
    }
    let mut bits = Vec::new();
    bits.extend(std::iter::repeat(text_bits).take(text_lens[0]));
    for (m, &l) in seg_lens.iter().enumerate() {
        bits.extend(std::iter::repeat(1u64 << (m + 1)).take(l));
        bits.extend(std::iter::repeat(text_bits).take(text_lens[m + 1]));
    }
    Bam::new(bits, TEXT_BIT)
}

/// Multimodal packing (Figure 11c): each sample gets a disjoint bit range
/// (its own text bit + its modality bits), so cross-sample attention is
/// structurally impossible. `text_mask` is the union of all text bits.
pub fn mp(samples: &[(usize, Vec<usize>)]) -> Bam {
    let mut bits = Vec::new();
    let mut text_mask = 0u64;
    let mut next_bit = 0u32;
    for (text_len, seg_lens) in samples {
        let need = 1 + seg_lens.len() as u32;
        assert!(
            next_bit + need <= 62,
            "multimodal packing exceeds the 64-bit field (paper: ~60 modalities)"
        );
        let tbit = 1u64 << next_bit;
        text_mask |= tbit;
        let mut tfield = tbit;
        let mut seg_bits = Vec::new();
        for (m, _) in seg_lens.iter().enumerate() {
            let b = 1u64 << (next_bit + 1 + m as u32);
            tfield |= b;
            seg_bits.push(b);
        }
        next_bit += need;
        // Layout inside a sample: text/2, segments, text - text/2 (EE-ish).
        let pre = text_len / 2;
        bits.extend(std::iter::repeat(tfield).take(pre));
        for (m, &l) in seg_lens.iter().enumerate() {
            bits.extend(std::iter::repeat(seg_bits[m]).take(l));
        }
        bits.extend(std::iter::repeat(tfield).take(text_len - pre));
    }
    Bam::new(bits, text_mask)
}

/// Randomized EE-style mask with total length `t`: random number of
/// modality segments at random offsets — what Table 4 draws per run.
pub fn random_ee(rng: &mut Rng, t: usize, max_modalities: usize) -> Bam {
    let n_mod = rng.range(1, max_modalities + 1);
    // Each modality gets 5%..25% of the sequence.
    let mut seg_lens = Vec::new();
    let mut used = 0usize;
    for _ in 0..n_mod {
        let l = rng.range(t / 20 + 1, t / 4 + 2).min(t.saturating_sub(used + n_mod));
        seg_lens.push(l.max(1));
        used += l.max(1);
    }
    let text_total = t.saturating_sub(used).max(n_mod + 1);
    // Split text into n_mod+1 random runs.
    let mut text_lens = vec![1usize; n_mod + 1];
    let mut rem = text_total - (n_mod + 1);
    for i in 0..n_mod {
        let take = rng.range(0, rem + 1);
        text_lens[i] += take;
        rem -= take;
    }
    text_lens[n_mod] += rem;
    ee(&text_lens, &seg_lens)
}

/// Randomized MP mask: pack samples of random size until `t` is filled.
pub fn random_mp(rng: &mut Rng, t: usize) -> Bam {
    let mut samples = Vec::new();
    let mut used = 0usize;
    let mut bit_budget = 62usize;
    while used < t && bit_budget >= 2 {
        let remaining = t - used;
        let sample_len = if remaining < 32 {
            remaining
        } else {
            rng.range(remaining / 4 + 1, remaining + 1).max(16)
        }
        .min(remaining);
        let n_mod = rng.range(0, (bit_budget - 1).min(3) + 1).min(2);
        let mut seg_lens = Vec::new();
        let mut seg_total = 0usize;
        for _ in 0..n_mod {
            let l = (sample_len / 4).max(1);
            if seg_total + l < sample_len {
                seg_lens.push(l);
                seg_total += l;
            }
        }
        let text_len = sample_len - seg_total;
        bit_budget -= 1 + seg_lens.len();
        samples.push((text_len, seg_lens));
        used += sample_len;
    }
    mp(&samples)
}

/// Randomized EP mask with total length `t`.
pub fn random_ep(rng: &mut Rng, t: usize, max_modalities: usize) -> Bam {
    let n_mod = rng.range(1, max_modalities + 1);
    let mut seg_lens = Vec::new();
    let mut used = 0usize;
    for _ in 0..n_mod {
        let l = rng.range(t / 20 + 1, t / 4 + 2);
        seg_lens.push(l);
        used += l;
    }
    let text_len = t.saturating_sub(used).max(1);
    ep(text_len, &seg_lens)
}

/// Build the Bam for an exported model config from its manifest segment
/// records `(start, end, bits)`.
pub fn from_segments(total: usize, segments: &[(usize, usize, u64)]) -> Bam {
    let mut bits = vec![0u64; total];
    for &(s, e, b) in segments {
        assert!(e <= total && s <= e, "segment out of range");
        for slot in &mut bits[s..e] {
            *slot = b;
        }
    }
    assert!(bits.iter().all(|&b| b != 0), "segments must cover the sequence");
    Bam::new(bits, TEXT_BIT)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bam::workload::workloads_naive;
    use crate::util::check::check;

    #[test]
    fn ep_structure() {
        let m = ep(4, &[2, 3]);
        assert_eq!(m.len(), 9);
        assert_eq!(m.bits[0], 2); // modality 1
        assert_eq!(m.bits[2], 4); // modality 2
        assert_eq!(m.bits[5], 0b111); // text sees both
    }

    #[test]
    fn ee_structure() {
        let m = ee(&[1, 2], &[3]);
        assert_eq!(m.bits, vec![0b11, 0b10, 0b10, 0b10, 0b11, 0b11]);
    }

    #[test]
    fn mp_samples_are_isolated() {
        let m = mp(&[(4, vec![2]), (4, vec![2])]);
        let t = m.len();
        assert_eq!(t, 12);
        // No token of sample 1 attends any token of sample 2 and vice versa.
        for i in 0..6 {
            for j in 6..t {
                assert!(!m.can_attend(i, j), "{i} -> {j}");
                assert!(!m.can_attend(j, i), "{j} -> {i}");
            }
        }
        // Inside a sample attention still works.
        assert!(m.can_attend(1, 0));
        assert!(m.can_attend(7, 6));
    }

    #[test]
    fn mp_text_mask_covers_all_samples() {
        let m = mp(&[(4, vec![1]), (4, vec![1, 1]), (4, vec![])]);
        assert_eq!(m.text_mask.count_ones(), 3);
    }

    #[test]
    #[should_panic]
    fn mp_rejects_bit_overflow() {
        let samples: Vec<(usize, Vec<usize>)> =
            (0..40).map(|_| (2, vec![1])).collect();
        mp(&samples);
    }

    #[test]
    fn random_generators_satisfy_invariants() {
        check("random masks well-formed", 30, |g| {
            let t = g.usize(16, 512);
            let mut rng = crate::util::rng::Rng::new(g.seed);
            for m in [
                random_ep(&mut rng, t, 3),
                random_ee(&mut rng, t, 3),
                random_mp(&mut rng, t),
            ] {
                assert!(!m.is_empty());
                assert!(m.bits.iter().all(|&b| b != 0));
                // workloads via fast path == naive on a sample
                if m.len() <= 256 {
                    assert_eq!(
                        m.workloads(),
                        workloads_naive(&m.bits, m.text_mask)
                    );
                }
                // every token attends itself
                for i in 0..m.len() {
                    assert!(m.can_attend(i, i));
                }
            }
        });
    }

    #[test]
    fn from_segments_roundtrip() {
        let m = from_segments(8, &[(0, 2, 0b11), (2, 5, 2), (5, 8, 0b11)]);
        assert_eq!(m.bits, vec![3, 3, 2, 2, 2, 3, 3, 3]);
    }

    #[test]
    #[should_panic]
    fn from_segments_rejects_gaps() {
        from_segments(8, &[(0, 2, 3), (4, 8, 3)]);
    }
}
