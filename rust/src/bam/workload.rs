//! Per-token attention workloads from BAM — without materializing `[T,T]`.
//!
//! `W_i = Σ_j can_attend(i, j)` is the row-sum of the implied mask; the
//! paper's token-distribution algorithms (§4.3.2) balance these. The naive
//! computation is O(T²); we exploit that the number of *distinct bitfield
//! values* `V` is tiny (≈ #modalities + #distinct text-visibility sets):
//!
//! * a text token's row-sum is the number of tokens at `pos ≤ i` whose
//!   value shares a bit with it — a running prefix count per distinct
//!   value;
//! * a modality token's row-sum is the total count of its own value.
//!
//! Overall O(T·V) time, O(V) extra space. For 1 M tokens with 3 modalities
//! this is ~4 M bit-ands instead of 10¹² predicate evaluations.

use std::collections::HashMap;

/// O(T·V) workload computation. `bits` must be position-sorted (pos = idx),
/// which holds for all generator outputs; context-parallel shards should
/// compute workloads *before* distribution (as the paper does).
pub fn workloads(bits: &[u64], text_mask: u64) -> Vec<u64> {
    let t = bits.len();
    // Map distinct values -> dense ids.
    let mut ids: HashMap<u64, usize> = HashMap::new();
    let mut vals: Vec<u64> = Vec::new();
    let mut val_id = vec![0usize; t];
    for (i, &b) in bits.iter().enumerate() {
        let id = *ids.entry(b).or_insert_with(|| {
            vals.push(b);
            vals.len() - 1
        });
        val_id[i] = id;
    }
    let v = vals.len();

    // Total counts per value (for the modality rule).
    let mut totals = vec![0u64; v];
    for &id in &val_id {
        totals[id] += 1;
    }

    // For each query value q, which value ids intersect it (text rule)?
    // Precomputed once: O(V^2) with V tiny.
    let mut intersects: Vec<Vec<usize>> = vec![Vec::new(); v];
    for (qi, &qv) in vals.iter().enumerate() {
        for (ki, &kv) in vals.iter().enumerate() {
            if qv & kv != 0 {
                intersects[qi].push(ki);
            }
        }
    }

    let mut prefix = vec![0u64; v];
    let mut out = vec![0u64; t];
    for i in 0..t {
        let id = val_id[i];
        prefix[id] += 1; // include self (pos j == i)
        if bits[i] & text_mask != 0 {
            let mut w = 0;
            for &ki in &intersects[id] {
                w += prefix[ki];
            }
            out[i] = w;
        } else {
            out[i] = totals[id];
        }
    }
    out
}

/// O(T²) reference used by tests and as the correctness oracle.
pub fn workloads_naive(bits: &[u64], text_mask: u64) -> Vec<u64> {
    let t = bits.len();
    (0..t)
        .map(|i| {
            (0..t)
                .filter(|&j| {
                    super::can_attend(bits[i], i as u32, bits[j], j as u32, text_mask)
                })
                .count() as u64
        })
        .collect()
}

/// Aggregate workloads into contiguous blocks of `block_size` tokens
/// (tokens are distributed at block granularity for accelerator
/// efficiency — §4.3.2 "within 1 ms for 1M tokens / 128 block size").
/// The final block may be short.
pub fn block_workloads(w: &[u64], block_size: usize) -> Vec<u64> {
    assert!(block_size > 0);
    w.chunks(block_size).map(|c| c.iter().sum()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bam::{generators, TEXT_BIT};
    use crate::util::check::{check, Gen};

    fn random_bits(g: &mut Gen, t: usize, n_mod: usize) -> Vec<u64> {
        let text_bits = (1u64 << (n_mod + 1)) - 1; // text sees everything
        (0..t)
            .map(|_| {
                let k = g.usize(0, n_mod + 1);
                if k == 0 {
                    text_bits
                } else {
                    1u64 << k
                }
            })
            .collect()
    }

    #[test]
    fn matches_naive_on_random_masks() {
        check("workloads == naive", 60, |g| {
            let t = g.usize(1, 200);
            let n_mod = g.usize(1, 5);
            let bits = random_bits(g, t, n_mod);
            assert_eq!(
                workloads(&bits, TEXT_BIT),
                workloads_naive(&bits, TEXT_BIT)
            );
        });
    }

    #[test]
    fn pure_causal_text_is_arange() {
        let bits = vec![TEXT_BIT; 10];
        let w = workloads(&bits, TEXT_BIT);
        assert_eq!(w, (1..=10).collect::<Vec<u64>>());
    }

    #[test]
    fn single_modality_block_is_full() {
        let bits = vec![2u64; 7];
        let w = workloads(&bits, TEXT_BIT);
        assert_eq!(w, vec![7; 7]);
    }

    #[test]
    fn ep_layout_matches_naive() {
        let m = generators::ep(100, &[30, 20]);
        assert_eq!(m.workloads(), workloads_naive(&m.bits, m.text_mask));
    }

    #[test]
    fn ee_layout_matches_naive() {
        let m = generators::ee(&[10, 40, 50], &[16, 24]);
        assert_eq!(m.workloads(), workloads_naive(&m.bits, m.text_mask));
    }

    #[test]
    fn mp_layout_matches_naive() {
        let m = generators::mp(&[(40, vec![8, 4]), (30, vec![16]), (20, vec![])]);
        assert_eq!(m.workloads(), workloads_naive(&m.bits, m.text_mask));
    }

    #[test]
    fn block_workloads_sum_preserved() {
        check("block sums preserve total", 40, |g| {
            let w = g.vec_u64(1..300, 1000);
            let bs = g.usize(1, 64);
            let b = block_workloads(&w, bs);
            assert_eq!(
                b.iter().sum::<u64>(),
                w.iter().sum::<u64>(),
                "total preserved"
            );
            assert_eq!(b.len(), w.len().div_ceil(bs));
        });
    }

    #[test]
    fn workloads_scale_linearly_not_quadratically() {
        // Smoke perf guard: 1M tokens in well under a second.
        let t = 1_000_000;
        let bits: Vec<u64> = (0..t)
            .map(|i| if i % 5 == 0 { 2 } else { 0b111 })
            .collect();
        let start = std::time::Instant::now();
        let w = workloads(&bits, TEXT_BIT);
        assert_eq!(w.len(), t);
        assert!(
            start.elapsed().as_millis() < 900,
            "took {:?}",
            start.elapsed()
        );
    }
}
