//! Bitfield Attention Mask (BAM) — §4.3.1.
//!
//! A full multimodal attention mask over `T` tokens is `O(T²)` memory (1 M
//! tokens ⇒ 1 TB); BAM compresses it to a 1-D vector of 64-bit integers:
//! bit 0 is the text modality, bits `1..` are modality encoders. The mask
//! semantics here are byte-identical to the normative oracle in
//! `python/compile/kernels/ref.py` (and the L1 Pallas kernel):
//!
//! * **text token** (`bits & text_mask != 0`): attends `j` iff
//!   `pos[j] <= pos[i]` and `bits[i] & bits[j] != 0` — causal over every
//!   modality its field enables;
//! * **modality token**: attends `j` iff `bits[j] == bits[i]` — full
//!   bidirectional attention within its own modality segment.
//!
//! `text_mask` is `TEXT_BIT` (bit 0) for single-sample sequences; the
//! multimodal-packing generator (`generators::mp`) assigns each packed
//! sample its own text bit, so `text_mask` is the union (the paper's
//! "control bits" headroom).

pub mod workload;
pub mod generators;

pub use generators::{ep, ee, mp, MaskSpec};
pub use workload::{block_workloads, workloads, workloads_naive};

/// Bit 0: the text modality (single-sample sequences).
pub const TEXT_BIT: u64 = 1;

/// A BAM sequence: per-token bitfields plus global positions.
///
/// Positions are explicit so context-parallel shards of the sequence can
/// still evaluate the predicate against gathered keys (§4.3.2).
#[derive(Clone, Debug, PartialEq)]
pub struct Bam {
    pub bits: Vec<u64>,
    pub pos: Vec<u32>,
    /// Union of all text bits in this sequence (bit 0 unless packed).
    pub text_mask: u64,
}

impl Bam {
    pub fn new(bits: Vec<u64>, text_mask: u64) -> Self {
        let pos = (0..bits.len() as u32).collect();
        Bam { bits, pos, text_mask }
    }

    pub fn len(&self) -> usize {
        self.bits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bits.is_empty()
    }

    /// The normative predicate: does query token `i` attend key token `j`?
    #[inline]
    pub fn can_attend(&self, i: usize, j: usize) -> bool {
        can_attend(
            self.bits[i],
            self.pos[i],
            self.bits[j],
            self.pos[j],
            self.text_mask,
        )
    }

    /// Materialize the full `[T, T]` mask. **Test-only** helper: the whole
    /// point of BAM is to never do this on the hot path.
    pub fn materialize(&self) -> Vec<Vec<bool>> {
        let t = self.len();
        (0..t)
            .map(|i| (0..t).map(|j| self.can_attend(i, j)).collect())
            .collect()
    }

    /// Row-sums of the mask (per-token workloads W_i), O(T·V).
    pub fn workloads(&self) -> Vec<u64> {
        workload::workloads(&self.bits, self.text_mask)
    }

    /// The i32 lowering fed to the L1 kernel artifacts (the kernel carries
    /// bitfields as 32-bit lanes; see DESIGN.md §Hardware-Adaptation).
    /// Panics if any bitfield needs more than 31 bits.
    pub fn bits_i32(&self) -> Vec<i32> {
        self.bits
            .iter()
            .map(|&b| {
                assert!(
                    b <= i32::MAX as u64,
                    "bitfield {b:#x} exceeds the kernel's 32-bit lanes"
                );
                b as i32
            })
            .collect()
    }

    pub fn pos_i32(&self) -> Vec<i32> {
        self.pos.iter().map(|&p| p as i32).collect()
    }
}

/// Scalar BAM predicate (identical to `ref.can_attend`).
#[inline]
pub fn can_attend(bq: u64, pq: u32, bk: u64, pk: u32, text_mask: u64) -> bool {
    if bq & text_mask != 0 {
        pk <= pq && (bq & bk) != 0
    } else {
        bk == bq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Figure 8 example: t0..t1 text, t2..t3 encoder A,
    /// t4..t5 encoder B, t6..t8 text.
    fn fig8() -> Bam {
        let a = 1u64 << 1;
        let b = 1u64 << 2;
        let txt = TEXT_BIT | a | b;
        Bam::new(vec![txt, txt, a, a, b, b, txt, txt, txt], TEXT_BIT)
    }

    #[test]
    fn text_attends_previous_including_modalities() {
        let m = fig8();
        // t6 attends everything at pos <= 6
        for j in 0..=6 {
            assert!(m.can_attend(6, j), "t6 should attend t{j}");
        }
        assert!(!m.can_attend(6, 7));
        assert!(!m.can_attend(6, 8));
    }

    #[test]
    fn modality_tokens_attend_own_segment_bidirectionally() {
        let m = fig8();
        assert!(m.can_attend(2, 3)); // A attends forward inside A
        assert!(m.can_attend(3, 2));
        assert!(!m.can_attend(2, 4)); // A does not attend B
        assert!(!m.can_attend(2, 0)); // A does not attend text
    }

    #[test]
    fn self_attention_always_allowed() {
        let m = fig8();
        for i in 0..m.len() {
            assert!(m.can_attend(i, i), "token {i} must attend itself");
        }
    }

    #[test]
    fn early_text_does_not_attend_later_modalities() {
        let m = fig8();
        assert!(m.can_attend(1, 0));
        assert!(!m.can_attend(1, 2)); // pos 2 > 1: causal
    }

    #[test]
    fn memory_footprint_is_linear() {
        // 1M tokens: 8 bytes each = 8MB, vs 1TB for the full mask (paper).
        let t = 1_000_000usize;
        let bytes = t * std::mem::size_of::<u64>();
        assert!(bytes <= 8 * (1 << 20));
    }

    #[test]
    fn bits_i32_rejects_wide_fields() {
        let m = Bam::new(vec![1u64 << 40], TEXT_BIT);
        let r = std::panic::catch_unwind(|| m.bits_i32());
        assert!(r.is_err());
    }

    #[test]
    fn workloads_match_materialized_rows() {
        let m = fig8();
        let w = m.workloads();
        let full = m.materialize();
        for (i, row) in full.iter().enumerate() {
            let row_sum = row.iter().filter(|&&b| b).count() as u64;
            assert_eq!(w[i], row_sum, "row {i}");
        }
    }
}
