//! The bench harness for `cargo bench` (`rust/benches/*`, `harness =
//! false` — the vendored offline crate set has no criterion, so this is a
//! small warmup+repeat timer with median/p10/p90 reporting). The benches
//! regenerate the paper's tables/figures; the heavy lifting lives in
//! [`crate::coordinator::experiments`].

use std::time::Instant;

/// A named benchmark group: warms up, runs `iters` samples per case, and
/// prints a stats table at the end.
pub struct Bencher {
    title: String,
    warmup: usize,
    iters: usize,
    rows: Vec<(String, Vec<f64>)>,
}

impl Bencher {
    pub fn new(title: &str) -> Self {
        // CORNSTARCH_BENCH_FAST=1 trims iterations (used by `make test`
        // smoke runs); default matches a criterion-ish sample count.
        let fast = std::env::var_os("CORNSTARCH_BENCH_FAST").is_some();
        Bencher {
            title: title.to_string(),
            warmup: if fast { 1 } else { 3 },
            iters: if fast { 3 } else { 15 },
            rows: Vec::new(),
        }
    }

    pub fn with_iters(mut self, warmup: usize, iters: usize) -> Self {
        self.warmup = warmup;
        self.iters = iters;
        self
    }

    /// Time `f` and record under `name`.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) {
        for _ in 0..self.warmup {
            f();
        }
        let samples = time_n(self.iters, f);
        self.rows.push((name.to_string(), samples));
    }

    /// Record externally-collected samples (e.g. per-step wall times).
    pub fn record(&mut self, name: &str, samples: Vec<f64>) {
        self.rows.push((name.to_string(), samples));
    }

    /// Median of a recorded row (for cross-row assertions in benches).
    pub fn median_of(&self, name: &str) -> Option<f64> {
        self.rows
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| median(s))
    }

    /// Print the stats table.
    pub fn report(&self) {
        let mut t = crate::util::table::Table::new(
            &self.title,
            &["case", "n", "median (ms)", "p10", "p90"],
        );
        for (name, samples) in &self.rows {
            let mut s = samples.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let p = |q: f64| s[((s.len() - 1) as f64 * q) as usize];
            t.row(&[
                name.clone(),
                s.len().to_string(),
                format!("{:.3}", median(&s)),
                format!("{:.3}", p(0.10)),
                format!("{:.3}", p(0.90)),
            ]);
        }
        crate::telemetry::report(t.render().trim_end());
    }
}

/// Run `f` `n` times, return per-run wall milliseconds.
pub fn time_n<F: FnMut()>(n: usize, mut f: F) -> Vec<f64> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    out
}

/// Median of a sample (sorts a copy).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s[s.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_n_returns_n_samples() {
        let t = time_n(5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(t.len(), 5);
        assert!(t.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 3.0);
    }
}
