//! Zero-dependency observability for the planning stack: counters,
//! RAII spans, Chrome-trace export, and leveled progress logging —
//! hand-rolled in the repo's style (like [`crate::util::json`]), no
//! external crates.
//!
//! Three primitives:
//!
//! * **Counters** — named monotonic `u64`s ([`count`] / [`incr`]).
//!   The registry is process-wide in its namespace (any module may
//!   bump any [`key`]), but storage is per planner thread so parallel
//!   tests and concurrent tenants never bleed into each other; every
//!   counter site in the stack runs on the thread that called
//!   `plan()` / `tune()`. [`snapshot`] returns an ordered,
//!   deterministic [`Snapshot`], and [`Snapshot::delta_since`] scopes
//!   a region (one `plan()` call, one fleet carve) without resets, so
//!   nested scopes compose. Counter values are part of the
//!   determinism contract: identical inputs produce identical
//!   snapshots, and goldens may pin them.
//!
//!   When one logical request spans *several* threads — the planning
//!   service's evaluation workers, `cornstarch serve` connections, a
//!   search another request's thread is leading on our behalf — the
//!   thread-local registry alone would silently mis-attribute
//!   provenance. [`Scope`] fixes that: a cheap shared accumulator a
//!   request [`Scope::attach`]es on every thread that works for it
//!   (RAII guard; attach nests, so a fleet's scope and its inner
//!   tenant-plan scopes compose). Every `count` feeds the thread-local
//!   registry *and* each scope attached to the current thread;
//!   [`current_scopes`] hands a worker-pool spawner the scopes to
//!   re-attach inside its workers.
//! * **Spans** — RAII wall-clock timers ([`span`]) that record Chrome
//!   trace-event `X` slices (µs since process epoch, one lane per
//!   thread) while tracing is on ([`enable_trace`]); otherwise they
//!   are inert and cost one relaxed atomic load. [`instant`] marks
//!   point events (best-so-far trajectory), [`slice`] records
//!   *virtual-time* slices on a separate `pid` lane (the simulator's
//!   per-stage fwd/bwd timeline). [`write_trace`] renders the sink as
//!   a Chrome trace-event JSON array, loadable in Perfetto /
//!   `chrome://tracing`. Timings are explicitly *not* deterministic
//!   and never golden-held.
//! * **Logging** — one door ([`log`]) for every progress print, with
//!   [`Verbosity`] routing: [`Level::Report`] lines (rendered plans,
//!   tables) always reach stdout, [`Level::Info`] unless `--quiet`,
//!   [`Level::Debug`] only under `-v`, [`Level::Error`] to stderr.
//!
//! The contract throughout: telemetry is off-path. Enabling or
//! disabling any of it never changes a planning result — winners stay
//! byte-identical (held by `tests/telemetry_checks.rs`).

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// The well-known counter names. Call sites go through these consts so
/// a typo is a compile error, and the stats renderer / goldens see one
/// stable vocabulary.
pub mod key {
    /// Raw configurations produced by space enumeration, pre-pruning.
    pub const CANDIDATES_ENUMERATED: &str = "candidates_enumerated";
    /// Candidates cut by the cost-model lower bound or budget.
    pub const PRUNED_LOWER_BOUND: &str = "pruned_lower_bound";
    /// Candidates cut by the per-device memory model.
    pub const PRUNED_MEMORY: &str = "pruned_memory";
    /// Hetero assignments cut for oversubscribing a device group.
    pub const PRUNED_GROUP_CAPACITY: &str = "pruned_group_capacity";
    /// Candidates actually simulated.
    pub const EVALUATED: &str = "evaluated";
    /// Plan-cache lookups answered without a search.
    pub const CACHE_HIT: &str = "cache_hit";
    /// Plan-cache lookups that fell through to a search.
    pub const CACHE_MISS: &str = "cache_miss";
    /// Plan-cache entries persisted to disk.
    pub const CACHE_WRITE: &str = "cache_write";
    /// Fleet pool carves enumerated.
    pub const CARVES_CONSIDERED: &str = "carves_considered";
    /// Fleet carves dropped by the static (pre-search) prune.
    pub const CARVES_PRUNED: &str = "carves_pruned";
    /// Fleet carves where every tenant got a feasible, fair plan.
    pub const CARVES_FEASIBLE: &str = "carves_feasible";
    /// Per-tenant sub-pool searches launched (memo misses).
    pub const PLANS_SEARCHED: &str = "plans_searched";
    /// Verifier runs that came back clean (no Error lints).
    pub const VERIFY_PASS: &str = "verify_pass";
    /// Verifier runs that found at least one Error lint.
    pub const VERIFY_FAIL: &str = "verify_fail";
    /// Plan-store lookups answered from the in-process tier (no disk).
    pub const CACHE_MEM_HIT: &str = "cache_mem_hit";
    /// Requests that joined an identical in-flight search instead of
    /// launching their own.
    pub const INFLIGHT_JOIN: &str = "inflight_join";
    /// Requests handled by `cornstarch serve`.
    pub const SERVE_REQUESTS: &str = "serve_requests";
    /// Branch-and-bound carve-search tree nodes expanded.
    pub const BNB_NODES: &str = "bnb_nodes";
    /// Branch-and-bound subtrees cut by the static admissible bound.
    pub const BNB_PRUNED: &str = "bnb_subtrees_pruned";
    /// Local-search carve moves accepted (hill-climb steps taken).
    pub const LOCAL_MOVES: &str = "local_moves";
    /// Elastic fleet events folded into a re-plan (device loss,
    /// tenant join/leave).
    pub const ELASTIC_EVENTS: &str = "elastic_events";
}

thread_local! {
    static COUNTERS: RefCell<BTreeMap<&'static str, u64>> =
        const { RefCell::new(BTreeMap::new()) };
    static SCOPES: RefCell<Vec<Scope>> = const { RefCell::new(Vec::new()) };
}

/// Add `n` to the named counter on this planner thread, and to every
/// [`Scope`] currently attached to it.
pub fn count(name: &'static str, n: u64) {
    COUNTERS.with(|c| *c.borrow_mut().entry(name).or_insert(0) += n);
    SCOPES.with(|s| {
        for scope in s.borrow().iter() {
            scope.add(name, n);
        }
    });
}

/// Increment the named counter by one.
pub fn incr(name: &'static str) {
    count(name, 1);
}

/// Zero every counter on this thread. Scoped accounting should prefer
/// [`Snapshot::delta_since`], which composes under nesting; `reset` is
/// for process entry points and tests.
pub fn reset_counters() {
    COUNTERS.with(|c| c.borrow_mut().clear());
}

/// An ordered, deterministic view of the counter registry: same
/// inputs, same snapshot, byte for byte.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Snapshot {
    counts: BTreeMap<String, u64>,
}

impl Snapshot {
    /// The counter's value, zero if it never fired.
    pub fn get(&self, name: &str) -> u64 {
        self.counts.get(name).copied().unwrap_or(0)
    }

    /// True when no counter fired.
    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    /// Iterate `(name, value)` in name order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counts.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// The counters this snapshot gained over `earlier` — the scoped
    /// accounting primitive. Zero deltas are dropped, so the result
    /// does not depend on what fired before the baseline was taken.
    pub fn delta_since(&self, earlier: &Snapshot) -> Snapshot {
        let counts = self
            .counts
            .iter()
            .filter_map(|(k, &v)| {
                let d = v.saturating_sub(earlier.get(k));
                (d > 0).then(|| (k.clone(), d))
            })
            .collect();
        Snapshot { counts }
    }

    /// JSON object `{name: value, ...}` in name order.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.counts
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Int(v as i64)))
                .collect(),
        )
    }

    /// Rebuild a snapshot from [`Snapshot::to_json`] output.
    pub fn from_json(j: &Json) -> Option<Snapshot> {
        let Json::Obj(pairs) = j else { return None };
        let mut counts = BTreeMap::new();
        for (k, v) in pairs {
            counts.insert(k.clone(), v.as_i64()? as u64);
        }
        Some(Snapshot { counts })
    }

    /// Aligned `name  value` lines (indented two spaces), name order.
    pub fn render(&self) -> String {
        let width =
            self.counts.keys().map(|k| k.len()).max().unwrap_or(0);
        let mut out = String::new();
        for (k, v) in &self.counts {
            let _ = writeln!(out, "  {k:<width$}  {v}");
        }
        out
    }
}

/// Snapshot this thread's counters.
pub fn snapshot() -> Snapshot {
    COUNTERS.with(|c| Snapshot {
        counts: c
            .borrow()
            .iter()
            .map(|(&k, &v)| (k.to_string(), v))
            .collect(),
    })
}

// ---------------------------------------------------------------- scopes

/// A per-request counter accumulator that follows the request across
/// threads. The thread-local registry attributes counts to whichever
/// thread fired them — correct for a CLI process, silently wrong for a
/// request whose search runs on evaluation workers or on another
/// request's thread (in-flight dedupe). A `Scope` is attached
/// ([`Scope::attach`]) on every thread doing work for the request;
/// while attached, every [`count`] on that thread also lands in the
/// scope. Cloning shares the accumulator (`Arc` inside), so the same
/// scope can be live on many threads at once.
#[derive(Clone, Default)]
pub struct Scope {
    inner: std::sync::Arc<Mutex<BTreeMap<&'static str, u64>>>,
}

impl Scope {
    /// A fresh, empty scope.
    pub fn new() -> Scope {
        Scope::default()
    }

    fn add(&self, name: &'static str, n: u64) {
        *self.inner.lock().unwrap().entry(name).or_insert(0) += n;
    }

    /// Attach this scope to the current thread; counts fired here flow
    /// into it until the returned guard drops. Attaching nests: a
    /// thread may carry several scopes (a fleet's plus a tenant's) and
    /// every one of them sees every count.
    #[must_use]
    pub fn attach(&self) -> ScopeGuard {
        SCOPES.with(|s| s.borrow_mut().push(self.clone()));
        ScopeGuard { scope: self.clone() }
    }

    /// The counts accumulated so far, as an ordered [`Snapshot`] —
    /// already a delta (scopes start empty), no baseline arithmetic.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            counts: self
                .inner
                .lock()
                .unwrap()
                .iter()
                .map(|(&k, &v)| (k.to_string(), v))
                .collect(),
        }
    }
}

/// RAII guard from [`Scope::attach`]; detaches the scope from the
/// current thread on drop.
pub struct ScopeGuard {
    scope: Scope,
}

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        SCOPES.with(|s| {
            let mut stack = s.borrow_mut();
            // Remove the most recent attachment of *this* accumulator
            // (identity, not value — the same scope may be attached
            // more than once on a thread).
            if let Some(i) = stack.iter().rposition(|sc| {
                std::sync::Arc::ptr_eq(&sc.inner, &self.scope.inner)
            }) {
                stack.remove(i);
            }
        });
    }
}

/// The scopes attached to the current thread, outermost first. A
/// worker-pool spawner captures these before `thread::scope` and
/// re-attaches each inside its workers, so per-request accounting
/// survives the hop onto pool threads.
pub fn current_scopes() -> Vec<Scope> {
    SCOPES.with(|s| s.borrow().clone())
}

// ---------------------------------------------------------------- logging

/// How much progress output reaches the terminal. Report output (the
/// rendered plan / table a command exists to produce) is exempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Verbosity {
    /// `--quiet`: report output only.
    Quiet,
    /// Default: progress lines plus report output.
    Normal,
    /// `-v`: adds debug detail (per-wave search progress, cache IO).
    Verbose,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(1);

/// Set the process-wide verbosity (the CLI does this once, from
/// `--quiet` / `-v`).
pub fn set_verbosity(v: Verbosity) {
    VERBOSITY.store(v as u8, Ordering::Relaxed);
}

/// The current process-wide verbosity.
pub fn verbosity() -> Verbosity {
    match VERBOSITY.load(Ordering::Relaxed) {
        0 => Verbosity::Quiet,
        2 => Verbosity::Verbose,
        _ => Verbosity::Normal,
    }
}

/// The kind of line being emitted; [`log`] maps it onto a stream and a
/// verbosity gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Failures. Always emitted, to stderr.
    Error,
    /// The command's actual output (plans, tables, diffs). Always
    /// emitted, to stdout — `--quiet` never eats the report.
    Report,
    /// Progress narration. Stdout, suppressed by `--quiet`.
    Info,
    /// Detail for humans watching a search. Stdout, only under `-v`.
    Debug,
}

/// The one door every print in the stack goes through.
pub fn log(level: Level, msg: &str) {
    match level {
        Level::Error => eprintln!("{msg}"),
        Level::Report => println!("{msg}"),
        Level::Info => {
            if verbosity() >= Verbosity::Normal {
                println!("{msg}");
            }
        }
        Level::Debug => {
            if verbosity() >= Verbosity::Verbose {
                println!("{msg}");
            }
        }
    }
}

/// [`log`] at [`Level::Error`].
pub fn error(msg: &str) {
    log(Level::Error, msg);
}

/// [`log`] at [`Level::Report`].
pub fn report(msg: &str) {
    log(Level::Report, msg);
}

/// [`log`] at [`Level::Info`].
pub fn info(msg: &str) {
    log(Level::Info, msg);
}

/// [`log`] at [`Level::Debug`].
pub fn debug(msg: &str) {
    log(Level::Debug, msg);
}

// ------------------------------------------------------- spans and traces

/// Real wall-clock lanes (planner threads).
const PID_PLANNER: i64 = 1;
/// Virtual-time lanes (the simulator's device timeline).
const PID_SIM: i64 = 2;

static TRACE_ON: AtomicBool = AtomicBool::new(false);

#[derive(Clone, Debug)]
struct TraceEvent {
    name: String,
    /// Chrome trace phase: `X` (complete slice) or `i` (instant).
    ph: char,
    ts_us: u64,
    dur_us: u64,
    pid: i64,
    tid: u64,
    args: Vec<(String, Json)>,
}

impl TraceEvent {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("ph", Json::Str(self.ph.to_string())),
            ("ts", Json::Int(self.ts_us as i64)),
            ("pid", Json::Int(self.pid)),
            ("tid", Json::Int(self.tid as i64)),
        ];
        if self.ph == 'X' {
            pairs.push(("dur", Json::Int(self.dur_us as i64)));
        }
        if self.ph == 'i' {
            // Instant scope: thread-local tick mark.
            pairs.push(("s", Json::Str("t".to_string())));
        }
        if !self.args.is_empty() {
            pairs.push((
                "args",
                Json::Obj(self.args.clone()),
            ));
        }
        Json::obj(pairs)
    }
}

fn sink() -> &'static Mutex<Vec<TraceEvent>> {
    static SINK: OnceLock<Mutex<Vec<TraceEvent>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Vec::new()))
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// A stable per-thread lane id (1, 2, ... in thread-creation order).
fn lane() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static LANE: Cell<u64> = const { Cell::new(0) };
    }
    LANE.with(|l| {
        if l.get() == 0 {
            l.set(NEXT.fetch_add(1, Ordering::Relaxed));
        }
        l.get()
    })
}

fn push(ev: TraceEvent) {
    sink().lock().unwrap().push(ev);
}

/// Start collecting spans / events into the trace sink.
pub fn enable_trace() {
    epoch(); // pin the epoch before the first span
    TRACE_ON.store(true, Ordering::Relaxed);
}

/// Stop collecting (already-recorded events stay in the sink).
pub fn disable_trace() {
    TRACE_ON.store(false, Ordering::Relaxed);
}

/// Is the trace sink collecting?
pub fn trace_enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// Drop every recorded event (tests).
pub fn clear_trace() {
    sink().lock().unwrap().clear();
}

/// Number of events recorded so far.
pub fn trace_len() -> usize {
    sink().lock().unwrap().len()
}

/// An RAII wall-clock span: records a Chrome `X` slice on this
/// thread's lane when dropped, or nothing at all while tracing is off.
pub struct Span {
    name: String,
    start_us: u64,
    tid: u64,
    live: bool,
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        let end = now_us();
        push(TraceEvent {
            name: std::mem::take(&mut self.name),
            ph: 'X',
            ts_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
            pid: PID_PLANNER,
            tid: self.tid,
            args: Vec::new(),
        });
    }
}

/// Open a span; hold the guard for the region's lifetime
/// (`let _span = telemetry::span("tune");`).
#[must_use]
pub fn span(name: &str) -> Span {
    if !trace_enabled() {
        return Span {
            name: String::new(),
            start_us: 0,
            tid: 0,
            live: false,
        };
    }
    Span {
        name: name.to_string(),
        start_us: now_us(),
        tid: lane(),
        live: true,
    }
}

/// Record an instant event (a point on the timeline) with optional
/// args — e.g. the search's best-so-far trajectory.
pub fn instant(name: &str, args: Vec<(&str, Json)>) {
    if !trace_enabled() {
        return;
    }
    push(TraceEvent {
        name: name.to_string(),
        ph: 'i',
        ts_us: now_us(),
        dur_us: 0,
        pid: PID_PLANNER,
        tid: lane(),
        args: args
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    });
}

/// Record a *virtual-time* slice on the simulator's pid — `lane` is
/// the simulated device, `ts_us`/`dur_us` are simulated microseconds.
pub fn slice(name: &str, lane: u64, ts_us: u64, dur_us: u64) {
    if !trace_enabled() {
        return;
    }
    push(TraceEvent {
        name: name.to_string(),
        ph: 'X',
        ts_us,
        dur_us,
        pid: PID_SIM,
        tid: lane,
        args: Vec::new(),
    });
}

/// The whole sink as a Chrome trace-event JSON array.
pub fn trace_json() -> Json {
    Json::Arr(sink().lock().unwrap().iter().map(TraceEvent::to_json).collect())
}

/// Write the trace to `path` (Perfetto / `chrome://tracing` loadable).
pub fn write_trace(path: &str) -> std::io::Result<()> {
    std::fs::write(path, trace_json().render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot_in_name_order() {
        reset_counters();
        count(key::EVALUATED, 3);
        incr(key::CACHE_MISS);
        incr(key::EVALUATED);
        let s = snapshot();
        assert_eq!(s.get(key::EVALUATED), 4);
        assert_eq!(s.get(key::CACHE_MISS), 1);
        assert_eq!(s.get(key::CACHE_HIT), 0);
        let names: Vec<&str> = s.iter().map(|(k, _)| k).collect();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(names, sorted, "snapshot must be name-ordered");
        reset_counters();
        assert!(snapshot().is_empty());
    }

    #[test]
    fn delta_scopes_a_region_without_resets() {
        reset_counters();
        count(key::EVALUATED, 10);
        let before = snapshot();
        count(key::EVALUATED, 5);
        incr(key::CACHE_HIT);
        let delta = snapshot().delta_since(&before);
        assert_eq!(delta.get(key::EVALUATED), 5);
        assert_eq!(delta.get(key::CACHE_HIT), 1);
        // untouched counters do not appear in the delta
        assert!(delta.iter().all(|(_, v)| v > 0));
        reset_counters();
    }

    #[test]
    fn snapshot_json_roundtrips() {
        reset_counters();
        count(key::CANDIDATES_ENUMERATED, 42);
        incr(key::CACHE_WRITE);
        let s = snapshot();
        let j = Json::parse(&s.to_json().render()).unwrap();
        assert_eq!(Snapshot::from_json(&j).unwrap(), s);
        reset_counters();
    }

    #[test]
    fn render_is_aligned_and_deterministic() {
        reset_counters();
        incr(key::CACHE_HIT);
        count(key::CANDIDATES_ENUMERATED, 7);
        let a = snapshot().render();
        let b = snapshot().render();
        assert_eq!(a, b);
        assert!(a.contains("cache_hit"));
        assert!(a.contains("candidates_enumerated"));
        assert_eq!(a.lines().count(), 2);
        reset_counters();
    }

    #[test]
    fn spans_are_inert_until_tracing_is_enabled() {
        // While tracing is off a span records nothing; once on, a
        // uniquely-named span shows up as a Chrome X slice. (The sink
        // is global, so assert only on our own names.)
        disable_trace();
        {
            let _s = span("telemetry-test-off");
        }
        let j = trace_json();
        let has = |name: &str| {
            j.as_arr().unwrap().iter().any(|e| {
                e.get("name").and_then(Json::as_str) == Some(name)
            })
        };
        assert!(!has("telemetry-test-off"));
        enable_trace();
        {
            let _s = span("telemetry-test-on");
            instant("telemetry-test-mark", vec![("k", Json::Int(1))]);
        }
        slice("telemetry-test-slice", 3, 100, 50);
        disable_trace();
        let j = trace_json();
        let find = |name: &str| {
            j.as_arr()
                .unwrap()
                .iter()
                .find(|e| {
                    e.get("name").and_then(Json::as_str) == Some(name)
                })
                .cloned()
        };
        let on = find("telemetry-test-on").expect("span recorded");
        assert_eq!(on.get("ph").and_then(Json::as_str), Some("X"));
        assert!(on.get("ts").and_then(Json::as_i64).is_some());
        assert!(on.get("dur").and_then(Json::as_i64).is_some());
        assert!(on.get("tid").and_then(Json::as_i64).unwrap() >= 1);
        let mark = find("telemetry-test-mark").expect("instant");
        assert_eq!(mark.get("ph").and_then(Json::as_str), Some("i"));
        let sl = find("telemetry-test-slice").expect("slice");
        assert_eq!(sl.get("pid").and_then(Json::as_i64), Some(2));
        assert_eq!(sl.get("ts").and_then(Json::as_i64), Some(100));
        assert_eq!(sl.get("dur").and_then(Json::as_i64), Some(50));
    }

    #[test]
    fn scope_captures_counts_fired_on_other_threads() {
        let scope = Scope::new();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let sc = scope.clone();
                std::thread::spawn(move || {
                    let _g = sc.attach();
                    count(key::EVALUATED, 2);
                    incr(key::CACHE_MEM_HIT);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = scope.snapshot();
        assert_eq!(s.get(key::EVALUATED), 8);
        assert_eq!(s.get(key::CACHE_MEM_HIT), 4);
        // the spawning thread never attached, so nothing leaked here
        // beyond whatever other tests put in the thread-local registry
    }

    #[test]
    fn scopes_nest_and_detach_in_any_order() {
        let outer = Scope::new();
        let inner = Scope::new();
        let og = outer.attach();
        incr(key::INFLIGHT_JOIN);
        {
            let _ig = inner.attach();
            count(key::EVALUATED, 3);
        }
        incr(key::SERVE_REQUESTS);
        drop(og);
        incr(key::CACHE_MISS); // after detach: reaches neither scope
        assert_eq!(outer.snapshot().get(key::INFLIGHT_JOIN), 1);
        assert_eq!(outer.snapshot().get(key::EVALUATED), 3);
        assert_eq!(outer.snapshot().get(key::SERVE_REQUESTS), 1);
        assert_eq!(outer.snapshot().get(key::CACHE_MISS), 0);
        let i = inner.snapshot();
        assert_eq!(i.get(key::EVALUATED), 3);
        assert_eq!(i.get(key::INFLIGHT_JOIN), 0);
        assert_eq!(i.get(key::SERVE_REQUESTS), 0);
    }

    #[test]
    fn current_scopes_rehydrate_on_worker_threads() {
        // The evaluate worker-pool pattern: capture the attached
        // scopes, spawn, re-attach inside each worker.
        let scope = Scope::new();
        let _g = scope.attach();
        let carried = current_scopes();
        assert_eq!(carried.len(), 1);
        std::thread::scope(|s| {
            for _ in 0..3 {
                let carried = carried.clone();
                s.spawn(move || {
                    let _gs: Vec<_> =
                        carried.iter().map(Scope::attach).collect();
                    incr(key::EVALUATED);
                });
            }
        });
        assert_eq!(scope.snapshot().get(key::EVALUATED), 3);
    }

    #[test]
    fn verbosity_defaults_to_normal_and_orders() {
        assert!(Verbosity::Quiet < Verbosity::Normal);
        assert!(Verbosity::Normal < Verbosity::Verbose);
        assert_eq!(verbosity(), Verbosity::Normal);
    }
}
