//! Dense-transformer flop counting.

use crate::model::ModuleGeom;

/// Forward flops of ONE transformer layer over `tokens` tokens.
///
/// * QKV/O projections: `8·T·h²`  (4 matmuls, 2 flops/MAC)
/// * attention scores + weighted values: `4·T²·h·density`
/// * MLP: `4·T·h·d_ff` (2 matmuls)
pub fn layer_flops_fwd(geom: &ModuleGeom, tokens: usize, attn_density: f64) -> f64 {
    let t = tokens as f64;
    let h = geom.hidden as f64;
    let f = geom.d_ff as f64;
    8.0 * t * h * h + 4.0 * t * t * h * attn_density + 4.0 * t * h * f
}

/// Forward flops of the whole module.
pub fn module_flops_fwd(geom: &ModuleGeom, tokens: usize, attn_density: f64) -> f64 {
    geom.n_layers as f64 * layer_flops_fwd(geom, tokens, attn_density)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_nd_rule_of_thumb() {
        // For T << h the projections dominate: fwd flops ≈ 2·params·T
        // (the classic 6ND rule has fwd = 2ND, bwd = 4ND).
        let g = ModuleGeom::new("x", 32, 4096);
        let t = 128; // T << h
        let flops = module_flops_fwd(&g, t, 0.5);
        let rule = 2.0 * g.params() as f64 * t as f64;
        let ratio = flops / rule;
        assert!((0.9..1.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn quadratic_term_appears_at_long_context() {
        let g = ModuleGeom::new("x", 1, 1024);
        let f1 = layer_flops_fwd(&g, 1024, 1.0);
        let f2 = layer_flops_fwd(&g, 2048, 1.0);
        // more than 2x because of the T² attention term
        assert!(f2 / f1 > 2.0);
    }

    #[test]
    fn density_halves_attention_only() {
        let g = ModuleGeom::new("x", 1, 512);
        let full = layer_flops_fwd(&g, 4096, 1.0);
        let causal = layer_flops_fwd(&g, 4096, 0.5);
        let attn = 4.0 * 4096.0f64 * 4096.0 * 512.0;
        assert!((full - causal - attn / 2.0).abs() < 1.0);
    }
}
