//! Analytic execution-time model — flops-derived forward times and the
//! paper's frozen-status backward rule (§4.2).
//!
//! ```text
//! T_bwd = 0          frozen, and no trainable module earlier in fwd order
//!       = 1×T_fwd    frozen, but a trainable module precedes it (must
//!                    propagate input gradients)
//!       = 2×T_fwd    trainable (input grads + param grads)
//! (+1×T_fwd recompute when gradient checkpointing is on and T_bwd > 0)
//! ```
//!
//! Calibrated against the paper's Figure 3b breakdown (CLIP + Mistral-7b
//! on one A40); see `calibrate` and the `reproduce fig3b` target.
//!
//! [`Device`] is deliberately a *value*, not a global: on a heterogeneous
//! pool every pipeline chain is priced with the time model of the device
//! group its assignment lands it on
//! ([`crate::api::DeviceClass::time_model`] →
//! [`crate::modality::planner::plan_assigned`]), so one plan can mix A40-
//! and A100-priced stages.

pub mod flops;

use crate::model::ModuleGeom;
pub use flops::{layer_flops_fwd, module_flops_fwd};

/// Device throughput model (defaults: NVIDIA A40, bf16).
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub peak_flops: f64,
    /// Model flops utilization for big dense matmuls (LLM-shaped work).
    pub mfu: f64,
}

impl Device {
    pub fn a40() -> Self {
        // 149.7 TF bf16 peak; 0.67 *effective* utilization calibrates the
        // model so the paper's Fig. 3b Mistral-7b forward (≈399 ms at
        // bs=2×1577 tokens) is reproduced within ~5% (see cost::tests).
        // This is a single scalar calibration — every result we derive from
        // the model is a *ratio* of times, which the scalar cancels out of.
        // The canonical numbers live in `crate::api::cluster` so the
        // ClusterSpec the planning facade threads everywhere is the single
        // source of hardware truth.
        Device {
            peak_flops: crate::api::cluster::A40_PEAK_FLOPS,
            mfu: crate::api::cluster::A40_MFU,
        }
    }

    pub fn effective_flops(&self) -> f64 {
        self.peak_flops * self.mfu
    }
}

/// Frozen-status of a module plus its position relative to trainable
/// modules — the inputs to the §4.2 rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GradFlow {
    /// This module's own parameters are trainable.
    pub trainable: bool,
    /// Some trainable module precedes it in forward order, so input
    /// gradients must flow through it.
    pub upstream_trainable: bool,
}

impl GradFlow {
    /// The backward/forward time multiplier of §4.2.
    pub fn bwd_multiplier(&self) -> f64 {
        match (self.trainable, self.upstream_trainable) {
            (false, false) => 0.0,
            (false, true) => 1.0,
            // Trainable: param grads + input grads ≈ 2× fwd (the input-grad
            // half is skipped only when nothing upstream needs it, which the
            // paper folds into the same 2× bucket; we keep 2× for parity).
            (true, _) => 2.0,
        }
    }

    /// Full backward time including the activation-recomputation term.
    pub fn bwd_ms(&self, fwd_ms: f64, grad_ckpt: bool) -> f64 {
        let m = self.bwd_multiplier();
        if m == 0.0 {
            0.0
        } else {
            m * fwd_ms + if grad_ckpt { fwd_ms } else { 0.0 }
        }
    }
}

/// Cost model for one module processing `tokens` tokens per microbatch.
#[derive(Clone, Debug)]
pub struct ModuleCost {
    pub geom: ModuleGeom,
    pub tokens: usize,
    pub device: Device,
    /// Attention-mask density: 0.5 for causal LLMs, 1.0 for bidirectional
    /// encoders.
    pub attn_density: f64,
}

impl ModuleCost {
    pub fn llm(geom: ModuleGeom, tokens: usize, device: Device) -> Self {
        ModuleCost { geom, tokens, device, attn_density: 0.5 }
    }

    pub fn encoder(geom: ModuleGeom, tokens: usize, device: Device) -> Self {
        ModuleCost { geom, tokens, device, attn_density: 1.0 }
    }

    /// Forward time of a single layer (ms), on `shards` GPUs (TP/CP fold).
    pub fn layer_fwd_ms(&self, shards: usize) -> f64 {
        let f = flops::layer_flops_fwd(&self.geom, self.tokens, self.attn_density);
        f / (self.device.effective_flops() * shards as f64) * 1e3
    }

    /// Forward time of `n_layers` consecutive layers (ms).
    pub fn layers_fwd_ms(&self, n_layers: usize, shards: usize) -> f64 {
        self.layer_fwd_ms(shards) * n_layers as f64
    }

    /// Whole-module forward (ms).
    pub fn module_fwd_ms(&self, shards: usize) -> f64 {
        self.layers_fwd_ms(self.geom.n_layers, shards)
    }
}

/// A tiny projector's cost (single linear layer, §6.1): negligible but
/// non-zero, matching Figure 3b's ~3.7 ms at CLIP/Mistral scale.
pub fn projector_fwd_ms(d_in: usize, d_out: usize, tokens: usize, device: Device) -> f64 {
    2.0 * d_in as f64 * d_out as f64 * tokens as f64 / device.effective_flops() * 1e3
}

/// Measured per-stage times that override the flops-derived
/// [`crate::pipeline::StageCost`]s of a stage graph — the seam through
/// which a real execution profile ([`crate::profile::CalibrationProfile`])
/// replaces the analytic model, stage by stage, keyed on the planner's
/// stage names (`enc:vision[0]`, `llm[2]`, …).
///
/// Stages without a measured entry keep their modeled cost, so a partial
/// profile (say, LLM stages only) still calibrates what it covers.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MeasuredTimes {
    entries: Vec<(String, crate::pipeline::StageCost)>,
}

impl MeasuredTimes {
    /// Record (or overwrite) the measured cost of `stage`.
    pub fn insert(&mut self, stage: &str, cost: crate::pipeline::StageCost) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == stage) {
            e.1 = cost;
        } else {
            self.entries.push((stage.to_string(), cost));
        }
    }

    pub fn get(&self, stage: &str) -> Option<crate::pipeline::StageCost> {
        self.entries.iter().find(|(n, _)| n == stage).map(|(_, c)| *c)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Rewrite the cost of every node of `g` whose stage name (from
    /// `names`, parallel to the nodes — a plan's `stage_names`) has a
    /// measured entry. Returns how many stages were overridden.
    pub fn apply(
        &self,
        g: &mut crate::pipeline::StageGraph,
        names: &[String],
    ) -> usize {
        let mut overridden = 0;
        for (i, node) in g.nodes.iter_mut().enumerate() {
            let name = names.get(i).map(String::as_str).unwrap_or(&node.name);
            if let Some(c) = self.get(name) {
                node.cost = c;
                overridden += 1;
            }
        }
        overridden
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModuleGeom;

    /// The Figure 3b setting: CLIP-style encoder + Mistral-7b, batch 2,
    /// activation checkpointing on, projector trainable.
    #[test]
    fn fig3b_mistral_forward_in_band() {
        let d = Device::a40();
        // Mistral-7b: 32 layers, h=4096, ff=14336; bs=2 x (577 vis + 1000
        // text) tokens ≈ 3154 LLM tokens.
        let mut g = ModuleGeom::new("mistral7b", 32, 4096);
        g.d_ff = 14336;
        let c = ModuleCost::llm(g, 2 * 1577, d);
        let fwd = c.module_fwd_ms(1);
        // Paper: 397–401 ms.
        assert!(
            (fwd - 399.0).abs() / 399.0 < 0.25,
            "Mistral fwd {fwd:.1} ms vs paper ~399 ms"
        );
    }

    #[test]
    fn fig3b_frozen_llm_bwd_close_to_fwd() {
        // Paper: frozen LLM bwd 530 ms vs fwd 397 ms (ratio 1.34 — the
        // 1x input-grad rule plus recompute overheads folded in).
        let flow = GradFlow { trainable: false, upstream_trainable: true };
        let bwd = flow.bwd_ms(397.0, false);
        assert!((bwd - 397.0).abs() < 1e-9);
        // with grad ckpt the recompute lands between paper's 1.34x and 2x
        let bwd_ck = flow.bwd_ms(397.0, true);
        assert!(bwd_ck > bwd && bwd_ck <= 2.0 * 397.0);
    }

    #[test]
    fn fig3b_trainable_bwd_is_roughly_2x() {
        // Paper (not frozen): LLM fwd 400.87, bwd 1184.65 ≈ 2.95x with
        // checkpointing (2x grads + 1x recompute).
        let flow = GradFlow { trainable: true, upstream_trainable: true };
        let bwd = flow.bwd_ms(400.0, true);
        assert!((bwd - 1200.0).abs() / 1200.0 < 0.05, "{bwd}");
    }

    #[test]
    fn frozen_head_of_pipeline_skips_backward_entirely() {
        let flow = GradFlow { trainable: false, upstream_trainable: false };
        assert_eq!(flow.bwd_ms(100.0, true), 0.0);
    }

    #[test]
    fn tensor_parallel_shards_divide_time() {
        let d = Device::a40();
        let g = ModuleGeom::new("x", 8, 1024);
        let c = ModuleCost::llm(g, 512, d);
        let t1 = c.module_fwd_ms(1);
        let t2 = c.module_fwd_ms(2);
        assert!((t1 / t2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn encoder_attention_denser_than_llm() {
        let d = Device::a40();
        let g = ModuleGeom::new("x", 4, 2048);
        let enc = ModuleCost::encoder(g.clone(), 2048, d).module_fwd_ms(1);
        let llm = ModuleCost::llm(g, 2048, d).module_fwd_ms(1);
        assert!(enc > llm);
    }

    #[test]
    fn projector_is_negligible_but_nonzero() {
        let d = Device::a40();
        let p = projector_fwd_ms(1024, 4096, 2 * 577, d);
        assert!(p > 0.0 && p < 10.0, "{p}");
    }

    #[test]
    fn measured_times_insert_overwrites_by_name() {
        use crate::pipeline::StageCost;
        let mut t = MeasuredTimes::default();
        assert!(t.is_empty());
        t.insert("llm[0]", StageCost { fwd_ms: 1.0, bwd_ms: 2.0 });
        t.insert("llm[0]", StageCost { fwd_ms: 3.0, bwd_ms: 4.0 });
        assert_eq!(t.len(), 1);
        assert_eq!(t.get("llm[0]").unwrap().fwd_ms, 3.0);
        assert!(t.get("llm[1]").is_none());
    }
}
