//! Static plan/schedule verification: typed lints over a [`Plan`], its
//! 1F1B task graph, and the candidate configuration that produced it.
//!
//! The planning stack *constructs* plans it believes are valid; this
//! module is the independent check that they actually are, run at the
//! three trust boundaries where a bad plan would otherwise reach
//! expensive machinery:
//!
//! * **cache admission** ([`crate::tuner::tune_with`]) — a cached entry
//!   is re-verified against the live cluster before it answers a query,
//!   so a corrupted or hand-edited cache file degrades to a re-search
//!   instead of a downstream panic;
//! * **the service boundary** ([`crate::api::PlanningService::plan`] and
//!   [`crate::api::plan_fleet`]) — no report leaves the facade unless
//!   its winner (and, for fleets, the carve itself) verifies clean;
//!   the result is recorded as a provenance field;
//! * **trainer setup** ([`crate::train::PipelineTrainer`]) — the
//!   executor's stage topology is checked for schedulability before any
//!   stage thread spawns.
//!
//! Every finding is a [`Diagnostic`] with a stable [`Code`] (`V001` …
//! `V008`), a severity, and a deterministic rendering: diagnostics are
//! sorted, the JSON form uses the ordered [`crate::util::json`] printer,
//! and two runs over the same inputs are byte-identical. Verifier
//! outcomes feed the [`crate::telemetry::key::VERIFY_PASS`] /
//! [`crate::telemetry::key::VERIFY_FAIL`] counters.
//!
//! Submodules split by what they look at: [`schedule`] walks the task
//! graph and the simulated trace (V001–V004); [`resources`] checks
//! group assignments, memory budgets, CP token distribution, and frozen
//! consistency (V005–V008).

#![warn(clippy::pedantic)]
#![allow(
    clippy::must_use_candidate,
    clippy::missing_panics_doc,
    clippy::module_name_repetitions,
    clippy::cast_precision_loss,
    clippy::cast_possible_truncation,
    clippy::cast_possible_wrap,
    clippy::cast_sign_loss,
    clippy::doc_markdown,
    clippy::similar_names
)]

pub mod resources;
pub mod schedule;

use crate::api::cluster::ClusterSpec;
use crate::api::fleet::FleetPartition;
use crate::modality::Plan;
use crate::pipeline::{onef1b_tasks, StageGraph, TaskSpec};
use crate::tuner::Candidate;
use crate::util::json::Json;

/// How bad a finding is. `Error` means the plan must not be executed or
/// returned; `Warn` flags a smell the caller may accept.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    Warn,
    Error,
}

impl Severity {
    pub fn key(self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }
}

/// The stable lint vocabulary. Codes never change meaning; new lints get
/// new codes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Code {
    /// Cycle in the task dependency DAG (would deadlock the simulator
    /// and the executor alike).
    V001,
    /// A backward task scheduled before its matching forward completed.
    V002,
    /// In-flight microbatches at some stage exceed the 1F1B window
    /// (`min(m, depth-to-sink)`), the bound the memory model budgets.
    V003,
    /// A device double-booked: two tasks overlap in virtual time.
    V004,
    /// A stage/chain assigned to an out-of-range or over-capacity
    /// device group.
    V005,
    /// A stage's peak bytes exceed the budget of its device group.
    V006,
    /// The CP token distribution drops or duplicates token blocks.
    V007,
    /// An all-frozen configuration whose stages still carry backward
    /// cost.
    V008,
}

impl Code {
    pub const ALL: [Code; 8] = [
        Code::V001,
        Code::V002,
        Code::V003,
        Code::V004,
        Code::V005,
        Code::V006,
        Code::V007,
        Code::V008,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Code::V001 => "V001",
            Code::V002 => "V002",
            Code::V003 => "V003",
            Code::V004 => "V004",
            Code::V005 => "V005",
            Code::V006 => "V006",
            Code::V007 => "V007",
            Code::V008 => "V008",
        }
    }

    /// One-line human title, used by renderings and the docs table.
    pub fn title(self) -> &'static str {
        match self {
            Code::V001 => "cycle in stage DAG",
            Code::V002 => "bwd scheduled before matching fwd",
            Code::V003 => "in-flight microbatches exceed 1F1B window",
            Code::V004 => "device double-booked at overlapping virtual times",
            Code::V005 => "stage assigned to out-of-range/over-capacity group",
            Code::V006 => "peak bytes exceed group budget",
            Code::V007 => "cp token distribution drops/duplicates tokens",
            Code::V008 => "frozen stage carries nonzero bwd cost",
        }
    }

    /// The severity this lint always carries: V008 flags a cost-model
    /// smell (a plan that is merely pessimistic, not wrong), everything
    /// else would corrupt or deadlock execution.
    pub fn severity(self) -> Severity {
        match self {
            Code::V008 => Severity::Warn,
            _ => Severity::Error,
        }
    }
}

/// One verification finding.
#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    pub code: Code,
    pub severity: Severity,
    /// What the finding anchors to — a stage name, device index, or
    /// tenant; empty for whole-plan findings.
    pub subject: String,
    pub message: String,
}

impl Diagnostic {
    pub fn new(code: Code, subject: impl Into<String>, message: impl Into<String>) -> Self {
        Diagnostic {
            code,
            severity: code.severity(),
            subject: subject.into(),
            message: message.into(),
        }
    }

    /// `error V006 [llm[0]] peak bytes exceed group budget: …` — one
    /// line, stable field order.
    pub fn render_line(&self) -> String {
        let subject = if self.subject.is_empty() {
            String::from("plan")
        } else {
            self.subject.clone()
        };
        format!(
            "{} {} [{}] {}: {}",
            self.severity.key(),
            self.code.as_str(),
            subject,
            self.code.title(),
            self.message
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::Str(self.code.as_str().into())),
            ("severity", Json::Str(self.severity.key().into())),
            ("subject", Json::Str(self.subject.clone())),
            ("message", Json::Str(self.message.clone())),
        ])
    }
}

/// The verifier's answer: every diagnostic, deterministically ordered
/// by (code, subject, message).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct VerifyReport {
    pub diagnostics: Vec<Diagnostic>,
}

impl VerifyReport {
    /// Wrap raw findings in canonical order (the order every rendering
    /// and the JSON form use).
    pub fn from_diagnostics(mut diagnostics: Vec<Diagnostic>) -> Self {
        diagnostics.sort_by(|a, b| {
            (a.code, &a.subject, &a.message).cmp(&(b.code, &b.subject, &b.message))
        });
        VerifyReport { diagnostics }
    }

    /// Clean means *no errors* — warnings don't block a plan.
    pub fn is_clean(&self) -> bool {
        self.errors() == 0
    }

    pub fn errors(&self) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .count()
    }

    pub fn warnings(&self) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Warn)
            .count()
    }

    /// Every error line joined — what gate failures carry in their
    /// [`crate::api::PlanError`].
    pub fn error_summary(&self) -> String {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(Diagnostic::render_line)
            .collect::<Vec<_>>()
            .join("; ")
    }

    /// Human rendering: a verdict line, then one line per finding.
    pub fn render(&self) -> String {
        let mut out = format!(
            "verify: {} ({} error(s), {} warning(s))\n",
            if self.is_clean() { "clean" } else { "FAILED" },
            self.errors(),
            self.warnings()
        );
        for d in &self.diagnostics {
            out.push_str("  ");
            out.push_str(&d.render_line());
            out.push('\n');
        }
        out
    }

    /// Byte-stable machine form (ordered keys, ordered diagnostics).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("clean", Json::Bool(self.is_clean())),
            ("errors", Json::Int(self.errors() as i64)),
            ("warnings", Json::Int(self.warnings() as i64)),
            (
                "diagnostics",
                Json::Arr(self.diagnostics.iter().map(Diagnostic::to_json).collect()),
            ),
        ])
    }
}

/// Bump the pass/fail telemetry counter for a finished verification.
fn count_outcome(report: &VerifyReport) {
    if report.is_clean() {
        crate::telemetry::incr(crate::telemetry::key::VERIFY_PASS);
    } else {
        crate::telemetry::incr(crate::telemetry::key::VERIFY_FAIL);
    }
}

/// The full static analysis of a constructed plan: schedule lints over
/// its 1F1B task graph (V001–V004), resource lints over its group
/// assignment and memory footprint (V005, V006), and — when the
/// producing [`Candidate`] is known — CP distribution and frozen
/// consistency (V005 assignment rules, V007, V008).
pub fn verify_plan(
    plan: &Plan,
    cluster: &ClusterSpec,
    candidate: Option<&Candidate>,
    llm_tokens: usize,
) -> VerifyReport {
    let tasks = onef1b_tasks(&plan.graph, plan.num_microbatches);
    let mut diags = schedule_diagnostics(&tasks, &plan.graph, plan.num_microbatches);
    diags.extend(resources::check_plan(plan, cluster));
    if let Some(c) = candidate {
        diags.extend(resources::check_candidate(c, cluster));
        diags.extend(resources::check_cp(llm_tokens, c.cp));
        diags.extend(resources::check_frozen(plan, c.frozen));
    }
    let report = VerifyReport::from_diagnostics(diags);
    count_outcome(&report);
    report
}

/// Schedule-only verification of an explicit task list (the trainer's
/// gate, and what mutation tests drive directly): V001 statically, then
/// — only when the graph is acyclic, since a cycle would deadlock the
/// simulator — V002/V003/V004 over the simulated trace.
pub fn verify_schedule(tasks: &[TaskSpec], graph: &StageGraph, m: usize) -> VerifyReport {
    let report = VerifyReport::from_diagnostics(schedule_diagnostics(tasks, graph, m));
    count_outcome(&report);
    report
}

fn schedule_diagnostics(tasks: &[TaskSpec], graph: &StageGraph, m: usize) -> Vec<Diagnostic> {
    let mut diags = schedule::check_tasks(tasks);
    if diags.is_empty() {
        let sim = crate::sim::simulate(tasks);
        diags.extend(schedule::check_trace(&sim.trace, graph, m));
    }
    diags
}

/// Candidate-only verification (the cache-admission gate): the V005
/// assignment lints, with no plan construction or simulation.
pub fn verify_candidate(candidate: &Candidate, cluster: &ClusterSpec) -> VerifyReport {
    let report = VerifyReport::from_diagnostics(resources::check_candidate(candidate, cluster));
    count_outcome(&report);
    report
}

/// Fleet-carve verification: every tenant slice shaped to the pool, no
/// device group oversubscribed across tenants (Error), and full pool
/// coverage (idle devices are a Warn, not an Error — a carve may
/// legitimately leave headroom).
pub fn verify_partition(partition: &FleetPartition, cluster: &ClusterSpec) -> VerifyReport {
    let report =
        VerifyReport::from_diagnostics(resources::check_partition(partition, cluster));
    count_outcome(&report);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_render_stable_and_ordered() {
        let strs: Vec<&str> = Code::ALL.iter().map(|c| c.as_str()).collect();
        assert_eq!(
            strs,
            ["V001", "V002", "V003", "V004", "V005", "V006", "V007", "V008"]
        );
        let mut sorted = Code::ALL.to_vec();
        sorted.sort();
        assert_eq!(sorted, Code::ALL.to_vec());
    }

    #[test]
    fn report_sorts_diagnostics_and_counts_severities() {
        let r = VerifyReport::from_diagnostics(vec![
            Diagnostic::new(Code::V006, "llm[1]", "b"),
            Diagnostic::new(Code::V001, "", "a"),
            Diagnostic::new(Code::V008, "enc:vision[0]", "c"),
        ]);
        assert_eq!(r.diagnostics[0].code, Code::V001);
        assert_eq!(r.diagnostics[2].code, Code::V008);
        assert_eq!(r.errors(), 2);
        assert_eq!(r.warnings(), 1);
        assert!(!r.is_clean());
        assert!(r.render().contains("FAILED"));
        assert!(r.error_summary().contains("V001"));
        assert!(!r.error_summary().contains("V008"));
    }

    #[test]
    fn clean_report_renders_clean_and_json_roundtrips() {
        let r = VerifyReport::default();
        assert!(r.is_clean());
        assert!(r.render().starts_with("verify: clean"));
        let j = r.to_json().render();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.get("clean").and_then(Json::as_bool), Some(true));
    }
}
