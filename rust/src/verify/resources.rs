//! Resource lints (V005–V008): device-group assignments, per-stage
//! memory budgets, CP token-distribution coverage, and frozen-policy
//! consistency. Everything here is pure arithmetic over the plan and
//! the cluster — no simulation, so these checks are safe to run on
//! *untrusted* inputs (a cache entry, a hand-edited plan) where the
//! stack's constructive invariants may not hold.

use super::{Code, Diagnostic};
use crate::api::cluster::ClusterSpec;
use crate::api::fleet::FleetPartition;
use crate::modality::{Plan, Strategy};
use crate::tuner::evaluate::{cp_block_workloads, pick_cp_over, CP_PICK_SEED};
use crate::tuner::{Candidate, FrozenSetting};

const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

fn gib(bytes: u64) -> f64 {
    bytes as f64 / GIB
}

/// V005 over a candidate's chain→group assignment: arity matching the
/// strategy's chain count, every index in range, Colocated encoders
/// sharing one group, and no device group oversubscribed. This subsumes
/// what `Candidate::assignment_is_valid` used to answer with a bare
/// `bool` — the cache-admission gate runs exactly this.
pub fn check_candidate(c: &Candidate, cluster: &ClusterSpec) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let n_groups = cluster.groups.len();
    if n_groups == 0 {
        diags.push(Diagnostic::new(
            Code::V005,
            c.label(),
            "cluster has no device groups",
        ));
        return diags;
    }
    if !c.chain_groups.is_empty() {
        let n_chains = match c.strategy {
            Strategy::Replicated => 1,
            _ => c.enc_pps.len() + 1,
        };
        if c.chain_groups.len() != n_chains {
            diags.push(Diagnostic::new(
                Code::V005,
                c.label(),
                format!(
                    "{} chain-group entries for {} chain(s)",
                    c.chain_groups.len(),
                    n_chains
                ),
            ));
        }
        for (chain, &g) in c.chain_groups.iter().enumerate() {
            if g >= n_groups {
                diags.push(Diagnostic::new(
                    Code::V005,
                    format!("chain {chain}"),
                    format!("assigned to group {g}, cluster has {n_groups} group(s)"),
                ));
            }
        }
        if c.strategy == Strategy::Colocated && c.chain_groups.len() == n_chains {
            let enc = &c.chain_groups[..c.enc_pps.len().min(c.chain_groups.len())];
            if enc.windows(2).any(|w| w[0] != w[1]) {
                diags.push(Diagnostic::new(
                    Code::V005,
                    c.label(),
                    format!("colocated encoders split across groups {enc:?}"),
                ));
            }
        }
    }
    // Capacity is only meaningful once the indices themselves are sane.
    if diags.is_empty() {
        let used = c.gpus_per_group(n_groups);
        for (g, (&u, grp)) in used.iter().zip(&cluster.groups).enumerate() {
            if u > grp.count {
                diags.push(Diagnostic::new(
                    Code::V005,
                    format!("group {g}"),
                    format!("{u} GPUs assigned, group has {}", grp.count),
                ));
            }
        }
    }
    diags
}

/// V005 + V006 over a constructed plan: every stage's recorded device
/// group must exist, and the stage's peak bytes must fit that group's
/// per-device memory. Out-of-range groups are reported (not budgeted on
/// a fallback) — this runs on untrusted plans, so it must never index.
pub fn check_plan(plan: &Plan, cluster: &ClusterSpec) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let n_groups = cluster.groups.len();
    for (i, sm) in plan.stage_mem.iter().enumerate() {
        let g = plan.stage_groups.get(i).copied().unwrap_or(0);
        let name = stage_name(plan, i);
        match cluster.groups.get(g) {
            None => diags.push(Diagnostic::new(
                Code::V005,
                name,
                format!("assigned to group {g}, cluster has {n_groups} group(s)"),
            )),
            Some(grp) => {
                let peak = sm.peak_bytes();
                if peak > grp.device.mem_bytes {
                    diags.push(Diagnostic::new(
                        Code::V006,
                        name,
                        format!(
                            "peak {:.2} GiB exceeds the {:.2} GiB budget of group {g} ({})",
                            gib(peak),
                            gib(grp.device.mem_bytes),
                            grp.device.name
                        ),
                    ));
                }
            }
        }
    }
    diags
}

/// V007 entry point for a plan: rebuild the exact workload the tuner
/// scored (same seed, same blocking) and check the picked algorithm's
/// assignment for coverage.
pub fn check_cp(llm_tokens: usize, cp: usize) -> Vec<Diagnostic> {
    if cp <= 1 {
        return Vec::new();
    }
    let w = cp_block_workloads(llm_tokens, CP_PICK_SEED);
    let assignment = pick_cp_over(&w, cp).assign(&w, cp);
    check_cp_assignment(w.len(), cp, &assignment)
}

/// The raw coverage check behind V007, callable with an arbitrary
/// (possibly doctored) assignment: every token block assigned exactly
/// once, and only to ranks that exist. A length mismatch means blocks
/// were dropped or duplicated; an out-of-range rank silently loses its
/// blocks at execution time.
pub fn check_cp_assignment(n_blocks: usize, cp: usize, assignment: &[usize]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if assignment.len() != n_blocks {
        diags.push(Diagnostic::new(
            Code::V007,
            "cp",
            format!(
                "{} block assignments for {n_blocks} token blocks",
                assignment.len()
            ),
        ));
    }
    for (b, &r) in assignment.iter().enumerate() {
        if r >= cp {
            diags.push(Diagnostic::new(
                Code::V007,
                "cp",
                format!("block {b} assigned to rank {r}, cp degree is {cp}"),
            ));
            break;
        }
    }
    diags
}

/// V008: an all-frozen configuration promises ~zero backward work, so a
/// stage still carrying backward cost means the cost model and the
/// frozen policy disagree. Warn-severity — the plan is pessimistic, not
/// executable-wrong.
pub fn check_frozen(plan: &Plan, frozen: FrozenSetting) -> Vec<Diagnostic> {
    if frozen != FrozenSetting::AllFrozen {
        return Vec::new();
    }
    let mut diags = Vec::new();
    for (i, node) in plan.graph.nodes.iter().enumerate() {
        if node.cost.bwd_ms > 1e-6 {
            diags.push(Diagnostic::new(
                Code::V008,
                stage_name(plan, i),
                format!(
                    "all-frozen config, stage carries {:.3} ms of bwd cost",
                    node.cost.bwd_ms
                ),
            ));
        }
    }
    diags
}

/// Fleet-carve lints, all in the V005 family: slice widths must match
/// the pool's group list, no group may be oversubscribed across tenants
/// (both Errors), and devices left idle by every tenant are a Warn
/// (a carve may legitimately keep headroom, but it should be visible).
pub fn check_partition(partition: &FleetPartition, cluster: &ClusterSpec) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let n_groups = cluster.groups.len();
    for (t, slice) in partition.slices.iter().enumerate() {
        if slice.len() != n_groups {
            diags.push(Diagnostic::new(
                Code::V005,
                format!("tenant {t}"),
                format!(
                    "slice spans {} group(s), pool has {n_groups}",
                    slice.len()
                ),
            ));
        }
    }
    if !diags.is_empty() {
        return diags;
    }
    for (g, grp) in cluster.groups.iter().enumerate() {
        let assigned: usize = partition.slices.iter().map(|s| s[g]).sum();
        if assigned > grp.count {
            diags.push(Diagnostic::new(
                Code::V005,
                format!("group {g}"),
                format!(
                    "{assigned} devices assigned across tenants, group has {}",
                    grp.count
                ),
            ));
        } else if assigned < grp.count {
            let mut d = Diagnostic::new(
                Code::V005,
                format!("group {g}"),
                format!(
                    "{} of {} devices unassigned (idle headroom)",
                    grp.count - assigned,
                    grp.count
                ),
            );
            d.severity = super::Severity::Warn;
            diags.push(d);
        }
    }
    diags
}

fn stage_name(plan: &Plan, i: usize) -> String {
    plan.stage_names
        .get(i)
        .cloned()
        .unwrap_or_else(|| format!("stage {i}"))
}
