//! Schedule lints (V001–V004): the task-graph cycle check that runs
//! *before* anything simulates (a cycle would deadlock the simulator's
//! ready-queue loop and the real executor's channel topology alike),
//! and the trace checks that hold a simulated schedule to the 1F1B
//! contract — bwd after its fwd, the in-flight window respected, no
//! device in two places at once.

use std::collections::BTreeMap;

use super::{Code, Diagnostic};
use crate::pipeline::{StageGraph, TaskKind, TaskSpec};
use crate::sim::TaskTrace;

/// Comparison slop for virtual-time boundaries: a bwd may start exactly
/// when its fwd ends, a fwd exactly when the window-opening bwd ends.
const EPS_MS: f64 = 1e-9;

fn task_label(tasks: &[TaskSpec], i: usize) -> String {
    let t = &tasks[i];
    let kind = match t.kind {
        TaskKind::Fwd => "fwd",
        TaskKind::Bwd => "bwd",
    };
    format!("{kind} s{} mb{}", t.stage, t.microbatch)
}

/// V001: static cycle detection over the dependency edges, iterative
/// three-color DFS in deterministic node order. Returns at most one
/// diagnostic — the first cycle found — since a single cycle usually
/// implicates many tasks and one precise report beats a flood.
/// Out-of-range dependency indices are reported through the same code
/// (the scheduler could never satisfy them, the same deadlock).
pub fn check_tasks(tasks: &[TaskSpec]) -> Vec<Diagnostic> {
    let n = tasks.len();
    for (d, i) in crate::sim::dependency_edges(tasks) {
        if d >= n {
            return vec![Diagnostic::new(
                Code::V001,
                task_label(tasks, i),
                format!("dependency index {d} out of range ({n} tasks)"),
            )];
        }
    }
    // 0 = unvisited, 1 = on the current DFS path, 2 = done.
    let mut state = vec![0u8; n];
    for root in 0..n {
        if state[root] != 0 {
            continue;
        }
        let mut path: Vec<usize> = vec![root];
        let mut next_dep: Vec<usize> = vec![0];
        state[root] = 1;
        while let Some(&node) = path.last() {
            let i = *next_dep.last().unwrap();
            if let Some(&(d, _)) = tasks[node].deps.get(i) {
                *next_dep.last_mut().unwrap() += 1;
                match state[d] {
                    0 => {
                        state[d] = 1;
                        path.push(d);
                        next_dep.push(0);
                    }
                    1 => {
                        let start =
                            path.iter().position(|&p| p == d).unwrap_or(0);
                        return vec![Diagnostic::new(
                            Code::V001,
                            task_label(tasks, d),
                            format!(
                                "dependency cycle of {} task(s): {} waits for {}",
                                path.len() - start,
                                task_label(tasks, d),
                                task_label(tasks, node),
                            ),
                        )];
                    }
                    _ => {}
                }
            } else {
                state[node] = 2;
                path.pop();
                next_dep.pop();
            }
        }
    }
    Vec::new()
}

/// V002/V003/V004 over an executed (simulated) schedule. The trace may
/// come from [`crate::sim::simulate`] or be hand-doctored — nothing
/// here assumes the simulator's own invariants.
pub fn check_trace(trace: &[TaskTrace], graph: &StageGraph, m: usize) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let mut fwd: BTreeMap<(usize, usize), &TaskTrace> = BTreeMap::new();
    let mut bwd: BTreeMap<(usize, usize), &TaskTrace> = BTreeMap::new();
    for t in trace {
        match t.kind {
            TaskKind::Fwd => fwd.insert((t.stage, t.microbatch), t),
            TaskKind::Bwd => bwd.insert((t.stage, t.microbatch), t),
        };
    }
    let stage_name = |s: usize| -> String {
        graph
            .nodes
            .get(s)
            .map_or_else(|| format!("stage {s}"), |n| n.name.clone())
    };

    // V002: every bwd starts no earlier than its matching fwd ends.
    for ((s, mb), b) in &bwd {
        if let Some(f) = fwd.get(&(*s, *mb)) {
            if b.start_ms < f.end_ms - EPS_MS {
                diags.push(Diagnostic::new(
                    Code::V002,
                    stage_name(*s),
                    format!(
                        "bwd mb{mb} starts at {:.3} ms, before its fwd completes at {:.3} ms",
                        b.start_ms, f.end_ms
                    ),
                ));
            }
        }
    }

    // V003: per stage, sweep the [fwd start, bwd end) activation-liveness
    // intervals; the peak overlap is the in-flight microbatch count the
    // memory model budgets as min(m, depth-to-sink).
    let depth = graph.depth_to_sink();
    for s in 0..graph.nodes.len() {
        let limit = depth.get(s).copied().unwrap_or(m).min(m);
        let mut events: Vec<(f64, i64)> = Vec::new();
        for mb in 0..m {
            let (Some(f), Some(b)) = (fwd.get(&(s, mb)), bwd.get(&(s, mb))) else {
                continue;
            };
            if b.end_ms > f.start_ms + EPS_MS {
                events.push((f.start_ms, 1));
                events.push((b.end_ms, -1));
            }
        }
        // At equal times the release (-1) lands first: a fwd may start
        // exactly when the bwd that opened its window ends.
        events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut live = 0i64;
        let mut peak = 0i64;
        for (_, d) in events {
            live += d;
            peak = peak.max(live);
        }
        if peak > limit as i64 {
            diags.push(Diagnostic::new(
                Code::V003,
                stage_name(s),
                format!("{peak} microbatches in flight, 1F1B window allows {limit}"),
            ));
        }
    }

    // V004: per device, no two nonzero-duration tasks overlap.
    let mut by_dev: BTreeMap<usize, Vec<&TaskTrace>> = BTreeMap::new();
    for t in trace {
        if t.end_ms > t.start_ms + EPS_MS {
            by_dev.entry(t.device).or_default().push(t);
        }
    }
    for (dev, mut iv) in by_dev {
        iv.sort_by(|a, b| {
            a.start_ms
                .total_cmp(&b.start_ms)
                .then(a.end_ms.total_cmp(&b.end_ms))
        });
        for w in iv.windows(2) {
            if w[1].start_ms < w[0].end_ms - EPS_MS {
                diags.push(Diagnostic::new(
                    Code::V004,
                    format!("device {dev}"),
                    format!(
                        "s{} mb{} [{:.3}, {:.3}) overlaps s{} mb{} [{:.3}, {:.3})",
                        w[1].stage,
                        w[1].microbatch,
                        w[1].start_ms,
                        w[1].end_ms,
                        w[0].stage,
                        w[0].microbatch,
                        w[0].start_ms,
                        w[0].end_ms
                    ),
                ));
                break; // one report per device keeps the output readable
            }
        }
    }
    diags
}
