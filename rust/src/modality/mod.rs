//! The Cornstarch programming model (§3.2) in Rust: `ModalityModule`,
//! `MultimodalModule`, `ParallelSpec`, `MultimodalParallelSpec`, and the
//! planners that turn them into executable pipeline stage DAGs.
//!
//! Listing 1 of the paper maps onto this module as follows:
//!
//! ```text
//! paper (python)                          this crate
//! -------------------------------------   ---------------------------------
//! ModalityModule(vis, proj="mlp")         ModalityModule::encoder(geom, ..)
//! MultimodalModule(encoders=.., llm=..)   MultimodalModule::new(..)
//! mllm.vision_encoder.module.train(False) module.train(false)
//! ParallelSpec(tp_size, cp_size, pp_size) ParallelSpec { tp, cp, pp }
//! MultimodalParallelSpec(...)             MultimodalParallelSpec { .. }
//! mm_spec.apply(mllm)                     spec.apply(&mllm) -> Plan
//! parallel_mllm.execute(batch)            crate::train (real PJRT) or
//!                                         crate::sim (calibrated model)
//! ```
//!
//! [`planner`] holds the three parallelization policies compared in §6:
//! Cornstarch's modality-parallel + frozen-aware planner and the two
//! baselines (encoders-colocated, encoders-replicated). [`auto`] is the
//! loosely-coupled auto-parallelization of Algorithm 1.

pub mod auto;
pub mod planner;

pub use auto::{auto_parallelize, AutoResult};
pub use planner::{Plan, Strategy};

use crate::cost::{Device, GradFlow, ModuleCost};
use crate::model::{MllmSpec, ModuleGeom, TokenCounts};

/// What a module is, which decides attention density, token count, and
/// grad-flow classification.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModuleKind {
    /// A modality encoder with a trailing projector.
    Encoder,
    /// The language model (consumes all projected modality tokens).
    Llm,
}

/// One unimodal constituent of an MLLM: an encoder (+projector) or the LLM.
///
/// `frozen` mirrors `module.train(mode=False)` in the paper's Listing 1;
/// `projector_trainable` mirrors `mllm.vision_encoder.projector.train(True)`
/// (encoders only).
#[derive(Clone, Debug)]
pub struct ModalityModule {
    pub name: String,
    pub geom: ModuleGeom,
    pub kind: ModuleKind,
    pub frozen: bool,
    /// Only meaningful for encoders. The common MLLM recipe (§2.1) freezes
    /// the encoder and LLM and trains projectors; this defaults to `true`.
    pub projector_trainable: bool,
    /// Tokens this module processes per sample (sequence length).
    pub tokens: usize,
}

impl ModalityModule {
    pub fn encoder(name: &str, geom: ModuleGeom, tokens: usize) -> Self {
        ModalityModule {
            name: name.to_string(),
            geom,
            kind: ModuleKind::Encoder,
            frozen: true,
            projector_trainable: true,
            tokens,
        }
    }

    pub fn llm(geom: ModuleGeom, tokens: usize) -> Self {
        ModalityModule {
            name: "llm".to_string(),
            geom,
            kind: ModuleKind::Llm,
            frozen: true,
            projector_trainable: false,
            tokens,
        }
    }

    /// `train(mode)` from Listing 1: `train(false)` freezes the module.
    pub fn train(&mut self, mode: bool) -> &mut Self {
        self.frozen = !mode;
        self
    }

    /// Grad-flow classification of the module body under the §4.2 rule.
    ///
    /// * encoder body: nothing precedes it ⇒ `upstream_trainable = false`;
    /// * LLM: a trainable projector precedes it whenever any encoder's
    ///   projector (or the encoder itself) is trainable.
    pub fn flow(&self, upstream_trainable: bool) -> GradFlow {
        GradFlow { trainable: !self.frozen, upstream_trainable }
    }

    /// Per-layer forward time (ms) on one device group of `shards` GPUs.
    pub fn layer_fwd_ms(&self, device: Device, shards: usize) -> f64 {
        let cost = match self.kind {
            ModuleKind::Encoder => {
                ModuleCost::encoder(self.geom.clone(), self.tokens, device)
            }
            ModuleKind::Llm => {
                ModuleCost::llm(self.geom.clone(), self.tokens, device)
            }
        };
        cost.layer_fwd_ms(shards)
    }
}

/// An MLLM assembled from unimodal modules (the paper's
/// `MultimodalModule`). The execution DAG is implicit in the structure:
/// every encoder chain feeds the LLM's first stage (Figure 6a).
#[derive(Clone, Debug)]
pub struct MultimodalModule {
    pub encoders: Vec<ModalityModule>,
    pub llm: ModalityModule,
    /// Microbatch size in samples (the paper uses 1 sample/microbatch).
    pub microbatch_size: usize,
}

impl MultimodalModule {
    pub fn new(encoders: Vec<ModalityModule>, llm: ModalityModule) -> Self {
        MultimodalModule { encoders, llm, microbatch_size: 1 }
    }

    /// Build from a Table-1 composition with the paper's §6.1 recipe:
    /// encoders and LLM frozen, projectors trainable.
    pub fn from_spec(spec: &MllmSpec) -> Self {
        let tok = spec.tokens;
        let mut encoders = Vec::new();
        if let Some(v) = &spec.vision {
            encoders.push(ModalityModule::encoder("vision", v.clone(), tok.vision));
        }
        if let Some(a) = &spec.audio {
            encoders.push(ModalityModule::encoder("audio", a.clone(), tok.audio));
        }
        let llm_tokens = spec.llm_tokens();
        MultimodalModule::new(encoders, ModalityModule::llm(spec.llm.clone(), llm_tokens))
    }

    /// Does any trainable parameter precede the LLM in forward order?
    /// (Decides whether the LLM must propagate input gradients — §4.2.)
    pub fn llm_has_trainable_upstream(&self) -> bool {
        self.encoders
            .iter()
            .any(|e| !e.frozen || e.projector_trainable)
    }

    /// Token counts helper for the synthetic §6.1 dataset.
    pub fn paper_tokens() -> TokenCounts {
        TokenCounts::paper()
    }
}

/// Per-module parallelization degrees (the paper's `ParallelSpec`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelSpec {
    pub tp: usize,
    pub cp: usize,
    pub pp: usize,
}

impl ParallelSpec {
    pub fn new(tp: usize, cp: usize, pp: usize) -> Self {
        assert!(tp >= 1 && cp >= 1 && pp >= 1);
        ParallelSpec { tp, cp, pp }
    }

    /// GPUs per pipeline stage of this module.
    pub fn gpus_per_stage(&self) -> usize {
        self.tp * self.cp
    }

    /// Total GPUs this module occupies.
    pub fn gpus(&self) -> usize {
        self.gpus_per_stage() * self.pp
    }
}

/// The whole-MLLM parallelization request (the paper's
/// `MultimodalParallelSpec`): one spec per encoder plus one for the LLM.
#[derive(Clone, Debug)]
pub struct MultimodalParallelSpec {
    /// Parallel spec per encoder, in `MultimodalModule::encoders` order.
    pub encoder_specs: Vec<ParallelSpec>,
    pub llm_spec: ParallelSpec,
    pub num_microbatches: usize,
    /// ms charged on every cross-stage activation/gradient hop.
    pub comm_ms: f64,
    /// Gradient checkpointing (activation recomputation, §4.2 note).
    pub grad_ckpt: bool,
}

impl MultimodalParallelSpec {
    pub fn paper_default(
        encoder_pp: &[usize],
        llm_pp: usize,
        tp: usize,
        cp: usize,
    ) -> Self {
        MultimodalParallelSpec {
            encoder_specs: encoder_pp
                .iter()
                .map(|&pp| ParallelSpec::new(tp, cp, pp))
                .collect(),
            llm_spec: ParallelSpec::new(tp, cp, llm_pp),
            num_microbatches: 24, // §6.1: 24 microbatches of 1 sample
            comm_ms: 0.5,
            grad_ckpt: true,
        }
    }

    /// [`Self::paper_default`] with the cross-stage comm hop priced off a
    /// cluster's interconnect bandwidth instead of the paper constant.
    /// The A40 default cluster reproduces the 0.5 ms constant exactly, so
    /// default-cluster plans are byte-identical to `paper_default` ones.
    pub fn for_cluster(
        encoder_pp: &[usize],
        llm_pp: usize,
        tp: usize,
        cp: usize,
        cluster: &crate::api::ClusterSpec,
    ) -> Self {
        let mut s = Self::paper_default(encoder_pp, llm_pp, tp, cp);
        s.comm_ms = cluster.comm_hop_ms();
        s
    }

    /// `apply()` from Listing 1: parallelize the MLLM with Cornstarch's
    /// multimodality-aware planner (modality parallelism + frozen-aware
    /// partitioning). Baselines are reachable via [`planner::plan`].
    pub fn apply(&self, mm: &MultimodalModule) -> Plan {
        planner::plan(Strategy::Cornstarch, mm, self, Device::a40())
    }

    pub fn total_gpus(&self) -> usize {
        self.llm_spec.gpus()
            + self
                .encoder_specs
                .iter()
                .map(|s| s.gpus())
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Size;

    fn valm_mm() -> MultimodalModule {
        MultimodalModule::from_spec(&MllmSpec::valm(Size::M, Size::M, Size::M))
    }

    #[test]
    fn from_spec_follows_paper_recipe() {
        let mm = valm_mm();
        assert_eq!(mm.encoders.len(), 2);
        assert!(mm.encoders.iter().all(|e| e.frozen && e.projector_trainable));
        assert!(mm.llm.frozen);
        // projectors trainable => LLM must propagate input gradients
        assert!(mm.llm_has_trainable_upstream());
    }

    #[test]
    fn train_toggles_frozen() {
        let mut mm = valm_mm();
        mm.llm.train(true);
        assert!(!mm.llm.frozen);
        mm.llm.train(false);
        assert!(mm.llm.frozen);
    }

    #[test]
    fn fully_frozen_everything_stops_llm_backprop() {
        let mut mm = valm_mm();
        for e in &mut mm.encoders {
            e.projector_trainable = false;
        }
        assert!(!mm.llm_has_trainable_upstream());
        let flow = mm.llm.flow(mm.llm_has_trainable_upstream());
        assert_eq!(flow.bwd_multiplier(), 0.0);
    }

    #[test]
    fn parallel_spec_gpu_accounting() {
        let s = ParallelSpec::new(2, 2, 3);
        assert_eq!(s.gpus_per_stage(), 4);
        assert_eq!(s.gpus(), 12);
        let mspec = MultimodalParallelSpec::paper_default(&[1, 1], 4, 2, 2);
        assert_eq!(mspec.total_gpus(), (4 + 1 + 1) * 4);
    }

    #[test]
    fn for_cluster_prices_comm_off_the_bandwidth() {
        let a40 = crate::api::ClusterSpec::a40_default();
        let def = MultimodalParallelSpec::paper_default(&[1], 4, 2, 2);
        let clu = MultimodalParallelSpec::for_cluster(&[1], 4, 2, 2, &a40);
        // golden parity: the A40 default reproduces the paper constant
        assert_eq!(clu.comm_ms, def.comm_ms);
        let mut slow = a40.clone();
        slow.groups[0].link_gbps /= 2.0;
        let s = MultimodalParallelSpec::for_cluster(&[1], 4, 2, 2, &slow);
        assert_eq!(s.comm_ms, 2.0 * def.comm_ms);
    }

    #[test]
    fn llm_attention_is_causal_encoders_full() {
        let mm = valm_mm();
        let d = Device::a40();
        // same geom for vision-M and llm-M (32 x 4096) but encoders use
        // density 1.0 — at equal token counts the encoder layer is slower.
        let enc = &mm.encoders[0];
        let mut enc_eq = enc.clone();
        enc_eq.tokens = mm.llm.tokens;
        assert!(enc_eq.layer_fwd_ms(d, 1) > mm.llm.layer_fwd_ms(d, 1));
    }
}
