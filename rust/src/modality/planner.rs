//! The three MLLM parallelization policies evaluated in §6.
//!
//! * [`Strategy::Cornstarch`] — modality parallelism (§4.1: every encoder
//!   chain on its own devices, feeding the LLM chain) with frozen-status-
//!   aware stage partitioning (§4.2: balance `fwd + bwd` where bwd obeys
//!   the `0/1×/2×` rule).
//! * [`plan_chain`] — joint-chain partitioning with a frozen-aware toggle
//!   (the Table 3 / Figure 7 ablation).
//! * [`Strategy::Colocated`] — Megatron-LM-style: all encoders partitioned
//!   into the *same* number of stages, colocated per stage and executed
//!   sequentially, chained in front of the LLM (Figure 1c), partitioned by
//!   forward time under the "bwd = 2×fwd" assumption.
//! * [`Strategy::Replicated`] — Meta-Llama-style: LLM-only pipeline, all
//!   encoders replicated into and re-executed by every stage (Figure 1b).
//!
//! Whichever policy *partitions* the model, *execution* reality is the
//! same: backward times follow the frozen rule (that mismatch is exactly
//! the paper's Figure 7b imbalance).

use crate::cost::{projector_fwd_ms, Device, GradFlow};
use crate::memory::{self, StageMemory};
use crate::model::ModuleGeom;
use crate::pipeline::{
    onef1b_tasks, partition_min_max, stage_sums, LayerCost, StageCost,
    StageGraph,
};
use crate::sim::{simulate, SimResult};

use super::{ModalityModule, MultimodalModule, MultimodalParallelSpec};

/// Parallelization policy under comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Cornstarch,
    Colocated,
    Replicated,
}

impl Strategy {
    pub const ALL: [Strategy; 3] = [
        Strategy::Cornstarch,
        Strategy::Colocated,
        Strategy::Replicated,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Cornstarch => "Cornstarch",
            Strategy::Colocated => "Encoders-colocated",
            Strategy::Replicated => "Encoders-replicated",
        }
    }

    /// Stable machine-readable key (CLI flags, tuner cache entries).
    pub fn key(&self) -> &'static str {
        match self {
            Strategy::Cornstarch => "cornstarch",
            Strategy::Colocated => "colocated",
            Strategy::Replicated => "replicated",
        }
    }

    pub fn from_key(s: &str) -> Option<Strategy> {
        match s {
            "cornstarch" => Some(Strategy::Cornstarch),
            "colocated" => Some(Strategy::Colocated),
            "replicated" => Some(Strategy::Replicated),
            _ => None,
        }
    }
}

/// A fully-planned parallel MLLM: the stage DAG plus accounting needed to
/// report the paper's metrics.
#[derive(Clone, Debug)]
pub struct Plan {
    pub strategy: Strategy,
    pub graph: StageGraph,
    /// Stage names parallel to `graph.nodes` (`enc:vision[0]`, `llm[2]`…).
    pub stage_names: Vec<String>,
    /// Per-stage per-GPU memory accounting ([`crate::memory`]), parallel
    /// to `graph.nodes`.
    pub stage_mem: Vec<StageMemory>,
    /// Cluster device-group index each stage lands on, parallel to
    /// `graph.nodes`. All zeros for plans built against a homogeneous
    /// pool; heterogeneous assignments ([`plan_assigned`]) record which
    /// group's time model priced the stage and which group's memory
    /// budget its verdict is held to.
    pub stage_groups: Vec<usize>,
    pub n_gpus: usize,
    pub num_microbatches: usize,
    pub microbatch_size: usize,
}

/// The hardware one pipeline chain is planned onto: the device time
/// model its layer costs are priced with, the cluster group index its
/// stages occupy, and the per-hop cost of that group's link. This is how
/// the cost layer's per-device time models are keyed by a heterogeneous
/// assignment.
#[derive(Clone, Copy, Debug)]
pub struct ChainHw {
    pub device: Device,
    pub group: usize,
    pub link_ms: f64,
}

/// Iteration-level metrics computed by replaying the plan through the
/// discrete-event simulator.
#[derive(Clone, Debug)]
pub struct PlanMetrics {
    pub iteration_ms: f64,
    /// Samples per second (whole job).
    pub throughput: f64,
    /// The paper's normalized metric: input/s per GPU.
    pub throughput_per_gpu: f64,
    /// 1 − mean(device busy / makespan).
    pub bubble_ratio: f64,
    pub sim: SimResult,
}

impl Plan {
    pub fn simulate(&self) -> PlanMetrics {
        let tasks = onef1b_tasks(&self.graph, self.num_microbatches);
        let sim = simulate(&tasks);
        let iteration_ms = sim.makespan_ms;
        let samples =
            (self.num_microbatches * self.microbatch_size) as f64;
        let throughput = samples / (iteration_ms / 1e3);
        let n_dev = self.graph.n_devices() as f64;
        let busy: f64 = sim.device_busy_ms.iter().sum();
        let bubble_ratio = 1.0 - busy / (iteration_ms * n_dev);
        PlanMetrics {
            iteration_ms,
            throughput,
            throughput_per_gpu: throughput / self.n_gpus as f64,
            bubble_ratio,
            sim,
        }
    }

    /// (min, max) of per-stage fwd+bwd over all stages — the balance metric
    /// quoted in §6.2 ("50 ms ~ 131 ms range of per-stage fwd+bwd time").
    pub fn stage_time_range(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for n in &self.graph.nodes {
            let t = n.cost.total();
            lo = lo.min(t);
            hi = hi.max(t);
        }
        (lo, hi)
    }

    /// Modeled peak per-GPU memory over all stages (bytes) — the quantity
    /// Appendix D's feasibility verdicts and the tuner's capacity filter
    /// compare against the device budget.
    pub fn peak_device_bytes(&self) -> u64 {
        memory::peak_device_bytes(&self.stage_mem)
    }

    /// Mean per-stage fwd and bwd of stages whose name starts with `prefix`
    /// (Table 3's "Per-Stage Fwd/Bwd (ms), Encoder | LLM" columns).
    pub fn mean_stage_cost(&self, prefix: &str) -> Option<StageCost> {
        let sel: Vec<&StageCost> = self
            .stage_names
            .iter()
            .zip(&self.graph.nodes)
            .filter(|(n, _)| n.starts_with(prefix))
            .map(|(_, node)| &node.cost)
            .collect();
        if sel.is_empty() {
            return None;
        }
        let k = sel.len() as f64;
        Some(StageCost {
            fwd_ms: sel.iter().map(|c| c.fwd_ms).sum::<f64>() / k,
            bwd_ms: sel.iter().map(|c| c.bwd_ms).sum::<f64>() / k,
        })
    }
}

/// Per-layer cost rows of one module: encoder body layers followed by its
/// projector pseudo-layer, or the LLM's layers.
pub fn encoder_layer_costs(
    e: &ModalityModule,
    llm_geom: &ModuleGeom,
    device: Device,
    shards: usize,
) -> Vec<LayerCost> {
    let body_flow = GradFlow { trainable: !e.frozen, upstream_trainable: false };
    let fwd = e.layer_fwd_ms(device, shards);
    let mut layers: Vec<LayerCost> = (0..e.geom.n_layers)
        .map(|_| LayerCost { fwd_ms: fwd, flow: body_flow })
        .collect();
    // Trailing projector: a single linear layer (§6.1).
    layers.push(LayerCost {
        fwd_ms: projector_fwd_ms(
            e.geom.hidden,
            llm_geom.hidden,
            e.tokens,
            device,
        ) / shards as f64,
        flow: GradFlow {
            trainable: e.projector_trainable,
            upstream_trainable: !e.frozen,
        },
    });
    layers
}

pub fn llm_layer_costs(
    mm: &MultimodalModule,
    device: Device,
    shards: usize,
) -> Vec<LayerCost> {
    let flow = mm.llm.flow(mm.llm_has_trainable_upstream());
    let fwd = mm.llm.layer_fwd_ms(device, shards);
    (0..mm.llm.geom.n_layers)
        .map(|_| LayerCost { fwd_ms: fwd, flow })
        .collect()
}

/// Partition `layers` into `pp` stages. Frozen-aware balances `fwd+bwd`
/// (with recompute when checkpointing); unaware balances fwd only — the
/// classic "bwd is 2×fwd" assumption makes both orderings identical.
/// Returns the boundaries too, so callers can sum per-stage *memory*
/// over the same split.
fn partition(
    layers: &[LayerCost],
    pp: usize,
    frozen_aware: bool,
    grad_ckpt: bool,
) -> (Vec<usize>, Vec<StageCost>) {
    let costs: Vec<f64> = if frozen_aware {
        layers.iter().map(|l| l.fwd_ms + l.bwd_ms(grad_ckpt)).collect()
    } else {
        layers.iter().map(|l| l.fwd_ms).collect()
    };
    let bounds = partition_min_max(&costs, pp);
    // Execution reality always applies the frozen rule.
    let sums = stage_sums(layers, &bounds, grad_ckpt);
    (bounds, sums)
}

/// Plan an MLLM under `strategy` on a homogeneous pool: every chain is
/// priced with the same `device` and the flat `spec.comm_ms` hop. GPU
/// accounting: every pipeline stage is one device group of `tp×cp` GPUs;
/// Replicated reuses the LLM's groups.
pub fn plan(
    strategy: Strategy,
    mm: &MultimodalModule,
    spec: &MultimodalParallelSpec,
    device: Device,
) -> Plan {
    let hw = ChainHw { device, group: 0, link_ms: spec.comm_ms };
    let enc_hw = vec![hw; mm.encoders.len()];
    plan_on_hw(strategy, mm, spec, &enc_hw, hw)
}

/// Plan an MLLM under `strategy` with each pipeline chain assigned to a
/// device group of `cluster` — the heterogeneous-pools entry point.
///
/// `chain_groups` names a cluster group per chain: one entry per encoder
/// (in `mm.encoders` order) followed by the LLM's, except
/// [`Strategy::Replicated`], which has a single chain (encoders ride the
/// LLM stages) and takes exactly one entry. An empty slice means "all on
/// group 0". Every chain's layer costs are priced with its group's time
/// model; within-chain hops pay the group's link, and cross-chain hops
/// pay the slower of the two links (the bottleneck).
pub fn plan_assigned(
    strategy: Strategy,
    mm: &MultimodalModule,
    spec: &MultimodalParallelSpec,
    cluster: &crate::api::ClusterSpec,
    chain_groups: &[usize],
) -> Plan {
    let n_chains = match strategy {
        Strategy::Replicated => 1,
        _ => mm.encoders.len() + 1,
    };
    let zeros;
    let groups: &[usize] = if chain_groups.is_empty() {
        zeros = vec![0usize; n_chains];
        &zeros
    } else {
        chain_groups
    };
    assert_eq!(
        groups.len(),
        n_chains,
        "{} wants one group per chain ({n_chains}), got {:?}",
        strategy.name(),
        groups
    );
    let hw_of = |g: usize| ChainHw {
        device: cluster.group_device(g),
        group: g,
        link_ms: cluster.groups[g].hop_ms(),
    };
    let llm_hw = hw_of(*groups.last().unwrap());
    let enc_hw: Vec<ChainHw> = match strategy {
        Strategy::Replicated => Vec::new(),
        _ => groups[..groups.len() - 1]
            .iter()
            .map(|&g| hw_of(g))
            .collect(),
    };
    plan_on_hw(strategy, mm, spec, &enc_hw, llm_hw)
}

fn plan_on_hw(
    strategy: Strategy,
    mm: &MultimodalModule,
    spec: &MultimodalParallelSpec,
    enc_hw: &[ChainHw],
    llm_hw: ChainHw,
) -> Plan {
    match strategy {
        Strategy::Cornstarch => {
            plan_modality_parallel(mm, spec, enc_hw, llm_hw)
        }
        Strategy::Colocated => {
            // All encoders fuse stage-wise into one chain, so they must
            // share one device group (§6.3's equal-stage constraint has
            // a hardware twin).
            assert!(
                enc_hw.windows(2).all(|w| w[0].group == w[1].group),
                "encoders-colocated requires all encoders on one group"
            );
            let enc = enc_hw.first().copied().unwrap_or(llm_hw);
            plan_colocated(mm, spec, enc, llm_hw)
        }
        Strategy::Replicated => plan_replicated(mm, spec, llm_hw),
    }
}

/// Plan a Table-1 composition with uniform per-encoder stage counts and
/// the §6.1 spec defaults — the single construction path behind every
/// memory-verdict consumer (`configs::validate_llm_l_memory`,
/// `reproduce memory`, the `cornstarch memory` CLI), so their verdicts
/// can never diverge.
#[allow(clippy::too_many_arguments)]
pub fn plan_uniform(
    strategy: Strategy,
    spec: &crate::model::MllmSpec,
    enc_pp: usize,
    llm_pp: usize,
    tp: usize,
    cp: usize,
    num_microbatches: usize,
    device: Device,
) -> Plan {
    let mm = MultimodalModule::from_spec(spec);
    let enc_pps = if strategy == Strategy::Replicated {
        Vec::new()
    } else {
        vec![enc_pp; mm.encoders.len()]
    };
    let mut ps =
        MultimodalParallelSpec::paper_default(&enc_pps, llm_pp, tp, cp);
    ps.num_microbatches = num_microbatches;
    plan(strategy, &mm, &ps, device)
}

/// Joint-chain partitioning for single-chain MLLMs — the §4.2 / Figure 7
/// experiment (Tables 3, 10, 11). All modules' layers are concatenated in
/// forward order (encoders, projectors, LLM) and split into `total_stages`
/// contiguous stages:
///
/// * `frozen_aware = true` balances per-stage `fwd + bwd` under the frozen
///   rule (Figure 7c) — the boundary shifts *toward the encoder*, giving
///   encoder stages more forward work since their backward is ~0;
/// * `frozen_aware = false` balances per-stage fwd assuming `bwd = 2×fwd`
///   (Figure 7a) — balanced forward, imbalanced execution (Figure 7b).
pub fn plan_chain(
    mm: &MultimodalModule,
    total_stages: usize,
    frozen_aware: bool,
    spec: &MultimodalParallelSpec,
    device: Device,
) -> Plan {
    let gps = spec.llm_spec.gpus_per_stage();
    // Concatenate all modules' layers in forward order; remember which
    // module each layer belongs to for stage naming. Memory rows stay
    // index-aligned with the cost rows.
    let mut layers: Vec<LayerCost> = Vec::new();
    let mut mems: Vec<memory::LayerMemory> = Vec::new();
    let mut owner: Vec<String> = Vec::new();
    for e in &mm.encoders {
        let ls = encoder_layer_costs(e, &mm.llm.geom, device, gps);
        owner.extend(std::iter::repeat_n(format!("enc:{}", e.name), ls.len()));
        layers.extend(ls);
        mems.extend(memory::encoder_layer_memory(
            e,
            &mm.llm.geom,
            &spec.llm_spec,
            mm.microbatch_size,
        ));
    }
    let ls = llm_layer_costs(mm, device, gps);
    owner.extend(std::iter::repeat_n("llm".to_string(), ls.len()));
    layers.extend(ls);
    mems.extend(memory::llm_layer_memory(
        mm,
        &spec.llm_spec,
        mm.microbatch_size,
    ));
    debug_assert_eq!(layers.len(), mems.len());

    let weights: Vec<f64> = if frozen_aware {
        layers
            .iter()
            .map(|l| l.fwd_ms + l.bwd_ms(spec.grad_ckpt))
            .collect()
    } else {
        layers.iter().map(|l| l.fwd_ms).collect()
    };
    let bounds = partition_min_max(&weights, total_stages);
    let costs = stage_sums(&layers, &bounds, spec.grad_ckpt);
    let mut stage_mem = memory::stage_sums(&mems, &bounds);
    let mut graph = StageGraph {
        nodes: Vec::new(),
        comm_ms: spec.comm_ms,
        device_link_ms: Vec::new(),
    };
    graph.add_chain("stage", &costs, 0, &[]);
    memory::assign_in_flight(&mut stage_mem, &graph, spec.num_microbatches);
    // A stage is named for the module owning its first layer.
    let names: Vec<String> = bounds
        .windows(2)
        .enumerate()
        .map(|(i, w)| format!("{}[{i}]", owner[w[0]]))
        .collect();
    Plan {
        strategy: Strategy::Cornstarch,
        graph,
        stage_names: names,
        stage_mem,
        stage_groups: vec![0; total_stages],
        n_gpus: total_stages * gps,
        num_microbatches: spec.num_microbatches,
        microbatch_size: mm.microbatch_size,
    }
}

fn plan_modality_parallel(
    mm: &MultimodalModule,
    spec: &MultimodalParallelSpec,
    enc_hw: &[ChainHw],
    llm_hw: ChainHw,
) -> Plan {
    assert_eq!(spec.encoder_specs.len(), mm.encoders.len());
    assert_eq!(enc_hw.len(), mm.encoders.len());
    let aware = true; // Cornstarch always partitions frozen-aware
    let mut graph = StageGraph {
        nodes: Vec::new(),
        comm_ms: spec.comm_ms,
        device_link_ms: Vec::new(),
    };
    let mut names = Vec::new();
    let mut stage_mem: Vec<StageMemory> = Vec::new();
    let mut stage_groups: Vec<usize> = Vec::new();
    let mut dev = 0usize;
    let mut enc_tails = Vec::new();
    let mut n_gpus = 0usize;
    for ((e, ps), hw) in
        mm.encoders.iter().zip(&spec.encoder_specs).zip(enc_hw)
    {
        let layers = encoder_layer_costs(
            e,
            &mm.llm.geom,
            hw.device,
            ps.gpus_per_stage(),
        );
        let (bounds, costs) = partition(&layers, ps.pp, aware, spec.grad_ckpt);
        let mems = memory::encoder_layer_memory(
            e,
            &mm.llm.geom,
            ps,
            mm.microbatch_size,
        );
        stage_mem.extend(memory::stage_sums(&mems, &bounds));
        let ids = graph.add_chain(&format!("enc:{}", e.name), &costs, dev, &[]);
        for i in 0..costs.len() {
            names.push(format!("enc:{}[{}]", e.name, i));
        }
        stage_groups.extend(std::iter::repeat_n(hw.group, ps.pp));
        graph
            .device_link_ms
            .extend(std::iter::repeat_n(hw.link_ms, ps.pp));
        dev += ps.pp;
        n_gpus += ps.gpus();
        enc_tails.push(*ids.last().unwrap());
    }
    let lp = &spec.llm_spec;
    let layers = llm_layer_costs(mm, llm_hw.device, lp.gpus_per_stage());
    let (bounds, costs) = partition(&layers, lp.pp, aware, spec.grad_ckpt);
    stage_mem.extend(memory::stage_sums(
        &memory::llm_layer_memory(mm, lp, mm.microbatch_size),
        &bounds,
    ));
    graph.add_chain("llm", &costs, dev, &enc_tails);
    for i in 0..costs.len() {
        names.push(format!("llm[{i}]"));
    }
    stage_groups.extend(std::iter::repeat_n(llm_hw.group, lp.pp));
    graph
        .device_link_ms
        .extend(std::iter::repeat_n(llm_hw.link_ms, lp.pp));
    n_gpus += lp.gpus();
    memory::assign_in_flight(&mut stage_mem, &graph, spec.num_microbatches);
    Plan {
        strategy: Strategy::Cornstarch,
        graph,
        stage_names: names,
        stage_mem,
        stage_groups,
        n_gpus,
        num_microbatches: spec.num_microbatches,
        microbatch_size: mm.microbatch_size,
    }
}

fn plan_colocated(
    mm: &MultimodalModule,
    spec: &MultimodalParallelSpec,
    enc_hw: ChainHw,
    llm_hw: ChainHw,
) -> Plan {
    // All encoders share ONE stage count (the colocated constraint the
    // paper calls out in §6.3: "all encoders in the colocated module must
    // be partitioned with the same number of stages").
    let enc_pp = spec
        .encoder_specs
        .first()
        .map(|s| s.pp)
        .unwrap_or(0);
    assert!(
        spec.encoder_specs.iter().all(|s| s.pp == enc_pp),
        "encoders-colocated requires equal encoder stage counts"
    );
    let gps = spec.llm_spec.gpus_per_stage();
    let mut graph = StageGraph {
        nodes: Vec::new(),
        comm_ms: spec.comm_ms,
        device_link_ms: Vec::new(),
    };
    let mut names = Vec::new();
    let mut stage_mem: Vec<StageMemory> = Vec::new();
    let mut stage_groups: Vec<usize> = Vec::new();
    let mut enc_tail = Vec::new();
    let mut dev = 0usize;
    if enc_pp > 0 && !mm.encoders.is_empty() {
        // Partition each encoder into enc_pp stages by fwd time, then fuse
        // stage-wise: colocated stage i runs every encoder's stage i
        // sequentially (Figure 1c) — and holds every encoder's slice.
        let mut fused = vec![StageCost { fwd_ms: 0.0, bwd_ms: 0.0 }; enc_pp];
        let mut fused_mem = vec![StageMemory::default(); enc_pp];
        for e in &mm.encoders {
            let layers =
                encoder_layer_costs(e, &mm.llm.geom, enc_hw.device, gps);
            let (bounds, costs) = partition(&layers, enc_pp, false, spec.grad_ckpt);
            let mems = memory::encoder_layer_memory(
                e,
                &mm.llm.geom,
                &spec.llm_spec,
                mm.microbatch_size,
            );
            for (fm, m) in
                fused_mem.iter_mut().zip(memory::stage_sums(&mems, &bounds))
            {
                fm.absorb(&m);
            }
            for (f, c) in fused.iter_mut().zip(costs) {
                f.fwd_ms += c.fwd_ms;
                f.bwd_ms += c.bwd_ms;
            }
        }
        let ids = graph.add_chain("enc", &fused, 0, &[]);
        for i in 0..enc_pp {
            names.push(format!("enc[{i}]"));
        }
        stage_mem.extend(fused_mem);
        stage_groups.extend(std::iter::repeat_n(enc_hw.group, enc_pp));
        graph
            .device_link_ms
            .extend(std::iter::repeat_n(enc_hw.link_ms, enc_pp));
        enc_tail.push(*ids.last().unwrap());
        dev = enc_pp;
    }
    let layers = llm_layer_costs(mm, llm_hw.device, gps);
    let (bounds, costs) = partition(&layers, spec.llm_spec.pp, false, spec.grad_ckpt);
    stage_mem.extend(memory::stage_sums(
        &memory::llm_layer_memory(mm, &spec.llm_spec, mm.microbatch_size),
        &bounds,
    ));
    graph.add_chain("llm", &costs, dev, &enc_tail);
    for i in 0..costs.len() {
        names.push(format!("llm[{i}]"));
    }
    stage_groups.extend(std::iter::repeat_n(llm_hw.group, spec.llm_spec.pp));
    graph
        .device_link_ms
        .extend(std::iter::repeat_n(llm_hw.link_ms, spec.llm_spec.pp));
    memory::assign_in_flight(&mut stage_mem, &graph, spec.num_microbatches);
    let n_gpus = (enc_pp + spec.llm_spec.pp) * gps;
    Plan {
        strategy: Strategy::Colocated,
        graph,
        stage_names: names,
        stage_mem,
        stage_groups,
        n_gpus,
        num_microbatches: spec.num_microbatches,
        microbatch_size: mm.microbatch_size,
    }
}

fn plan_replicated(
    mm: &MultimodalModule,
    spec: &MultimodalParallelSpec,
    hw: ChainHw,
) -> Plan {
    let gps = spec.llm_spec.gpus_per_stage();
    let pp = spec.llm_spec.pp;
    let layers = llm_layer_costs(mm, hw.device, gps);
    let (bounds, mut costs) = partition(&layers, pp, false, spec.grad_ckpt);
    // Every stage redundantly re-runs ALL encoders per microbatch
    // (Figure 1b / Figure 2a): add the full encoder fwd (+frozen-rule bwd)
    // to every stage — and the full encoder weights + activations to
    // every stage's memory. The encoders execute on the LLM's devices, so
    // they are priced with the LLM chain's time model.
    let mut enc_fwd = 0.0;
    let mut enc_bwd = 0.0;
    let mut enc_mem = StageMemory::default();
    for e in &mm.encoders {
        for l in encoder_layer_costs(e, &mm.llm.geom, hw.device, gps) {
            enc_fwd += l.fwd_ms;
            enc_bwd += l.bwd_ms(spec.grad_ckpt);
        }
        for l in memory::encoder_layer_memory(
            e,
            &mm.llm.geom,
            &spec.llm_spec,
            mm.microbatch_size,
        ) {
            enc_mem.add_layer(&l);
        }
    }
    for c in &mut costs {
        c.fwd_ms += enc_fwd;
        c.bwd_ms += enc_bwd;
    }
    let mut stage_mem = memory::stage_sums(
        &memory::llm_layer_memory(mm, &spec.llm_spec, mm.microbatch_size),
        &bounds,
    );
    for sm in &mut stage_mem {
        sm.absorb(&enc_mem);
    }
    let mut graph = StageGraph {
        nodes: Vec::new(),
        comm_ms: spec.comm_ms,
        device_link_ms: vec![hw.link_ms; pp],
    };
    graph.add_chain("llm", &costs, 0, &[]);
    memory::assign_in_flight(&mut stage_mem, &graph, spec.num_microbatches);
    let names = (0..pp).map(|i| format!("llm[{i}]")).collect();
    Plan {
        strategy: Strategy::Replicated,
        graph,
        stage_names: names,
        stage_mem,
        stage_groups: vec![hw.group; pp],
        n_gpus: pp * gps,
        num_microbatches: spec.num_microbatches,
        microbatch_size: mm.microbatch_size,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{MllmSpec, Size};
    use crate::modality::MultimodalModule;

    fn plan_for(
        strategy: Strategy,
        spec: &MllmSpec,
        enc_pp: &[usize],
        llm_pp: usize,
    ) -> Plan {
        let mm = MultimodalModule::from_spec(spec);
        let ps = MultimodalParallelSpec::paper_default(enc_pp, llm_pp, 2, 2);
        plan(strategy, &mm, &ps, Device::a40())
    }

    #[test]
    fn cornstarch_builds_modality_parallel_dag() {
        let p = plan_for(
            Strategy::Cornstarch,
            &MllmSpec::valm(Size::M, Size::M, Size::M),
            &[1, 1],
            4,
        );
        assert_eq!(p.graph.nodes.len(), 1 + 1 + 4);
        // both encoder tails feed llm[0]
        let llm0 = 2;
        assert_eq!(p.graph.nodes[llm0].preds, vec![0, 1]);
        // distinct devices for every stage
        let mut devs: Vec<usize> =
            p.graph.nodes.iter().map(|n| n.device).collect();
        devs.sort_unstable();
        devs.dedup();
        assert_eq!(devs.len(), 6);
        assert_eq!(p.n_gpus, 6 * 4);
    }

    #[test]
    fn colocated_is_a_chain() {
        let p = plan_for(
            Strategy::Colocated,
            &MllmSpec::valm(Size::M, Size::M, Size::M),
            &[3, 3],
            3,
        );
        assert_eq!(p.graph.nodes.len(), 6);
        for (i, n) in p.graph.nodes.iter().enumerate() {
            if i == 0 {
                assert!(n.preds.is_empty());
            } else {
                assert_eq!(n.preds, vec![i - 1]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "equal encoder stage counts")]
    fn colocated_rejects_unequal_encoder_stages() {
        plan_for(
            Strategy::Colocated,
            &MllmSpec::valm(Size::M, Size::M, Size::M),
            &[2, 3],
            3,
        );
    }

    #[test]
    fn replicated_pays_encoder_cost_in_every_stage() {
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let rep = plan_for(Strategy::Replicated, &spec, &[1], 4);
        let mm = MultimodalModule::from_spec(&spec);
        let d = Device::a40();
        let enc_fwd: f64 =
            encoder_layer_costs(&mm.encoders[0], &mm.llm.geom, d, 4)
                .iter()
                .map(|l| l.fwd_ms)
                .sum();
        // every stage's fwd strictly exceeds the encoder-only fwd
        for n in &rep.graph.nodes {
            assert!(n.cost.fwd_ms > enc_fwd);
        }
        assert_eq!(rep.graph.nodes.len(), 4);
    }

    #[test]
    fn frozen_aware_beats_unaware_on_vlm_l() {
        // Table 3's headline: VLM-L frozen-aware 1.53x faster. Same total
        // stage count, only the partitioning policy differs (Figure 7).
        let spec = MllmSpec::vlm(Size::M, Size::L);
        let mm = MultimodalModule::from_spec(&spec);
        let ps = MultimodalParallelSpec::paper_default(&[2], 3, 2, 1);
        let d = Device::a40();
        let aware = plan_chain(&mm, 5, true, &ps, d);
        let unaware = plan_chain(&mm, 5, false, &ps, d);
        let ta = aware.simulate().iteration_ms;
        let tu = unaware.simulate().iteration_ms;
        assert!(
            ta < tu,
            "frozen-aware {ta:.1} ms should beat unaware {tu:.1} ms"
        );
        // Figure 7c: aware gives encoder stages MORE forward work.
        let enc_aware = aware.mean_stage_cost("enc:").unwrap();
        let enc_unaware = unaware.mean_stage_cost("enc:").unwrap();
        assert!(enc_aware.fwd_ms > enc_unaware.fwd_ms);
        // and the fwd+bwd spread across stages is tighter.
        let spread = |p: &Plan| {
            let (lo, hi) = p.stage_time_range();
            hi / lo
        };
        assert!(spread(&aware) <= spread(&unaware) + 1e-9);
    }

    #[test]
    fn cornstarch_beats_replicated_on_large_encoders() {
        // Figure 2a: replicating large encoders wastes compute.
        let spec = MllmSpec::vlm(Size::M, Size::L);
        let cs = plan_for(Strategy::Cornstarch, &spec, &[2], 4);
        let rep = plan_for(Strategy::Replicated, &spec, &[2], 4);
        let m_cs = cs.simulate();
        let m_rep = rep.simulate();
        assert!(
            m_cs.throughput_per_gpu > m_rep.throughput_per_gpu,
            "cornstarch {:.3} vs replicated {:.3} input/s/GPU",
            m_cs.throughput_per_gpu,
            m_rep.throughput_per_gpu
        );
    }

    #[test]
    fn assigned_plan_prices_each_chain_with_its_group() {
        let cluster = crate::api::ClusterSpec::a40_a100_demo();
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let mm = MultimodalModule::from_spec(&spec);
        let ps = MultimodalParallelSpec::paper_default(&[1], 2, 1, 1);
        // encoder on the A40 group (0), LLM on the A100 group (1)
        let split =
            plan_assigned(Strategy::Cornstarch, &mm, &ps, &cluster, &[0, 1]);
        assert_eq!(split.stage_groups, vec![0, 1, 1]);
        // the same shape all on the A40 group
        let a40 =
            plan_assigned(Strategy::Cornstarch, &mm, &ps, &cluster, &[0, 0]);
        assert_eq!(a40.stage_groups, vec![0, 0, 0]);
        // encoder stages identical (same device), LLM stages faster on
        // the A100's higher effective flops
        assert!(
            split.graph.nodes[0].cost.fwd_ms == a40.graph.nodes[0].cost.fwd_ms
        );
        let a100_eff = cluster.group_device(1).effective_flops();
        let a40_eff = cluster.group_device(0).effective_flops();
        assert!(a100_eff > a40_eff, "demo premise: A100 faster");
        for s in 1..3 {
            assert!(
                split.graph.nodes[s].cost.fwd_ms
                    < a40.graph.nodes[s].cost.fwd_ms
            );
        }
        // links: encoder device slow, LLM devices fast; the crossing
        // edge pays the slow (bottleneck) link
        assert_eq!(split.graph.device_link_ms.len(), 3);
        assert_eq!(split.graph.hop_ms(0, 1), cluster.hop_ms_between(0, 1));
        assert_eq!(split.graph.hop_ms(1, 2), cluster.hop_ms_between(1, 1));
        assert!(split.graph.hop_ms(1, 2) < split.graph.hop_ms(0, 1));
        // and the heterogeneous split simulates faster than all-A40
        assert!(
            split.simulate().iteration_ms < a40.simulate().iteration_ms
        );
    }

    #[test]
    fn assigned_plan_on_one_group_matches_the_homogeneous_planner() {
        // plan_assigned on a single-group cluster must be byte-identical
        // to the legacy plan() path — golden parity depends on it.
        let cluster = crate::api::ClusterSpec::a40_default();
        let spec = MllmSpec::valm(Size::M, Size::M, Size::M);
        let mm = MultimodalModule::from_spec(&spec);
        for (strategy, enc_pp, groups) in [
            (Strategy::Cornstarch, vec![1usize, 2], vec![0usize, 0, 0]),
            (Strategy::Colocated, vec![2, 2], vec![0, 0, 0]),
            (Strategy::Replicated, vec![], vec![0]),
        ] {
            let ps = MultimodalParallelSpec::for_cluster(
                &enc_pp, 3, 2, 2, &cluster,
            );
            let legacy = plan(strategy, &mm, &ps, cluster.device_model());
            let assigned =
                plan_assigned(strategy, &mm, &ps, &cluster, &groups);
            assert_eq!(legacy.stage_names, assigned.stage_names);
            assert_eq!(legacy.stage_groups, assigned.stage_groups);
            assert_eq!(legacy.n_gpus, assigned.n_gpus);
            for (a, b) in
                legacy.graph.nodes.iter().zip(&assigned.graph.nodes)
            {
                assert!(a.cost.fwd_ms == b.cost.fwd_ms);
                assert!(a.cost.bwd_ms == b.cost.bwd_ms);
                assert_eq!(a.device, b.device);
                assert_eq!(a.preds, b.preds);
            }
            let (ml, ma) =
                (legacy.simulate(), assigned.simulate());
            assert!(ml.iteration_ms == ma.iteration_ms);
        }
    }

    #[test]
    #[should_panic(expected = "one group per chain")]
    fn assigned_plan_rejects_wrong_assignment_arity() {
        let cluster = crate::api::ClusterSpec::a40_a100_demo();
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let mm = MultimodalModule::from_spec(&spec);
        let ps = MultimodalParallelSpec::paper_default(&[1], 2, 1, 1);
        plan_assigned(Strategy::Cornstarch, &mm, &ps, &cluster, &[0]);
    }

    #[test]
    fn metrics_are_consistent() {
        let p = plan_for(
            Strategy::Cornstarch,
            &MllmSpec::alm(Size::S, Size::M),
            &[2],
            3,
        );
        let m = p.simulate();
        assert!(m.iteration_ms > 0.0);
        assert!((m.throughput - 24.0 / (m.iteration_ms / 1e3)).abs() < 1e-9);
        assert!(m.bubble_ratio >= 0.0 && m.bubble_ratio < 1.0);
        let (lo, hi) = p.stage_time_range();
        assert!(lo <= hi);
        assert!(p.mean_stage_cost("llm").is_some());
        assert!(p.mean_stage_cost("enc:audio").is_some());
        assert!(p.mean_stage_cost("enc:vision").is_none());
    }
}
