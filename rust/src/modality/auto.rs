//! Loosely-coupled auto-parallelization — Algorithm 1 (§5.2).
//!
//! Cornstarch does not invent a new unimodal auto-parallelizer; it reuses
//! one (here: the exact min-max partitioner) per module and *couples* the
//! per-module choices loosely: enumerate feasible LLM stage counts, derive
//! each option's per-stage time target `t_i`, pick for every encoder the
//! stage count whose per-stage time best matches `t_i`, then simulate each
//! combination and keep the minimum-iteration-time plan.

use crate::cost::Device;
use crate::pipeline::{partition_min_max, stage_sums, LayerCost};

use super::planner::{plan, Plan, PlanMetrics, Strategy};
use super::{MultimodalModule, MultimodalParallelSpec, ParallelSpec};

/// Result of the search: the winning plan plus the whole frontier for
/// inspection (the reproduce harness prints it).
#[derive(Clone, Debug)]
pub struct AutoResult {
    pub best: Plan,
    pub best_metrics: PlanMetrics,
    /// (llm_pp, encoder_pps, iteration_ms, tput_per_gpu) per candidate.
    pub frontier: Vec<(usize, Vec<usize>, f64, f64)>,
}

/// Worst per-stage fwd+bwd time of `layers` split into `pp` stages
/// (frozen-aware, the partitioner Cornstarch plugs in).
fn stage_time(layers: &[LayerCost], pp: usize, grad_ckpt: bool) -> f64 {
    let costs: Vec<f64> =
        layers.iter().map(|l| l.fwd_ms + l.bwd_ms(grad_ckpt)).collect();
    let bounds = partition_min_max(&costs, pp);
    stage_sums(layers, &bounds, grad_ckpt)
        .iter()
        .map(|s| s.total())
        .fold(0.0, f64::max)
}

/// Encoder stage count whose per-stage time is closest to `target` without
/// exceeding the device budget (`get_parallel_model(e, target_stage_time)`
/// of Algorithm 1 line 6).
fn match_encoder_pp(
    layers: &[LayerCost],
    target_ms: f64,
    max_pp: usize,
    grad_ckpt: bool,
) -> usize {
    let mut best = 1usize;
    let mut best_err = f64::INFINITY;
    for pp in 1..=max_pp.min(layers.len()) {
        let t = stage_time(layers, pp, grad_ckpt);
        let err = (t - target_ms).abs();
        if err < best_err {
            best_err = err;
            best = pp;
        }
    }
    best
}

/// Algorithm 1. `gpu_budget` bounds the total device-group count
/// (`llm_pp + Σ enc_pp`); `tp`/`cp` are fixed per the §6.1 setup. The
/// paper caps each modality at 6 stages — we accept any `max_pp`.
pub fn auto_parallelize(
    mm: &MultimodalModule,
    gpu_budget_groups: usize,
    tp: usize,
    cp: usize,
    max_pp: usize,
    device: Device,
) -> AutoResult {
    assert!(gpu_budget_groups >= 1 + mm.encoders.len());
    let grad_ckpt = true;
    let llm_layers = super::planner::llm_layer_costs(mm, device, tp * cp);
    let enc_layers: Vec<Vec<LayerCost>> = mm
        .encoders
        .iter()
        .map(|e| {
            super::planner::encoder_layer_costs(e, &mm.llm.geom, device, tp * cp)
        })
        .collect();

    let mut frontier = Vec::new();
    let mut best: Option<(Plan, PlanMetrics)> = None;
    let llm_max =
        max_pp.min(llm_layers.len()).min(gpu_budget_groups - mm.encoders.len());
    for llm_pp in 1..=llm_max {
        // line 4: t_i — per-stage fwd+bwd of this LLM option
        let t_i = stage_time(&llm_layers, llm_pp, grad_ckpt);
        // line 6: match each encoder to the target stage time
        let groups_left = gpu_budget_groups - llm_pp;
        let per_enc_cap = if mm.encoders.is_empty() {
            0
        } else {
            // leave one group for every other encoder
            groups_left.saturating_sub(mm.encoders.len() - 1)
        };
        let enc_pps: Vec<usize> = enc_layers
            .iter()
            .map(|l| {
                match_encoder_pp(l, t_i, per_enc_cap.min(max_pp), grad_ckpt)
            })
            .collect();
        if llm_pp + enc_pps.iter().sum::<usize>() > gpu_budget_groups {
            continue;
        }
        // lines 8-9: evaluate the combination end-to-end
        let spec =
            MultimodalParallelSpec::paper_default(&enc_pps, llm_pp, tp, cp);
        let p = plan(Strategy::Cornstarch, mm, &spec, device);
        let m = p.simulate();
        frontier.push((
            llm_pp,
            enc_pps.clone(),
            m.iteration_ms,
            m.throughput_per_gpu,
        ));
        let better = match &best {
            None => true,
            Some((_, bm)) => m.iteration_ms < bm.iteration_ms,
        };
        if better {
            best = Some((p, m));
        }
    }
    let (best, best_metrics) = best.expect("no feasible parallelization");
    AutoResult { best, best_metrics, frontier }
}

/// Convenience: build the spec the winning plan used.
pub fn spec_of(plan: &Plan, tp: usize, cp: usize) -> MultimodalParallelSpec {
    // Recover stage counts per module from the stage names.
    let mut enc_names: Vec<String> = Vec::new();
    let mut enc_counts: Vec<usize> = Vec::new();
    let mut llm_pp = 0usize;
    for n in &plan.stage_names {
        if let Some(rest) = n.strip_prefix("enc:") {
            let name = rest.split('[').next().unwrap().to_string();
            match enc_names.iter().position(|x| *x == name) {
                Some(i) => enc_counts[i] += 1,
                None => {
                    enc_names.push(name);
                    enc_counts.push(1);
                }
            }
        } else if n.starts_with("llm[") {
            llm_pp += 1;
        }
    }
    MultimodalParallelSpec {
        encoder_specs: enc_counts
            .iter()
            .map(|&pp| ParallelSpec::new(tp, cp, pp))
            .collect(),
        llm_spec: ParallelSpec::new(tp, cp, llm_pp),
        num_microbatches: plan.num_microbatches,
        comm_ms: plan.graph.comm_ms,
        grad_ckpt: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{MllmSpec, Size};

    #[test]
    fn auto_finds_feasible_plan_within_budget() {
        let mm = MultimodalModule::from_spec(&MllmSpec::valm(
            Size::M,
            Size::M,
            Size::M,
        ));
        let r = auto_parallelize(&mm, 6, 2, 2, 6, Device::a40());
        let groups: usize = r
            .best
            .graph
            .nodes
            .iter()
            .map(|n| n.device + 1)
            .max()
            .unwrap();
        assert!(groups <= 6);
        assert!(!r.frontier.is_empty());
        assert!(r.best_metrics.iteration_ms > 0.0);
    }

    #[test]
    fn auto_best_is_frontier_minimum() {
        let mm =
            MultimodalModule::from_spec(&MllmSpec::vlm(Size::S, Size::M));
        let r = auto_parallelize(&mm, 6, 2, 2, 6, Device::a40());
        let min = r
            .frontier
            .iter()
            .map(|f| f.2)
            .fold(f64::INFINITY, f64::min);
        assert!((r.best_metrics.iteration_ms - min).abs() < 1e-9);
    }

    #[test]
    fn auto_gives_llm_more_stages_when_llm_dominates() {
        // LLM-L with a small encoder: the LLM should win most groups.
        let mm =
            MultimodalModule::from_spec(&MllmSpec::vlm(Size::L, Size::S));
        let r = auto_parallelize(&mm, 6, 2, 2, 6, Device::a40());
        let (llm_pp, enc_pps, _, _) = r
            .frontier
            .iter()
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .unwrap()
            .clone();
        assert!(llm_pp > enc_pps[0], "llm {llm_pp} enc {enc_pps:?}");
    }

    #[test]
    fn spec_roundtrip_matches_plan_topology() {
        let mm = MultimodalModule::from_spec(&MllmSpec::valm(
            Size::S,
            Size::S,
            Size::L,
        ));
        let spec = MultimodalParallelSpec::paper_default(&[1, 2], 3, 2, 2);
        let p = plan(Strategy::Cornstarch, &mm, &spec, Device::a40());
        let rt = spec_of(&p, 2, 2);
        assert_eq!(rt.llm_spec.pp, 3);
        assert_eq!(
            rt.encoder_specs.iter().map(|s| s.pp).collect::<Vec<_>>(),
            vec![1, 2]
        );
    }
}
