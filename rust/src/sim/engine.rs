//! Event-driven executor for dependency task graphs.
//!
//! Semantics: a task becomes *ready* when all dependencies have finished
//! (plus per-edge latency). Each device runs one task at a time; when a
//! device is free it starts the ready task with the smallest priority key
//! (1F1B: backward first, then lowest microbatch). Zero-duration tasks
//! (e.g. the skipped backward of a fully-frozen encoder stage, §4.2) are
//! legal and complete instantly.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::pipeline::{TaskKind, TaskSpec};

/// Per-task execution record. Carries enough of the originating
/// [`TaskSpec`] (device, stage, microbatch, kind) that a trace can be
/// decomposed — per device, per 1F1B phase, per stage — without holding
/// on to the task list it was simulated from (see [`crate::profile`]).
#[derive(Clone, Copy, Debug)]
pub struct TaskTrace {
    pub start_ms: f64,
    pub end_ms: f64,
    /// Device the task executed on (index into `device_busy_ms`).
    pub device: usize,
    /// Stage index in the originating [`crate::pipeline::StageGraph`].
    pub stage: usize,
    pub microbatch: usize,
    /// Forward or backward (§4.2 frozen backwards appear with 0 ms).
    pub kind: TaskKind,
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub makespan_ms: f64,
    pub device_busy_ms: Vec<f64>,
    pub trace: Vec<TaskTrace>,
}

/// Ordered-f64 wrapper for heap keys.
#[derive(PartialEq, PartialOrd)]
struct F(f64);
impl Eq for F {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for F {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap()
    }
}

/// Run the simulation. Panics on dependency cycles (tasks that never
/// become ready).
pub fn simulate(tasks: &[TaskSpec]) -> SimResult {
    let n = tasks.len();
    let n_dev = tasks.iter().map(|t| t.device + 1).max().unwrap_or(0);
    let mut indegree = vec![0usize; n];
    let mut dependents: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
    for (i, t) in tasks.iter().enumerate() {
        indegree[i] = t.deps.len();
        for &(d, lat) in &t.deps {
            dependents[d].push((i, lat));
        }
    }

    // ready_at[i): time the task's last dependency (incl. latency) cleared.
    let mut ready_at = vec![0.0f64; n];
    // Per-device ready queues ordered by (priority, ready_at) — min-heaps.
    let mut queues: Vec<BinaryHeap<Reverse<((u8, usize), F, usize)>>> =
        (0..n_dev).map(|_| BinaryHeap::new()).collect();
    for (i, t) in tasks.iter().enumerate() {
        if indegree[i] == 0 {
            queues[t.device].push(Reverse((t.priority, F(0.0), i)));
        }
    }

    let mut device_free = vec![0.0f64; n_dev];
    let mut device_busy = vec![0.0f64; n_dev];
    let mut trace: Vec<TaskTrace> = tasks
        .iter()
        .map(|t| TaskTrace {
            start_ms: 0.0,
            end_ms: 0.0,
            device: t.device,
            stage: t.stage,
            microbatch: t.microbatch,
            kind: t.kind,
        })
        .collect();
    let mut done = vec![false; n];
    let mut n_done = 0usize;

    // Event heap: (finish_time, task).
    let mut events: BinaryHeap<Reverse<(F, usize)>> = BinaryHeap::new();

    // Greedy device dispatch at current time.
    fn dispatch(
        now: f64,
        dev: usize,
        tasks: &[TaskSpec],
        queues: &mut [BinaryHeap<Reverse<((u8, usize), F, usize)>>],
        device_free: &mut [f64],
        device_busy: &mut [f64],
        ready_at: &[f64],
        trace: &mut [TaskTrace],
        events: &mut BinaryHeap<Reverse<(F, usize)>>,
    ) {
        if device_free[dev] > now + 1e-12 {
            return;
        }
        // Pop tasks whose ready_at <= now; if the head is ready in the
        // future, we cannot start it yet (it re-enters consideration when
        // its enabling event fires).
        let mut deferred = Vec::new();
        let mut chosen = None;
        while let Some(Reverse((prio, F(r), i))) = queues[dev].pop() {
            if r <= now + 1e-12 {
                chosen = Some(i);
                break;
            }
            deferred.push(Reverse((prio, F(r), i)));
        }
        for d in deferred {
            queues[dev].push(d);
        }
        if let Some(i) = chosen {
            let start = now.max(ready_at[i]);
            let end = start + tasks[i].dur_ms;
            trace[i].start_ms = start;
            trace[i].end_ms = end;
            device_free[dev] = end;
            device_busy[dev] += tasks[i].dur_ms;
            events.push(Reverse((F(end), i)));
        }
    }

    // Kick off all devices at t=0.
    for dev in 0..n_dev {
        dispatch(
            0.0, dev, tasks, &mut queues, &mut device_free, &mut device_busy,
            &ready_at, &mut trace, &mut events,
        );
    }

    let mut makespan = 0.0f64;
    while let Some(Reverse((F(now), i))) = events.pop() {
        if done[i] {
            continue;
        }
        done[i] = true;
        n_done += 1;
        makespan = makespan.max(trace[i].end_ms);
        // Release dependents.
        for &(j, lat) in &dependents[i] {
            indegree[j] -= 1;
            ready_at[j] = ready_at[j].max(now + lat);
            if indegree[j] == 0 {
                queues[tasks[j].device].push(Reverse((
                    tasks[j].priority,
                    F(ready_at[j]),
                    j,
                )));
            }
        }
        // This device is free now; also devices whose queued tasks just
        // became ready may be idle — dispatch everywhere cheaply.
        for dev in 0..n_dev {
            dispatch(
                now, dev, tasks, &mut queues, &mut device_free,
                &mut device_busy, &ready_at, &mut trace, &mut events,
            );
        }
        // Some tasks may be ready only at now+lat with idle devices and no
        // further events; schedule a wake-up via a zero-task trick: handled
        // by dispatching at the *next* event anyway — ensure progress by
        // inserting a synthetic event at the earliest future ready time if
        // all devices idle and no events pending.
        if events.is_empty() && n_done < n {
            let mut min_ready = f64::INFINITY;
            let mut any = false;
            for q in &queues {
                if let Some(Reverse((_, F(r), _))) = q.peek() {
                    min_ready = min_ready.min(*&r.clone());
                    any = true;
                }
            }
            if any && min_ready.is_finite() {
                for dev in 0..n_dev {
                    dispatch(
                        min_ready, dev, tasks, &mut queues, &mut device_free,
                        &mut device_busy, &ready_at, &mut trace, &mut events,
                    );
                }
            }
        }
    }

    assert_eq!(
        n_done, n,
        "simulation deadlock: {} of {n} tasks completed (cycle in deps?)",
        n_done
    );

    SimResult { makespan_ms: makespan, device_busy_ms: device_busy, trace }
}

/// The dependency edges of a task list, flattened as `(from, to)` pairs
/// (`to` waits for `from`). This is the adjacency a static analyzer
/// ([`crate::verify`]) walks without re-deriving the simulator's
/// internal structures; out-of-range indices are kept as-is so callers
/// can lint them instead of panicking.
pub fn dependency_edges(tasks: &[TaskSpec]) -> Vec<(usize, usize)> {
    let mut edges = Vec::new();
    for (i, t) in tasks.iter().enumerate() {
        for &(d, _) in &t.deps {
            edges.push((d, i));
        }
    }
    edges
}

/// Emit a simulated schedule into the telemetry trace sink as
/// virtual-time slices: one Chrome-trace lane per simulated device, one
/// `X` slice per executed fwd/bwd task (simulated ms mapped to trace
/// µs). No-op while tracing is off; zero-duration tasks (skipped frozen
/// backwards) are elided. `stage_names[t.stage]` labels the slice when
/// available.
pub fn emit_timeline(
    result: &SimResult,
    tasks: &[TaskSpec],
    stage_names: &[String],
) {
    if !crate::telemetry::trace_enabled() {
        return;
    }
    for (task, tr) in tasks.iter().zip(&result.trace) {
        if task.dur_ms <= 0.0 {
            continue;
        }
        let kind = match task.kind {
            crate::pipeline::TaskKind::Fwd => "fwd",
            crate::pipeline::TaskKind::Bwd => "bwd",
        };
        let stage = stage_names
            .get(task.stage)
            .map(String::as_str)
            .unwrap_or("stage");
        crate::telemetry::slice(
            &format!("{kind} {stage} mb{}", task.microbatch),
            task.device as u64,
            (tr.start_ms * 1000.0) as u64,
            ((tr.end_ms - tr.start_ms) * 1000.0) as u64,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{
        onef1b_tasks, StageCost, StageGraph, TaskKind, TaskSpec,
    };

    fn t(
        device: usize,
        dur: f64,
        deps: Vec<(usize, f64)>,
        prio: (u8, usize),
    ) -> TaskSpec {
        TaskSpec {
            kind: TaskKind::Fwd,
            stage: 0,
            microbatch: 0,
            device,
            dur_ms: dur,
            deps,
            priority: prio,
        }
    }

    #[test]
    fn serial_chain() {
        let tasks = vec![
            t(0, 1.0, vec![], (0, 0)),
            t(0, 2.0, vec![(0, 0.0)], (0, 1)),
            t(0, 3.0, vec![(1, 0.0)], (0, 2)),
        ];
        let r = simulate(&tasks);
        assert!((r.makespan_ms - 6.0).abs() < 1e-9);
        assert!((r.device_busy_ms[0] - 6.0).abs() < 1e-9);
    }

    #[test]
    fn parallel_devices() {
        let tasks = vec![t(0, 5.0, vec![], (0, 0)), t(1, 3.0, vec![], (0, 0))];
        let r = simulate(&tasks);
        assert!((r.makespan_ms - 5.0).abs() < 1e-9);
    }

    #[test]
    fn edge_latency_delays_start() {
        let tasks = vec![
            t(0, 1.0, vec![], (0, 0)),
            t(1, 1.0, vec![(0, 2.5)], (0, 0)),
        ];
        let r = simulate(&tasks);
        assert!((r.trace[1].start_ms - 3.5).abs() < 1e-9);
        assert!((r.makespan_ms - 4.5).abs() < 1e-9);
    }

    #[test]
    fn priority_breaks_ties() {
        // Two ready tasks on one device: lower priority key first.
        let tasks = vec![
            t(0, 1.0, vec![], (1, 5)),
            t(0, 1.0, vec![], (0, 9)),
        ];
        let r = simulate(&tasks);
        assert!(r.trace[1].start_ms < r.trace[0].start_ms);
    }

    #[test]
    fn zero_duration_tasks_complete() {
        let tasks = vec![
            t(0, 0.0, vec![], (0, 0)),
            t(0, 1.0, vec![(0, 0.0)], (0, 1)),
        ];
        let r = simulate(&tasks);
        assert!((r.makespan_ms - 1.0).abs() < 1e-9);
    }

    #[test]
    fn dependency_edges_flatten_in_task_order() {
        let tasks = vec![
            t(0, 1.0, vec![], (0, 0)),
            t(0, 1.0, vec![(0, 0.0)], (0, 1)),
            t(1, 1.0, vec![(0, 0.5), (1, 0.0)], (0, 0)),
        ];
        assert_eq!(dependency_edges(&tasks), vec![(0, 1), (0, 2), (1, 2)]);
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn detects_cycles() {
        let tasks = vec![
            t(0, 1.0, vec![(1, 0.0)], (0, 0)),
            t(0, 1.0, vec![(0, 0.0)], (0, 1)),
        ];
        simulate(&tasks);
    }

    /// The textbook sanity check: a homogeneous 1F1B pipeline's iteration
    /// time is (M + S - 1)·(f+b) for M microbatches, S stages, when fwd
    /// and bwd times are equal per stage... with f != b the classic bound
    /// is (S-1)·(f+b) warmup+drain plus M·(f+b) steady state on the
    /// bottleneck stage.
    #[test]
    fn onef1b_chain_matches_analytic_bound() {
        let s = 4;
        let m = 8;
        let f = 1.0;
        let b = 2.0;
        let mut g = StageGraph::default();
        g.add_chain(
            "llm",
            &vec![StageCost { fwd_ms: f, bwd_ms: b }; s],
            0,
            &[],
        );
        let r = simulate(&onef1b_tasks(&g, m));
        let ideal = (m as f64) * (f + b) + (s as f64 - 1.0) * (f + b);
        assert!(
            (r.makespan_ms - ideal).abs() < 1e-6,
            "got {} want {ideal}",
            r.makespan_ms
        );
    }

    /// Modality parallelism (Fig 6b): two encoders on their own devices
    /// run concurrently; makespan < running them via a fused sequential
    /// chain (encoders-colocated on one device).
    #[test]
    fn modality_parallel_beats_colocated_encoders() {
        let m = 4;
        let enc = StageCost { fwd_ms: 2.0, bwd_ms: 0.0 };
        let llm = StageCost { fwd_ms: 1.0, bwd_ms: 1.0 };

        // modality-parallel: vision dev0, audio dev1, llm dev2..3
        let mut gmp = StageGraph::default();
        let v = gmp.add_chain("vision", &[enc], 0, &[]);
        let a = gmp.add_chain("audio", &[enc], 1, &[]);
        gmp.add_chain("llm", &[llm, llm], 2, &[v[0], a[0]]);
        let r_mp = simulate(&onef1b_tasks(&gmp, m));

        // colocated: both encoders fused into one stage (sequential) on
        // dev0, llm dev1..2 — one fewer device but 2x encoder stage time.
        let fused = StageCost { fwd_ms: 4.0, bwd_ms: 0.0 };
        let mut gco = StageGraph::default();
        let c = gco.add_chain("encoders", &[fused], 0, &[]);
        gco.add_chain("llm", &[llm, llm], 1, &[c[0]]);
        let r_co = simulate(&onef1b_tasks(&gco, m));

        assert!(
            r_mp.makespan_ms < r_co.makespan_ms,
            "mp {} vs co {}",
            r_mp.makespan_ms,
            r_co.makespan_ms
        );
    }
}
