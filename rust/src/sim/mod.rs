//! Discrete-event cluster simulator.
//!
//! Executes a dependency task graph ([`crate::pipeline::TaskSpec`]) over
//! devices with greedy per-device priority scheduling, producing the
//! iteration timeline the paper's evaluation figures are built from:
//! makespan (iteration time), per-device busy/idle (pipeline bubbles),
//! and a per-task trace for schedule visualization (Figure 2/6/7 style).

pub mod engine;
pub mod metrics;

pub use engine::{dependency_edges, emit_timeline, simulate, SimResult, TaskTrace};
pub use metrics::{bubble_fraction, throughput_per_gpu};
