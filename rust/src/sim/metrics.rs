//! Derived metrics over simulation results.

use super::engine::SimResult;

/// Fraction of device-time spent idle (pipeline bubbles) across **all**
/// devices in the result.
///
/// Semantics note: this used to drop zero-busy devices from the
/// denominator, which silently hid stranded hardware — a plan that left
/// a device fully idle looked *better* than one that gave it a little
/// work. A fully idle device now counts as 100% bubble, matching the
/// `bubble_ratio` reported by `Plan::simulate` (busy over
/// `makespan × n_devices`).
pub fn bubble_fraction(r: &SimResult) -> f64 {
    let n = r.device_busy_ms.len();
    if n == 0 || r.makespan_ms == 0.0 {
        return 0.0;
    }
    let busy: f64 = r.device_busy_ms.iter().sum();
    let capacity = r.makespan_ms * n as f64;
    (capacity - busy) / capacity
}

/// Samples/s/GPU given `samples` processed per iteration and `n_gpus`
/// total (the paper normalizes throughput by GPU count because
/// configurations use different numbers of GPUs, §6.1).
pub fn throughput_per_gpu(r: &SimResult, samples: usize, n_gpus: usize) -> f64 {
    samples as f64 / (r.makespan_ms / 1e3) / n_gpus as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::TaskKind;
    use crate::sim::engine::TaskTrace;

    fn res(makespan: f64, busy: Vec<f64>) -> SimResult {
        SimResult {
            makespan_ms: makespan,
            device_busy_ms: busy,
            trace: vec![TaskTrace {
                start_ms: 0.0,
                end_ms: 0.0,
                device: 0,
                stage: 0,
                microbatch: 0,
                kind: TaskKind::Fwd,
            }],
        }
    }

    #[test]
    fn no_bubbles_when_fully_busy() {
        let r = res(10.0, vec![10.0, 10.0]);
        assert!(bubble_fraction(&r).abs() < 1e-12);
    }

    #[test]
    fn half_idle() {
        let r = res(10.0, vec![10.0, 0.0, 5.0]);
        // 15 busy of 3*10 capacity: the idle device counts
        assert!((bubble_fraction(&r) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fully_idle_device_is_all_bubble() {
        let r = res(10.0, vec![0.0]);
        assert!((bubble_fraction(&r) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_normalizes_by_gpus() {
        let r = res(1000.0, vec![1000.0]);
        assert!((throughput_per_gpu(&r, 24, 24) - 1.0).abs() < 1e-12);
        assert!((throughput_per_gpu(&r, 24, 12) - 2.0).abs() < 1e-12);
    }
}
