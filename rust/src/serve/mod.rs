//! `cornstarch serve` — planning as a long-lived service.
//!
//! A zero-dependency line-protocol TCP server over the planning facade
//! ([`crate::api::PlanningService`]): one JSON object per request line,
//! one JSON object per response line. Because every request runs inside
//! the same process, the two-tier plan store ([`crate::tuner::PlanStore`])
//! answers warm repeats from its in-process map without touching disk,
//! and identical concurrent requests coalesce onto a single search via
//! the in-flight dedupe table — the service gets strictly cheaper the
//! longer it lives, which is the point of running it as one.
//!
//! ## Protocol
//!
//! Requests are newline-delimited JSON objects:
//!
//! ```json
//! {"mllm": "VLM-M", "llm": "M", "devices": 16, "budget": 32,
//!  "top": 1, "threads": 4, "objective": "makespan",
//!  "cluster_file": "examples/clusters/a40.json"}
//! ```
//!
//! Only `mllm` is required; every other field falls back to the same
//! defaults the `cornstarch tune` CLI uses (and `cluster_file` to the
//! cluster the server was started with). The response is a single line:
//!
//! ```json
//! {"ok": true, "mllm": "VLM-M", "plan": "<winner label>",
//!  "cache_hit": false, "iteration_ms": 123.4, "signature": "…",
//!  "report": "<rendered PlanReport text>", "stats": {…}}
//! ```
//!
//! or `{"ok": false, "error": "…"}` on any parse or planning failure —
//! a bad request never kills the connection, only that line. Blank
//! lines are ignored, so `printf '…\n' | nc` style clients work as-is.
//!
//! Each connection gets its own handler thread; a connection may
//! pipeline any number of request lines. The server stops when
//! [`ServerHandle::shutdown`] is called or after `max_requests` total
//! request lines (the CI smoke test's exit condition).

use std::io::{BufRead, BufReader, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::api::{ClusterSpec, PlanRequest, PlanningService};
use crate::model::{MllmSpec, Size};
use crate::telemetry::{self, key as tkey};
use crate::tuner::Objective;
use crate::util::json::Json;

/// Server-level defaults applied to every request that doesn't override
/// them.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Persistent cache file shared by every request (`--cache`). When
    /// absent the server still shares one in-process plan store across
    /// requests ([`crate::api::CachePolicy::Memory`]) — warm hits and
    /// in-flight dedupe work either way; only durability differs.
    pub cache: Option<String>,
    /// Cluster requests plan against unless they name a `cluster_file`.
    pub cluster: ClusterSpec,
    /// Search-thread default for requests that don't set `threads`
    /// (0 = leave the facade's own default).
    pub threads: usize,
    /// Stop after this many request lines (`--max-requests`; CI smoke).
    pub max_requests: Option<u64>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            cache: None,
            cluster: ClusterSpec::a40_default(),
            threads: 0,
            max_requests: None,
        }
    }
}

/// Remote control for a running [`Server`] — owns no socket, safe to
/// clone into handler threads and tests.
#[derive(Clone)]
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ServerHandle {
    /// Ask the accept loop to exit. Idempotent; wakes a blocked
    /// `accept()` by self-connecting.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // The accept loop re-checks the flag after every connection;
        // this throwaway connect is only there to unblock it.
        let _ = TcpStream::connect(self.addr);
    }
}

/// A bound-but-not-yet-running planning server.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    opts: Arc<ServeOpts>,
    stop: Arc<AtomicBool>,
    served: Arc<AtomicU64>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:7070`; port 0 picks a free one).
    pub fn bind(addr: &str, opts: ServeOpts) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(Server {
            listener,
            addr,
            opts: Arc::new(opts),
            stop: Arc::new(AtomicBool::new(false)),
            served: Arc::new(AtomicU64::new(0)),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// A clonable handle that can stop this server from another thread.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { stop: Arc::clone(&self.stop), addr: self.addr }
    }

    /// Serve until [`ServerHandle::shutdown`] or the `max_requests`
    /// budget is exhausted. Blocks the calling thread; one handler
    /// thread per connection. Returns the number of request lines
    /// answered.
    pub fn run(self) -> std::io::Result<u64> {
        telemetry::info(&format!(
            "serving on {} (cache: {}, cluster: {})",
            self.addr,
            self.opts.cache.as_deref().unwrap_or("in-memory"),
            self.opts.cluster.name,
        ));
        let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        loop {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let (stream, peer) = match self.listener.accept() {
                Ok(conn) => conn,
                Err(e) => {
                    if self.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    return Err(e);
                }
            };
            if self.stop.load(Ordering::SeqCst) {
                // The connection that woke us was shutdown()'s nudge
                // (or arrived with it); the budget is spent either way.
                break;
            }
            telemetry::debug(&format!("serve: connection from {peer}"));
            let opts = Arc::clone(&self.opts);
            let served = Arc::clone(&self.served);
            let handle = self.handle();
            workers.retain(|w| !w.is_finished());
            workers.push(std::thread::spawn(move || {
                handle_connection(stream, &opts, &served, &handle);
            }));
        }
        for w in workers {
            let _ = w.join();
        }
        let n = self.served.load(Ordering::SeqCst);
        telemetry::info(&format!("serve: done after {n} request(s)"));
        Ok(n)
    }
}

/// Read newline-delimited requests off one connection until EOF, the
/// stop flag, or the request budget; answer each with one JSON line.
fn handle_connection(
    stream: TcpStream,
    opts: &ServeOpts,
    served: &AtomicU64,
    handle: &ServerHandle,
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            telemetry::debug(&format!("serve: clone failed: {e}"));
            return;
        }
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        if handle.stop.load(Ordering::SeqCst) {
            break;
        }
        // Claim a budget ticket before planning so concurrent
        // connections can't run past --max-requests together.
        let ticket = served.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some(max) = opts.max_requests {
            if ticket > max {
                served.fetch_sub(1, Ordering::SeqCst);
                break;
            }
        }
        telemetry::incr(tkey::SERVE_REQUESTS);
        let response = respond_line(&line, opts);
        let ok = writer
            .write_all(response.as_bytes())
            .and_then(|()| writer.write_all(b"\n"))
            .and_then(|()| writer.flush())
            .is_ok();
        if opts.max_requests.is_some_and(|max| ticket >= max) {
            handle.shutdown();
            break;
        }
        if !ok {
            break;
        }
    }
}

/// Answer one request line — the whole protocol minus the sockets
/// (tests drive this directly). Always returns a single-line JSON
/// object; errors come back as `{"ok":false,"error":…}`.
pub fn respond_line(line: &str, opts: &ServeOpts) -> String {
    let answer = match build_request(line, opts) {
        Ok(req) => PlanningService::new()
            .plan(&req)
            .map(|report| render_response(&req, &report))
            .map_err(|e| format!("{e}")),
        Err(e) => Err(e),
    };
    match answer {
        Ok(json) => json,
        Err(msg) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(msg)),
        ])
        .render(),
    }
}

/// Parse one request line into the same [`PlanRequest`] the CLI builds.
pub fn build_request(
    line: &str,
    opts: &ServeOpts,
) -> Result<PlanRequest, String> {
    let j = Json::parse(line).map_err(|e| format!("bad request: {e}"))?;
    let name = j
        .get("mllm")
        .and_then(Json::as_str)
        .ok_or_else(|| "missing required field \"mllm\"".to_string())?;
    let llm = match j.get("llm").and_then(Json::as_str) {
        Some(s) => Size::parse(s)
            .ok_or_else(|| format!("bad \"llm\" {s:?} (S|M|L)"))?,
        None => Size::M,
    };
    let spec = MllmSpec::parse_name(name, llm)?;
    let cluster = match j.get("cluster_file").and_then(Json::as_str) {
        Some(p) => ClusterSpec::load(std::path::Path::new(p))
            .map_err(|e| format!("loading cluster {p:?}: {e}"))?,
        None => opts.cluster.clone(),
    };
    let mut req = PlanRequest::default_for(spec).cluster(cluster);
    req = match &opts.cache {
        Some(path) => req.cache_file(path),
        None => req.cache_memory(),
    };
    if opts.threads > 0 {
        req = req.threads(opts.threads);
    }
    if let Some(d) = field_usize(&j, "devices")? {
        req = req.devices(d);
    }
    if let Some(b) = field_usize(&j, "budget")? {
        req = req.budget(b);
    }
    if let Some(t) = field_usize(&j, "threads")? {
        req = req.threads(t);
    }
    if let Some(t) = field_usize(&j, "top")? {
        req = req.top(t.max(1));
    }
    if let Some(o) = j.get("objective").and_then(Json::as_str) {
        req = req.objective(Objective::parse(o).ok_or_else(|| {
            format!("bad \"objective\" {o:?} (makespan|tput-per-gpu)")
        })?);
    }
    Ok(req)
}

fn field_usize(j: &Json, key: &str) -> Result<Option<usize>, String> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => {
            let n = v
                .as_i64()
                .filter(|n| *n >= 0)
                .ok_or_else(|| {
                    format!("\"{key}\" wants a non-negative integer")
                })?;
            Ok(Some(n as usize))
        }
    }
}

/// The success response: identity + the one-line numbers a client
/// dashboards on + the full rendered report (byte-identical to what a
/// one-shot `cornstarch tune` prints for the same request).
fn render_response(
    req: &PlanRequest,
    report: &crate::api::PlanReport,
) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("mllm", Json::Str(req.mllm.name())),
        (
            "plan",
            Json::Str(report.winner().candidate.label()),
        ),
        ("cache_hit", Json::Bool(report.provenance.cache_hit)),
        (
            "iteration_ms",
            Json::Num(report.timeline.iteration_ms),
        ),
        (
            "signature",
            Json::Str(report.provenance.signature.clone()),
        ),
        ("report", Json::Str(report.render())),
        ("stats", report.provenance.stats.to_json()),
    ])
    .render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> ServeOpts {
        ServeOpts {
            cluster: ClusterSpec::a40_default().with_devices(8),
            ..ServeOpts::default()
        }
    }

    #[test]
    fn build_request_applies_fields_and_defaults() {
        let req = build_request(
            r#"{"mllm":"VLM-S","llm":"S","budget":4,"threads":2,
                "top":3,"objective":"makespan"}"#,
            &opts(),
        )
        .unwrap();
        assert_eq!(req.mllm.name(), "VLM-S");
        assert_eq!(req.budget, 4);
        assert_eq!(req.threads, 2);
        assert_eq!(req.top, 3);
        assert_eq!(req.cluster.devices(), 8);

        let bare = build_request(r#"{"mllm":"ALM-M"}"#, &opts()).unwrap();
        assert_eq!(bare.mllm.name(), "ALM-M");
        assert_eq!(bare.cluster.devices(), 8);
    }

    #[test]
    fn bad_requests_become_error_lines_not_panics() {
        for line in [
            "not json",
            r#"{"llm":"M"}"#,
            r#"{"mllm":"XLM-M"}"#,
            r#"{"mllm":"VLM-M","llm":"Q"}"#,
            r#"{"mllm":"VLM-M","budget":-1}"#,
            r#"{"mllm":"VLM-M","objective":"fastest"}"#,
        ] {
            let resp = respond_line(line, &opts());
            let j = Json::parse(&resp).expect("error responses are JSON");
            assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
            assert!(
                j.get("error").and_then(Json::as_str).is_some(),
                "{line} -> {resp}"
            );
        }
    }

    #[test]
    fn respond_line_plans_and_reports() {
        let o = opts();
        let line = r#"{"mllm":"VLM-S","llm":"S","budget":4,"threads":1}"#;
        let resp = respond_line(line, &o);
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("mllm").and_then(Json::as_str), Some("VLM-S"));
        assert!(j.get("plan").and_then(Json::as_str).is_some());
        assert!(j.get("report").and_then(Json::as_str).is_some());
        assert!(j.get("stats").is_some());
        assert!(j.get("signature").and_then(Json::as_str).is_some());
    }

    #[test]
    fn server_answers_over_a_real_socket_and_honors_max_requests() {
        use std::io::{BufRead, BufReader, Write};
        let server = Server::bind(
            "127.0.0.1:0",
            ServeOpts {
                cluster: ClusterSpec::a40_default().with_devices(8),
                max_requests: Some(2),
                ..ServeOpts::default()
            },
        )
        .unwrap();
        let addr = server.local_addr();
        let runner = std::thread::spawn(move || server.run().unwrap());

        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let line = "{\"mllm\":\"VLM-S\",\"llm\":\"S\",\"budget\":4,\
                    \"threads\":1}\n";
        for _ in 0..2 {
            stream.write_all(line.as_bytes()).unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            let j = Json::parse(resp.trim()).unwrap();
            assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        }
        // Budget of 2 is spent: the accept loop exits on its own.
        assert_eq!(runner.join().unwrap(), 2);
    }

    #[test]
    fn shutdown_handle_stops_an_idle_server() {
        let server =
            Server::bind("127.0.0.1:0", ServeOpts::default()).unwrap();
        let handle = server.handle();
        let runner = std::thread::spawn(move || server.run().unwrap());
        handle.shutdown();
        assert_eq!(runner.join().unwrap(), 0);
    }
}
