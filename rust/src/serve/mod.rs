//! `cornstarch serve` — planning as a long-lived service.
//!
//! A zero-dependency line-protocol TCP server over the planning facade
//! ([`crate::api::PlanningService`]): one JSON object per request line,
//! one JSON object per response line. Because every request runs inside
//! the same process, the two-tier plan store ([`crate::tuner::PlanStore`])
//! answers warm repeats from its in-process map without touching disk,
//! and identical concurrent requests coalesce onto a single search via
//! the in-flight dedupe table — the service gets strictly cheaper the
//! longer it lives, which is the point of running it as one.
//!
//! ## Protocol
//!
//! Requests are newline-delimited JSON objects:
//!
//! ```json
//! {"mllm": "VLM-M", "llm": "M", "devices": 16, "budget": 32,
//!  "top": 1, "threads": 4, "objective": "makespan",
//!  "cluster_file": "examples/clusters/a40.json"}
//! ```
//!
//! Only `mllm` is required; every other field falls back to the same
//! defaults the `cornstarch tune` CLI uses (and `cluster_file` to the
//! cluster the server was started with). The response is a single line:
//!
//! ```json
//! {"ok": true, "mllm": "VLM-M", "plan": "<winner label>",
//!  "cache_hit": false, "iteration_ms": 123.4, "signature": "…",
//!  "report": "<rendered PlanReport text>", "stats": {…}}
//! ```
//!
//! or `{"ok": false, "error": "…"}` on any parse or planning failure —
//! a bad request never kills the connection, only that line. Blank
//! lines are ignored, so `printf '…\n' | nc` style clients work as-is.
//!
//! A request line carrying a `tenants` array is a **fleet** request and
//! runs through [`PlanningService::plan_fleet`] — the very same carve
//! search, caches, and in-flight dedupe the one-shot `cornstarch fleet`
//! uses, so a served fleet report is byte-identical to the CLI's:
//!
//! ```json
//! {"tenants": ["VLM-S", "ALM-S"], "llm": "S", "floor": 0.25,
//!  "budget": 4, "threads": 2, "search_mode": "auto"}
//! ```
//!
//! Tenant entries are either workload names (deduplicated with a `#i`
//! suffix, LLM size from the top-level `llm`) or objects
//! `{"name": …, "mllm": …, "llm": …}`. The response line is
//! `{"ok": true, "fleet": true, "carve": …, "aggregate_throughput": …,
//! "search_mode": …, "report": …, "stats": …}`.
//!
//! Each connection gets its own handler thread; a connection may
//! pipeline any number of request lines. The server stops when
//! [`ServerHandle::shutdown`] is called or after `max_requests` total
//! request lines (the CI smoke test's exit condition).

use std::io::{BufRead, BufReader, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::api::{
    ClusterSpec, FleetReport, FleetRequest, PlanRequest, PlanningService,
    SearchMode,
};
use crate::model::{MllmSpec, Size};
use crate::telemetry::{self, key as tkey};
use crate::tuner::Objective;
use crate::util::json::Json;

/// Server-level defaults applied to every request that doesn't override
/// them.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Persistent cache file shared by every request (`--cache`). When
    /// absent the server still shares one in-process plan store across
    /// requests ([`crate::api::CachePolicy::Memory`]) — warm hits and
    /// in-flight dedupe work either way; only durability differs.
    pub cache: Option<String>,
    /// Cluster requests plan against unless they name a `cluster_file`.
    pub cluster: ClusterSpec,
    /// Search-thread default for requests that don't set `threads`
    /// (0 = leave the facade's own default).
    pub threads: usize,
    /// Stop after this many request lines (`--max-requests`; CI smoke).
    pub max_requests: Option<u64>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            cache: None,
            cluster: ClusterSpec::a40_default(),
            threads: 0,
            max_requests: None,
        }
    }
}

/// Remote control for a running [`Server`] — owns no socket, safe to
/// clone into handler threads and tests.
#[derive(Clone)]
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ServerHandle {
    /// Ask the accept loop to exit. Idempotent; wakes a blocked
    /// `accept()` by self-connecting.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // The accept loop re-checks the flag after every connection;
        // this throwaway connect is only there to unblock it.
        let _ = TcpStream::connect(self.addr);
    }
}

/// A bound-but-not-yet-running planning server.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    opts: Arc<ServeOpts>,
    stop: Arc<AtomicBool>,
    served: Arc<AtomicU64>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:7070`; port 0 picks a free one).
    pub fn bind(addr: &str, opts: ServeOpts) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(Server {
            listener,
            addr,
            opts: Arc::new(opts),
            stop: Arc::new(AtomicBool::new(false)),
            served: Arc::new(AtomicU64::new(0)),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// A clonable handle that can stop this server from another thread.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { stop: Arc::clone(&self.stop), addr: self.addr }
    }

    /// Serve until [`ServerHandle::shutdown`] or the `max_requests`
    /// budget is exhausted. Blocks the calling thread; one handler
    /// thread per connection. Returns the number of request lines
    /// answered.
    pub fn run(self) -> std::io::Result<u64> {
        telemetry::info(&format!(
            "serving on {} (cache: {}, cluster: {})",
            self.addr,
            self.opts.cache.as_deref().unwrap_or("in-memory"),
            self.opts.cluster.name,
        ));
        let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        loop {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let (stream, peer) = match self.listener.accept() {
                Ok(conn) => conn,
                Err(e) => {
                    if self.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    return Err(e);
                }
            };
            if self.stop.load(Ordering::SeqCst) {
                // The connection that woke us was shutdown()'s nudge
                // (or arrived with it); the budget is spent either way.
                break;
            }
            telemetry::debug(&format!("serve: connection from {peer}"));
            let opts = Arc::clone(&self.opts);
            let served = Arc::clone(&self.served);
            let handle = self.handle();
            workers.retain(|w| !w.is_finished());
            workers.push(std::thread::spawn(move || {
                handle_connection(stream, &opts, &served, &handle);
            }));
        }
        for w in workers {
            let _ = w.join();
        }
        let n = self.served.load(Ordering::SeqCst);
        telemetry::info(&format!("serve: done after {n} request(s)"));
        Ok(n)
    }
}

/// Read newline-delimited requests off one connection until EOF, the
/// stop flag, or the request budget; answer each with one JSON line.
fn handle_connection(
    stream: TcpStream,
    opts: &ServeOpts,
    served: &AtomicU64,
    handle: &ServerHandle,
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            telemetry::debug(&format!("serve: clone failed: {e}"));
            return;
        }
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        if handle.stop.load(Ordering::SeqCst) {
            break;
        }
        // Claim a budget ticket before planning so concurrent
        // connections can't run past --max-requests together.
        let ticket = served.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some(max) = opts.max_requests {
            if ticket > max {
                served.fetch_sub(1, Ordering::SeqCst);
                break;
            }
        }
        telemetry::incr(tkey::SERVE_REQUESTS);
        let response = respond_line(&line, opts);
        let ok = writer
            .write_all(response.as_bytes())
            .and_then(|()| writer.write_all(b"\n"))
            .and_then(|()| writer.flush())
            .is_ok();
        if opts.max_requests.is_some_and(|max| ticket >= max) {
            handle.shutdown();
            break;
        }
        if !ok {
            break;
        }
    }
}

/// Answer one request line — the whole protocol minus the sockets
/// (tests drive this directly). Always returns a single-line JSON
/// object; errors come back as `{"ok":false,"error":…}`.
pub fn respond_line(line: &str, opts: &ServeOpts) -> String {
    // A `tenants` array marks a fleet request; everything else is the
    // single-model plan protocol.
    let is_fleet = Json::parse(line)
        .ok()
        .is_some_and(|j| j.get("tenants").is_some());
    let answer = if is_fleet {
        build_fleet_request(line, opts).and_then(|freq| {
            PlanningService::new()
                .plan_fleet(&freq)
                .map(|report| render_fleet_response(&report))
                .map_err(|e| format!("{e}"))
        })
    } else {
        match build_request(line, opts) {
            Ok(req) => PlanningService::new()
                .plan(&req)
                .map(|report| render_response(&req, &report))
                .map_err(|e| format!("{e}")),
            Err(e) => Err(e),
        }
    };
    match answer {
        Ok(json) => json,
        Err(msg) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(msg)),
        ])
        .render(),
    }
}

/// Parse one request line into the same [`PlanRequest`] the CLI builds.
pub fn build_request(
    line: &str,
    opts: &ServeOpts,
) -> Result<PlanRequest, String> {
    let j = Json::parse(line).map_err(|e| format!("bad request: {e}"))?;
    let name = j
        .get("mllm")
        .and_then(Json::as_str)
        .ok_or_else(|| "missing required field \"mllm\"".to_string())?;
    let llm = match j.get("llm").and_then(Json::as_str) {
        Some(s) => Size::parse(s)
            .ok_or_else(|| format!("bad \"llm\" {s:?} (S|M|L)"))?,
        None => Size::M,
    };
    let spec = MllmSpec::parse_name(name, llm)?;
    let cluster = match j.get("cluster_file").and_then(Json::as_str) {
        Some(p) => ClusterSpec::load(std::path::Path::new(p))
            .map_err(|e| format!("loading cluster {p:?}: {e}"))?,
        None => opts.cluster.clone(),
    };
    let mut req = PlanRequest::default_for(spec).cluster(cluster);
    req = match &opts.cache {
        Some(path) => req.cache_file(path),
        None => req.cache_memory(),
    };
    if opts.threads > 0 {
        req = req.threads(opts.threads);
    }
    if let Some(d) = field_usize(&j, "devices")? {
        req = req.devices(d);
    }
    if let Some(b) = field_usize(&j, "budget")? {
        req = req.budget(b);
    }
    if let Some(t) = field_usize(&j, "threads")? {
        req = req.threads(t);
    }
    if let Some(t) = field_usize(&j, "top")? {
        req = req.top(t.max(1));
    }
    if let Some(o) = j.get("objective").and_then(Json::as_str) {
        req = req.objective(Objective::parse(o).ok_or_else(|| {
            format!("bad \"objective\" {o:?} (makespan|tput-per-gpu)")
        })?);
    }
    Ok(req)
}

/// Parse one fleet request line into the same [`FleetRequest`] the
/// `cornstarch fleet` CLI builds — the served carve is the carve the
/// one-shot command would have printed.
pub fn build_fleet_request(
    line: &str,
    opts: &ServeOpts,
) -> Result<FleetRequest, String> {
    let j = Json::parse(line).map_err(|e| format!("bad request: {e}"))?;
    let entries = j
        .get("tenants")
        .and_then(Json::as_arr)
        .ok_or_else(|| "\"tenants\" wants an array".to_string())?;
    if entries.is_empty() {
        return Err("\"tenants\" wants at least one entry".to_string());
    }
    let cluster = match j.get("cluster_file").and_then(Json::as_str) {
        Some(p) => ClusterSpec::load(std::path::Path::new(p))
            .map_err(|e| format!("loading cluster {p:?}: {e}"))?,
        None => opts.cluster.clone(),
    };
    let default_llm = match j.get("llm").and_then(Json::as_str) {
        Some(s) => Size::parse(s)
            .ok_or_else(|| format!("bad \"llm\" {s:?} (S|M|L)"))?,
        None => Size::M,
    };
    let floor = match j.get("floor") {
        None | Some(Json::Null) => 0.25,
        Some(v) => v
            .as_f64()
            .ok_or_else(|| "\"floor\" wants a number".to_string())?,
    };
    let budget = field_usize(&j, "budget")?;
    let threads = field_usize(&j, "threads")?;
    let mut freq = FleetRequest::new(cluster).fairness_floor(floor);
    freq = match &opts.cache {
        Some(path) => freq.cache_file(path),
        None => freq.cache_memory(),
    };
    let mut names: Vec<String> = Vec::new();
    for (i, entry) in entries.iter().enumerate() {
        let (label, mllm_name, llm) = match entry {
            Json::Str(s) => (None, s.clone(), default_llm),
            Json::Obj(_) => {
                let m = entry
                    .get("mllm")
                    .and_then(Json::as_str)
                    .ok_or_else(|| {
                        format!("tenant #{i} is missing \"mllm\"")
                    })?
                    .to_string();
                let llm = match entry.get("llm").and_then(Json::as_str) {
                    Some(s) => Size::parse(s).ok_or_else(|| {
                        format!("tenant #{i}: bad \"llm\" {s:?} (S|M|L)")
                    })?,
                    None => default_llm,
                };
                let label = entry
                    .get("name")
                    .and_then(Json::as_str)
                    .map(str::to_string);
                (label, m, llm)
            }
            other => {
                return Err(format!(
                    "tenant #{i} wants a workload name or an object, \
                     got {}",
                    other.render()
                ))
            }
        };
        let spec = MllmSpec::parse_name(&mllm_name, llm)?;
        let base = label.unwrap_or_else(|| mllm_name.clone());
        let name = if names.iter().any(|n| *n == base) {
            format!("{base}#{i}")
        } else {
            base
        };
        names.push(name.clone());
        let mut preq = PlanRequest::default_for(spec);
        if let Some(b) = budget {
            preq = preq.budget(b);
        }
        match threads {
            Some(t) => preq = preq.threads(t),
            None if opts.threads > 0 => {
                preq = preq.threads(opts.threads);
            }
            None => {}
        }
        freq = freq.tenant(&name, preq);
    }
    if let Some(m) = j.get("search_mode").and_then(Json::as_str) {
        if m != "auto" {
            freq =
                freq.search_mode(SearchMode::parse(m).ok_or_else(|| {
                    format!(
                        "bad \"search_mode\" {m:?} (exact|bnb|local|auto)"
                    )
                })?);
        }
    }
    if let Some(cap) = field_usize(&j, "search_evals")? {
        freq = freq.search_evals(cap);
    }
    Ok(freq)
}

fn field_usize(j: &Json, key: &str) -> Result<Option<usize>, String> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => {
            let n = v
                .as_i64()
                .filter(|n| *n >= 0)
                .ok_or_else(|| {
                    format!("\"{key}\" wants a non-negative integer")
                })?;
            Ok(Some(n as usize))
        }
    }
}

/// The success response: identity + the one-line numbers a client
/// dashboards on + the full rendered report (byte-identical to what a
/// one-shot `cornstarch tune` prints for the same request).
fn render_response(
    req: &PlanRequest,
    report: &crate::api::PlanReport,
) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("mllm", Json::Str(req.mllm.name())),
        (
            "plan",
            Json::Str(report.winner().candidate.label()),
        ),
        ("cache_hit", Json::Bool(report.provenance.cache_hit)),
        (
            "iteration_ms",
            Json::Num(report.timeline.iteration_ms),
        ),
        (
            "signature",
            Json::Str(report.provenance.signature.clone()),
        ),
        ("report", Json::Str(report.render())),
        ("stats", report.provenance.stats.to_json()),
    ])
    .render()
}

/// The fleet success response: the carve and aggregate a dashboard
/// wants, plus the full rendered report (byte-identical to a one-shot
/// [`PlanningService::plan_fleet`] on the same request).
fn render_fleet_response(report: &FleetReport) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("fleet", Json::Bool(true)),
        ("carve", Json::Str(report.partition.label())),
        (
            "aggregate_throughput",
            Json::Num(report.aggregate_throughput),
        ),
        (
            "search_mode",
            Json::Str(report.provenance.search_mode.name().to_string()),
        ),
        ("report", Json::Str(report.render())),
        ("stats", report.provenance.stats.to_json()),
    ])
    .render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> ServeOpts {
        ServeOpts {
            cluster: ClusterSpec::a40_default().with_devices(8),
            ..ServeOpts::default()
        }
    }

    #[test]
    fn build_request_applies_fields_and_defaults() {
        let req = build_request(
            r#"{"mllm":"VLM-S","llm":"S","budget":4,"threads":2,
                "top":3,"objective":"makespan"}"#,
            &opts(),
        )
        .unwrap();
        assert_eq!(req.mllm.name(), "VLM-S");
        assert_eq!(req.budget, 4);
        assert_eq!(req.threads, 2);
        assert_eq!(req.top, 3);
        assert_eq!(req.cluster.devices(), 8);

        let bare = build_request(r#"{"mllm":"ALM-M"}"#, &opts()).unwrap();
        assert_eq!(bare.mllm.name(), "ALM-M");
        assert_eq!(bare.cluster.devices(), 8);
    }

    #[test]
    fn bad_requests_become_error_lines_not_panics() {
        for line in [
            "not json",
            r#"{"llm":"M"}"#,
            r#"{"mllm":"XLM-M"}"#,
            r#"{"mllm":"VLM-M","llm":"Q"}"#,
            r#"{"mllm":"VLM-M","budget":-1}"#,
            r#"{"mllm":"VLM-M","objective":"fastest"}"#,
        ] {
            let resp = respond_line(line, &opts());
            let j = Json::parse(&resp).expect("error responses are JSON");
            assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
            assert!(
                j.get("error").and_then(Json::as_str).is_some(),
                "{line} -> {resp}"
            );
        }
    }

    #[test]
    fn build_fleet_request_parses_tenants_and_knobs() {
        let freq = build_fleet_request(
            r#"{"tenants":["VLM-S",{"mllm":"ALM-S","name":"audio"},
                "VLM-S"],"llm":"S","floor":0.5,"budget":4,"threads":2,
                "search_mode":"bnb","search_evals":64}"#,
            &opts(),
        )
        .unwrap();
        let names: Vec<&str> =
            freq.tenants.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, ["VLM-S", "audio", "VLM-S#2"]);
        assert_eq!(freq.fairness_floor, 0.5);
        assert_eq!(freq.search_mode, Some(SearchMode::BranchAndBound));
        assert_eq!(freq.search_evals, Some(64));
        for t in &freq.tenants {
            assert_eq!(t.request.budget, 4);
            assert_eq!(t.request.threads, 2);
        }

        // Defaults: floor 0.25, auto mode, server cluster.
        let bare =
            build_fleet_request(r#"{"tenants":["ALM-S"]}"#, &opts())
                .unwrap();
        assert_eq!(bare.fairness_floor, 0.25);
        assert_eq!(bare.search_mode, None);
        assert_eq!(bare.cluster.devices(), 8);
    }

    #[test]
    fn bad_fleet_requests_become_error_lines() {
        for line in [
            r#"{"tenants":"VLM-S"}"#,
            r#"{"tenants":[]}"#,
            r#"{"tenants":[7]}"#,
            r#"{"tenants":[{"name":"x"}]}"#,
            r#"{"tenants":["VLM-S"],"floor":"high"}"#,
            r#"{"tenants":["VLM-S"],"search_mode":"psychic"}"#,
        ] {
            let resp = respond_line(line, &opts());
            let j = Json::parse(&resp).unwrap();
            assert_eq!(
                j.get("ok").and_then(Json::as_bool),
                Some(false),
                "{line} -> {resp}"
            );
            assert!(j.get("error").and_then(Json::as_str).is_some());
        }
    }

    #[test]
    fn respond_line_carves_fleets_too() {
        let line = r#"{"tenants":["VLM-S","ALM-S"],"llm":"S",
            "floor":0.0,"budget":4,"threads":1}"#;
        let resp = respond_line(line, &opts());
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("fleet").and_then(Json::as_bool), Some(true));
        assert!(j.get("carve").and_then(Json::as_str).is_some());
        assert_eq!(
            j.get("search_mode").and_then(Json::as_str),
            Some("exact")
        );
        let text = j.get("report").and_then(Json::as_str).unwrap();
        assert!(text.contains("VLM-S") && text.contains("ALM-S"));
        assert!(j.get("stats").is_some());
    }

    #[test]
    fn respond_line_plans_and_reports() {
        let o = opts();
        let line = r#"{"mllm":"VLM-S","llm":"S","budget":4,"threads":1}"#;
        let resp = respond_line(line, &o);
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("mllm").and_then(Json::as_str), Some("VLM-S"));
        assert!(j.get("plan").and_then(Json::as_str).is_some());
        assert!(j.get("report").and_then(Json::as_str).is_some());
        assert!(j.get("stats").is_some());
        assert!(j.get("signature").and_then(Json::as_str).is_some());
    }

    #[test]
    fn server_answers_over_a_real_socket_and_honors_max_requests() {
        use std::io::{BufRead, BufReader, Write};
        let server = Server::bind(
            "127.0.0.1:0",
            ServeOpts {
                cluster: ClusterSpec::a40_default().with_devices(8),
                max_requests: Some(2),
                ..ServeOpts::default()
            },
        )
        .unwrap();
        let addr = server.local_addr();
        let runner = std::thread::spawn(move || server.run().unwrap());

        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let line = "{\"mllm\":\"VLM-S\",\"llm\":\"S\",\"budget\":4,\
                    \"threads\":1}\n";
        for _ in 0..2 {
            stream.write_all(line.as_bytes()).unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            let j = Json::parse(resp.trim()).unwrap();
            assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        }
        // Budget of 2 is spent: the accept loop exits on its own.
        assert_eq!(runner.join().unwrap(), 2);
    }

    #[test]
    fn shutdown_handle_stops_an_idle_server() {
        let server =
            Server::bind("127.0.0.1:0", ServeOpts::default()).unwrap();
        let handle = server.handle();
        let runner = std::thread::spawn(move || server.run().unwrap());
        handle.shutdown();
        assert_eq!(runner.join().unwrap(), 0);
    }
}
