//! The four token-distribution algorithms compared in Table 4.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::Distribution;
use crate::util::rng::Rng;

/// Enum-dispatch over the distribution algorithms (object safety not
/// needed; benches iterate a `Vec<Algorithm>`).
#[derive(Clone, Debug)]
pub enum Algorithm {
    Lpt,
    Random { seed: u64 },
    Zigzag,
    Ring,
}

impl Algorithm {
    pub fn assign(&self, w: &[u64], g: usize) -> Vec<usize> {
        match self {
            Algorithm::Lpt => lpt(w, g),
            Algorithm::Random { seed } => random(w, g, *seed),
            Algorithm::Zigzag => zigzag(w, g),
            Algorithm::Ring => ring(w, g),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Lpt => "LPT",
            Algorithm::Random { .. } => "Random",
            Algorithm::Zigzag => "Zigzag",
            Algorithm::Ring => "Naive Ring",
        }
    }
}

impl Distribution for Algorithm {
    fn assign(&self, w: &[u64], g: usize) -> Vec<usize> {
        Algorithm::assign(self, w, g)
    }
    fn name(&self) -> &'static str {
        Algorithm::name(self)
    }
}

/// Greedy Longest-Processing-Time-First (the paper's Algorithm 2).
///
/// Sort blocks by workload descending; pop the least-loaded rank from a
/// min-heap for each block. `O(B log B + B log G)`; Graham's bound puts
/// the result within `(4/3 − 1/3G)·OPT`, and within `mean + t_max` of
/// perfect balance — negligible as `T` grows (§4.3.2).
pub fn lpt(w: &[u64], g: usize) -> Vec<usize> {
    assert!(g > 0);
    let mut order: Vec<usize> = (0..w.len()).collect();
    order.sort_unstable_by_key(|&i| Reverse(w[i]));
    // Min-heap of (load, rank); Reverse for min-ordering. Ties broken by
    // rank id for determinism.
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> =
        (0..g).map(|r| Reverse((0u64, r))).collect();
    let mut assign = vec![0usize; w.len()];
    for i in order {
        let Reverse((load, r)) = heap.pop().expect("g > 0");
        assign[i] = r;
        heap.push(Reverse((load + w[i], r)));
    }
    assign
}

/// Uniform random rank per block (§5.3). For `T >> G²` the Chernoff bound
/// keeps the deviation from perfect balance negligible, and assignment is
/// O(B) with no sort — the paper recommends it when a non-all-gather CP
/// backend makes LPT's bookkeeping impractical.
pub fn random(w: &[u64], g: usize, seed: u64) -> Vec<usize> {
    assert!(g > 0);
    let mut rng = Rng::new(seed);
    (0..w.len()).map(|_| rng.below(g as u64) as usize).collect()
}

/// Zigzag distribution (Figure 4a): split into `2G` contiguous chunks;
/// rank `i` takes chunks `i` and `2G−1−i`. Perfect for causal masks.
pub fn zigzag(w: &[u64], g: usize) -> Vec<usize> {
    assert!(g > 0);
    let b = w.len();
    let chunks = 2 * g;
    let mut assign = vec![0usize; b];
    for (i, a) in assign.iter_mut().enumerate() {
        // chunk of block i with ceil-balanced chunk sizes
        let c = i * chunks / b.max(1);
        let c = c.min(chunks - 1);
        *a = if c < g { c } else { chunks - 1 - c };
    }
    assign
}

/// Naive ring attention placement: `G` contiguous equal chunks.
pub fn ring(w: &[u64], g: usize) -> Vec<usize> {
    assert!(g > 0);
    let b = w.len();
    (0..b).map(|i| (i * g / b.max(1)).min(g - 1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lpt_two_ranks_classic() {
        // workloads 7,6,5,4 -> LPT gives {7,4} and {6,5}: makespan 11.
        let a = lpt(&[7, 6, 5, 4], 2);
        let loads = crate::cp::rank_loads(&[7, 6, 5, 4], &a, 2);
        let mut l = loads.clone();
        l.sort_unstable();
        assert_eq!(l, vec![11, 11]);
    }

    #[test]
    fn lpt_is_deterministic() {
        let w = [3, 1, 4, 1, 5, 9, 2, 6];
        assert_eq!(lpt(&w, 3), lpt(&w, 3));
    }

    #[test]
    fn zigzag_pairs_head_and_tail() {
        // 8 blocks, 2 ranks -> chunks [0,1,2,3] of 2 blocks each;
        // rank0 = chunks 0,3; rank1 = chunks 1,2.
        let a = zigzag(&[1; 8], 2);
        assert_eq!(a, vec![0, 0, 1, 1, 1, 1, 0, 0]);
    }

    #[test]
    fn ring_contiguous() {
        let a = ring(&[1; 6], 3);
        assert_eq!(a, vec![0, 0, 1, 1, 2, 2]);
    }

    #[test]
    fn ring_uneven_lengths() {
        let a = ring(&[1; 7], 3);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "{a:?}");
        assert_eq!(*a.last().unwrap(), 2);
    }

    #[test]
    fn random_deterministic_per_seed() {
        let w = [1u64; 100];
        assert_eq!(random(&w, 4, 9), random(&w, 4, 9));
        assert_ne!(random(&w, 4, 9), random(&w, 4, 10));
    }

    #[test]
    fn single_rank_degenerates() {
        let w = [5u64, 3, 8];
        for alg in [Algorithm::Lpt, Algorithm::Zigzag, Algorithm::Ring] {
            assert_eq!(alg.assign(&w, 1), vec![0, 0, 0], "{}", alg.name());
        }
    }
}
