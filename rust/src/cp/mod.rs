//! Context-parallel token distribution — §4.3.2.
//!
//! Given per-block workloads (row-sums of the BAM mask aggregated at block
//! granularity) and `G` ranks, a [`Distribution`] assigns each block to a
//! rank. Makespan (max per-rank workload) is what the attention step costs,
//! so balancing it is makespan-minimization scheduling (NP-hard; the paper
//! formulates the ILP and solves greedily):
//!
//! * [`lpt`] — the paper's greedy Longest-Processing-Time-First
//!   (Algorithm 2): sort blocks by workload descending, repeatedly give the
//!   next block to the least-loaded rank. Worst case `OPT + t_max`
//!   (Graham), `O(B log B + B log G)` with a binary heap.
//! * [`random`] — §5.3's fallback: uniform random rank per block; within
//!   Chernoff-bound distance of balanced when `T >> G²`.
//! * [`zigzag`] — the LLM-causal baseline (Figure 4a): rank `i` takes
//!   chunks `i` and `2G-1-i` of `2G` contiguous chunks. Perfect for causal
//!   masks, imbalanced for multimodal ones (Figure 4b).
//! * [`ring`] — naive ring attention: contiguous equal chunks.
//! * [`exact`] — branch-and-bound ILP solver for small instances; the
//!   test oracle for LPT's approximation quality.

pub mod algorithms;
pub mod exact;
pub mod metrics;

pub use algorithms::{lpt, random, ring, zigzag, Algorithm};
pub use exact::exact_min_makespan;
pub use metrics::{makespan, rank_loads, Assignment};

/// A token/block distribution policy.
pub trait Distribution {
    /// Map each block index to a rank in `[0, g)`.
    fn assign(&self, block_workloads: &[u64], g: usize) -> Vec<usize>;
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::Rng;

    fn all_algorithms() -> Vec<Algorithm> {
        vec![
            Algorithm::Lpt,
            Algorithm::Random { seed: 7 },
            Algorithm::Zigzag,
            Algorithm::Ring,
        ]
    }

    #[test]
    fn assignments_are_total_and_in_range() {
        check("every block assigned to a valid rank", 40, |gen| {
            let w = gen.vec_u64(1..200, 1000);
            let g = gen.usize(1, 9);
            for alg in all_algorithms() {
                let a = alg.assign(&w, g);
                assert_eq!(a.len(), w.len(), "{}", alg.name());
                assert!(a.iter().all(|&r| r < g), "{}", alg.name());
            }
        });
    }

    #[test]
    fn workload_is_conserved() {
        check("sum of rank loads == total workload", 40, |gen| {
            let w = gen.vec_u64(1..200, 1000);
            let g = gen.usize(1, 9);
            let total: u64 = w.iter().sum();
            for alg in all_algorithms() {
                let a = alg.assign(&w, g);
                let loads = rank_loads(&w, &a, g);
                assert_eq!(loads.iter().sum::<u64>(), total, "{}", alg.name());
            }
        });
    }

    #[test]
    fn lpt_never_worse_than_contiguous_ring() {
        check("LPT makespan <= ring makespan", 40, |gen| {
            let w = gen.vec_u64(8..300, 1000);
            let g = gen.usize(2, 9);
            let m_lpt = makespan(&w, &Algorithm::Lpt.assign(&w, g), g);
            let m_ring = makespan(&w, &Algorithm::Ring.assign(&w, g), g);
            assert!(m_lpt <= m_ring, "lpt {m_lpt} > ring {m_ring}");
        });
    }

    #[test]
    fn lpt_within_graham_bound_of_exact() {
        // LPT <= (4/3 - 1/(3G)) * OPT (Graham 1969).
        check("LPT within Graham bound", 25, |gen| {
            let b = gen.usize(4, 13);
            let w: Vec<u64> = (0..b).map(|_| gen.rng.below(100) + 1).collect();
            let g = gen.usize(2, 5);
            let opt = exact_min_makespan(&w, g);
            let got = makespan(&w, &Algorithm::Lpt.assign(&w, g), g);
            let bound = (4.0 / 3.0 - 1.0 / (3.0 * g as f64)) * opt as f64;
            assert!(
                got as f64 <= bound + 1e-9,
                "LPT {got} vs OPT {opt} (bound {bound})"
            );
        });
    }

    #[test]
    fn zigzag_is_perfect_on_causal_workloads() {
        // Causal text: W_i = i+1. With B = 2G equal-size chunks the zigzag
        // pairing (i, 2G-1-i) gives every rank the same total (Figure 4a).
        for g in [2usize, 4, 8] {
            let b = 2 * g;
            // workload of chunk c of a causal mask with chunk size s:
            // sum_{i=cs}^{cs+s-1} (i+1) — use s=16.
            let s = 16u64;
            let w: Vec<u64> = (0..b as u64)
                .map(|c| (0..s).map(|i| c * s + i + 1).sum())
                .collect();
            let a = Algorithm::Zigzag.assign(&w, g);
            let loads = rank_loads(&w, &a, g);
            assert!(
                loads.iter().all(|&l| l == loads[0]),
                "zigzag causal loads {loads:?}"
            );
        }
    }

    #[test]
    fn random_balances_large_t() {
        // T >> G^2 (paper §5.3): random is close to balanced.
        let mut rng = Rng::new(3);
        let w: Vec<u64> = (0..40_000).map(|_| rng.below(64) + 1).collect();
        let g = 8;
        let a = Algorithm::Random { seed: 11 }.assign(&w, g);
        let loads: Vec<f64> =
            rank_loads(&w, &a, g).iter().map(|&l| l as f64).collect();
        let imb = crate::util::stats::imbalance(&loads);
        assert!(imb < 1.03, "random imbalance {imb}");
    }

    #[test]
    fn lpt_beats_zigzag_on_multimodal_masks() {
        // The paper's core CP claim: on EE/MP masks LPT balances better
        // than zigzag (Table 4 / Figure 12).
        let mut rng = Rng::new(5);
        let mut lpt_wins = 0;
        let n = 20;
        for _ in 0..n {
            let m = crate::bam::generators::random_ee(&mut rng, 4096, 3);
            let w = crate::bam::block_workloads(&m.workloads(), 64);
            let g = 8;
            let m_l = makespan(&w, &Algorithm::Lpt.assign(&w, g), g);
            let m_z = makespan(&w, &Algorithm::Zigzag.assign(&w, g), g);
            if m_l <= m_z {
                lpt_wins += 1;
            }
        }
        assert!(lpt_wins >= n * 9 / 10, "LPT won only {lpt_wins}/{n}");
    }
}
