//! Assignment metrics: per-rank loads, makespan, and the attention-time
//! model used by the Table 4 / Figure 12 reproductions.

/// A block→rank assignment together with its block workloads.
#[derive(Clone, Debug)]
pub struct Assignment {
    pub rank_of_block: Vec<usize>,
    pub g: usize,
}

/// Sum of block workloads per rank.
pub fn rank_loads(w: &[u64], assign: &[usize], g: usize) -> Vec<u64> {
    assert_eq!(w.len(), assign.len());
    let mut loads = vec![0u64; g];
    for (i, &r) in assign.iter().enumerate() {
        loads[r] += w[i];
    }
    loads
}

/// Max per-rank load — the quantity `C` the §4.3.2 ILP minimizes.
pub fn makespan(w: &[u64], assign: &[usize], g: usize) -> u64 {
    rank_loads(w, assign, g).into_iter().max().unwrap_or(0)
}

/// Attention execution-time model for a context-parallel step, ms.
///
/// The all-gather CP implementation (§5.3, Llama-3 style) computes
/// row-wise attention for local tokens against all gathered keys: a rank's
/// time is proportional to its summed row workloads (unmasked (q,k)
/// pairs), plus a per-local-token linear term (projections, softmax
/// normalization) and a fixed launch/collective overhead. Calibrated
/// against the paper's Table 4 (Llama-3.1-70B geometry on A40s); the
/// *relative* numbers are what the reproduction checks.
#[derive(Clone, Copy, Debug)]
pub struct AttnTimeModel {
    /// ms per unmasked (q,k) pair per head-dim-normalized unit.
    pub ms_per_pair: f64,
    /// ms per local query token (projection + rescale work).
    pub ms_per_token: f64,
    /// fixed per-step overhead (launches, all-gather latency), ms.
    pub overhead_ms: f64,
}

impl AttnTimeModel {
    /// Llama-3.1 70B single attention layer, calibrated to the paper's
    /// Table 4 testbed (FlexAttention block-sparse kernels on A40s): the
    /// per-pair rate is fit so the 64k-token EP/LPT row lands at the
    /// paper's ~25 ms, the per-token term covers the non-quadratic share
    /// visible between the 16k and 64k rows. Only *relative* numbers
    /// (which algorithm wins, by what factor) are asserted by tests.
    pub fn llama70b_a40() -> Self {
        AttnTimeModel {
            ms_per_pair: 8.5e-8,
            ms_per_token: 2.5e-4,
            overhead_ms: 0.15,
        }
    }

    /// Time for one rank holding `local_tokens` queries with summed
    /// workload `load` (unmasked pairs).
    pub fn rank_ms(&self, load: u64, local_tokens: u64) -> f64 {
        self.overhead_ms
            + self.ms_per_pair * load as f64
            + self.ms_per_token * local_tokens as f64
    }

    /// Step time = slowest rank (ranks synchronize at the collective).
    pub fn step_ms(&self, loads: &[u64], local_tokens: &[u64]) -> f64 {
        loads
            .iter()
            .zip(local_tokens)
            .map(|(&l, &t)| self.rank_ms(l, t))
            .fold(0.0, f64::max)
    }
}

/// Per-rank local token counts for an assignment over fixed-size blocks.
pub fn rank_tokens(
    assign: &[usize],
    block_size: usize,
    total_tokens: usize,
    g: usize,
) -> Vec<u64> {
    let mut toks = vec![0u64; g];
    for (b, &r) in assign.iter().enumerate() {
        let start = b * block_size;
        let end = ((b + 1) * block_size).min(total_tokens);
        toks[r] += (end - start) as u64;
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_and_makespan() {
        let w = [4u64, 3, 2, 1];
        let a = [0usize, 1, 0, 1];
        assert_eq!(rank_loads(&w, &a, 2), vec![6, 4]);
        assert_eq!(makespan(&w, &a, 2), 6);
    }

    #[test]
    fn rank_tokens_handles_short_tail() {
        // 10 tokens, block 4 -> blocks of 4,4,2
        let toks = rank_tokens(&[0, 1, 0], 4, 10, 2);
        assert_eq!(toks, vec![6, 4]);
    }

    #[test]
    fn step_time_is_max_rank() {
        let m = AttnTimeModel {
            ms_per_pair: 1.0,
            ms_per_token: 0.0,
            overhead_ms: 0.0,
        };
        assert_eq!(m.step_ms(&[3, 9, 1], &[0, 0, 0]), 9.0);
    }

    #[test]
    fn model_orders_match_workload_orders() {
        let m = AttnTimeModel::llama70b_a40();
        assert!(m.rank_ms(1000, 10) < m.rank_ms(5000, 10));
        assert!(m.rank_ms(1000, 10) < m.rank_ms(1000, 50));
    }
}
