//! Exact makespan minimization (the §4.3.2 ILP) via branch-and-bound.
//!
//! Intractable in real time during training (the paper's point), but
//! perfect as a test oracle for LPT's approximation quality on small
//! instances, and as the `reproduce`-harness upper bound.

/// Minimum achievable makespan of assigning `w` blocks to `g` ranks.
/// Exponential in `w.len()` — keep instances small (≤ ~20 blocks).
pub fn exact_min_makespan(w: &[u64], g: usize) -> u64 {
    assert!(g > 0);
    if w.is_empty() {
        return 0;
    }
    let mut items: Vec<u64> = w.to_vec();
    items.sort_unstable_by(|a, b| b.cmp(a)); // big first: better pruning
    let total: u64 = items.iter().sum();
    let lower = (total + g as u64 - 1) / g as u64;
    let lower = lower.max(items[0]);
    // Initial upper bound from LPT.
    let lpt_assign = super::lpt(&items, g);
    let mut best = super::makespan(&items, &lpt_assign, g);
    if best == lower {
        return best;
    }

    let mut loads = vec![0u64; g];
    // Suffix sums for the remaining-work lower bound.
    let mut suffix = vec![0u64; items.len() + 1];
    for i in (0..items.len()).rev() {
        suffix[i] = suffix[i + 1] + items[i];
    }

    fn dfs(
        idx: usize,
        items: &[u64],
        suffix: &[u64],
        loads: &mut [u64],
        g: usize,
        best: &mut u64,
        lower: u64,
    ) {
        if *best == lower {
            return; // proven optimal
        }
        if idx == items.len() {
            let mk = *loads.iter().max().unwrap();
            if mk < *best {
                *best = mk;
            }
            return;
        }
        // Bound: even spreading the rest perfectly cannot beat `need`.
        let cur_max = *loads.iter().max().unwrap();
        let min_load = *loads.iter().min().unwrap();
        let optimistic =
            cur_max.max((min_load * g as u64 + suffix[idx]).div_ceil(g as u64).max(0));
        if optimistic >= *best {
            // Optimistic bound can still not prune if equal; >= prunes ties.
            if cur_max >= *best {
                return;
            }
        }
        let mut tried: Vec<u64> = Vec::with_capacity(g);
        for r in 0..g {
            // Symmetry breaking: identical current loads are equivalent.
            if tried.contains(&loads[r]) {
                continue;
            }
            tried.push(loads[r]);
            if loads[r] + items[idx] >= *best {
                continue;
            }
            loads[r] += items[idx];
            dfs(idx + 1, items, suffix, loads, g, best, lower);
            loads[r] -= items[idx];
        }
    }

    dfs(0, &items, &suffix, &mut loads, g, &mut best, lower);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::{makespan, Algorithm};
    use crate::util::check::check;

    #[test]
    fn trivial_cases() {
        assert_eq!(exact_min_makespan(&[], 3), 0);
        assert_eq!(exact_min_makespan(&[5], 3), 5);
        assert_eq!(exact_min_makespan(&[5, 5, 5], 3), 5);
    }

    #[test]
    fn classic_instance() {
        // 3,3,2,2,2 on 2 ranks: OPT = 6.
        assert_eq!(exact_min_makespan(&[3, 3, 2, 2, 2], 2), 6);
    }

    #[test]
    fn lpt_suboptimal_instance() {
        // Known LPT-suboptimal: {5,5,4,4,3,3} on 2 -> OPT 12, LPT 12? Use
        // {6,5,4,4,2,2,2} g=2: OPT = 12..13. Verify exact <= LPT always.
        let w = [6u64, 5, 4, 4, 2, 2, 2];
        let opt = exact_min_makespan(&w, 2);
        let l = makespan(&w, &Algorithm::Lpt.assign(&w, 2), 2);
        assert!(opt <= l);
        assert_eq!(opt, 13); // total 25 -> ceil(25/2) = 13 achievable
    }

    #[test]
    fn exact_never_above_lpt_and_never_below_mean() {
        check("exact bounds", 30, |g| {
            let b = g.usize(1, 14);
            let w: Vec<u64> = (0..b).map(|_| g.rng.below(50) + 1).collect();
            let ranks = g.usize(1, 5);
            let opt = exact_min_makespan(&w, ranks);
            let l = makespan(&w, &Algorithm::Lpt.assign(&w, ranks), ranks);
            let total: u64 = w.iter().sum();
            assert!(opt <= l);
            assert!(opt >= total.div_ceil(ranks as u64));
            assert!(opt >= w.iter().copied().max().unwrap_or(0));
        });
    }
}
