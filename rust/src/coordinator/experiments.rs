//! One function per table/figure of the paper's evaluation — the
//! `cornstarch reproduce` harness. Each returns both a rendered
//! [`Table`] and structured rows so the criterion benches and integration
//! tests can assert on the numbers.
//!
//! Experiment index (DESIGN.md §Experiments):
//!
//! | id          | paper artifact                  | function              |
//! |-------------|---------------------------------|-----------------------|
//! | `fig2`      | Figure 2 (PP policies, 1F1B)    | [`fig2`]              |
//! | `fig3b`     | Figure 3b (frozen breakdown)    | [`fig3b`]             |
//! | `fig9`      | Figure 9 (VLM/ALM e2e, LLM-M)   | [`fig9_13_14`]        |
//! | `fig13/14`  | Appendix B (LLM-S / LLM-L)      | [`fig9_13_14`]        |
//! | `fig10/15`  | Figure 10 / Appendix B (VALM)   | [`fig10_15`]          |
//! | `table2/7/8`| Tables 2, 7, 8 (modality par.)  | [`table2_7_8`]        |
//! | `table3/10/11`| Tables 3, 10, 11 (frozen PP)  | [`table3_10_11`]      |
//! | `table4`    | Table 4 (CP attention time)     | [`table4`]            |
//! | `fig12`     | Figure 12 (per-rank balance)    | [`fig12`]             |
//! | `auto`      | Algorithm 1 frontier            | [`auto_frontier`]     |
//! | `memory`    | Appendix D (LLM-L OOM verdicts) | [`memory_feasibility`]|
//! | `hetero`    | heterogeneous device pools      | [`hetero_pools`]      |
//! | `fleet`     | multi-tenant pool carving       | [`fleet_planning`]    |
//! | `fleet`     | large-fleet heuristic carving   | [`fleet_scale`]       |
//! | `attn`      | PJRT cross-check of the model   | [`attn_crosscheck`]   |

use crate::bam::{self, Bam};
use crate::cost::Device;
use crate::cp::{metrics::rank_tokens, Algorithm};
use crate::cp::metrics::AttnTimeModel;
use crate::memory;
use crate::modality::{
    auto_parallelize, planner, MultimodalModule, MultimodalParallelSpec,
    Plan, Strategy,
};
use crate::model::{MllmSpec, Size};
use crate::util::rng::Rng;
use crate::util::table::Table;

use super::configs::{
    single_enc_name, validate_llm_l_memory, SingleEncCfg, TABLE2_7_8,
    TABLE5, TABLE6, TABLE9,
};

/// §6.1 defaults: 24 microbatches of 1 sample, tp=2, cp=2.
const MICROBATCHES: usize = 24;

fn spec_single(c: &SingleEncCfg) -> MllmSpec {
    if c.vision {
        MllmSpec::vlm(c.llm, c.enc)
    } else {
        MllmSpec::alm(c.llm, c.enc)
    }
}

fn plan_of(
    strategy: Strategy,
    spec: &MllmSpec,
    enc_pp: &[usize],
    llm_pp: usize,
    tp: usize,
    cp: usize,
) -> Plan {
    let mm = MultimodalModule::from_spec(spec);
    let mut ps = MultimodalParallelSpec::paper_default(enc_pp, llm_pp, tp, cp);
    ps.num_microbatches = MICROBATCHES;
    planner::plan(strategy, &mm, &ps, Device::a40())
}

/// One comparison row used by benches/tests.
#[derive(Clone, Debug)]
pub struct E2eRow {
    pub model: String,
    pub colocated_tput: f64,
    pub replicated_tput: f64,
    pub cornstarch_tput: f64,
}

impl E2eRow {
    pub fn speedup_vs_best_baseline(&self) -> f64 {
        self.cornstarch_tput / self.colocated_tput.max(self.replicated_tput)
    }
}

/// Figure 2: the three pipeline policies on one VLM, 8 microbatches.
/// The paper's caption: encoders-replicated takes 1.57× longer.
pub fn fig2() -> (Table, Vec<(String, f64)>) {
    let spec = MllmSpec::vlm(Size::M, Size::M);
    let mm = MultimodalModule::from_spec(&spec);
    let mut rows = Vec::new();
    let mut t = Table::new(
        "Figure 2 — 1F1B execution of PP policies (VLM-M, 8 microbatches)",
        &["policy", "iteration (ms)", "vs Cornstarch"],
    );
    let mut base = 0.0;
    for (strategy, enc_pp, llm_pp) in [
        (Strategy::Cornstarch, 1usize, 3usize),
        (Strategy::Colocated, 1, 3),
        (Strategy::Replicated, 1, 4),
    ] {
        let mut ps =
            MultimodalParallelSpec::paper_default(&[enc_pp], llm_pp, 2, 2);
        ps.num_microbatches = 8;
        let plan = planner::plan(strategy, &mm, &ps, Device::a40());
        let m = plan.simulate();
        if strategy == Strategy::Cornstarch {
            base = m.iteration_ms;
        }
        t.row(&[
            strategy.name().to_string(),
            format!("{:.1}", m.iteration_ms),
            format!("{:.2}x", m.iteration_ms / base),
        ]);
        rows.push((strategy.name().to_string(), m.iteration_ms));
    }
    (t, rows)
}

/// Figure 3b: the calibrated cost model vs the paper's measured breakdown
/// (CLIP + Mistral-7b on one A40, batch 2, activation checkpointing).
pub fn fig3b() -> Table {
    use crate::cost::{projector_fwd_ms, GradFlow, ModuleCost};
    use crate::model::ModuleGeom;
    let d = Device::a40();
    let mut clip = ModuleGeom::new("CLIP-L", 24, 1024);
    clip.d_ff = 4096;
    let mut mistral = ModuleGeom::new("Mistral-7b", 32, 4096);
    mistral.d_ff = 14336;
    let enc_tokens = 2 * 577;
    let llm_tokens = 2 * 1577;
    let enc = ModuleCost::encoder(clip, enc_tokens, d);
    let llm = ModuleCost::llm(mistral, llm_tokens, d);
    let proj = projector_fwd_ms(1024, 4096, enc_tokens, d);

    let mut t = Table::new(
        "Figure 3b — fwd/bwd breakdown, model vs paper (ms)",
        &["case", "component", "fwd model", "fwd paper", "bwd model", "bwd paper"],
    );
    let frozen_enc = GradFlow { trainable: false, upstream_trainable: false };
    let frozen_llm = GradFlow { trainable: false, upstream_trainable: true };
    let train_flow = GradFlow { trainable: true, upstream_trainable: true };
    let proj_flow = GradFlow { trainable: true, upstream_trainable: false };
    let enc_fwd = enc.module_fwd_ms(1);
    let llm_fwd = llm.module_fwd_ms(1);
    // paper rows: (frozen) enc 67.89/0.01, proj 3.74/9.01, llm 397.11/530.67
    //             (not)    enc 67.94/205.09, proj 3.75/9.47, llm 400.87/1184.65
    let rows: Vec<(&str, &str, f64, f64, f64, f64)> = vec![
        ("frozen", "encoder", enc_fwd, 67.89, frozen_enc.bwd_ms(enc_fwd, false), 0.01),
        ("frozen", "projector", proj, 3.74, proj_flow.bwd_ms(proj, true), 9.01),
        ("frozen", "LLM", llm_fwd, 397.11, frozen_llm.bwd_ms(llm_fwd, false), 530.67),
        ("not frozen", "encoder", enc_fwd, 67.94, train_flow.bwd_ms(enc_fwd, true), 205.09),
        ("not frozen", "projector", proj, 3.75, proj_flow.bwd_ms(proj, true), 9.47),
        ("not frozen", "LLM", llm_fwd, 400.87, train_flow.bwd_ms(llm_fwd, true), 1184.65),
    ];
    for (case, comp, fm, fp, bm, bp) in rows {
        t.row(&[
            case.to_string(),
            comp.to_string(),
            format!("{fm:.2}"),
            format!("{fp:.2}"),
            format!("{bm:.2}"),
            format!("{bp:.2}"),
        ]);
    }
    t
}

/// Figures 9 / 13 / 14: VLM+ALM end-to-end per-GPU throughput for one LLM
/// size, Cornstarch vs both baselines, using the Table 5 configs.
pub fn fig9_13_14(llm: Size) -> (Table, Vec<E2eRow>) {
    let mut t = Table::new(
        &format!(
            "Figure {} — e2e throughput/GPU (input/s), LLM-{}",
            match llm {
                Size::M => "9",
                Size::S => "13",
                Size::L => "14",
            },
            llm.letter()
        ),
        &["model", "colocated", "replicated", "cornstarch", "speedup"],
    );
    let mut rows = Vec::new();
    for c in TABLE5.iter().filter(|c| c.llm == llm) {
        let spec = spec_single(c);
        let col = plan_of(
            Strategy::Colocated,
            &spec,
            &[c.colocated.1],
            c.colocated.0,
            2,
            2,
        )
        .simulate();
        // Encoders-replicated always uses 6 LLM stages (§B.1).
        let rep =
            plan_of(Strategy::Replicated, &spec, &[1], 6, 2, 2).simulate();
        let cs = plan_of(
            Strategy::Cornstarch,
            &spec,
            &[c.cornstarch.1],
            c.cornstarch.0,
            2,
            2,
        )
        .simulate();
        let row = E2eRow {
            model: single_enc_name(c.vision, c.enc),
            colocated_tput: col.throughput_per_gpu,
            replicated_tput: rep.throughput_per_gpu,
            cornstarch_tput: cs.throughput_per_gpu,
        };
        t.row(&[
            row.model.clone(),
            format!("{:.2}", row.colocated_tput),
            format!("{:.2}", row.replicated_tput),
            format!("{:.2}", row.cornstarch_tput),
            format!("{:.2}x", row.speedup_vs_best_baseline()),
        ]);
        rows.push(row);
    }
    (t, rows)
}

/// Figures 10 / 15: VALM end-to-end, Table 6 configs.
pub fn fig10_15(llm: Size) -> (Table, Vec<E2eRow>) {
    let mut t = Table::new(
        &format!(
            "Figure {} — VALM e2e throughput/GPU (input/s), LLM-{}",
            if llm == Size::M { "10" } else { "15" },
            llm.letter()
        ),
        &["model", "colocated", "replicated", "cornstarch", "speedup"],
    );
    let mut rows = Vec::new();
    for c in TABLE6.iter().filter(|c| c.llm == llm) {
        let spec = MllmSpec::valm(c.llm, c.vis, c.aud);
        let col = plan_of(
            Strategy::Colocated,
            &spec,
            &[c.colocated.1, c.colocated.1],
            c.colocated.0,
            2,
            2,
        )
        .simulate();
        let rep =
            plan_of(Strategy::Replicated, &spec, &[1, 1], 6, 2, 2).simulate();
        let cs = plan_of(
            Strategy::Cornstarch,
            &spec,
            &[c.cornstarch.1, c.cornstarch.2],
            c.cornstarch.0,
            2,
            2,
        )
        .simulate();
        let row = E2eRow {
            model: format!("VALM-{}{}", c.vis.letter(), c.aud.letter()),
            colocated_tput: col.throughput_per_gpu,
            replicated_tput: rep.throughput_per_gpu,
            cornstarch_tput: cs.throughput_per_gpu,
        };
        t.row(&[
            row.model.clone(),
            format!("{:.2}", row.colocated_tput),
            format!("{:.2}", row.replicated_tput),
            format!("{:.2}", row.cornstarch_tput),
            format!("{:.2}x", row.speedup_vs_best_baseline()),
        ]);
        rows.push(row);
    }
    (t, rows)
}

/// Tables 2 / 7 / 8: encoders-colocated vs modality parallelism at the
/// paper's stage counts.
pub fn table2_7_8(llm: Size) -> (Table, Vec<(String, f64, f64)>) {
    let id = match llm {
        Size::M => "2",
        Size::S => "7",
        Size::L => "8",
    };
    let mut t = Table::new(
        &format!(
            "Table {id} — colocated vs modality parallelism, LLM-{}",
            llm.letter()
        ),
        &[
            "model", "coloc (L,C)", "tput/GPU", "modality (L,V,A)", "tput/GPU",
        ],
    );
    let mut rows = Vec::new();
    for c in TABLE2_7_8.iter().filter(|c| c.llm == llm) {
        let spec = MllmSpec::valm(c.llm, c.vis, c.aud);
        let col = plan_of(
            Strategy::Colocated,
            &spec,
            &[c.colocated.1, c.colocated.1],
            c.colocated.0,
            2,
            2,
        )
        .simulate();
        let md = plan_of(
            Strategy::Cornstarch,
            &spec,
            &[c.modality.1, c.modality.2],
            c.modality.0,
            2,
            2,
        )
        .simulate();
        let name = format!("VALM-{}{}", c.vis.letter(), c.aud.letter());
        t.row(&[
            name.clone(),
            format!("{}, {}", c.colocated.0, c.colocated.1),
            format!("{:.2}", col.throughput_per_gpu),
            format!("{}, {}, {}", c.modality.0, c.modality.1, c.modality.2),
            format!("{:.2}", md.throughput_per_gpu),
        ]);
        rows.push((name, col.throughput_per_gpu, md.throughput_per_gpu));
    }
    (t, rows)
}

/// Structured row of the frozen-awareness ablation.
#[derive(Clone, Debug)]
pub struct FrozenRow {
    pub model: String,
    pub aware: bool,
    pub enc_fwd: f64,
    pub llm_fwd: f64,
    pub enc_bwd: f64,
    pub llm_bwd: f64,
    pub tput_per_gpu: f64,
}

/// Tables 3 / 10 / 11: frozen-status-aware vs -unaware pipeline
/// partitioning. The policies differ in how many stages each module gets
/// (the §4.2 partitioner balances fwd+bwd; the unaware one balances fwd
/// assuming bwd = 2×fwd) — Table 9 records both policies' resulting stage
/// counts, which we replay. CP = 1 per Appendix D, except LLM-L: the
/// memory model proves CP off exceeds the A40 budget there even at tp=4
/// (`validate_llm_l_memory`), so those rows replay at the cp=2 the
/// validator certifies. The comparison is unaffected — aware and unaware
/// scale identically with CP.
pub fn table3_10_11(llm: Size) -> (Table, Vec<FrozenRow>) {
    let id = match llm {
        Size::M => "3",
        Size::S => "10",
        Size::L => "11",
    };
    let cp = if llm == Size::L {
        // Fail loudly if the geometry drifts from the Appendix D
        // verdicts this cp choice is based on.
        validate_llm_l_memory();
        2
    } else {
        1
    };
    let mut t = Table::new(
        &format!(
            "Table {id} — frozen-aware vs -unaware PP, LLM-{}",
            llm.letter()
        ),
        &[
            "model", "aware", "enc fwd", "llm fwd", "enc bwd", "llm bwd",
            "tput/GPU",
        ],
    );
    let mut rows = Vec::new();
    for c in TABLE9.iter().filter(|c| c.llm == llm) {
        let spec = if c.vision {
            MllmSpec::vlm(c.llm, c.enc)
        } else {
            MllmSpec::alm(c.llm, c.enc)
        };
        let mm = MultimodalModule::from_spec(&spec);
        for (aware, (llm_pp, enc_pp)) in
            [(true, c.aware), (false, c.unaware)]
        {
            let mut ps = MultimodalParallelSpec::paper_default(
                &[enc_pp], llm_pp, c.tp, cp,
            );
            ps.num_microbatches = MICROBATCHES;
            let plan =
                planner::plan(Strategy::Cornstarch, &mm, &ps, Device::a40());
            let m = plan.simulate();
            let enc = plan
                .mean_stage_cost("enc:")
                .unwrap_or(crate::pipeline::StageCost { fwd_ms: 0.0, bwd_ms: 0.0 });
            let lc = plan
                .mean_stage_cost("llm")
                .unwrap_or(crate::pipeline::StageCost { fwd_ms: 0.0, bwd_ms: 0.0 });
            let row = FrozenRow {
                model: single_enc_name(c.vision, c.enc),
                aware,
                enc_fwd: enc.fwd_ms,
                llm_fwd: lc.fwd_ms,
                enc_bwd: enc.bwd_ms,
                llm_bwd: lc.bwd_ms,
                tput_per_gpu: m.throughput_per_gpu,
            };
            t.row(&[
                row.model.clone(),
                if aware { "yes" } else { "no" }.to_string(),
                format!("{:.2}", row.enc_fwd),
                format!("{:.2}", row.llm_fwd),
                format!("{:.2}", row.enc_bwd),
                format!("{:.2}", row.llm_bwd),
                format!("{:.2}", row.tput_per_gpu),
            ]);
            rows.push(row);
        }
    }
    (t, rows)
}

/// Mask family of Table 4 / Figures 11–12.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaskType {
    Ep,
    Ee,
    Mp,
}

impl MaskType {
    pub const ALL: [MaskType; 3] = [MaskType::Ep, MaskType::Ee, MaskType::Mp];

    pub fn name(&self) -> &'static str {
        match self {
            MaskType::Ep => "EP",
            MaskType::Ee => "EE",
            MaskType::Mp => "MP",
        }
    }

    pub fn random(&self, rng: &mut Rng, t: usize) -> Bam {
        match self {
            MaskType::Ep => bam::generators::random_ep(rng, t, 3),
            MaskType::Ee => bam::generators::random_ee(rng, t, 3),
            MaskType::Mp => bam::generators::random_mp(rng, t),
        }
    }
}

/// CP distribution timing for one (mask, algorithm): model-predicted
/// attention step time (ms).
///
/// LPT/zigzag/ring distribute 128-token blocks (§4.3.2: "token assignment
/// is done in block granularity"); the random fallback distributes
/// *tokens* (§5.3: "randomly assigns tokens to GPUs" — the whole point is
/// that per-token randomization needs no workload computation and its
/// variance vanishes for `T >> G²`).
pub fn cp_step_ms(
    mask: &Bam,
    alg: &Algorithm,
    g: usize,
    block: usize,
    model: &AttnTimeModel,
) -> f64 {
    let block = match alg {
        Algorithm::Random { .. } => 1,
        _ => block,
    };
    let w = bam::block_workloads(&mask.workloads(), block);
    let assign = alg.assign(&w, g);
    let loads = crate::cp::rank_loads(&w, &assign, g);
    let toks = rank_tokens(&assign, block, mask.len(), g);
    model.step_ms(&loads, &toks)
}

/// Table 4: mean attention step time over 50 random masks per (length,
/// type), 8 CP ranks, Llama-3.1-70B attention-layer time model.
pub fn table4(runs: usize) -> (Table, Vec<(usize, MaskType, String, f64)>) {
    let g = 8;
    let block = 128;
    let model = AttnTimeModel::llama70b_a40();
    let algs = [
        Algorithm::Lpt,
        Algorithm::Random { seed: 11 },
        Algorithm::Ring,
        Algorithm::Zigzag,
    ];
    let mut t = Table::new(
        "Table 4 — CP attention time (ms), Llama-3.1-70B layer, 8 ranks",
        &["seq len", "mask", "LPT", "Random", "Naive Ring", "Zigzag"],
    );
    let mut rows = Vec::new();
    for &len in &[16384usize, 32768, 65536] {
        for mt in MaskType::ALL {
            let mut sums = [0.0f64; 4];
            for run in 0..runs {
                let mut rng =
                    Rng::new(0xC0FFEE ^ (len as u64) << 8 ^ run as u64);
                let mask = mt.random(&mut rng, len);
                for (i, a) in algs.iter().enumerate() {
                    sums[i] += cp_step_ms(&mask, a, g, block, &model);
                }
            }
            let means: Vec<f64> =
                sums.iter().map(|s| s / runs as f64).collect();
            t.row(&[
                len.to_string(),
                mt.name().to_string(),
                format!("{:.2}", means[0]),
                format!("{:.2}", means[1]),
                format!("{:.2}", means[2]),
                format!("{:.2}", means[3]),
            ]);
            for (i, a) in algs.iter().enumerate() {
                rows.push((len, mt, a.name().to_string(), means[i]));
            }
        }
    }
    (t, rows)
}

/// Figure 12: one sampled 64k mask per type; per-rank execution times for
/// each algorithm (the balance picture).
pub fn fig12() -> Table {
    let g = 8;
    let block = 128;
    let len = 65536;
    let model = AttnTimeModel::llama70b_a40();
    let mut t = Table::new(
        "Figure 12 — per-rank attention time (ms), 64k tokens, 8 ranks",
        &["mask", "algorithm", "ranks (ms)", "max"],
    );
    for mt in MaskType::ALL {
        let mut rng = Rng::new(0xFEED ^ len as u64);
        let mask = mt.random(&mut rng, len);
        let workloads = mask.workloads();
        for a in [
            Algorithm::Lpt,
            Algorithm::Random { seed: 3 },
            Algorithm::Ring,
            Algorithm::Zigzag,
        ] {
            // random distributes tokens, the rest 128-token blocks (§5.3)
            let blk = if matches!(a, Algorithm::Random { .. }) { 1 } else { block };
            let w = bam::block_workloads(&workloads, blk);
            let assign = a.assign(&w, g);
            let loads = crate::cp::rank_loads(&w, &assign, g);
            let toks = rank_tokens(&assign, blk, mask.len(), g);
            let times: Vec<String> = loads
                .iter()
                .zip(&toks)
                .map(|(&l, &tk)| format!("{:.1}", model.rank_ms(l, tk)))
                .collect();
            let max = model.step_ms(&loads, &toks);
            t.row(&[
                mt.name().to_string(),
                a.name().to_string(),
                times.join(" "),
                format!("{max:.1}"),
            ]);
        }
    }
    t
}

/// Algorithm 1 frontier for a given composition and budget.
pub fn auto_frontier(spec: &MllmSpec, groups: usize) -> Table {
    let mm = MultimodalModule::from_spec(spec);
    let r = auto_parallelize(&mm, groups, 2, 2, 6, Device::a40());
    let mut t = Table::new(
        &format!(
            "Algorithm 1 — loosely-coupled auto-parallelization, {} ({} groups)",
            spec.name(),
            groups
        ),
        &["llm pp", "encoder pp", "iteration (ms)", "tput/GPU", "best"],
    );
    let best = r.best_metrics.iteration_ms;
    for (llm_pp, enc_pps, ms, tput) in &r.frontier {
        t.row(&[
            llm_pp.to_string(),
            format!("{enc_pps:?}"),
            format!("{ms:.1}"),
            format!("{tput:.3}"),
            if (*ms - best).abs() < 1e-9 { "<--" } else { "" }.to_string(),
        ]);
    }
    t
}

/// Appendix D's memory feasibility verdicts for the heaviest Table 9 row
/// (VLM-L @ LLM-L, frozen-aware split): the per-device peak of the
/// memory model across TP/CP degrees, against the 40 GB A40 budget.
/// The paper's claim pattern: tp=4 with CP off exceeds the budget, tp=4
/// with cp=2 fits — and tp=2 exceeds either way, which is why Table 9
/// pins tp=4 for LLM-L. Returns `(tp, cp, peak_bytes, fits)` rows.
pub fn memory_feasibility() -> (Table, Vec<(usize, usize, u64, bool)>) {
    validate_llm_l_memory();
    let a40_budget =
        crate::api::ClusterSpec::a40_default().mem_budget_bytes();
    let row = TABLE9
        .iter()
        .find(|c| c.llm == Size::L && c.vision && c.enc == Size::L)
        .expect("Table 9 carries a VLM-L @ LLM-L row");
    let (llm_pp, enc_pp) = row.aware;
    let spec = MllmSpec::vlm(Size::L, Size::L);
    let mut t = Table::new(
        &format!(
            "Appendix D — LLM-L memory feasibility (VLM-L, aware split \
             llm_pp={llm_pp}/enc_pp={enc_pp}, {:.0} GB A40 budget)",
            memory::gb(a40_budget)
        ),
        &["tp", "cp", "peak GB/GPU", "worst stage", "within budget"],
    );
    let mut rows = Vec::new();
    for (tp, cp) in [(2, 1), (2, 2), (4, 1), (4, 2)] {
        let plan = planner::plan_uniform(
            Strategy::Cornstarch,
            &spec,
            enc_pp,
            llm_pp,
            tp,
            cp,
            MICROBATCHES,
            Device::a40(),
        );
        let peak = plan.peak_device_bytes();
        let fits = peak <= a40_budget;
        let worst = plan
            .stage_mem
            .iter()
            .zip(&plan.stage_names)
            .max_by_key(|(s, _)| s.peak_bytes())
            .map(|(_, n)| n.clone())
            .unwrap_or_default();
        t.row(&[
            tp.to_string(),
            cp.to_string(),
            format!("{:.1}", memory::gb(peak)),
            worst,
            if fits { "yes" } else { "no (OOM)" }.to_string(),
        ]);
        rows.push((tp, cp, peak, fits));
    }
    (t, rows)
}

/// Autotuner vs the fixed-policy planners at a device budget: each
/// baseline at its default split, then the searched best (reached
/// through the planning facade, [`crate::api::PlanningService`], like
/// every other tuned-plan consumer). The tuned row must never lose to a
/// baseline on iteration time — the tuner's space is a superset of the
/// baselines' configurations.
pub fn tuner_vs_baselines(
    spec: &MllmSpec,
    devices: usize,
    budget: usize,
) -> (Table, Vec<(String, f64)>) {
    use crate::api::{PlanRequest, PlanningService};
    let mm = MultimodalModule::from_spec(spec);
    let n_enc = mm.encoders.len();
    let groups = devices / 4; // baselines use tp=2, cp=2
    let mut t = Table::new(
        &format!(
            "Autotuner — {} on {} GPUs (budget {} simulations)",
            spec.name(),
            devices,
            budget
        ),
        &["config", "iteration (ms)", "tput/GPU", "GPUs", "peak GB/GPU"],
    );
    let mut rows = Vec::new();
    // Baselines that would exceed the budget at tp=cp=2 are skipped (the
    // tuner itself still searches lower degrees that fit).
    let baselines = [
        (Strategy::Cornstarch, vec![1usize; n_enc], groups.saturating_sub(n_enc)),
        (Strategy::Colocated, vec![1; n_enc], groups.saturating_sub(1)),
        (Strategy::Replicated, Vec::new(), groups),
    ];
    for (strategy, enc_pp, llm_pp) in baselines {
        if llm_pp == 0 {
            continue;
        }
        let mut ps =
            MultimodalParallelSpec::paper_default(&enc_pp, llm_pp, 2, 2);
        ps.num_microbatches = MICROBATCHES;
        let plan = planner::plan(strategy, &mm, &ps, Device::a40());
        let m = plan.simulate();
        t.row(&[
            strategy.name().to_string(),
            format!("{:.1}", m.iteration_ms),
            format!("{:.3}", m.throughput_per_gpu),
            plan.n_gpus.to_string(),
            format!("{:.1}", memory::gb(plan.peak_device_bytes())),
        ]);
        rows.push((strategy.name().to_string(), m.iteration_ms));
    }
    let req = PlanRequest::default_for(spec.clone())
        .devices(devices)
        .budget(budget);
    match PlanningService::new().plan(&req) {
        Ok(report) => {
            let best = report.winner();
            t.row(&[
                format!("tuned: {}", best.candidate.label()),
                format!("{:.1}", best.iteration_ms),
                format!("{:.3}", best.throughput_per_gpu),
                best.n_gpus.to_string(),
                format!("{:.1}", memory::gb(best.peak_mem_bytes)),
            ]);
            rows.push(("tuned".to_string(), best.iteration_ms));
        }
        Err(e) => {
            t.row(&[
                format!("tuned: infeasible ({e})"),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
            ]);
        }
    }
    (t, rows)
}

/// One row of the heterogeneous-pools comparison.
#[derive(Clone, Debug)]
pub struct HeteroRow {
    /// Winner iteration time on the mixed 4×A40 + 4×A100-80G pool.
    pub hetero_ms: f64,
    /// Winner iteration time on the all-A40 pool of the same size.
    pub a40_ms: f64,
    /// Did every LLM stage land on the A100 group?
    pub llm_on_a100: bool,
    /// Did at least one frozen encoder stage land on the A40 group?
    pub encoder_on_a40: bool,
}

/// Heterogeneous pools: tune the paper's VLM-L on the mixed
/// 4×A40 + 4×A100-80G demo pool
/// ([`crate::api::ClusterSpec::a40_a100_demo`],
/// `examples/clusters/a40x4-a100x4.json`) and on an all-A40 pool of the
/// same total size. The searched placement is the hardware dual of the
/// frozen/trainable split (§4.2): the frozen encoder rides the cheap
/// 40 GB cards, the LLM claims the faster 80 GB ones, and the mixed
/// pool beats the homogeneous one on simulated makespan.
pub fn hetero_pools() -> (Table, HeteroRow) {
    use crate::api::{ClusterSpec, PlanRequest, PlanningService};
    let spec = MllmSpec::vlm(Size::M, Size::L);
    let service = PlanningService::new();
    let hetero_cluster = ClusterSpec::a40_a100_demo();
    let hetero = service
        .plan(
            &PlanRequest::default_for(spec.clone())
                .cluster(hetero_cluster.clone()),
        )
        .expect("VLM-L is feasible on the mixed pool");
    let a40 = service
        .plan(
            &PlanRequest::default_for(spec.clone())
                .cluster(ClusterSpec::a40_default().with_devices(8)),
        )
        .expect("VLM-L is feasible on 8 A40s");

    let mut t = Table::new(
        &format!(
            "Heterogeneous pools — {} on {} vs a40x8",
            spec.name(),
            hetero_cluster.name
        ),
        &["stage", "device", "fwd+bwd (ms)", "peak GB/GPU"],
    );
    let mut llm_on_a100 = true;
    let mut encoder_on_a40 = false;
    for (i, name) in hetero.plan.stage_names.iter().enumerate() {
        let g = hetero.plan.stage_groups[i];
        let dev = &hetero_cluster.groups[g].device.name;
        if name.starts_with("llm") && g != 1 {
            llm_on_a100 = false;
        }
        // "enc:" (modality-parallel) or "enc[" (colocated fusion)
        if name.starts_with("enc") && g == 0 {
            encoder_on_a40 = true;
        }
        t.row(&[
            name.clone(),
            dev.clone(),
            format!("{:.1}", hetero.plan.graph.nodes[i].cost.total()),
            format!(
                "{:.1}",
                memory::gb(hetero.plan.stage_mem[i].peak_bytes())
            ),
        ]);
    }
    let row = HeteroRow {
        hetero_ms: hetero.timeline.iteration_ms,
        a40_ms: a40.timeline.iteration_ms,
        llm_on_a100,
        encoder_on_a40,
    };
    t.row(&[
        "mixed-pool iteration".to_string(),
        String::new(),
        format!("{:.1}", row.hetero_ms),
        String::new(),
    ]);
    t.row(&[
        "all-A40 iteration".to_string(),
        String::new(),
        format!("{:.1}", row.a40_ms),
        String::new(),
    ]);
    t.row(&[
        "speedup".to_string(),
        String::new(),
        format!("{:.2}x", row.a40_ms / row.hetero_ms),
        String::new(),
    ]);
    (t, row)
}

/// One row of the fleet-planning comparison (`reproduce fleet`).
#[derive(Clone, Debug)]
pub struct FleetRow {
    /// Aggregate samples/s of the searched carve.
    pub searched_tput: f64,
    /// Aggregate samples/s of the naive static halving.
    pub naive_tput: f64,
    /// The chosen carve — tenant-major, group-minor device counts.
    pub partition: Vec<Vec<usize>>,
    /// Rendered per-tenant `PlanDiff`s from the naive allocation to the
    /// searched one (`cornstarch diff fleet` prints the same delta).
    pub diff: String,
}

/// Fleet planning: two tenants — the motivating pair of a VLM-L finetune
/// and a Whisper-encoder pretrain (Whisper-M under a small LM) — share
/// the mixed 4×A40 + 4×A100-80G pool
/// ([`crate::api::ClusterSpec::a40_a100_demo`]).
/// The searched carve is compared against the naive static halving
/// (every group split 2/2): the halving strands both tenants on 2-device
/// groups where a tp=2 × cp=2 stage cannot even fit, while the searched
/// carve can hand a tenant a whole group. Both allocations share one
/// plan cache — entries are keyed by each sub-pool carve's fingerprint,
/// so the naive evaluation reuses every sub-pool plan the search already
/// made.
pub fn fleet_planning() -> (Table, FleetRow) {
    use crate::api::{
        ClusterSpec, FleetRequest, PlanRequest, PlanningService,
        TenantReport,
    };

    let cluster = ClusterSpec::a40_a100_demo();
    let mut cache = std::env::temp_dir();
    cache.push(format!(
        "cornstarch-fleet-reproduce-{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&cache);
    let cache_s = cache.to_string_lossy().into_owned();
    let tenant = |spec: MllmSpec| {
        PlanRequest::default_for(spec).budget(64).cache_file(&cache_s)
    };
    let freq = FleetRequest::new(cluster)
        .tenant("vlm-finetune", tenant(MllmSpec::vlm(Size::M, Size::L)))
        // the pretrain job trains the Whisper-M encoder under a small
        // LM — the asymmetry (52 GB finetune vs 16 GB pretrain) is what
        // makes the even split wasteful
        .tenant("whisper-pretrain", tenant(MllmSpec::alm(Size::S, Size::M)))
        .fairness_floor(0.25);
    let service = PlanningService::new();
    let searched = service
        .plan_fleet(&freq)
        .expect("both tenants fit the demo pool");
    let naive = service
        .plan_fleet_partition(&freq, &freq.naive_partition())
        .expect("the halved pool hosts both tenants");
    let _ = std::fs::remove_file(&cache);

    let mut t = Table::new(
        "Fleet planning — VLM-L finetune + Whisper-encoder pretrain share \
         a40x4-a100x4",
        &["tenant", "slice", "plan", "iter (ms)", "input/s"],
    );
    let slice_of = |rep: &TenantReport| -> String {
        rep.slice
            .iter()
            .zip(&searched.group_names)
            .map(|(c, g)| format!("{c}x{g}"))
            .collect::<Vec<_>>()
            .join(" + ")
    };
    for rep in &naive.tenants {
        t.row(&[
            format!("naive: {}", rep.name),
            slice_of(rep),
            rep.report.winner().candidate.label(),
            format!("{:.1}", rep.report.timeline.iteration_ms),
            format!("{:.2}", rep.throughput()),
        ]);
    }
    for rep in &searched.tenants {
        t.row(&[
            format!("searched: {}", rep.name),
            slice_of(rep),
            rep.report.winner().candidate.label(),
            format!("{:.1}", rep.report.timeline.iteration_ms),
            format!("{:.2}", rep.throughput()),
        ]);
    }
    t.row(&[
        "naive aggregate".to_string(),
        naive.partition.label(),
        String::new(),
        String::new(),
        format!("{:.2}", naive.aggregate_throughput),
    ]);
    t.row(&[
        "searched aggregate".to_string(),
        searched.partition.label(),
        String::new(),
        String::new(),
        format!("{:.2}", searched.aggregate_throughput),
    ]);
    t.row(&[
        "improvement".to_string(),
        String::new(),
        String::new(),
        String::new(),
        format!(
            "{:.2}x",
            searched.aggregate_throughput / naive.aggregate_throughput
        ),
    ]);

    let diff = searched
        .diff_from(&naive)
        .into_iter()
        .map(|(name, d)| format!("tenant {name}:\n{}", d.render()))
        .collect::<Vec<_>>()
        .join("");
    let row = FleetRow {
        searched_tput: searched.aggregate_throughput,
        naive_tput: naive.aggregate_throughput,
        partition: searched
            .tenants
            .iter()
            .map(|ten| ten.slice.clone())
            .collect(),
        diff,
    };
    (t, row)
}

/// One row of the large-fleet scaling demo (`reproduce fleet`).
#[derive(Clone, Debug)]
pub struct FleetScaleRow {
    /// Size of the exhaustive carve space — why exact enumeration is
    /// off the table for this pool.
    pub carves: u128,
    /// The engine the auto mode degraded to.
    pub search_mode: crate::api::SearchMode,
    /// Carves the heuristic actually examined.
    pub considered: usize,
    /// Aggregate samples/s of the returned carve.
    pub aggregate: f64,
}

/// Large-fleet carving: four tenants share a 36-GPU pool of three
/// 12-device groups (A40 / A100-80G / A40). The carve space is
/// `C(15,3)^3` ≈ 94 M compositions — far past both the exact
/// enumeration cap and the branch-and-bound budget — so auto mode
/// degrades to LPT-seeded local search and the request *plans* instead
/// of refusing (pre-heuristic behaviour was an `InvalidRequest`).
/// Mirrored by `examples/clusters/pool-3x12.json` and the CI fleet
/// smoke step.
pub fn fleet_scale() -> (Table, FleetScaleRow) {
    use crate::api::{
        carve_count, ClusterSpec, DeviceClass, DeviceGroup,
        FleetRequest, PlanRequest, PlanningService, SearchMode,
    };

    let cluster = ClusterSpec {
        name: "pool-3x12".to_string(),
        groups: vec![
            DeviceGroup {
                device: DeviceClass::a40(),
                count: 12,
                link_gbps: 32.0,
            },
            DeviceGroup {
                device: DeviceClass::a100_80g(),
                count: 12,
                link_gbps: 300.0,
            },
            DeviceGroup {
                device: DeviceClass::a40(),
                count: 12,
                link_gbps: 32.0,
            },
        ],
    };
    let tenant =
        |spec: MllmSpec| PlanRequest::default_for(spec).budget(8);
    let mut freq = FleetRequest::new(cluster)
        .fairness_floor(0.0)
        .cache_memory()
        .search_evals(48);
    for (i, spec) in [
        MllmSpec::vlm(Size::S, Size::S),
        MllmSpec::alm(Size::S, Size::S),
        MllmSpec::vlm(Size::S, Size::S),
        MllmSpec::alm(Size::S, Size::S),
    ]
    .into_iter()
    .enumerate()
    {
        freq = freq.tenant(&format!("{}#{i}", spec.name()), tenant(spec));
    }
    let carves = carve_count(&freq.cluster, freq.tenants.len());
    let report = PlanningService::new()
        .plan_fleet(&freq)
        .expect("the 36-GPU pool hosts all four small tenants");
    assert_ne!(
        report.provenance.search_mode,
        SearchMode::Exact,
        "a 94M-carve pool must degrade to a heuristic engine"
    );

    let mut t = Table::new(
        "Fleet at scale — four tenants carve 3 x 12 mixed GPUs \
         heuristically",
        &["tenant", "slice", "plan", "input/s"],
    );
    for rep in &report.tenants {
        t.row(&[
            rep.name.clone(),
            rep.slice
                .iter()
                .zip(&report.group_names)
                .map(|(c, g)| format!("{c}x{g}"))
                .collect::<Vec<_>>()
                .join(" + "),
            rep.report.winner().candidate.label(),
            format!("{:.2}", rep.throughput()),
        ]);
    }
    t.row(&[
        "carve space".to_string(),
        format!("{carves} compositions"),
        String::new(),
        String::new(),
    ]);
    t.row(&[
        "engine".to_string(),
        format!(
            "{} ({} carves considered, {} feasible)",
            report.provenance.search_mode.name(),
            report.provenance.partitions_considered,
            report.provenance.partitions_feasible,
        ),
        String::new(),
        String::new(),
    ]);
    t.row(&[
        "aggregate".to_string(),
        report.partition.label(),
        String::new(),
        format!("{:.2}", report.aggregate_throughput),
    ]);
    let row = FleetScaleRow {
        carves,
        search_mode: report.provenance.search_mode,
        considered: report.provenance.partitions_considered,
        aggregate: report.aggregate_throughput,
    };
    (t, row)
}

/// Table 1: the model zoo geometry.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1 — modality configurations",
        &["arch", "size", "layers", "hidden", "params"],
    );
    for (arch, f) in [
        ("Llama 3.1 (LLM)", crate::model::llama as fn(Size) -> _),
        ("EVA-CLIP (vision)", crate::model::eva_clip),
        ("Whisper (audio)", crate::model::whisper),
    ] {
        for s in Size::ALL {
            let g = f(s);
            t.row(&[
                arch.to_string(),
                s.letter().to_string(),
                g.n_layers.to_string(),
                g.hidden.to_string(),
                format!("{:.1}b", g.params() as f64 / 1e9),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_replicated_slowest_cornstarch_fastest() {
        let (_, rows) = fig2();
        let get = |n: &str| {
            rows.iter().find(|(k, _)| k.contains(n)).unwrap().1
        };
        let cs = get("Cornstarch");
        let co = get("colocated");
        let rep = get("replicated");
        assert!(cs <= co, "cornstarch {cs} vs colocated {co}");
        assert!(co < rep, "colocated {co} vs replicated {rep}");
        // paper: replicated takes ~1.57x longer than the chain policies
        let ratio = rep / co;
        assert!(
            (1.2..2.5).contains(&ratio),
            "replicated/colocated ratio {ratio:.2} out of band"
        );
    }

    #[test]
    fn fig9_cornstarch_wins_on_most_models() {
        let (_, rows) = fig9_13_14(Size::M);
        assert_eq!(rows.len(), 6);
        let wins = rows
            .iter()
            .filter(|r| r.speedup_vs_best_baseline() >= 1.0)
            .count();
        // paper: wins everywhere except VLM-S at LLM-M
        assert!(wins >= 4, "cornstarch won only {wins}/6");
        let max_speedup = rows
            .iter()
            .map(|r| r.speedup_vs_best_baseline())
            .fold(0.0, f64::max);
        assert!(
            max_speedup > 1.1,
            "max speedup {max_speedup:.2} — paper reports up to 1.57x"
        );
    }

    #[test]
    fn fig10_valm_speedups_in_band() {
        let (_, rows) = fig10_15(Size::M);
        assert_eq!(rows.len(), 9);
        let max_speedup = rows
            .iter()
            .map(|r| r.speedup_vs_best_baseline())
            .fold(0.0, f64::max);
        assert!((1.0..2.5).contains(&max_speedup), "{max_speedup}");
    }

    #[test]
    fn table3_aware_beats_unaware_where_paper_says() {
        let (_, rows) = table3_10_11(Size::M);
        // VLM-L: the paper's 1.53x headline. Compare tput aware vs unaware.
        let vlm_l_aware = rows
            .iter()
            .find(|r| r.model == "VLM-L" && r.aware)
            .unwrap();
        let vlm_l_unaware = rows
            .iter()
            .find(|r| r.model == "VLM-L" && !r.aware)
            .unwrap();
        assert!(
            vlm_l_aware.tput_per_gpu > vlm_l_unaware.tput_per_gpu,
            "aware {} <= unaware {}",
            vlm_l_aware.tput_per_gpu,
            vlm_l_unaware.tput_per_gpu
        );
        // Figure 7c signature: aware gives encoder stages more fwd work.
        assert!(vlm_l_aware.enc_fwd > vlm_l_unaware.enc_fwd);
        // encoder bwd is negligible under the frozen recipe
        assert!(vlm_l_aware.enc_bwd < 0.1 * vlm_l_aware.enc_fwd);
    }

    #[test]
    fn table4_lpt_beats_zigzag_on_ee_and_mp() {
        let (_, rows) = table4(8);
        for len in [16384usize, 32768, 65536] {
            for mt in [MaskType::Ee, MaskType::Mp] {
                let get = |alg: &str| {
                    rows.iter()
                        .find(|(l, m, a, _)| {
                            *l == len && *m == mt && a == alg
                        })
                        .unwrap()
                        .3
                };
                let lpt = get("LPT");
                let zz = get("Zigzag");
                let ring = get("Naive Ring");
                assert!(
                    lpt <= zz * 1.02,
                    "{len}/{:?}: LPT {lpt:.2} vs zigzag {zz:.2}",
                    mt
                );
                assert!(
                    lpt <= ring * 1.02,
                    "{len}/{:?}: LPT {lpt:.2} vs ring {ring:.2}",
                    mt
                );
            }
        }
    }

    #[test]
    fn appendix_d_oom_claim_reproduced() {
        let (_, rows) = memory_feasibility();
        let fits = |tp: usize, cp: usize| {
            rows.iter()
                .find(|(t, c, _, _)| *t == tp && *c == cp)
                .unwrap()
                .3
        };
        assert!(!fits(4, 1), "LLM-L tp=4 with CP off must exceed 40 GB");
        assert!(fits(4, 2), "LLM-L tp=4 cp=2 must fit");
        assert!(
            !fits(2, 1) && !fits(2, 2),
            "tp=2 must exceed either way (why Table 9 pins tp=4)"
        );
    }

    #[test]
    fn tuner_row_is_at_least_as_fast_as_every_baseline() {
        let spec = MllmSpec::vlm(Size::M, Size::M);
        // budget 0 = exhaustive over the space, which contains every
        // baseline configuration.
        let (_, rows) = tuner_vs_baselines(&spec, 16, 0);
        let tuned = rows
            .iter()
            .find(|(n, _)| n == "tuned")
            .expect("tuned row present")
            .1;
        for (name, ms) in rows.iter().filter(|(n, _)| n != "tuned") {
            assert!(
                tuned <= ms + 1e-9,
                "tuned {tuned:.1} ms slower than {name} {ms:.1} ms"
            );
        }
    }

    #[test]
    fn hetero_pools_places_and_wins_as_claimed() {
        let (t, row) = hetero_pools();
        assert!(
            row.llm_on_a100,
            "an LLM stage landed off the A100 group"
        );
        assert!(
            row.encoder_on_a40,
            "no frozen encoder stage landed on the A40 group"
        );
        assert!(
            row.hetero_ms < row.a40_ms,
            "mixed pool {:.1} ms did not beat all-A40 {:.1} ms",
            row.hetero_ms,
            row.a40_ms
        );
        let text = t.render();
        assert!(text.contains("A100-80G"), "{text}");
        assert!(text.contains("A40"), "{text}");
        assert!(text.contains("speedup"), "{text}");
    }

    #[test]
    fn tables_render() {
        // smoke: all table builders produce non-empty renderings
        assert!(fig3b().render().len() > 100);
        assert!(table1().render().contains("Llama"));
        assert!(fig12().render().contains("EP"));
        let spec = MllmSpec::vlm(Size::S, Size::M);
        assert!(auto_frontier(&spec, 6).render().contains("<--"));
        let (t, _) = table2_7_8(Size::M);
        assert!(t.render().contains("VALM-MM"));
    }
}
