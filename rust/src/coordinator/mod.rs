//! Leader entrypoint: glue from CLI → plan → build → run, plus the
//! `reproduce` harness that regenerates every table and figure of the
//! paper's evaluation (see [`experiments`]).

pub mod configs;
pub mod experiments;

use anyhow::{bail, Context, Result};

use crate::api::{PlanReport, PlanRequest, PlanningService};
use crate::model::{MllmSpec, Size};
use crate::runtime::Manifest;
use crate::train::{
    FrozenPolicy, PipelineTrainer, SyntheticDataset, Trainer,
};
use crate::util::json::Json;

pub use experiments::{
    E2eRow, FleetRow, FleetScaleRow, FrozenRow, MaskType,
};

/// The tuner hook — a thin wrapper over the planning facade
/// ([`crate::api::PlanningService`]): resolve the fastest known plan for
/// `spec` on `devices` A40s, consulting (and filling) the persistent
/// cache when given one. Callers get the full [`PlanReport`] — the
/// executable plan, the frontier, the memory verdicts, and the
/// provenance that says whether the cache answered.
pub fn tuned_plan(
    spec: &MllmSpec,
    devices: usize,
    cache: Option<&str>,
) -> Result<PlanReport> {
    let mut req = PlanRequest::default_for(spec.clone()).devices(devices);
    if let Some(p) = cache {
        req = req.cache_file(p);
    }
    Ok(PlanningService::new().plan(&req)?)
}

/// Run one named experiment (or `all`). Returns the rendered report.
pub fn reproduce(which: &str) -> Result<String> {
    let mut out = String::new();
    let mut push = |t: crate::util::table::Table| {
        out.push_str(&t.render());
        out.push('\n');
    };
    let all = which == "all";
    let mut known = false;
    if all || which == "table1" {
        known = true;
        push(experiments::table1());
    }
    if all || which == "fig2" {
        known = true;
        push(experiments::fig2().0);
    }
    if all || which == "fig3b" {
        known = true;
        push(experiments::fig3b());
    }
    if all || which == "fig9" || which == "fig13" || which == "fig14" {
        known = true;
        let sizes: &[Size] = if all {
            &[Size::S, Size::M, Size::L]
        } else {
            match which {
                "fig9" => &[Size::M],
                "fig13" => &[Size::S],
                _ => &[Size::L],
            }
        };
        for &s in sizes {
            push(experiments::fig9_13_14(s).0);
        }
    }
    if all || which == "fig10" || which == "fig15" {
        known = true;
        let sizes: &[Size] = if all {
            &[Size::S, Size::M, Size::L]
        } else if which == "fig10" {
            &[Size::M]
        } else {
            &[Size::S, Size::L]
        };
        for &s in sizes {
            push(experiments::fig10_15(s).0);
        }
    }
    if all || which == "table2" || which == "table7" || which == "table8" {
        known = true;
        let sizes: &[Size] = if all {
            &[Size::S, Size::M, Size::L]
        } else {
            match which {
                "table7" => &[Size::S],
                "table8" => &[Size::L],
                _ => &[Size::M],
            }
        };
        for &s in sizes {
            push(experiments::table2_7_8(s).0);
        }
    }
    if all || which == "table3" || which == "table10" || which == "table11" {
        known = true;
        let sizes: &[Size] = if all {
            &[Size::S, Size::M, Size::L]
        } else {
            match which {
                "table10" => &[Size::S],
                "table11" => &[Size::L],
                _ => &[Size::M],
            }
        };
        for &s in sizes {
            push(experiments::table3_10_11(s).0);
        }
    }
    if all || which == "table4" {
        known = true;
        let runs = if all { 20 } else { 50 };
        push(experiments::table4(runs).0);
    }
    if all || which == "fig12" {
        known = true;
        push(experiments::fig12());
    }
    if all || which == "auto" {
        known = true;
        push(experiments::auto_frontier(
            &MllmSpec::valm(Size::M, Size::M, Size::M),
            6,
        ));
    }
    if all || which == "tuner" {
        known = true;
        push(
            experiments::tuner_vs_baselines(
                &MllmSpec::vlm(Size::M, Size::M),
                16,
                64,
            )
            .0,
        );
    }
    if all || which == "memory" {
        known = true;
        push(experiments::memory_feasibility().0);
    }
    if all || which == "hetero" {
        known = true;
        push(experiments::hetero_pools().0);
    }
    if all || which == "fleet" {
        known = true;
        push(experiments::fleet_planning().0);
        push(experiments::fleet_scale().0);
    }
    if !known {
        bail!(
            "unknown experiment {which:?}; known: all, table1, fig2, fig3b, \
             fig9, fig10, fig13, fig14, fig15, table2, table3, table4, \
             table7, table8, table10, table11, fig12, auto, tuner, memory, \
             hetero, fleet"
        );
    }
    Ok(out)
}

/// Training driver options.
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub model: String,
    pub steps: usize,
    pub microbatches: usize,
    pub lr: f32,
    pub seed: u64,
    pub policy: FrozenPolicy,
    /// true = thread-per-stage pipeline executor; false = single process.
    pub pipelined: bool,
    /// Optional JSON path for the loss curve.
    pub log_json: Option<String>,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            model: "tiny".to_string(),
            steps: 20,
            microbatches: 4,
            lr: 1e-3,
            seed: 42,
            policy: FrozenPolicy::paper(),
            pipelined: true,
            log_json: None,
        }
    }
}

/// Run a training job against the AOT artifacts; returns the loss curve.
pub fn train(opts: &TrainOpts) -> Result<Vec<f32>> {
    let manifest = Manifest::load(Manifest::default_root())
        .context("loading artifacts (run `make artifacts` first)")?;
    let model = manifest.model(&opts.model)?.clone();
    let ds = SyntheticDataset::new(&model, opts.seed);
    let mut losses = Vec::with_capacity(opts.steps);
    let mut wall = Vec::with_capacity(opts.steps);

    let mut run = |stats: crate::train::StepStats| {
        crate::telemetry::info(&format!(
            "step {:>4}  loss {:.4}  ({:.0} ms, {} mb)",
            stats.step, stats.loss, stats.wall_ms, stats.microbatches
        ));
        losses.push(stats.loss);
        wall.push(stats.wall_ms);
    };

    if opts.pipelined {
        let mut tr =
            PipelineTrainer::new(&manifest, &opts.model, opts.policy, opts.lr)?;
        crate::telemetry::info(&format!(
            "pipeline executor: {} stages (modality-parallel encoders + \
             LLM chain)",
            tr.n_stages()
        ));
        for step in 0..opts.steps {
            let batch: Vec<_> = (0..opts.microbatches)
                .map(|i| ds.sample((step * opts.microbatches + i) as u64))
                .collect();
            run(tr.train_step(&batch)?);
        }
    } else {
        let mut tr =
            Trainer::new(&manifest, &opts.model, opts.policy, opts.lr)?;
        for step in 0..opts.steps {
            let batch: Vec<_> = (0..opts.microbatches)
                .map(|i| ds.sample((step * opts.microbatches + i) as u64))
                .collect();
            run(tr.train_step(&batch)?);
        }
    }

    if let Some(path) = &opts.log_json {
        let loss64: Vec<f64> = losses.iter().map(|&x| x as f64).collect();
        let j = Json::obj(vec![
            ("model", Json::Str(opts.model.clone())),
            ("steps", Json::Int(opts.steps as i64)),
            ("microbatches", Json::Int(opts.microbatches as i64)),
            ("lr", Json::Num(opts.lr as f64)),
            ("loss", Json::arr_f64(&loss64)),
            ("wall_ms", Json::arr_f64(&wall)),
        ]);
        std::fs::write(path, j.render())?;
        crate::telemetry::info(&format!("wrote {path}"));
    }
    Ok(losses)
}

/// Cross-check the CP workload model against real PJRT execution of the
/// BAM-attention artifact: the measured time ordering across mask types
/// must match the workload ordering (the quantity both the paper's Table 4
/// and our model measure is unmasked (q,k) pairs).
pub fn attn_crosscheck(artifact: &str, repeats: usize) -> Result<String> {
    use crate::runtime::AttnRuntime;
    use crate::util::rng::Rng;

    let manifest = Manifest::load(Manifest::default_root())?;
    let rt = AttnRuntime::load(&manifest, artifact)?;
    let t = rt.spec.tokens;
    let h = rt.spec.heads;
    let d = rt.spec.head_dim;
    let mut rng = Rng::new(0xA77);
    let n = t * h * d;
    let mk = |rng: &mut Rng| -> Vec<f32> {
        (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.2).collect()
    };
    let q = mk(&mut rng);
    let k = mk(&mut rng);
    let v = mk(&mut rng);

    let mut table = crate::util::table::Table::new(
        &format!("PJRT cross-check — {artifact} (T={t}, H={h}, D={d})"),
        &["mask", "unmasked pairs", "measured ms (median)"],
    );
    for mt in MaskType::ALL {
        let mut mask_rng = Rng::new(0xBEE ^ t as u64);
        let mask = mt.random(&mut mask_rng, t);
        // pad/trim mask to exactly t tokens (generators may round)
        let mut bits = mask.bits.clone();
        bits.resize(t, *bits.last().unwrap());
        let bam = crate::bam::Bam::new(bits, mask.text_mask);
        let pairs: u64 = bam.workloads().iter().sum();
        let bits_i32 = bam.bits_i32();
        let pos_i32 = bam.pos_i32();
        let mut times = Vec::new();
        for _ in 0..repeats {
            let (_, ms) = rt.run(&q, &k, &v, &bits_i32, &pos_i32)?;
            times.push(ms);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = times[times.len() / 2];
        table.row(&[
            mt.name().to_string(),
            pairs.to_string(),
            format!("{med:.2}"),
        ]);
    }
    Ok(table.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduce_rejects_unknown() {
        assert!(reproduce("figNaN").is_err());
    }

    #[test]
    fn reproduce_fig2_renders() {
        let r = reproduce("fig2").unwrap();
        assert!(r.contains("Cornstarch"));
        assert!(r.contains("Encoders-replicated"));
    }

    #[test]
    fn reproduce_fig12_renders() {
        let r = reproduce("fig12").unwrap();
        assert!(r.contains("Zigzag"));
    }

    #[test]
    fn reproduce_tuner_renders() {
        let r = reproduce("tuner").unwrap();
        assert!(r.contains("Autotuner"));
        assert!(r.contains("tuned:"));
    }

    #[test]
    fn reproduce_memory_renders_the_appendix_d_verdicts() {
        let r = reproduce("memory").unwrap();
        assert!(r.contains("Appendix D"), "{r}");
        assert!(r.contains("no (OOM)"), "{r}");
        assert!(r.contains("yes"), "{r}");
    }

    #[test]
    fn tuned_plan_hook_returns_an_executable_plan() {
        let spec = MllmSpec::vlm(Size::M, Size::S);
        let report = tuned_plan(&spec, 8, None).unwrap();
        assert!(!report.provenance.cache_hit);
        assert!(report.plan.n_gpus <= 8);
        let m = report.plan.simulate();
        assert!(
            (m.iteration_ms - report.winner().iteration_ms).abs() < 1e-6
        );
        assert!(report.fits_budget());
    }
}
