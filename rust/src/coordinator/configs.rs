//! The paper's manually-profiled parallelism configurations (Appendix B–D,
//! Tables 5, 6, and 9) plus the modality-parallelism comparison configs
//! (Tables 2, 7, 8). Transcribed verbatim so the reproduce harness sweeps
//! exactly the paper's grid.
//!
//! All end-to-end configs use TP=2, CP=2 (§6.1 / Table 5-6). The pipeline
//! ablation (Table 9) uses TP=2, CP=1 except LLM-L which needs TP=4.

use crate::model::Size;

/// Single-encoder e2e config (Table 5): stage counts per strategy.
#[derive(Clone, Copy, Debug)]
pub struct SingleEncCfg {
    pub llm: Size,
    /// true = VLM (EVA-CLIP), false = ALM (Whisper).
    pub vision: bool,
    pub enc: Size,
    /// (llm_pp, enc_pp) for encoders-colocated.
    pub colocated: (usize, usize),
    /// (llm_pp, enc_pp) for Cornstarch.
    pub cornstarch: (usize, usize),
}

/// Table 5 — parallelism configurations for VLM/ALM end-to-end comparison.
pub const TABLE5: &[SingleEncCfg] = &{
    use Size::*;
    const fn c(
        llm: Size,
        vision: bool,
        enc: Size,
        colocated: (usize, usize),
        cornstarch: (usize, usize),
    ) -> SingleEncCfg {
        SingleEncCfg { llm, vision, enc, colocated, cornstarch }
    }
    [
        // LLM-S
        c(S, true, S, (5, 2), (4, 2)),
        c(S, true, M, (2, 3), (3, 3)),
        c(S, true, L, (1, 4), (2, 4)),
        c(S, false, S, (3, 2), (3, 1)),
        c(S, false, M, (3, 5), (2, 3)),
        c(S, false, L, (2, 6), (3, 5)),
        // LLM-M
        c(M, true, S, (3, 1), (5, 1)),
        c(M, true, M, (3, 2), (3, 1)),
        c(M, true, L, (2, 3), (3, 2)),
        c(M, false, S, (4, 2), (5, 1)),
        c(M, false, M, (3, 3), (4, 2)),
        c(M, false, L, (2, 4), (4, 2)),
        // LLM-L
        c(L, true, S, (5, 1), (5, 1)),
        c(L, true, M, (4, 1), (5, 1)),
        c(L, true, L, (3, 2), (4, 1)),
        c(L, false, S, (5, 1), (5, 1)),
        c(L, false, M, (5, 1), (5, 1)),
        c(L, false, L, (5, 2), (5, 1)),
    ]
};

/// Two-encoder (VALM) e2e config (Table 6).
#[derive(Clone, Copy, Debug)]
pub struct ValmCfg {
    pub llm: Size,
    pub vis: Size,
    pub aud: Size,
    /// (llm_pp, colocated_enc_pp).
    pub colocated: (usize, usize),
    /// (llm_pp, vision_pp, audio_pp).
    pub cornstarch: (usize, usize, usize),
}

/// Table 6 — parallelism configurations for VALM end-to-end comparison.
pub const TABLE6: &[ValmCfg] = &{
    use Size::*;
    const fn c(
        llm: Size,
        vis: Size,
        aud: Size,
        colocated: (usize, usize),
        cornstarch: (usize, usize, usize),
    ) -> ValmCfg {
        ValmCfg { llm, vis, aud, colocated, cornstarch }
    }
    [
        // LLM-S
        c(S, S, S, (3, 4), (3, 1, 1)),
        c(S, S, M, (1, 3), (3, 1, 4)),
        c(S, S, L, (1, 4), (3, 1, 5)),
        c(S, M, S, (2, 4), (3, 3, 1)),
        c(S, M, M, (1, 4), (3, 2, 3)),
        c(S, M, L, (1, 5), (3, 2, 4)),
        c(S, L, S, (1, 4), (3, 5, 1)),
        c(S, L, M, (1, 6), (2, 4, 3)),
        c(S, L, L, (5, 2), (2, 3, 3)),
        // LLM-M
        c(M, S, S, (5, 2), (5, 1, 1)),
        c(M, S, M, (4, 3), (5, 1, 1)),
        c(M, S, L, (3, 4), (4, 1, 2)),
        c(M, M, S, (4, 4), (4, 2, 1)),
        c(M, M, M, (3, 4), (4, 1, 1)),
        c(M, M, L, (2, 4), (3, 1, 1)),
        c(M, L, S, (2, 4), (4, 2, 1)),
        c(M, L, M, (2, 4), (4, 2, 2)),
        c(M, L, L, (2, 5), (5, 1, 1)),
        // LLM-L
        c(L, S, S, (5, 1), (5, 1, 1)),
        c(L, S, M, (5, 2), (5, 1, 1)),
        c(L, S, L, (5, 2), (5, 1, 1)),
        c(L, M, S, (4, 1), (5, 1, 1)),
        c(L, M, M, (4, 2), (5, 1, 1)),
        c(L, M, L, (4, 3), (5, 1, 1)),
        c(L, L, S, (4, 2), (5, 1, 1)),
        c(L, L, M, (4, 3), (5, 1, 1)),
        c(L, L, L, (4, 3), (5, 1, 1)),
    ]
};

/// Modality-parallelism comparison configs (Tables 2, 7, 8): stage counts
/// per strategy at fixed LLM stages.
#[derive(Clone, Copy, Debug)]
pub struct ModalityCfg {
    pub llm: Size,
    pub vis: Size,
    pub aud: Size,
    /// (llm_pp, colocated_enc_pp).
    pub colocated: (usize, usize),
    /// (llm_pp, vision_pp, audio_pp).
    pub modality: (usize, usize, usize),
}

/// Tables 2 (LLM-M), 7 (LLM-S), 8 (LLM-L).
pub const TABLE2_7_8: &[ModalityCfg] = &{
    use Size::*;
    const fn c(
        llm: Size,
        vis: Size,
        aud: Size,
        colocated: (usize, usize),
        modality: (usize, usize, usize),
    ) -> ModalityCfg {
        ModalityCfg { llm, vis, aud, colocated, modality }
    }
    [
        // Table 7: LLM-S
        c(S, S, S, (3, 4), (3, 1, 1)),
        c(S, S, M, (1, 3), (3, 1, 4)),
        c(S, S, L, (1, 4), (3, 1, 5)),
        c(S, M, S, (2, 4), (3, 3, 1)),
        c(S, M, M, (1, 4), (3, 2, 3)),
        c(S, M, L, (1, 5), (3, 2, 4)),
        c(S, L, S, (1, 4), (3, 5, 1)),
        c(S, L, M, (1, 6), (2, 4, 3)),
        c(S, L, L, (1, 6), (2, 3, 3)),
        // Table 2: LLM-M (fixed 6 LLM stages)
        c(M, S, S, (6, 1), (6, 1, 1)),
        c(M, S, M, (6, 2), (6, 1, 1)),
        c(M, S, L, (6, 2), (6, 1, 2)),
        c(M, M, S, (6, 2), (6, 2, 1)),
        c(M, M, M, (6, 3), (6, 1, 1)),
        c(M, M, L, (6, 4), (6, 2, 2)),
        c(M, L, S, (6, 4), (6, 3, 1)),
        c(M, L, M, (6, 4), (6, 3, 1)),
        c(M, L, L, (6, 5), (6, 3, 2)),
        // Table 8: LLM-L
        c(L, S, S, (5, 1), (5, 1, 1)),
        c(L, S, M, (5, 2), (5, 1, 1)),
        c(L, S, L, (5, 2), (5, 1, 1)),
        c(L, M, S, (4, 1), (5, 1, 1)),
        c(L, M, M, (4, 2), (5, 1, 1)),
        c(L, M, L, (6, 1), (5, 1, 1)),
        c(L, L, S, (4, 2), (5, 1, 1)),
        c(L, L, M, (4, 3), (5, 1, 1)),
        c(L, L, L, (4, 3), (5, 1, 1)),
    ]
};

/// Frozen-awareness ablation config (Table 9): (llm_pp, enc_pp) per
/// policy, TP per LLM size, CP = 1.
#[derive(Clone, Copy, Debug)]
pub struct FrozenCfg {
    pub llm: Size,
    pub vision: bool,
    pub enc: Size,
    /// frozen-UNAWARE (colocated-style fwd-balanced) stage counts.
    pub unaware: (usize, usize),
    /// frozen-AWARE (Cornstarch) stage counts.
    pub aware: (usize, usize),
    pub tp: usize,
}

/// Table 9 — pipeline-parallel configs for the §6.4 ablation.
pub const TABLE9: &[FrozenCfg] = &{
    use Size::*;
    const fn c(
        llm: Size,
        vision: bool,
        enc: Size,
        unaware: (usize, usize),
        aware: (usize, usize),
        tp: usize,
    ) -> FrozenCfg {
        FrozenCfg { llm, vision, enc, unaware, aware, tp }
    }
    [
        // LLM-S (tp=2)
        c(S, true, S, (4, 4), (4, 2), 2),
        c(S, true, M, (1, 4), (2, 4), 2),
        c(S, true, L, (1, 5), (1, 4), 2),
        c(S, false, S, (3, 2), (5, 1), 2),
        c(S, false, M, (2, 3), (4, 2), 2),
        c(S, false, L, (2, 4), (4, 3), 2),
        // LLM-M (tp=2)
        c(M, true, S, (3, 1), (6, 1), 2),
        c(M, true, M, (4, 3), (5, 2), 2),
        c(M, true, L, (3, 5), (5, 4), 2),
        c(M, false, S, (5, 1), (6, 1), 2),
        c(M, false, M, (4, 4), (6, 1), 2),
        c(M, false, L, (5, 5), (4, 2), 2),
        // LLM-L rows pin tp=4, and need CP: enforced against the memory
        // model by `validate_llm_l_memory`, not by a prose claim.
        c(L, true, S, (3, 5), (5, 1), 4),
        c(L, true, M, (5, 1), (5, 1), 4),
        c(L, true, L, (4, 2), (4, 1), 4),
        c(L, false, S, (5, 1), (5, 1), 4),
        c(L, false, M, (3, 1), (5, 1), 4),
        c(L, false, L, (4, 2), (5, 1), 4),
    ]
};

/// Human name of a single-encoder model (`VLM-L`, `ALM-S`...).
pub fn single_enc_name(vision: bool, enc: Size) -> String {
    format!("{}-{}", if vision { "VLM" } else { "ALM" }, enc.letter())
}

/// Appendix D's memory constraint, held to the analytic model
/// ([`crate::memory`]) instead of a prose comment: every LLM-L row of
/// Table 9 runs TP=4 because at TP=2 the 40 GB A40 budget is exceeded
/// even with CP=2, and CP is required because at TP=4 with CP off the
/// VLM-L row still exceeds it. Panics loudly if the Table 1 geometry or
/// the memory model ever drifts away from those verdicts.
pub fn validate_llm_l_memory() {
    use crate::cost::Device;
    use crate::memory;
    use crate::modality::{planner, Strategy};
    use crate::model::MllmSpec;

    let a40_budget =
        crate::api::ClusterSpec::a40_default().mem_budget_bytes();
    let plan_for = |c: &FrozenCfg, tp: usize, cp: usize| {
        let spec = if c.vision {
            MllmSpec::vlm(c.llm, c.enc)
        } else {
            MllmSpec::alm(c.llm, c.enc)
        };
        planner::plan_uniform(
            Strategy::Cornstarch,
            &spec,
            c.aware.1,
            c.aware.0,
            tp,
            cp,
            24,
            Device::a40(),
        )
    };
    for c in TABLE9.iter().filter(|c| c.llm == Size::L) {
        assert_eq!(
            c.tp, 4,
            "Table 9 LLM-L rows must pin tp=4 ({})",
            single_enc_name(c.vision, c.enc)
        );
        let plan = plan_for(c, 4, 2);
        if let Err(e) = memory::check(&plan, a40_budget) {
            panic!(
                "Table 9 {} @ LLM-L no longer fits at tp=4/cp=2: {e}",
                single_enc_name(c.vision, c.enc)
            );
        }
    }
    // The VLM-L row is the Appendix D OOM witness: with CP off its
    // encoder stage's warm-up window busts the budget.
    let witness = TABLE9
        .iter()
        .find(|c| c.llm == Size::L && c.vision && c.enc == Size::L)
        .expect("Table 9 carries a VLM-L @ LLM-L row");
    assert!(
        memory::check(&plan_for(witness, 4, 1), a40_budget).is_err(),
        "VLM-L @ LLM-L with CP off should exceed the A40 budget \
         (Appendix D)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_covers_the_grid() {
        assert_eq!(TABLE5.len(), 18); // 3 llm x {VLM,ALM} x 3 enc
        for llm in Size::ALL {
            for vision in [true, false] {
                for enc in Size::ALL {
                    assert!(
                        TABLE5.iter().any(|c| c.llm == llm
                            && c.vision == vision
                            && c.enc == enc),
                        "missing {llm:?} {vision} {enc:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn table6_covers_the_grid() {
        assert_eq!(TABLE6.len(), 27); // 3 llm x 3 vis x 3 aud
    }

    #[test]
    fn stage_counts_fit_the_testbed() {
        // 24 GPUs / (tp=2 x cp=2) = 6 device groups max per module config
        for c in TABLE5 {
            assert!(c.colocated.0 <= 6 && c.colocated.1 <= 6);
            assert!(c.cornstarch.0 <= 6 && c.cornstarch.1 <= 6);
        }
        for c in TABLE9 {
            assert!(c.aware.0 + c.aware.1 <= 12);
        }
    }

    #[test]
    fn llm_l_memory_constraints_hold() {
        // Must not panic: tp=4/cp=2 fits everywhere, CP off OOMs VLM-L.
        validate_llm_l_memory();
    }

    #[test]
    fn table2_7_8_has_three_llm_sizes() {
        for llm in Size::ALL {
            assert_eq!(
                TABLE2_7_8.iter().filter(|c| c.llm == llm).count(),
                9,
                "{llm:?}"
            );
        }
    }
}
