//! `ClusterSpec` — the single source of hardware truth for planning.
//!
//! Every planning entry point used to bake in one scenario: the A40 as a
//! single MFU scalar in [`crate::cost::Device::a40`] and a single memory
//! constant in `crate::memory`. A `ClusterSpec` names all of it in one
//! typed value — how many devices, what one device can hold
//! ([`DeviceClass::mem_bytes`]), how fast it computes
//! ([`DeviceClass::peak_flops`] × [`DeviceClass::mfu`]), and how fast
//! stages talk to each other ([`ClusterSpec::interconnect_gbps`]) — and
//! threads through `cost` (per-device-class time scaling), `memory`
//! (budget per device), `tuner` (search-space bounds and the cache
//! signature), and `sim` (comm hops priced off the bandwidth).
//!
//! Specs load from JSON (`cornstarch tune <mllm> --cluster <file>`):
//!
//! ```json
//! {
//!   "name": "a40x8",
//!   "devices": 8,
//!   "device": { "name": "A40", "mem_gb": 40.0,
//!               "peak_tflops": 149.7, "mfu": 0.67 },
//!   "interconnect_gbps": 32.0
//! }
//! ```

use std::path::Path;

use crate::cost::Device;
use crate::util::json::Json;

use super::error::PlanError;

/// A40 bf16 peak flops (§6.1 testbed).
pub const A40_PEAK_FLOPS: f64 = 149.7e12;
/// The single MFU scalar the analytic time model is calibrated by
/// (reproduces the paper's Fig. 3b Mistral-7b forward within ~5%; see
/// `crate::cost`). Every reproduced result is a ratio of times, which
/// this scalar cancels out of.
pub const A40_MFU: f64 = 0.67;
/// The A40 testbed's usable per-GPU budget (Appendix D): 48 GB HBM minus
/// the runtime/fragmentation reserve the paper plans against.
pub const A40_MEM_BYTES: u64 = 40_000_000_000;
/// A40 testbed interconnect, GB/s (PCIe-class effective bandwidth).
/// Chosen so the nominal activation hop prices at exactly the 0.5 ms the
/// pre-`ClusterSpec` model charged.
pub const A40_INTERCONNECT_GBPS: f64 = 32.0;

/// Nominal per-hop activation payload the analytic model prices: one
/// microbatch's hidden-state tensor at paper scale (~16 MB of bf16 at
/// h=4096 × ~2000 tokens).
pub const NOMINAL_HOP_BYTES: u64 = 16_000_000;

/// One device class of a cluster: memory capacity plus the throughput
/// model the cost layer scales times by.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceClass {
    pub name: String,
    /// Usable per-device memory budget in bytes.
    pub mem_bytes: u64,
    /// Peak flops (bf16).
    pub peak_flops: f64,
    /// Model flops utilization for big dense matmuls.
    pub mfu: f64,
}

impl DeviceClass {
    /// The A40 of the paper's testbed.
    pub fn a40() -> Self {
        DeviceClass {
            name: "A40".to_string(),
            mem_bytes: A40_MEM_BYTES,
            peak_flops: A40_PEAK_FLOPS,
            mfu: A40_MFU,
        }
    }

    /// The throughput model [`crate::cost`] consumes.
    pub fn time_model(&self) -> Device {
        Device { peak_flops: self.peak_flops, mfu: self.mfu }
    }
}

/// The hardware a [`super::PlanRequest`] plans against: a homogeneous
/// pool of `devices` GPUs of one [`DeviceClass`] connected at
/// `interconnect_gbps`. (Heterogeneous pools are the next scenario this
/// type exists to make expressible.)
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    pub name: String,
    /// Total GPU count the planner may occupy.
    pub devices: usize,
    pub device: DeviceClass,
    /// Cross-stage interconnect bandwidth in decimal GB/s.
    pub interconnect_gbps: f64,
}

impl ClusterSpec {
    /// The paper's §6.1 testbed: 16 × A40. This is the default every
    /// entry point falls back to, and it reproduces the pre-redesign
    /// constants exactly (0.5 ms comm hop, 40 GB budget, 0.67 MFU).
    pub fn a40_default() -> Self {
        ClusterSpec {
            name: "a40".to_string(),
            devices: 16,
            device: DeviceClass::a40(),
            interconnect_gbps: A40_INTERCONNECT_GBPS,
        }
    }

    /// Same device class and interconnect, different pool size.
    pub fn with_devices(mut self, devices: usize) -> Self {
        self.devices = devices;
        self
    }

    /// The throughput model [`crate::cost`] consumes.
    pub fn device_model(&self) -> Device {
        self.device.time_model()
    }

    /// Per-device memory budget the capacity checks compare against.
    pub fn mem_budget_bytes(&self) -> u64 {
        self.device.mem_bytes
    }

    /// Milliseconds one cross-stage activation/gradient hop costs:
    /// [`NOMINAL_HOP_BYTES`] over the interconnect. The A40 default
    /// yields exactly the 0.5 ms the pre-`ClusterSpec` model charged.
    pub fn comm_hop_ms(&self) -> f64 {
        (NOMINAL_HOP_BYTES as f64 * 1e3) / (self.interconnect_gbps * 1e9)
    }

    /// Stable fingerprint of everything that can change a planning
    /// answer — joins the tuner's cache signature, and is stored per
    /// cache entry so an entry written for one cluster can never answer
    /// for another. Deliberately excludes the display names.
    pub fn fingerprint(&self) -> String {
        format!(
            "n={}|mem={}|flops={:.6e}|mfu={}|bw={}",
            self.devices,
            self.device.mem_bytes,
            self.device.peak_flops,
            self.device.mfu,
            self.interconnect_gbps,
        )
    }

    /// Reject specs the planning layers cannot price.
    pub fn validate(&self) -> Result<(), PlanError> {
        let bad = |m: String| Err(PlanError::InvalidCluster(m));
        if self.devices == 0 {
            return bad("`devices` must be >= 1".to_string());
        }
        if self.device.mem_bytes == 0 {
            return bad("`device.mem_gb` must be > 0".to_string());
        }
        if !self.device.peak_flops.is_finite()
            || self.device.peak_flops <= 0.0
        {
            return bad("`device.peak_tflops` must be > 0".to_string());
        }
        if !self.device.mfu.is_finite()
            || self.device.mfu <= 0.0
            || self.device.mfu > 1.0
        {
            return bad(format!(
                "`device.mfu` must be in (0, 1], got {}",
                self.device.mfu
            ));
        }
        if !self.interconnect_gbps.is_finite()
            || self.interconnect_gbps <= 0.0
        {
            return bad("`interconnect_gbps` must be > 0".to_string());
        }
        Ok(())
    }

    /// Serialize to the `--cluster` JSON schema.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("devices", Json::Int(self.devices as i64)),
            (
                "device",
                Json::obj(vec![
                    ("name", Json::Str(self.device.name.clone())),
                    (
                        "mem_gb",
                        Json::Num(self.device.mem_bytes as f64 / 1e9),
                    ),
                    (
                        "peak_tflops",
                        Json::Num(self.device.peak_flops / 1e12),
                    ),
                    ("mfu", Json::Num(self.device.mfu)),
                ]),
            ),
            ("interconnect_gbps", Json::Num(self.interconnect_gbps)),
        ])
    }

    /// Parse the `--cluster` JSON schema (does not validate ranges; see
    /// [`ClusterSpec::validate`]).
    pub fn from_json(j: &Json) -> Result<ClusterSpec, String> {
        let devices = j
            .get("devices")
            .and_then(Json::as_i64)
            .and_then(|v| usize::try_from(v).ok())
            .ok_or_else(|| {
                "cluster JSON needs a non-negative integer `devices`"
                    .to_string()
            })?;
        let d = j
            .get("device")
            .ok_or_else(|| "cluster JSON needs a `device` object".to_string())?;
        let mem_gb = d.get("mem_gb").and_then(Json::as_f64).ok_or_else(|| {
            "`device.mem_gb` (decimal GB per device) is required".to_string()
        })?;
        let peak_tflops =
            d.get("peak_tflops").and_then(Json::as_f64).ok_or_else(|| {
                "`device.peak_tflops` is required".to_string()
            })?;
        let mfu = d
            .get("mfu")
            .and_then(Json::as_f64)
            .ok_or_else(|| "`device.mfu` is required".to_string())?;
        let interconnect_gbps = j
            .get("interconnect_gbps")
            .and_then(Json::as_f64)
            .ok_or_else(|| {
                "`interconnect_gbps` (decimal GB/s) is required".to_string()
            })?;
        Ok(ClusterSpec {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("unnamed")
                .to_string(),
            devices,
            device: DeviceClass {
                name: d
                    .get("name")
                    .and_then(Json::as_str)
                    .unwrap_or("custom")
                    .to_string(),
                mem_bytes: (mem_gb * 1e9) as u64,
                peak_flops: peak_tflops * 1e12,
                mfu,
            },
            interconnect_gbps,
        })
    }

    /// Load and validate a spec from a `--cluster <file>` path.
    pub fn load(path: &Path) -> Result<ClusterSpec, PlanError> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            PlanError::InvalidCluster(format!(
                "reading {}: {e}",
                path.display()
            ))
        })?;
        let j = Json::parse(&text).map_err(|e| {
            PlanError::InvalidCluster(format!(
                "parsing {}: {e}",
                path.display()
            ))
        })?;
        let spec =
            ClusterSpec::from_json(&j).map_err(PlanError::InvalidCluster)?;
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a40_default_reproduces_the_pre_cluster_constants() {
        let c = ClusterSpec::a40_default();
        let d = c.device_model();
        let legacy = Device::a40();
        assert_eq!(d.peak_flops, legacy.peak_flops);
        assert_eq!(d.mfu, legacy.mfu);
        assert_eq!(c.mem_budget_bytes(), 40_000_000_000);
        // the comm hop must be EXACTLY the 0.5 ms constant the planners
        // charged before the redesign — golden-plan parity depends on it
        assert_eq!(c.comm_hop_ms(), 0.5);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn json_roundtrip_preserves_the_spec() {
        let mut c = ClusterSpec::a40_default().with_devices(8);
        c.name = "a40x8".to_string();
        let j = c.to_json();
        let back = ClusterSpec::from_json(&j).unwrap();
        assert_eq!(back, c);
        // and through the text form too
        let reparsed = Json::parse(&j.render()).unwrap();
        assert_eq!(ClusterSpec::from_json(&reparsed).unwrap(), c);
    }

    #[test]
    fn fingerprint_tracks_semantics_not_names() {
        let a = ClusterSpec::a40_default();
        let mut renamed = a.clone();
        renamed.name = "somewhere-else".to_string();
        renamed.device.name = "A40-PCIe".to_string();
        assert_eq!(a.fingerprint(), renamed.fingerprint());
        let mut bigger = a.clone();
        bigger.device.mem_bytes = 80_000_000_000;
        assert_ne!(a.fingerprint(), bigger.fingerprint());
        let mut slower_net = a.clone();
        slower_net.interconnect_gbps = 16.0;
        assert_ne!(a.fingerprint(), slower_net.fingerprint());
        assert_ne!(
            a.fingerprint(),
            a.clone().with_devices(8).fingerprint()
        );
    }

    #[test]
    fn halved_bandwidth_doubles_the_comm_hop() {
        let a = ClusterSpec::a40_default();
        let mut slow = a.clone();
        slow.interconnect_gbps = a.interconnect_gbps / 2.0;
        assert_eq!(slow.comm_hop_ms(), 2.0 * a.comm_hop_ms());
    }

    #[test]
    fn validate_rejects_nonsense() {
        let ok = ClusterSpec::a40_default();
        let mut c = ok.clone();
        c.devices = 0;
        assert!(c.validate().is_err());
        let mut c = ok.clone();
        c.device.mfu = 1.5;
        assert!(c.validate().is_err());
        let mut c = ok.clone();
        c.device.mem_bytes = 0;
        assert!(c.validate().is_err());
        let mut c = ok;
        c.interconnect_gbps = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn from_json_reports_missing_fields() {
        let j = Json::parse(r#"{"devices": 8}"#).unwrap();
        let err = ClusterSpec::from_json(&j).unwrap_err();
        assert!(err.contains("device"), "{err}");
        assert!(ClusterSpec::load(Path::new(
            "/nonexistent/cluster.json"
        ))
        .is_err());
    }
}
