//! `ClusterSpec` — the single source of hardware truth for planning.
//!
//! Every planning entry point used to bake in one scenario: the A40 as a
//! single MFU scalar in [`crate::cost::Device::a40`] and a single memory
//! constant in `crate::memory`. A `ClusterSpec` names all of it in one
//! typed value — and, since the heterogeneous-pools redesign, it names it
//! **per device group**: a pool is a list of [`DeviceGroup`]s, each with
//! its own GPU count, [`DeviceClass`] (memory capacity + flops/MFU time
//! model), and link bandwidth. A mixed pool like 4×A40 + 4×A100-80G lets
//! the planner put frozen encoder stages on the cheap 40 GB cards while
//! the LLM claims the 80 GB ones — the hardware dual of the paper's
//! frozen-vs-trainable module heterogeneity (§4.2).
//!
//! Specs load from JSON (`cornstarch tune <mllm> --cluster <file>`), in
//! either form. The legacy single-device form keeps parsing as a
//! one-group pool (and one-group specs render back to it byte-for-byte):
//!
//! ```json
//! {
//!   "name": "a40x8",
//!   "devices": 8,
//!   "device": { "name": "A40", "mem_gb": 40.0,
//!               "peak_tflops": 149.7, "mfu": 0.67 },
//!   "interconnect_gbps": 32.0
//! }
//! ```
//!
//! or the heterogeneous `groups` form (`examples/clusters/
//! a40x4-a100x4.json`):
//!
//! ```json
//! {
//!   "name": "a40x4-a100x4",
//!   "groups": [
//!     { "count": 4, "link_gbps": 32.0,
//!       "device": { "name": "A40", "mem_gb": 40.0,
//!                   "peak_tflops": 149.7, "mfu": 0.67 } },
//!     { "count": 4, "link_gbps": 300.0,
//!       "device": { "name": "A100-80G", "mem_gb": 80.0,
//!                   "peak_tflops": 312.0, "mfu": 0.55 } }
//!   ]
//! }
//! ```

use std::path::Path;

use crate::cost::Device;
use crate::util::json::Json;

use super::error::PlanError;

/// A40 bf16 peak flops (§6.1 testbed).
pub const A40_PEAK_FLOPS: f64 = 149.7e12;
/// The single MFU scalar the analytic time model is calibrated by
/// (reproduces the paper's Fig. 3b Mistral-7b forward within ~5%; see
/// `crate::cost`). Every reproduced result is a ratio of times, which
/// this scalar cancels out of.
pub const A40_MFU: f64 = 0.67;
/// The A40 testbed's usable per-GPU budget (Appendix D): 48 GB HBM minus
/// the runtime/fragmentation reserve the paper plans against.
pub const A40_MEM_BYTES: u64 = 40_000_000_000;
/// A40 testbed interconnect, GB/s (PCIe-class effective bandwidth).
/// Chosen so the nominal activation hop prices at exactly the 0.5 ms the
/// pre-`ClusterSpec` model charged.
pub const A40_INTERCONNECT_GBPS: f64 = 32.0;

/// Nominal per-hop activation payload the analytic model prices: one
/// microbatch's hidden-state tensor at paper scale (~16 MB of bf16 at
/// h=4096 × ~2000 tokens).
pub const NOMINAL_HOP_BYTES: u64 = 16_000_000;

/// One device class of a cluster: memory capacity plus the throughput
/// model the cost layer scales times by.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceClass {
    pub name: String,
    /// Usable per-device memory budget in bytes.
    pub mem_bytes: u64,
    /// Peak flops (bf16).
    pub peak_flops: f64,
    /// Model flops utilization for big dense matmuls.
    pub mfu: f64,
}

impl DeviceClass {
    /// The A40 of the paper's testbed.
    pub fn a40() -> Self {
        DeviceClass {
            name: "A40".to_string(),
            mem_bytes: A40_MEM_BYTES,
            peak_flops: A40_PEAK_FLOPS,
            mfu: A40_MFU,
        }
    }

    /// The A100-80G of the heterogeneous demo pool.
    pub fn a100_80g() -> Self {
        DeviceClass {
            name: "A100-80G".to_string(),
            mem_bytes: 80_000_000_000,
            peak_flops: 312.0e12,
            mfu: 0.55,
        }
    }

    /// The throughput model [`crate::cost`] consumes.
    pub fn time_model(&self) -> Device {
        Device { peak_flops: self.peak_flops, mfu: self.mfu }
    }
}

/// One named pool of identical devices inside a [`ClusterSpec`]: how
/// many, what each can hold and compute, and how fast its links move
/// activations. A hop between two groups is priced at the slower of the
/// two links (the bottleneck).
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceGroup {
    pub device: DeviceClass,
    /// GPUs in this group.
    pub count: usize,
    /// Link bandwidth of this group's devices in decimal GB/s.
    pub link_gbps: f64,
}

impl DeviceGroup {
    /// Milliseconds one activation/gradient hop over this group's link
    /// costs: [`NOMINAL_HOP_BYTES`] over the bandwidth.
    pub fn hop_ms(&self) -> f64 {
        (NOMINAL_HOP_BYTES as f64 * 1e3) / (self.link_gbps * 1e9)
    }

    /// Stable fingerprint segment — everything that can change a
    /// planning answer, deliberately excluding the display names.
    fn fingerprint(&self) -> String {
        format!(
            "n={}|mem={}|flops={:.6e}|mfu={}|bw={}",
            self.count,
            self.device.mem_bytes,
            self.device.peak_flops,
            self.device.mfu,
            self.link_gbps,
        )
    }
}

/// The hardware a [`super::PlanRequest`] plans against: a pool of one or
/// more [`DeviceGroup`]s. A single group is the homogeneous cluster every
/// pre-hetero consumer assumed; several groups make the joint
/// model×device assignment a search dimension (`tuner::space` enumerates
/// which cluster group each pipeline chain lands on).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    pub name: String,
    /// The device pools; never empty. `groups[0]` is the *primary* group
    /// — the one the homogeneous compatibility views
    /// ([`ClusterSpec::device_model`], [`ClusterSpec::comm_hop_ms`])
    /// refer to.
    pub groups: Vec<DeviceGroup>,
}

impl ClusterSpec {
    /// A homogeneous pool of `count` devices of one class.
    pub fn homogeneous(
        name: &str,
        device: DeviceClass,
        count: usize,
        link_gbps: f64,
    ) -> Self {
        ClusterSpec {
            name: name.to_string(),
            groups: vec![DeviceGroup { device, count, link_gbps }],
        }
    }

    /// The paper's §6.1 testbed: 16 × A40. This is the default every
    /// entry point falls back to, and it reproduces the pre-redesign
    /// constants exactly (0.5 ms comm hop, 40 GB budget, 0.67 MFU).
    pub fn a40_default() -> Self {
        ClusterSpec::homogeneous(
            "a40",
            DeviceClass::a40(),
            16,
            A40_INTERCONNECT_GBPS,
        )
    }

    /// The heterogeneous demo pool: 4 × A40 (cheap 40 GB cards for the
    /// frozen encoders) + 4 × A100-80G (big-memory cards for the LLM).
    /// Mirrored by `examples/clusters/a40x4-a100x4.json`.
    pub fn a40_a100_demo() -> Self {
        ClusterSpec {
            name: "a40x4-a100x4".to_string(),
            groups: vec![
                DeviceGroup {
                    device: DeviceClass::a40(),
                    count: 4,
                    link_gbps: A40_INTERCONNECT_GBPS,
                },
                DeviceGroup {
                    device: DeviceClass::a100_80g(),
                    count: 4,
                    link_gbps: 300.0,
                },
            ],
        }
    }

    /// Total GPU count the planner may occupy, across all groups.
    pub fn devices(&self) -> usize {
        self.groups.iter().map(|g| g.count).sum()
    }

    /// More than one device group?
    pub fn is_heterogeneous(&self) -> bool {
        self.groups.len() > 1
    }

    /// Same device class and interconnect, different pool size. Only
    /// meaningful for single-group clusters — a multi-group pool is
    /// resized per group, not as a whole.
    pub fn with_devices(mut self, devices: usize) -> Self {
        assert!(
            self.groups.len() == 1,
            "with_devices resizes a homogeneous pool; edit the groups of \
             a heterogeneous one individually"
        );
        self.groups[0].count = devices;
        self
    }

    /// The throughput model of the **primary** group — the homogeneous
    /// view fixed-strategy planners use. Heterogeneity-aware consumers
    /// key per-chain time models off [`ClusterSpec::group_device`]
    /// instead.
    pub fn device_model(&self) -> Device {
        self.groups[0].device.time_model()
    }

    /// The time model of group `g` (the per-device model a stage
    /// assigned to that group is priced with).
    pub fn group_device(&self, g: usize) -> Device {
        self.groups[g].device.time_model()
    }

    /// Per-device memory budget of group `g`.
    pub fn group_mem_bytes(&self, g: usize) -> u64 {
        self.groups[g].device.mem_bytes
    }

    /// The most permissive per-device budget in the pool. For a
    /// homogeneous cluster this is *the* budget; heterogeneous capacity
    /// checks hold every stage to the budget of the group it actually
    /// lands on ([`crate::memory::stage_budgets`]), so the scalar is only
    /// a coarse upper bound there.
    pub fn mem_budget_bytes(&self) -> u64 {
        self.groups
            .iter()
            .map(|g| g.device.mem_bytes)
            .max()
            .unwrap_or(0)
    }

    /// Milliseconds one cross-stage hop over the **primary** group's
    /// link costs. The A40 default yields exactly the 0.5 ms the
    /// pre-`ClusterSpec` model charged.
    pub fn comm_hop_ms(&self) -> f64 {
        self.groups[0].hop_ms()
    }

    /// Hop cost between groups `a` and `b`: the slower of the two links
    /// is the bottleneck the transfer pays.
    pub fn hop_ms_between(&self, a: usize, b: usize) -> f64 {
        self.groups[a].hop_ms().max(self.groups[b].hop_ms())
    }

    /// Stable fingerprint of everything that can change a planning
    /// answer — joins the tuner's cache signature, and is stored per
    /// cache entry so an entry written for one cluster can never answer
    /// for another. Covers the **full pool** (every group's count,
    /// memory, flops/MFU, and link), so a heterogeneous pool and a
    /// homogeneous one of the same total size never alias. Single-group
    /// fingerprints are byte-identical to the pre-hetero format.
    pub fn fingerprint(&self) -> String {
        self.groups
            .iter()
            .map(|g| g.fingerprint())
            .collect::<Vec<_>>()
            .join("+")
    }

    /// Reject specs the planning layers cannot price.
    pub fn validate(&self) -> Result<(), PlanError> {
        let bad = |m: String| Err(PlanError::InvalidCluster(m));
        if self.groups.is_empty() {
            return bad("a cluster needs at least one device group".into());
        }
        for (i, g) in self.groups.iter().enumerate() {
            if g.count == 0 {
                return bad(format!("group {i}: `count` must be >= 1"));
            }
            if g.device.mem_bytes == 0 {
                return bad(format!("group {i}: `mem_gb` must be > 0"));
            }
            if !g.device.peak_flops.is_finite() || g.device.peak_flops <= 0.0
            {
                return bad(format!(
                    "group {i}: `peak_tflops` must be > 0"
                ));
            }
            if !g.device.mfu.is_finite()
                || g.device.mfu <= 0.0
                || g.device.mfu > 1.0
            {
                return bad(format!(
                    "group {i}: `mfu` must be in (0, 1], got {}",
                    g.device.mfu
                ));
            }
            if !g.link_gbps.is_finite() || g.link_gbps <= 0.0 {
                return bad(format!(
                    "group {i}: `link_gbps` must be > 0"
                ));
            }
        }
        Ok(())
    }

    fn device_to_json(d: &DeviceClass) -> Json {
        Json::obj(vec![
            ("name", Json::Str(d.name.clone())),
            ("mem_gb", Json::Num(d.mem_bytes as f64 / 1e9)),
            ("peak_tflops", Json::Num(d.peak_flops / 1e12)),
            ("mfu", Json::Num(d.mfu)),
        ])
    }

    /// Serialize to the `--cluster` JSON schema. A single-group spec
    /// renders the legacy single-device form byte-for-byte; multi-group
    /// specs render the `groups` form.
    pub fn to_json(&self) -> Json {
        if let [g] = self.groups.as_slice() {
            return Json::obj(vec![
                ("name", Json::Str(self.name.clone())),
                ("devices", Json::Int(g.count as i64)),
                ("device", Self::device_to_json(&g.device)),
                ("interconnect_gbps", Json::Num(g.link_gbps)),
            ]);
        }
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            (
                "groups",
                Json::Arr(
                    self.groups
                        .iter()
                        .map(|g| {
                            Json::obj(vec![
                                ("count", Json::Int(g.count as i64)),
                                (
                                    "device",
                                    Self::device_to_json(&g.device),
                                ),
                                ("link_gbps", Json::Num(g.link_gbps)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    fn device_from_json(d: &Json) -> Result<DeviceClass, String> {
        let mem_gb = d.get("mem_gb").and_then(Json::as_f64).ok_or_else(|| {
            "`device.mem_gb` (decimal GB per device) is required".to_string()
        })?;
        let peak_tflops =
            d.get("peak_tflops").and_then(Json::as_f64).ok_or_else(|| {
                "`device.peak_tflops` is required".to_string()
            })?;
        let mfu = d
            .get("mfu")
            .and_then(Json::as_f64)
            .ok_or_else(|| "`device.mfu` is required".to_string())?;
        Ok(DeviceClass {
            name: d
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("custom")
                .to_string(),
            mem_bytes: (mem_gb * 1e9) as u64,
            peak_flops: peak_tflops * 1e12,
            mfu,
        })
    }

    /// Parse the `--cluster` JSON schema, either form (does not validate
    /// ranges; see [`ClusterSpec::validate`]).
    pub fn from_json(j: &Json) -> Result<ClusterSpec, String> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("unnamed")
            .to_string();
        if let Some(gs) = j.get("groups").and_then(Json::as_arr) {
            if gs.is_empty() {
                return Err(
                    "`groups` must carry at least one device group".into()
                );
            }
            let groups = gs
                .iter()
                .enumerate()
                .map(|(i, g)| {
                    let count = g
                        .get("count")
                        .and_then(Json::as_i64)
                        .and_then(|v| usize::try_from(v).ok())
                        .ok_or_else(|| {
                            format!(
                                "group {i} needs a non-negative integer \
                                 `count`"
                            )
                        })?;
                    let d = g.get("device").ok_or_else(|| {
                        format!("group {i} needs a `device` object")
                    })?;
                    let link_gbps = g
                        .get("link_gbps")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| {
                            format!(
                                "group {i} needs `link_gbps` (decimal GB/s)"
                            )
                        })?;
                    Ok(DeviceGroup {
                        device: Self::device_from_json(d)?,
                        count,
                        link_gbps,
                    })
                })
                .collect::<Result<Vec<_>, String>>()?;
            return Ok(ClusterSpec { name, groups });
        }
        // Legacy single-device form: a one-group pool.
        let devices = j
            .get("devices")
            .and_then(Json::as_i64)
            .and_then(|v| usize::try_from(v).ok())
            .ok_or_else(|| {
                "cluster JSON needs a non-negative integer `devices` (or a \
                 `groups` array)"
                    .to_string()
            })?;
        let d = j
            .get("device")
            .ok_or_else(|| "cluster JSON needs a `device` object".to_string())?;
        let interconnect_gbps = j
            .get("interconnect_gbps")
            .and_then(Json::as_f64)
            .ok_or_else(|| {
                "`interconnect_gbps` (decimal GB/s) is required".to_string()
            })?;
        Ok(ClusterSpec {
            name,
            groups: vec![DeviceGroup {
                device: Self::device_from_json(d)?,
                count: devices,
                link_gbps: interconnect_gbps,
            }],
        })
    }

    /// Load and validate a spec from a `--cluster <file>` path.
    pub fn load(path: &Path) -> Result<ClusterSpec, PlanError> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            PlanError::InvalidCluster(format!(
                "reading {}: {e}",
                path.display()
            ))
        })?;
        let j = Json::parse(&text).map_err(|e| {
            PlanError::InvalidCluster(format!(
                "parsing {}: {e}",
                path.display()
            ))
        })?;
        let spec =
            ClusterSpec::from_json(&j).map_err(PlanError::InvalidCluster)?;
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a40_default_reproduces_the_pre_cluster_constants() {
        let c = ClusterSpec::a40_default();
        let d = c.device_model();
        let legacy = Device::a40();
        assert_eq!(d.peak_flops, legacy.peak_flops);
        assert_eq!(d.mfu, legacy.mfu);
        assert_eq!(c.mem_budget_bytes(), 40_000_000_000);
        assert_eq!(c.devices(), 16);
        assert!(!c.is_heterogeneous());
        // the comm hop must be EXACTLY the 0.5 ms constant the planners
        // charged before the redesign — golden-plan parity depends on it
        assert_eq!(c.comm_hop_ms(), 0.5);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn json_roundtrip_preserves_the_spec() {
        let mut c = ClusterSpec::a40_default().with_devices(8);
        c.name = "a40x8".to_string();
        let j = c.to_json();
        let back = ClusterSpec::from_json(&j).unwrap();
        assert_eq!(back, c);
        // and through the text form too
        let reparsed = Json::parse(&j.render()).unwrap();
        assert_eq!(ClusterSpec::from_json(&reparsed).unwrap(), c);
    }

    #[test]
    fn hetero_json_roundtrip_preserves_the_pool() {
        let c = ClusterSpec::a40_a100_demo();
        let j = c.to_json();
        let back = ClusterSpec::from_json(&j).unwrap();
        assert_eq!(back, c);
        let reparsed = Json::parse(&j.render()).unwrap();
        assert_eq!(ClusterSpec::from_json(&reparsed).unwrap(), c);
        assert!(c.is_heterogeneous());
        assert_eq!(c.devices(), 8);
    }

    #[test]
    fn single_group_renders_the_legacy_schema() {
        // A one-group pool must keep reading AND writing the old
        // single-device form, so pre-hetero files and tools interoperate.
        let mut c = ClusterSpec::a40_default().with_devices(8);
        c.name = "a40x8".to_string();
        let text = c.to_json().render();
        assert!(text.contains("\"devices\""), "{text}");
        assert!(text.contains("\"interconnect_gbps\""), "{text}");
        assert!(!text.contains("\"groups\""), "{text}");
    }

    #[test]
    fn fingerprint_tracks_semantics_not_names() {
        let a = ClusterSpec::a40_default();
        let mut renamed = a.clone();
        renamed.name = "somewhere-else".to_string();
        renamed.groups[0].device.name = "A40-PCIe".to_string();
        assert_eq!(a.fingerprint(), renamed.fingerprint());
        let mut bigger = a.clone();
        bigger.groups[0].device.mem_bytes = 80_000_000_000;
        assert_ne!(a.fingerprint(), bigger.fingerprint());
        let mut slower_net = a.clone();
        slower_net.groups[0].link_gbps = 16.0;
        assert_ne!(a.fingerprint(), slower_net.fingerprint());
        assert_ne!(
            a.fingerprint(),
            a.clone().with_devices(8).fingerprint()
        );
    }

    #[test]
    fn hetero_fingerprint_never_aliases_a_homogeneous_pool() {
        let hetero = ClusterSpec::a40_a100_demo();
        let a40x8 = ClusterSpec::a40_default().with_devices(8);
        assert_ne!(hetero.fingerprint(), a40x8.fingerprint());
        // group order is load-bearing (group indices name assignments)
        let mut flipped = hetero.clone();
        flipped.groups.reverse();
        assert_ne!(hetero.fingerprint(), flipped.fingerprint());
        // single-group fingerprints keep the pre-hetero format
        assert!(a40x8.fingerprint().starts_with("n=8|mem=40000000000|"));
        assert!(!a40x8.fingerprint().contains('+'));
        assert!(hetero.fingerprint().contains('+'));
    }

    #[test]
    fn hop_pricing_takes_the_bottleneck_link() {
        let c = ClusterSpec::a40_a100_demo();
        // within the A40 group: the PCIe-class 0.5 ms
        assert_eq!(c.hop_ms_between(0, 0), 0.5);
        // within the A100 group: the fast NVLink-class link
        assert!(c.hop_ms_between(1, 1) < 0.1);
        // crossing groups pays the slower link
        assert_eq!(c.hop_ms_between(0, 1), 0.5);
        assert_eq!(c.hop_ms_between(1, 0), 0.5);
    }

    #[test]
    fn halved_bandwidth_doubles_the_comm_hop() {
        let a = ClusterSpec::a40_default();
        let mut slow = a.clone();
        slow.groups[0].link_gbps = a.groups[0].link_gbps / 2.0;
        assert_eq!(slow.comm_hop_ms(), 2.0 * a.comm_hop_ms());
    }

    #[test]
    fn validate_rejects_nonsense() {
        let ok = ClusterSpec::a40_default();
        let mut c = ok.clone();
        c.groups[0].count = 0;
        assert!(c.validate().is_err());
        let mut c = ok.clone();
        c.groups[0].device.mfu = 1.5;
        assert!(c.validate().is_err());
        let mut c = ok.clone();
        c.groups[0].device.mem_bytes = 0;
        assert!(c.validate().is_err());
        let mut c = ok.clone();
        c.groups[0].link_gbps = 0.0;
        assert!(c.validate().is_err());
        let mut c = ok;
        c.groups.clear();
        assert!(c.validate().is_err());
        // a bad group anywhere in a heterogeneous pool is caught too
        let mut h = ClusterSpec::a40_a100_demo();
        h.groups[1].device.mfu = 0.0;
        assert!(h.validate().is_err());
    }

    #[test]
    fn from_json_reports_missing_fields() {
        let j = Json::parse(r#"{"devices": 8}"#).unwrap();
        let err = ClusterSpec::from_json(&j).unwrap_err();
        assert!(err.contains("device"), "{err}");
        let j = Json::parse(r#"{"groups": []}"#).unwrap();
        assert!(ClusterSpec::from_json(&j).is_err());
        let j =
            Json::parse(r#"{"groups": [{"count": 4}]}"#).unwrap();
        let err = ClusterSpec::from_json(&j).unwrap_err();
        assert!(err.contains("device"), "{err}");
        assert!(ClusterSpec::load(Path::new(
            "/nonexistent/cluster.json"
        ))
        .is_err());
    }
}
