//! Typed errors at the planning-service boundary.
//!
//! Inside the crate `anyhow` remains the working currency; the facade
//! converts to [`PlanError`] so programmatic callers can match on *what*
//! failed instead of parsing strings.

use std::fmt;

/// Why a [`super::PlanRequest`] could not be answered.
#[derive(Clone, Debug, PartialEq)]
pub enum PlanError {
    /// The [`super::ClusterSpec`] is unusable (bad file, bad field, or a
    /// value outside the model's domain).
    InvalidCluster(String),
    /// The request itself is malformed (e.g. a zero frontier depth).
    InvalidRequest(String),
    /// Every candidate in the search space is infeasible on this
    /// cluster — over the device budget or over the per-device memory.
    NoFeasiblePlan { mllm: String, devices: usize },
    /// No carve of the shared pool can host every tenant of a
    /// [`super::FleetRequest`] within its fairness floor (see
    /// [`super::fleet`]).
    InfeasibleFleet(String),
    /// An [`super::ElasticEvent`] queued on a [`super::FleetRequest`]
    /// cannot be applied (unknown group, losing a whole group, a
    /// duplicate tenant join, an unknown tenant leaving, or a
    /// warm-start carve that no longer fits the fleet).
    InvalidElasticEvent(String),
    /// The persistent plan cache could not be written.
    Cache(String),
    /// The static verifier ([`crate::verify`]) found Error-severity
    /// lints in a plan or carve the service was about to return. Carries
    /// the joined diagnostic lines.
    FailedVerification(String),
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::InvalidCluster(m) => {
                write!(f, "invalid cluster spec: {m}")
            }
            PlanError::InvalidRequest(m) => {
                write!(f, "invalid plan request: {m}")
            }
            PlanError::NoFeasiblePlan { mllm, devices } => write!(
                f,
                "no feasible plan for {mllm} on {devices} device(s): every \
                 candidate exceeds the device budget or the per-device \
                 memory capacity"
            ),
            PlanError::InfeasibleFleet(m) => {
                write!(f, "infeasible fleet: {m}")
            }
            PlanError::InvalidElasticEvent(m) => {
                write!(f, "invalid elastic event: {m}")
            }
            PlanError::Cache(m) => write!(f, "plan cache error: {m}"),
            PlanError::FailedVerification(m) => {
                write!(f, "plan failed verification: {m}")
            }
        }
    }
}

impl std::error::Error for PlanError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_failure() {
        let e = PlanError::NoFeasiblePlan {
            mllm: "VLM-M".to_string(),
            devices: 1,
        };
        let s = e.to_string();
        assert!(s.contains("VLM-M") && s.contains("1 device"), "{s}");
        assert!(PlanError::InvalidCluster("x".into())
            .to_string()
            .contains("cluster"));
        assert!(PlanError::InfeasibleFleet("no carve".into())
            .to_string()
            .contains("fleet"));
        assert!(PlanError::InvalidElasticEvent("gone".into())
            .to_string()
            .contains("elastic"));
    }
}
