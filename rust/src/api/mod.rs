//! The planning-service API: one typed facade over every planning entry
//! point.
//!
//! ```text
//! PlanRequest (builder)  ──►  PlanningService::plan()  ──►  PlanReport
//!   MLLM composition             consults the cache,          chosen Plan,
//!   ClusterSpec                  searches the joint            frontier,
//!   objective                    space, simulates,             memory verdicts,
//!   space overrides              prices comm off the           timeline summary,
//!   cache policy                 cluster's bandwidth           provenance
//! ```
//!
//! The CLI subcommands (`cornstarch plan/tune/memory/fleet/diff`),
//! [`crate::coordinator::tuned_plan`], the `reproduce` tuner experiment,
//! and `examples/autotune.rs` are all thin wrappers over this module —
//! the facade is the stable surface new scenarios build on.
//! Heterogeneous device pools were the first one; [`fleet`]
//! (multi-tenant carving of one shared pool, [`FleetRequest`] →
//! [`PlanningService::plan_fleet`] → [`FleetReport`]) and [`diff`]
//! ([`PlanDiff`], what a re-plan changed) are built the same way.
//!
//! [`ClusterSpec`] is the single source of hardware truth: one or more
//! named device groups, each with per-device memory capacity, a
//! flops/MFU time model, and link bandwidth, loadable from JSON
//! (`--cluster <file>`, see [`cluster`] for both schemas). On a
//! multi-group pool the tuner also searches *where* each pipeline chain
//! lands, so frozen encoders can ride the cheap cards while the LLM
//! claims the big-memory ones (`reproduce hetero`). Errors at this
//! boundary are the typed [`PlanError`], not `anyhow` strings.

pub mod cluster;
pub mod diff;
pub mod error;
pub mod fleet;
pub mod report;

pub use cluster::{ClusterSpec, DeviceClass, DeviceGroup};
pub use diff::{FieldDelta, PlanDiff, StageDelta};
pub use error::PlanError;
pub use fleet::{
    carve_count, enumerate_partitions, ElasticEvent, FleetPartition,
    FleetProvenance, FleetReport, FleetRequest, SearchMode, Tenant,
    TenantReport,
};
pub use report::{
    PlanReport, Provenance, SearchStats, StageVerdict, TimelineSummary,
};

use crate::model::MllmSpec;
use crate::telemetry;
use crate::tuner::{
    self, Objective, SearchSpace, TuneError, TuneRequest,
};

/// Where (and whether) answers persist between queries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CachePolicy {
    /// Search fresh every time (an in-memory cache that dies with the
    /// request).
    Fresh,
    /// Consult and fill the JSON plan cache at this path — backed by
    /// the process-wide two-tier store
    /// ([`crate::tuner::PlanStore::for_path`]): repeat queries are
    /// answered from memory, writes batch to the file.
    File(String),
    /// Share answers process-wide in memory, no disk — the long-lived
    /// service mode ([`crate::tuner::PlanStore::process_memory`]):
    /// repeat queries across threads hit, identical concurrent queries
    /// coalesce onto one search, nothing survives the process.
    Memory,
}

/// A planning query: what to train, on what hardware, optimizing what.
///
/// Build one with [`PlanRequest::default_for`] and the chained setters;
/// the defaults reproduce the paper's scenario (16 × A40, makespan
/// objective, the §6.1 search space, no persistent cache).
#[derive(Clone, Debug)]
pub struct PlanRequest {
    pub mllm: MllmSpec,
    pub cluster: ClusterSpec,
    pub objective: Objective,
    /// Max candidates to simulate; 0 = unlimited (exact over the space).
    pub budget: usize,
    pub threads: usize,
    /// Frontier depth to search for and report.
    pub top: usize,
    pub cache: CachePolicy,
    /// Full search-space override; `None` derives the space from the
    /// cluster ([`SearchSpace::for_cluster`]). The [`PlanRequest::cluster`]
    /// and [`PlanRequest::devices`] builders re-sync an override's device
    /// pool and memory budget; the other bounds are the override's own.
    pub space: Option<SearchSpace>,
    /// Set by a builder that received arguments it cannot honor (e.g.
    /// [`PlanRequest::devices`] on a multi-group pool); builders cannot
    /// return errors, so [`PlanningService::plan`] surfaces this as a
    /// typed [`PlanError::InvalidRequest`] instead of panicking.
    invalid: Option<String>,
}

impl PlanRequest {
    /// The default request for an MLLM: the paper's 16 × A40 testbed,
    /// makespan objective, fresh search. This reproduces what
    /// `cornstarch plan <mllm> --strategy tuned` chose before the facade
    /// existed.
    pub fn default_for(mllm: MllmSpec) -> Self {
        PlanRequest {
            mllm,
            cluster: ClusterSpec::a40_default(),
            objective: Objective::Makespan,
            budget: 0,
            threads: tuner::default_threads(),
            top: tuner::DEFAULT_TOP_K,
            cache: CachePolicy::Fresh,
            space: None,
            invalid: None,
        }
    }

    /// Plan against this cluster instead of the A40 default. Like
    /// [`PlanRequest::devices`], an existing space override is re-synced
    /// to the new cluster's device pool and memory budget.
    pub fn cluster(mut self, cluster: ClusterSpec) -> Self {
        if let Some(space) = &mut self.space {
            space.devices = cluster.devices();
            space.memory_budget_bytes = Some(cluster.mem_budget_bytes());
        }
        self.cluster = cluster;
        self
    }

    /// Resize the cluster's device pool (keeps the device class). Only
    /// meaningful for homogeneous clusters; on a multi-group pool the
    /// request is marked invalid and [`PlanningService::plan`] returns
    /// [`PlanError::InvalidRequest`] (resize a heterogeneous pool per
    /// group via [`PlanRequest::cluster`] instead).
    pub fn devices(mut self, devices: usize) -> Self {
        if self.cluster.is_heterogeneous() {
            self.invalid = Some(
                "`devices` resizes a homogeneous pool; edit the group \
                 counts of a heterogeneous cluster and pass it via \
                 `cluster` instead"
                    .to_string(),
            );
            return self;
        }
        self.cluster = self.cluster.clone().with_devices(devices);
        if let Some(space) = &mut self.space {
            space.devices = devices;
        }
        self
    }

    pub fn objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// Cap how many candidates may be simulated (0 = unlimited).
    pub fn budget(mut self, budget: usize) -> Self {
        self.budget = budget;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Frontier depth to search for and report (>= 1).
    pub fn top(mut self, top: usize) -> Self {
        self.top = top;
        self
    }

    /// Persist (and consult) the plan cache at `path`.
    pub fn cache_file(mut self, path: &str) -> Self {
        self.cache = CachePolicy::File(path.to_string());
        self
    }

    /// Share answers process-wide in memory (no disk) — see
    /// [`CachePolicy::Memory`].
    pub fn cache_memory(mut self) -> Self {
        self.cache = CachePolicy::Memory;
        self
    }

    /// Override the whole search space (see [`PlanRequest::space`]).
    pub fn space(mut self, space: SearchSpace) -> Self {
        self.space = Some(space);
        self
    }

    /// The search space this request resolves to.
    pub fn resolved_space(&self) -> SearchSpace {
        self.space
            .clone()
            .unwrap_or_else(|| SearchSpace::for_cluster(&self.cluster))
    }

    fn to_tune_request(&self) -> TuneRequest {
        TuneRequest {
            spec: self.mllm.clone(),
            cluster: self.cluster.clone(),
            space: self.resolved_space(),
            objective: self.objective,
            budget: self.budget,
            threads: self.threads.max(1),
            top: self.top.max(1),
            cache_path: match &self.cache {
                CachePolicy::Fresh | CachePolicy::Memory => None,
                CachePolicy::File(p) => Some(p.clone()),
            },
            shared_memory: self.cache == CachePolicy::Memory,
        }
    }
}

/// The planning service. Stateless today (state lives in the request's
/// cache policy); the type exists so the surface can grow configuration
/// without breaking callers. Single-job queries go through
/// [`PlanningService::plan`]; multi-tenant queries over one shared pool
/// go through [`PlanningService::plan_fleet`] (see [`fleet`]).
///
/// # Example
///
/// Build a [`PlanRequest`], plan it, read the [`PlanReport`]:
///
/// ```
/// use cornstarch::api::{PlanRequest, PlanningService};
/// use cornstarch::model::{MllmSpec, Size};
///
/// let request = PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::S))
///     .devices(8)
///     .threads(2);
/// let report = PlanningService::new().plan(&request)?;
/// assert!(report.fits_budget());
/// assert_eq!(report.winner().n_gpus, report.timeline.n_gpus);
/// println!("{}", report.render());
/// # Ok::<(), cornstarch::api::PlanError>(())
/// ```
#[derive(Clone, Debug, Default)]
pub struct PlanningService;

impl PlanningService {
    pub fn new() -> Self {
        PlanningService
    }

    /// Answer a [`PlanRequest`]: validate, consult the cache, search if
    /// needed, and package the winner as a [`PlanReport`].
    pub fn plan(&self, req: &PlanRequest) -> Result<PlanReport, PlanError> {
        let _root_span =
            telemetry::span(&format!("plan {}", req.mllm.name()));
        // Per-request accounting that stays correct across threads: a
        // scope travels with this request (into evaluation workers,
        // and NOT into a search some other request's thread leads on
        // our behalf), where a thread-local baseline delta would
        // mis-attribute counts the moment requests share threads.
        let scope = telemetry::Scope::new();
        let _scope_guard = scope.attach();
        if let Some(why) = &req.invalid {
            return Err(PlanError::InvalidRequest(why.clone()));
        }
        req.cluster.validate()?;
        if req.top == 0 {
            return Err(PlanError::InvalidRequest(
                "frontier depth `top` must be >= 1".to_string(),
            ));
        }
        let treq = req.to_tune_request();
        let outcome = tuner::tune_with(&treq).map_err(|e| match e {
            TuneError::NoFeasiblePlan { mllm, devices } => {
                PlanError::NoFeasiblePlan { mllm, devices }
            }
            TuneError::CacheIo(m) => PlanError::Cache(m),
        })?;
        let plan = outcome.instantiate(&req.mllm, &req.cluster);
        // The cache may hold a deeper frontier than this request asked
        // for (a hit only requires `satisfies_top`); trim so the same
        // request answers with the same shape warm or cold.
        let mut frontier = outcome.entry.frontier;
        frontier.truncate(req.top.max(1));
        let m = plan.simulate();
        if telemetry::trace_enabled() {
            // The winner's simulated schedule as a virtual-time trace
            // lane (one per device) — per-stage fwd/bwd slices.
            crate::sim::emit_timeline(
                &m.sim,
                &crate::pipeline::onef1b_tasks(
                    &plan.graph,
                    plan.num_microbatches,
                ),
                &plan.stage_names,
            );
        }
        // Every stage's verdict is held to the budget of the device
        // group it actually lands on — on a heterogeneous pool an
        // encoder stage on a 40 GB card and an LLM stage on an 80 GB
        // card answer to different budgets.
        let budgets = crate::memory::stage_budgets(&plan, &req.cluster);
        let stage_verdicts = plan
            .stage_names
            .iter()
            .enumerate()
            .zip(&plan.stage_mem)
            .zip(&budgets)
            .map(|(((i, name), sm), &budget_bytes)| {
                let g = plan.stage_groups.get(i).copied().unwrap_or(0);
                StageVerdict {
                    stage: name.clone(),
                    device: req.cluster.groups[g].device.name.clone(),
                    peak_bytes: sm.peak_bytes(),
                    budget_bytes,
                }
            })
            .collect();
        let timeline = TimelineSummary {
            iteration_ms: m.iteration_ms,
            throughput: m.throughput,
            throughput_per_gpu: m.throughput_per_gpu,
            bubble_ratio: m.bubble_ratio,
            n_gpus: plan.n_gpus,
            peak_device_bytes: plan.peak_device_bytes(),
        };
        // Decompose the winner's simulated schedule — where every
        // millisecond went (see `crate::profile`). The winning
        // candidate's cp degree names the token distribution whose
        // imbalance is scored.
        let analysis = crate::profile::analyze(
            &plan,
            &m.sim,
            &req.cluster,
            req.mllm.llm_tokens(),
            frontier.first().map(|s| s.candidate.cp).unwrap_or(1),
        );
        telemetry::instant(
            "plan analysis",
            vec![
                (
                    "makespan_ms",
                    crate::util::json::Json::Num(analysis.makespan_ms),
                ),
                (
                    "idle_ms",
                    crate::util::json::Json::Num(analysis.total_idle_ms()),
                ),
                (
                    "comm_ms",
                    crate::util::json::Json::Num(analysis.total_comm_ms()),
                ),
            ],
        );
        // Verification gate: no report leaves the facade unless the
        // winner statically verifies clean (schedule lints over its
        // 1F1B task graph, assignment/memory/cp/frozen lints over its
        // config). Warn-severity findings ride along in the provenance.
        let verification = crate::verify::verify_plan(
            &plan,
            &req.cluster,
            frontier.first().map(|s| &s.candidate),
            req.mllm.llm_tokens(),
        );
        if !verification.is_clean() {
            return Err(PlanError::FailedVerification(
                verification.error_summary(),
            ));
        }
        // Re-source the deterministic counters this request fired from
        // its scope: a scope starts empty, so its snapshot IS the
        // per-request delta — the report's SearchStats block (all
        // zeros except the hit counters on a hit).
        let stats = SearchStats::from_delta(&scope.snapshot());
        let provenance = Provenance {
            planner: "tuner",
            cache_hit: outcome.cache_hit,
            signature: treq.signature(),
            cluster: req.cluster.fingerprint(),
            total_candidates: outcome.total_candidates,
            evaluated: outcome.evaluated,
            pruned: outcome.pruned,
            verifier_clean: true,
            verifier_warnings: verification.warnings(),
            stats,
        };
        Ok(PlanReport {
            plan,
            frontier,
            stage_verdicts,
            timeline,
            provenance,
            analysis,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Size;

    #[test]
    fn default_request_is_the_paper_scenario() {
        let req = PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::M));
        assert_eq!(req.cluster, ClusterSpec::a40_default());
        assert_eq!(req.cluster.devices(), 16);
        assert_eq!(req.objective, Objective::Makespan);
        assert_eq!(req.cache, CachePolicy::Fresh);
        let space = req.resolved_space();
        assert_eq!(space.devices, 16);
        assert_eq!(
            space.memory_budget_bytes,
            Some(req.cluster.mem_budget_bytes())
        );
    }

    #[test]
    fn builders_thread_devices_into_an_overridden_space() {
        let req = PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::S));
        let space = req.resolved_space();
        let req = req.space(space).devices(8);
        assert_eq!(req.cluster.devices(), 8);
        assert_eq!(req.resolved_space().devices, 8);
    }

    #[test]
    fn cluster_builder_resyncs_an_overridden_space() {
        let req = PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::S));
        let space = req.resolved_space(); // A40 bounds: 16 dev, 40 GB
        let mut big = ClusterSpec::a40_default().with_devices(8);
        big.groups[0].device.mem_bytes = 80_000_000_000;
        let req = req.space(space).cluster(big);
        let resolved = req.resolved_space();
        assert_eq!(resolved.devices, 8);
        assert_eq!(resolved.memory_budget_bytes, Some(80_000_000_000));
    }

    #[test]
    fn devices_on_a_heterogeneous_pool_is_a_typed_error_not_a_panic() {
        let req = PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::S))
            .cluster(ClusterSpec::a40_a100_demo())
            .devices(8); // builders cannot error; plan() must
        match PlanningService::new().plan(&req) {
            Err(PlanError::InvalidRequest(m)) => {
                assert!(m.contains("group"), "{m}")
            }
            other => panic!("expected InvalidRequest, got {other:?}"),
        }
    }

    #[test]
    fn invalid_cluster_is_a_typed_error() {
        let mut req =
            PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::S));
        req.cluster.groups[0].device.mfu = 0.0;
        match PlanningService::new().plan(&req) {
            Err(PlanError::InvalidCluster(_)) => {}
            other => panic!("expected InvalidCluster, got {other:?}"),
        }
    }

    #[test]
    fn infeasible_pool_is_a_typed_error() {
        // A whole VLM-M cannot fit one 40 GB device: the capacity filter
        // rejects everything, and the facade says so in a typed way.
        let req = PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::M))
            .devices(1)
            .threads(2);
        match PlanningService::new().plan(&req) {
            Err(PlanError::NoFeasiblePlan { devices, .. }) => {
                assert_eq!(devices, 1)
            }
            other => panic!("expected NoFeasiblePlan, got {other:?}"),
        }
    }

    #[test]
    fn report_carries_verdicts_timeline_and_provenance() {
        let req = PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::S))
            .devices(8)
            .threads(2);
        let report = PlanningService::new().plan(&req).unwrap();
        assert!(!report.provenance.cache_hit);
        assert!(report.provenance.evaluated >= 1);
        assert_eq!(report.provenance.planner, "tuner");
        assert_eq!(
            report.provenance.cluster,
            req.cluster.fingerprint()
        );
        assert_eq!(
            report.stage_verdicts.len(),
            report.plan.stage_names.len()
        );
        assert!(report.fits_budget(), "winner must fit its own cluster");
        assert!(report.timeline.iteration_ms > 0.0);
        assert!(
            (report.timeline.iteration_ms
                - report.winner().iteration_ms)
                .abs()
                < 1e-6
        );
        let text = report.render();
        assert!(text.contains("plan:"), "{text}");
        assert!(text.contains("fits"), "{text}");
    }
}
