//! Fleet planning: N named tenants sharing one heterogeneous pool.
//!
//! A single [`PlanRequest`] claims its whole [`ClusterSpec`]. But a real
//! pool serves *concurrent* jobs — say a VLM-L finetune and a
//! Whisper-encoder pretrain — and the frozen-aware planner makes the
//! split interesting: the finetune's frozen encoder barely needs the big
//! cards, so handing it every A100 while the pretrain rides the A40s can
//! beat a naive even split on both jobs at once.
//!
//! The fleet layer makes that carve a search:
//!
//! ```text
//! FleetRequest ──► PlanningService::plan_fleet() ──► FleetReport
//!   tenants: name → PlanRequest     enumerate pool carves      per-tenant PlanReports,
//!   shared ClusterSpec              (per-group compositions),  the chosen FleetPartition,
//!   fairness floor                  prune by device/memory,    aggregate throughput,
//!                                   plan each sub-pool,        provenance
//!                                   maximize Σ throughput
//! ```
//!
//! A [`FleetPartition`] hands each tenant a per-group device count; every
//! device is assigned to exactly one tenant (a tenant's plan need not
//! *use* its whole slice). Carves are pruned the way
//! [`crate::tuner::space`] prunes chain→group assignments — a tenant
//! slice with zero devices, or with less total memory than the tenant's
//! model weights, is discarded before any search runs. Each surviving
//! sub-pool is planned through the ordinary [`PlanningService::plan`], so
//! the persistent plan cache applies: a tenant's cache entry is keyed by
//! its sub-pool's [`ClusterSpec::fingerprint`], i.e. **fleet entries
//! fingerprint the carve**, and re-carving a pool re-uses every sub-pool
//! plan it has seen before.
//!
//! The winner maximizes aggregate simulated throughput (Σ samples/s)
//! subject to a per-tenant *fairness floor*: each tenant must keep at
//! least `floor ×` the throughput it would get running **alone** on the
//! whole pool. `cornstarch fleet` is the CLI front-end, `reproduce fleet`
//! the demo (two tenants on the 4×A40 + 4×A100 pool beating the naive
//! static halving), and [`PlanDiff`](super::PlanDiff) renders what a
//! re-carve changed.

use std::collections::HashMap;
use std::fmt::Write as _;

use crate::memory;
use crate::model::MllmSpec;
use crate::telemetry::{self, key as tkey};

use super::cluster::{ClusterSpec, DeviceGroup};
use super::diff::PlanDiff;
use super::error::PlanError;
use super::report::{PlanReport, SearchStats};
use super::{PlanRequest, PlanningService};

/// Carve-enumeration guard: a pool whose exhaustive carve count exceeds
/// this is rejected as an [`PlanError::InvalidRequest`] instead of
/// spinning (compositions grow combinatorially with group sizes and
/// tenant count).
pub const MAX_PARTITIONS: usize = 20_000;

/// One named tenant of a [`FleetRequest`]: a workload plus its planning
/// options. The request's own `cluster` is ignored — the fleet search
/// replaces it with each candidate sub-pool carve (cache policy,
/// objective, budget, threads, and frontier depth are honored as-is).
#[derive(Clone, Debug)]
pub struct Tenant {
    pub name: String,
    pub request: PlanRequest,
}

/// A multi-tenant planning query over one shared pool.
#[derive(Clone, Debug)]
pub struct FleetRequest {
    /// The shared hardware truth all tenants carve.
    pub cluster: ClusterSpec,
    pub tenants: Vec<Tenant>,
    /// Fairness floor in `[0, 1]`: each tenant's carved throughput must
    /// be at least this fraction of its *solo* throughput (the whole
    /// pool to itself). `0.0` disables the floor.
    pub fairness_floor: f64,
    /// Fleet-wide plan-cache path, applied to every tenant — those
    /// already added *and* those added later, so the builder order does
    /// not matter (see [`FleetRequest::cache_file`]).
    pub cache: Option<String>,
}

impl FleetRequest {
    pub fn new(cluster: ClusterSpec) -> Self {
        FleetRequest {
            cluster,
            tenants: Vec::new(),
            fairness_floor: 0.0,
            cache: None,
        }
    }

    /// Add a named tenant (names must be unique within the request). A
    /// fleet-wide [`FleetRequest::cache_file`] set earlier is applied to
    /// the new tenant's request.
    pub fn tenant(mut self, name: &str, mut request: PlanRequest) -> Self {
        if let Some(path) = &self.cache {
            request = request.cache_file(path);
        }
        self.tenants.push(Tenant { name: name.to_string(), request });
        self
    }

    /// Set the per-tenant fairness floor (see [`FleetRequest::fairness_floor`]).
    pub fn fairness_floor(mut self, floor: f64) -> Self {
        self.fairness_floor = floor;
        self
    }

    /// Point every tenant's plan cache at `path` — tenants already
    /// added are rewritten and tenants added later inherit it, so this
    /// composes with [`FleetRequest::tenant`] in either order. Entries
    /// are keyed by each sub-pool carve's fingerprint, so tenants
    /// sharing one file never alias each other's answers.
    pub fn cache_file(mut self, path: &str) -> Self {
        self.cache = Some(path.to_string());
        for t in &mut self.tenants {
            t.request = t.request.clone().cache_file(path);
        }
        self
    }

    /// The baseline carve operators reach for without a search: split
    /// every group's devices evenly across tenants (earlier tenants
    /// absorb the remainder). For two tenants this is the naive static
    /// halving `reproduce fleet` compares against. On a tenant-less
    /// request this returns an empty (invalid) partition so the planning
    /// entry points can answer with their typed
    /// [`PlanError::InvalidRequest`] instead of panicking here.
    pub fn naive_partition(&self) -> FleetPartition {
        if self.tenants.is_empty() {
            return FleetPartition { slices: Vec::new() };
        }
        FleetPartition::even(&self.cluster, self.tenants.len())
    }

    fn validate(&self) -> Result<(), PlanError> {
        self.cluster.validate()?;
        if self.tenants.is_empty() {
            return Err(PlanError::InvalidRequest(
                "a fleet request needs at least one tenant".to_string(),
            ));
        }
        for (i, t) in self.tenants.iter().enumerate() {
            if self.tenants[..i].iter().any(|o| o.name == t.name) {
                return Err(PlanError::InvalidRequest(format!(
                    "duplicate tenant name {:?}",
                    t.name
                )));
            }
        }
        if !self.fairness_floor.is_finite()
            || !(0.0..=1.0).contains(&self.fairness_floor)
        {
            return Err(PlanError::InvalidRequest(format!(
                "fairness floor must be in [0, 1], got {}",
                self.fairness_floor
            )));
        }
        Ok(())
    }
}

/// One way of splitting a shared pool across tenants:
/// `slices[tenant][group]` devices of cluster group `group` go to tenant
/// `tenant`. The carves [`enumerate_partitions`] produces assign every
/// device of every group to exactly one tenant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FleetPartition {
    pub slices: Vec<Vec<usize>>,
}

impl FleetPartition {
    /// The even split (see [`FleetRequest::naive_partition`]).
    pub fn even(cluster: &ClusterSpec, tenants: usize) -> Self {
        assert!(tenants >= 1, "a partition needs at least one tenant");
        let slices = (0..tenants)
            .map(|t| {
                cluster
                    .groups
                    .iter()
                    .map(|g| {
                        g.count / tenants
                            + usize::from(t < g.count % tenants)
                    })
                    .collect()
            })
            .collect();
        FleetPartition { slices }
    }

    /// Total devices tenant `t` holds across all groups.
    pub fn tenant_devices(&self, t: usize) -> usize {
        self.slices[t].iter().sum()
    }

    /// Does this carve fit `cluster` — slice widths matching the group
    /// list and no group's devices double-assigned (per-group sums within
    /// the group's count)?
    pub fn respects(&self, cluster: &ClusterSpec) -> bool {
        let n_groups = cluster.groups.len();
        if self.slices.iter().any(|s| s.len() != n_groups) {
            return false;
        }
        cluster.groups.iter().enumerate().all(|(g, grp)| {
            self.slices.iter().map(|s| s[g]).sum::<usize>() <= grp.count
        })
    }

    /// Tenant `t`'s slice as a standalone [`ClusterSpec`] (zero-count
    /// groups dropped — [`ClusterSpec::validate`] rejects empty groups).
    /// `None` when the slice holds no devices at all. The sub-pool keeps
    /// each group's device class and link, so its fingerprint — and with
    /// it every cache entry planned against it — identifies the carve.
    pub fn subpool(
        &self,
        cluster: &ClusterSpec,
        t: usize,
        tenant_name: &str,
    ) -> Option<ClusterSpec> {
        let groups: Vec<DeviceGroup> = cluster
            .groups
            .iter()
            .zip(&self.slices[t])
            .filter(|(_, &count)| count > 0)
            .map(|(g, &count)| DeviceGroup {
                device: g.device.clone(),
                count,
                link_gbps: g.link_gbps,
            })
            .collect();
        if groups.is_empty() {
            return None;
        }
        Some(ClusterSpec {
            name: format!("{}:{}", cluster.name, tenant_name),
            groups,
        })
    }

    /// Compact stable form for provenance and logs, e.g. `[0,4]+[4,0]`
    /// (tenant-major, group-minor).
    pub fn label(&self) -> String {
        self.slices
            .iter()
            .map(|s| {
                let cells: Vec<String> =
                    s.iter().map(|c| c.to_string()).collect();
                format!("[{}]", cells.join(","))
            })
            .collect::<Vec<_>>()
            .join("+")
    }
}

/// All length-`t` vectors of non-negative counts summing exactly to `n`.
fn compositions(n: usize, t: usize) -> Vec<Vec<usize>> {
    if t == 1 {
        return vec![vec![n]];
    }
    let mut out = Vec::new();
    for first in 0..=n {
        for mut rest in compositions(n - first, t - 1) {
            let mut v = Vec::with_capacity(t);
            v.push(first);
            v.append(&mut rest);
            out.push(v);
        }
    }
    out
}

/// `C(n + t - 1, t - 1)` — how many compositions [`compositions`] yields,
/// computed without materializing them (the enumeration guard).
fn compositions_count(n: usize, t: usize) -> u128 {
    let a = (n + t - 1) as u128;
    let mut b = (t - 1) as u128;
    if b > a - b {
        b = a - b;
    }
    let mut r: u128 = 1;
    for i in 1..=b {
        r = r.saturating_mul(a - b + i) / i;
    }
    r
}

/// Every exact carve of `cluster` across `tenants`: the cross product of
/// per-group compositions. Each group's devices are fully assigned (sum
/// over tenants equals the group count), so no device is ever idle by
/// construction and none is double-assigned — the invariants
/// `tests/fleet_checks.rs` holds this enumeration to.
pub fn enumerate_partitions(
    cluster: &ClusterSpec,
    tenants: usize,
) -> Vec<FleetPartition> {
    assert!(tenants >= 1, "a partition needs at least one tenant");
    let per_group: Vec<Vec<Vec<usize>>> = cluster
        .groups
        .iter()
        .map(|g| compositions(g.count, tenants))
        .collect();
    let mut parts: Vec<Vec<Vec<usize>>> = vec![vec![Vec::new(); tenants]];
    for options in &per_group {
        let mut next = Vec::with_capacity(parts.len() * options.len());
        for base in &parts {
            for opt in options {
                let mut p = base.clone();
                for (t, slice) in p.iter_mut().enumerate() {
                    slice.push(opt[t]);
                }
                next.push(p);
            }
        }
        parts = next;
    }
    parts
        .into_iter()
        .map(|slices| FleetPartition { slices })
        .collect()
}

/// A lower bound on the pool memory a tenant's workload needs anywhere:
/// its model weights (bf16), which must all be resident at least once
/// regardless of sharding or frozen policy. Slices whose total memory
/// cannot even hold the weights are pruned before any search runs.
fn min_weight_bytes(spec: &MllmSpec) -> u64 {
    let mut params = spec.llm.params();
    if let Some(v) = &spec.vision {
        params += v.params();
    }
    if let Some(a) = &spec.audio {
        params += a.params();
    }
    params * memory::PARAM_BYTES
}

/// Total memory (bytes) of tenant `t`'s slice under `part`.
fn slice_mem_bytes(
    part: &FleetPartition,
    cluster: &ClusterSpec,
    t: usize,
) -> u64 {
    cluster
        .groups
        .iter()
        .zip(&part.slices[t])
        .map(|(g, &count)| g.device.mem_bytes * count as u64)
        .sum()
}

/// One tenant's share of a [`FleetReport`].
#[derive(Clone, Debug)]
pub struct TenantReport {
    pub name: String,
    /// Devices granted per cluster group (this tenant's row of the
    /// chosen [`FleetPartition`]).
    pub slice: Vec<usize>,
    /// Throughput (samples/s) the tenant would get with the whole pool
    /// to itself — the fairness baseline.
    pub solo_throughput: f64,
    pub report: PlanReport,
}

impl TenantReport {
    /// Simulated whole-job throughput under the carve (samples/s).
    pub fn throughput(&self) -> f64 {
        self.report.timeline.throughput
    }

    /// Carved throughput as a fraction of solo throughput — the quantity
    /// the fairness floor constrains.
    pub fn fairness(&self) -> f64 {
        if self.solo_throughput > 0.0 {
            self.throughput() / self.solo_throughput
        } else {
            0.0
        }
    }
}

/// How a fleet answer was found.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetProvenance {
    /// Fingerprint of the shared pool.
    pub cluster: String,
    pub fairness_floor: f64,
    /// Carves enumerated.
    pub partitions_considered: usize,
    /// Carves discarded by the static device/memory filter.
    pub partitions_pruned: usize,
    /// Distinct (tenant, sub-pool) planning queries actually issued
    /// (memoized within the search; cache hits still count).
    pub plans_searched: usize,
    /// Carves where every tenant was feasible and above the floor.
    pub partitions_feasible: usize,
    /// True when the returned carve passed the static verifier's fleet
    /// lints (no device double-assigned across tenants, slice widths
    /// matching the pool) — see [`crate::verify::verify_partition`].
    pub verifier_clean: bool,
    /// The aggregate search counters the whole fleet call fired
    /// (summed over every per-tenant sub-pool search), sourced from
    /// the [`crate::telemetry`] registry. Deterministic.
    pub stats: SearchStats,
}

/// The fleet search's answer (see [`PlanningService::plan_fleet`]).
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Display name of the shared pool.
    pub cluster_name: String,
    /// Device-class display name per cluster group, for rendering the
    /// carve (`["A40", "A100-80G"]`).
    pub group_names: Vec<String>,
    /// Per-tenant answers, in request order.
    pub tenants: Vec<TenantReport>,
    /// The chosen carve (rows parallel to `tenants`).
    pub partition: FleetPartition,
    /// Σ tenant throughput (samples/s) — the searched objective.
    pub aggregate_throughput: f64,
    pub provenance: FleetProvenance,
}

impl FleetReport {
    /// Per-tenant [`PlanDiff`]s from `baseline`'s allocation to this one.
    /// Tenants are matched **by name** (not position), so reports whose
    /// requests listed tenants in different orders still pair correctly;
    /// tenants absent from the baseline are skipped. The front-end of
    /// `cornstarch diff fleet`.
    pub fn diff_from(
        &self,
        baseline: &FleetReport,
    ) -> Vec<(String, PlanDiff)> {
        self.tenants
            .iter()
            .filter_map(|s| {
                baseline
                    .tenants
                    .iter()
                    .find(|b| b.name == s.name)
                    .map(|b| {
                        (
                            s.name.clone(),
                            PlanDiff::between(&b.report, &s.report),
                        )
                    })
            })
            .collect()
    }

    /// Human-readable rendering: the carve, each tenant's plan line, the
    /// aggregate, and provenance.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let total: usize = self
            .partition
            .slices
            .iter()
            .map(|sl| sl.iter().sum::<usize>())
            .sum();
        let _ = writeln!(
            s,
            "fleet plan — {} tenants on {} ({} GPUs, fairness floor {:.2})",
            self.tenants.len(),
            self.cluster_name,
            total,
            self.provenance.fairness_floor
        );
        s.push_str("  carve:\n");
        for t in &self.tenants {
            let cells: Vec<String> = t
                .slice
                .iter()
                .zip(&self.group_names)
                .map(|(c, g)| format!("{c}x {g}"))
                .collect();
            let _ = writeln!(s, "    {:<18} {}", t.name, cells.join(" + "));
        }
        s.push_str("  tenants:\n");
        for t in &self.tenants {
            let _ = writeln!(
                s,
                "    {:<18} {} | iteration {:.1} ms | {:.2} input/s | \
                 {:.2}x solo",
                t.name,
                t.report.winner().candidate.label(),
                t.report.timeline.iteration_ms,
                t.throughput(),
                t.fairness()
            );
        }
        let _ = writeln!(
            s,
            "  aggregate: {:.2} input/s",
            self.aggregate_throughput
        );
        let _ = writeln!(
            s,
            "  provenance: {} carves considered, {} pruned, {} sub-pool \
             plans, {} feasible | verifier {}",
            self.provenance.partitions_considered,
            self.provenance.partitions_pruned,
            self.provenance.plans_searched,
            self.provenance.partitions_feasible,
            if self.provenance.verifier_clean { "clean" } else { "FAILED" }
        );
        let _ = writeln!(
            s,
            "  search stats: {}",
            self.provenance.stats.render_line()
        );
        s
    }
}

impl PlanningService {
    /// Each tenant alone on the whole shared pool — the fairness
    /// baselines. A tenant that cannot run even there makes the fleet
    /// infeasible outright.
    fn solo_reports(
        &self,
        req: &FleetRequest,
    ) -> Result<Vec<PlanReport>, PlanError> {
        req.tenants
            .iter()
            .map(|t| {
                self.plan(
                    &t.request.clone().cluster(req.cluster.clone()),
                )
                .map_err(|e| match e {
                    PlanError::NoFeasiblePlan { .. } => {
                        PlanError::InfeasibleFleet(format!(
                            "tenant {:?} is infeasible even with the whole \
                             pool to itself: {e}",
                            t.name
                        ))
                    }
                    other => other,
                })
            })
            .collect()
    }

    /// Search the carve space: enumerate exact partitions, prune slices
    /// that cannot host their tenant, plan every surviving sub-pool
    /// (memoized by carve fingerprint), and keep the feasible carve with
    /// the highest aggregate throughput that honors the fairness floor.
    pub fn plan_fleet(
        &self,
        req: &FleetRequest,
    ) -> Result<FleetReport, PlanError> {
        req.validate()?;
        let n_tenants = req.tenants.len();
        let _fleet_span = telemetry::span(&format!(
            "plan_fleet {} tenants={n_tenants}",
            req.cluster.name
        ));
        // Provenance is re-sourced from the telemetry registry: the
        // loop below bumps the named counters at exactly the sites the
        // bespoke locals used to live, and the delta over this call
        // becomes the report's FleetProvenance — same numbers, one
        // accounting door.
        let counters_before = telemetry::snapshot();
        // Saturating fold: the guard itself must not overflow on a pool
        // whose carve count exceeds u128 (saturation lands far above the
        // cap, which is all the comparison needs).
        let carve_count: u128 = req
            .cluster
            .groups
            .iter()
            .map(|g| compositions_count(g.count, n_tenants))
            .fold(1u128, |acc, c| acc.saturating_mul(c));
        if carve_count > MAX_PARTITIONS as u128 {
            return Err(PlanError::InvalidRequest(format!(
                "{carve_count} carves of {} across {n_tenants} tenants \
                 exceed the exhaustive-search cap of {MAX_PARTITIONS}; \
                 reduce the tenant count or split the pool",
                req.cluster.name
            )));
        }
        let solo = self.solo_reports(req)?;
        let min_bytes: Vec<u64> = req
            .tenants
            .iter()
            .map(|t| min_weight_bytes(&t.request.mllm))
            .collect();

        let mut memo: HashMap<(usize, String), Option<PlanReport>> =
            HashMap::new();
        let mut best: Option<(f64, FleetPartition, Vec<PlanReport>)> = None;
        let partitions = enumerate_partitions(&req.cluster, n_tenants);
        telemetry::count(tkey::CARVES_CONSIDERED, partitions.len() as u64);
        'carves: for part in partitions {
            // Static pruning, the carve-level analogue of the tuner's
            // per-group capacity/memory filters: an empty slice, or one
            // whose total memory cannot hold the tenant's weights, dies
            // before any search.
            for t in 0..n_tenants {
                if part.tenant_devices(t) == 0
                    || slice_mem_bytes(&part, &req.cluster, t) < min_bytes[t]
                {
                    telemetry::incr(tkey::CARVES_PRUNED);
                    continue 'carves;
                }
            }
            let mut reports: Vec<PlanReport> =
                Vec::with_capacity(n_tenants);
            let mut ok = true;
            for (t, tenant) in req.tenants.iter().enumerate() {
                let sub = part
                    .subpool(&req.cluster, t, &tenant.name)
                    .expect("pruning kept only non-empty slices");
                let key = (t, sub.fingerprint());
                let cached = match memo.get(&key) {
                    Some(r) => r.clone(),
                    None => {
                        let r = match self
                            .plan(&tenant.request.clone().cluster(sub))
                        {
                            Ok(rep) => Some(rep),
                            Err(PlanError::NoFeasiblePlan { .. }) => None,
                            Err(e) => return Err(e),
                        };
                        telemetry::incr(tkey::PLANS_SEARCHED);
                        memo.insert(key, r.clone());
                        r
                    }
                };
                match cached {
                    Some(rep) => reports.push(rep),
                    None => {
                        ok = false;
                        break;
                    }
                }
            }
            if !ok {
                continue;
            }
            if reports.iter().zip(&solo).any(|(r, s)| {
                r.timeline.throughput
                    < req.fairness_floor * s.timeline.throughput
            }) {
                continue;
            }
            telemetry::incr(tkey::CARVES_FEASIBLE);
            let agg: f64 =
                reports.iter().map(|r| r.timeline.throughput).sum();
            if best.as_ref().is_none_or(|(b, _, _)| agg > *b + 1e-12) {
                best = Some((agg, part, reports));
            }
        }
        let fired = telemetry::snapshot().delta_since(&counters_before);
        let Some((_, partition, reports)) = best else {
            return Err(PlanError::InfeasibleFleet(format!(
                "no carve of {} hosts all {n_tenants} tenants within the \
                 {:.2} fairness floor ({} considered, {} pruned)",
                req.cluster.name,
                req.fairness_floor,
                fired.get(tkey::CARVES_CONSIDERED),
                fired.get(tkey::CARVES_PRUNED),
            )));
        };
        // Verification gate: the winning carve must pass the fleet
        // lints (no double-assignment, slice widths matching the pool)
        // before a report leaves the facade. Idle headroom is a Warn
        // and rides along; Errors refuse the report.
        let carve_verdict =
            crate::verify::verify_partition(&partition, &req.cluster);
        if !carve_verdict.is_clean() {
            return Err(PlanError::FailedVerification(
                carve_verdict.error_summary(),
            ));
        }
        Ok(self.assemble(
            req,
            partition,
            reports,
            &solo,
            FleetProvenance {
                cluster: req.cluster.fingerprint(),
                fairness_floor: req.fairness_floor,
                partitions_considered: fired.get(tkey::CARVES_CONSIDERED)
                    as usize,
                partitions_pruned: fired.get(tkey::CARVES_PRUNED) as usize,
                plans_searched: fired.get(tkey::PLANS_SEARCHED) as usize,
                partitions_feasible: fired.get(tkey::CARVES_FEASIBLE)
                    as usize,
                verifier_clean: true,
                stats: SearchStats::from_delta(&fired),
            },
        ))
    }

    /// Evaluate one *fixed* carve (e.g. the naive even split) through the
    /// same per-tenant planning path, without enforcing the fairness
    /// floor — the floor constrains the *search*; a handed-in carve is
    /// reported as-is so baselines can be compared and diffed.
    pub fn plan_fleet_partition(
        &self,
        req: &FleetRequest,
        partition: &FleetPartition,
    ) -> Result<FleetReport, PlanError> {
        req.validate()?;
        if partition.slices.len() != req.tenants.len()
            || !partition.respects(&req.cluster)
        {
            return Err(PlanError::InvalidRequest(format!(
                "partition {} does not fit {} tenants on {}",
                partition.label(),
                req.tenants.len(),
                req.cluster.name
            )));
        }
        // The handed-in carve goes through the same static verifier the
        // search path gates on. `respects()` above already refused the
        // Error cases with a typed InvalidRequest; this keeps the gate
        // mandatory even if the two checks ever drift, and surfaces
        // idle-headroom warnings under `-v`.
        let carve_verdict =
            crate::verify::verify_partition(partition, &req.cluster);
        if !carve_verdict.is_clean() {
            return Err(PlanError::FailedVerification(
                carve_verdict.error_summary(),
            ));
        }
        for d in &carve_verdict.diagnostics {
            telemetry::debug(&format!("fleet carve: {}", d.render_line()));
        }
        let _carve_span = telemetry::span(&format!(
            "plan_fleet_partition {}",
            partition.label()
        ));
        let counters_before = telemetry::snapshot();
        let solo = self.solo_reports(req)?;
        let mut reports = Vec::with_capacity(req.tenants.len());
        for (t, tenant) in req.tenants.iter().enumerate() {
            let Some(sub) =
                partition.subpool(&req.cluster, t, &tenant.name)
            else {
                return Err(PlanError::InfeasibleFleet(format!(
                    "tenant {:?} holds no devices under carve {}",
                    tenant.name,
                    partition.label()
                )));
            };
            telemetry::incr(tkey::PLANS_SEARCHED);
            let rep = self
                .plan(&tenant.request.clone().cluster(sub))
                .map_err(|e| match e {
                    PlanError::NoFeasiblePlan { .. } => {
                        PlanError::InfeasibleFleet(format!(
                            "tenant {:?} is infeasible on its slice under \
                             carve {}: {e}",
                            tenant.name,
                            partition.label()
                        ))
                    }
                    other => other,
                })?;
            reports.push(rep);
        }
        let fired = telemetry::snapshot().delta_since(&counters_before);
        let provenance = FleetProvenance {
            cluster: req.cluster.fingerprint(),
            // a handed-in carve is evaluated floor-free; recording the
            // request's floor here would render a below-floor baseline
            // as a violated constraint rather than one never applied
            fairness_floor: 0.0,
            partitions_considered: 1,
            partitions_pruned: 0,
            plans_searched: fired.get(tkey::PLANS_SEARCHED) as usize,
            partitions_feasible: 1,
            verifier_clean: true,
            stats: SearchStats::from_delta(&fired),
        };
        Ok(self.assemble(req, partition.clone(), reports, &solo, provenance))
    }

    fn assemble(
        &self,
        req: &FleetRequest,
        partition: FleetPartition,
        reports: Vec<PlanReport>,
        solo: &[PlanReport],
        provenance: FleetProvenance,
    ) -> FleetReport {
        let aggregate_throughput =
            reports.iter().map(|r| r.timeline.throughput).sum();
        let tenants = req
            .tenants
            .iter()
            .zip(reports)
            .zip(solo)
            .enumerate()
            .map(|(t, ((tenant, report), s))| TenantReport {
                name: tenant.name.clone(),
                slice: partition.slices[t].clone(),
                solo_throughput: s.timeline.throughput,
                report,
            })
            .collect();
        FleetReport {
            cluster_name: req.cluster.name.clone(),
            group_names: req
                .cluster
                .groups
                .iter()
                .map(|g| g.device.name.clone())
                .collect(),
            tenants,
            partition,
            aggregate_throughput,
            provenance,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Size;

    fn small_request(spec: MllmSpec) -> PlanRequest {
        PlanRequest::default_for(spec).threads(2)
    }

    fn tiny_fleet(devices: usize) -> FleetRequest {
        FleetRequest::new(
            ClusterSpec::a40_default().with_devices(devices),
        )
        .tenant("a", small_request(MllmSpec::vlm(Size::S, Size::S)))
        .tenant("b", small_request(MllmSpec::alm(Size::S, Size::S)))
        .fairness_floor(0.1)
    }

    #[test]
    fn compositions_cover_exactly_and_count_matches() {
        let c = compositions(4, 2);
        assert_eq!(c.len(), 5);
        assert_eq!(compositions_count(4, 2), 5);
        for v in &c {
            assert_eq!(v.len(), 2);
            assert_eq!(v.iter().sum::<usize>(), 4);
        }
        assert_eq!(compositions(3, 1), vec![vec![3]]);
        assert_eq!(compositions_count(3, 1), 1);
        assert_eq!(compositions(2, 3).len(), 6); // C(4, 2)
        assert_eq!(compositions_count(2, 3), 6);
    }

    #[test]
    fn partitions_assign_every_device_exactly_once() {
        let cluster = ClusterSpec::a40_a100_demo();
        let parts = enumerate_partitions(&cluster, 2);
        assert_eq!(parts.len(), 25); // 5 splits of each 4-device group
        for p in &parts {
            assert!(p.respects(&cluster));
            for (g, grp) in cluster.groups.iter().enumerate() {
                let sum: usize = p.slices.iter().map(|s| s[g]).sum();
                assert_eq!(sum, grp.count, "{}", p.label());
            }
        }
        // all distinct
        for (i, p) in parts.iter().enumerate() {
            assert!(!parts[..i].contains(p));
        }
    }

    #[test]
    fn even_partition_is_the_naive_halving() {
        let cluster = ClusterSpec::a40_a100_demo();
        let p = FleetPartition::even(&cluster, 2);
        assert_eq!(p.slices, vec![vec![2, 2], vec![2, 2]]);
        assert!(p.respects(&cluster));
        // remainders go to earlier tenants
        let odd = ClusterSpec::a40_default().with_devices(5);
        let p3 = FleetPartition::even(&odd, 3);
        assert_eq!(p3.slices, vec![vec![2], vec![2], vec![1]]);
        assert_eq!(p3.label(), "[2]+[2]+[1]");
    }

    #[test]
    fn subpool_keeps_device_classes_and_drops_empty_groups() {
        let cluster = ClusterSpec::a40_a100_demo();
        let p = FleetPartition { slices: vec![vec![0, 4], vec![4, 0]] };
        let sub = p.subpool(&cluster, 0, "llm-job").unwrap();
        assert_eq!(sub.groups.len(), 1);
        assert_eq!(sub.groups[0].device.name, "A100-80G");
        assert_eq!(sub.groups[0].count, 4);
        assert!(sub.validate().is_ok());
        assert!(sub.name.contains("llm-job"));
        let empty = FleetPartition { slices: vec![vec![0, 0]] };
        assert!(empty.subpool(&cluster, 0, "x").is_none());
        // two different carves of the same pool have different
        // fingerprints — what keys the plan cache per carve
        let q = FleetPartition { slices: vec![vec![1, 3], vec![3, 1]] };
        assert_ne!(
            sub.fingerprint(),
            q.subpool(&cluster, 0, "llm-job").unwrap().fingerprint()
        );
    }

    #[test]
    fn fleet_request_validation_catches_nonsense() {
        let cluster = ClusterSpec::a40_default().with_devices(4);
        let empty = FleetRequest::new(cluster.clone());
        assert!(matches!(
            PlanningService::new().plan_fleet(&empty),
            Err(PlanError::InvalidRequest(_))
        ));
        let dup = FleetRequest::new(cluster.clone())
            .tenant("t", small_request(MllmSpec::vlm(Size::S, Size::S)))
            .tenant("t", small_request(MllmSpec::alm(Size::S, Size::S)));
        assert!(matches!(
            PlanningService::new().plan_fleet(&dup),
            Err(PlanError::InvalidRequest(_))
        ));
        let bad_floor = tiny_fleet(4).fairness_floor(1.5);
        assert!(matches!(
            PlanningService::new().plan_fleet(&bad_floor),
            Err(PlanError::InvalidRequest(_))
        ));
    }

    #[test]
    fn tiny_pool_fleet_carves_and_aggregates() {
        let req = tiny_fleet(4);
        let service = PlanningService::new();
        let report = service.plan_fleet(&req).unwrap();
        assert_eq!(report.tenants.len(), 2);
        assert!(report.partition.respects(&req.cluster));
        // every device assigned, none double-assigned
        let total: usize =
            (0..2).map(|t| report.partition.tenant_devices(t)).sum();
        assert_eq!(total, 4);
        for t in &report.tenants {
            assert!(t.throughput() > 0.0);
            assert!(t.report.fits_budget());
            assert!(
                t.fairness() >= req.fairness_floor,
                "{} below floor",
                t.name
            );
            // the plan fits inside the granted slice
            assert!(t.report.plan.n_gpus <= t.slice.iter().sum::<usize>());
        }
        let agg: f64 =
            report.tenants.iter().map(TenantReport::throughput).sum();
        assert!((agg - report.aggregate_throughput).abs() < 1e-9);
        assert!(report.provenance.partitions_feasible >= 1);
        assert_eq!(report.provenance.partitions_considered, 5);
        let text = report.render();
        assert!(text.contains("carve:"), "{text}");
        assert!(text.contains("aggregate:"), "{text}");
    }

    #[test]
    fn searched_carve_never_loses_to_the_even_split() {
        let req = tiny_fleet(4);
        let service = PlanningService::new();
        let searched = service.plan_fleet(&req).unwrap();
        let naive = service
            .plan_fleet_partition(&req, &req.naive_partition())
            .unwrap();
        assert!(
            searched.aggregate_throughput
                >= naive.aggregate_throughput - 1e-9,
            "searched {:.3} vs naive {:.3}",
            searched.aggregate_throughput,
            naive.aggregate_throughput
        );
        // diffing the two allocations is stable and structured
        let diffs = searched.diff_from(&naive);
        assert_eq!(diffs.len(), 2);
        let again = searched.diff_from(&naive);
        for ((name, d), (name2, d2)) in diffs.iter().zip(&again) {
            assert!(!name.is_empty());
            assert_eq!(name, name2);
            assert_eq!(d.render(), d2.render());
        }
    }

    #[test]
    fn one_device_pool_cannot_host_two_tenants() {
        let req = tiny_fleet(1);
        match PlanningService::new().plan_fleet(&req) {
            Err(PlanError::InfeasibleFleet(m)) => {
                assert!(m.contains("carve") || m.contains("tenant"), "{m}")
            }
            other => panic!("expected InfeasibleFleet, got {other:?}"),
        }
    }

    #[test]
    fn partition_mode_rejects_misshapen_carves() {
        let req = tiny_fleet(4);
        let service = PlanningService::new();
        // wrong tenant arity
        let bad = FleetPartition { slices: vec![vec![4]] };
        assert!(matches!(
            service.plan_fleet_partition(&req, &bad),
            Err(PlanError::InvalidRequest(_))
        ));
        // over-assigned group
        let over = FleetPartition { slices: vec![vec![3], vec![3]] };
        assert!(matches!(
            service.plan_fleet_partition(&req, &over),
            Err(PlanError::InvalidRequest(_))
        ));
        // empty slice surfaces as an infeasible fleet, not a panic
        let empty = FleetPartition { slices: vec![vec![4], vec![0]] };
        assert!(matches!(
            service.plan_fleet_partition(&req, &empty),
            Err(PlanError::InfeasibleFleet(_))
        ));
    }

    #[test]
    fn carve_explosion_is_a_typed_error() {
        // 3 groups of 40 devices and 6 tenants: astronomically many
        // carves — must be rejected, not enumerated.
        let mut cluster = ClusterSpec::a40_a100_demo();
        cluster.groups[0].count = 40;
        cluster.groups[1].count = 40;
        cluster.groups.push(cluster.groups[0].clone());
        let mut req = FleetRequest::new(cluster);
        for i in 0..6 {
            req = req.tenant(
                &format!("t{i}"),
                small_request(MllmSpec::vlm(Size::S, Size::S)),
            );
        }
        match PlanningService::new().plan_fleet(&req) {
            Err(PlanError::InvalidRequest(m)) => {
                assert!(m.contains("carves"), "{m}")
            }
            other => panic!("expected InvalidRequest, got {other:?}"),
        }
    }

    #[test]
    fn cache_file_applies_regardless_of_builder_order() {
        use crate::api::CachePolicy;
        let cluster = ClusterSpec::a40_default().with_devices(4);
        let before = FleetRequest::new(cluster.clone())
            .cache_file("/tmp/fleet.json")
            .tenant("a", small_request(MllmSpec::vlm(Size::S, Size::S)));
        let after = FleetRequest::new(cluster)
            .tenant("a", small_request(MllmSpec::vlm(Size::S, Size::S)))
            .cache_file("/tmp/fleet.json");
        for req in [&before, &after] {
            assert_eq!(
                req.tenants[0].request.cache,
                CachePolicy::File("/tmp/fleet.json".to_string())
            );
        }
    }

    #[test]
    fn min_weight_bytes_is_the_bf16_model_footprint() {
        let spec = MllmSpec::vlm(Size::S, Size::S);
        let mut want = spec.llm.params();
        want += spec.vision.as_ref().unwrap().params();
        assert_eq!(min_weight_bytes(&spec), want * 2);
        // pruning threshold: one tiny slice cannot host an L-sized LLM
        let big = MllmSpec::vlm(Size::L, Size::L);
        assert!(min_weight_bytes(&big) > 40_000_000_000);
    }
}
