//! `PlanDiff` — a stable, human-readable delta between two
//! [`PlanReport`]s.
//!
//! A re-plan (a new cluster file, a deepened search, a fleet re-carve)
//! changes an answer operators may already be running. The diff says
//! *what* changed, in a deterministic order, so "the tuner moved the
//! encoder off the A40s and grew the microbatch count" is one glance,
//! not two full reports side by side:
//!
//! * **configuration** — winner-candidate fields (strategy, pipeline
//!   depths, TP/CP, microbatches, frozen policy, chain→group assignment)
//!   and the cluster fingerprint the plan is valid for;
//! * **stages** — stages added or removed, stages moved to another
//!   device class, and per-stage peak-memory changes;
//! * **timeline** — iteration time, whole-job throughput, GPU count,
//!   and peak per-GPU memory.
//!
//! Diffing a report against itself yields an empty diff whose rendering
//! is the fixed string `"plan diff: no differences\n"` (held by a
//! golden-file test). The CLI front-ends are `cornstarch diff <mllm>`
//! (one model, two clusters) and `cornstarch diff fleet` (naive split vs
//! searched carve, per tenant — see [`super::fleet`]).

use std::collections::HashMap;
use std::fmt::Write as _;

use crate::memory;

use super::report::PlanReport;

/// One scalar field that differs between the two plans.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FieldDelta {
    /// Field name (`strategy`, `tp`, `iteration`, …).
    pub field: &'static str,
    pub before: String,
    pub after: String,
}

/// One per-stage difference.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StageDelta {
    /// The stage exists in both plans but landed on another device class.
    Moved { stage: String, from: String, to: String },
    /// The stage exists in both plans with a different modeled peak.
    Resized { stage: String, from_bytes: u64, to_bytes: u64 },
    /// The stage exists only in the *before* plan.
    Removed { stage: String, device: String },
    /// The stage exists only in the *after* plan.
    Added { stage: String, device: String },
}

/// The delta between two [`PlanReport`]s (see [`PlanDiff::between`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PlanDiff {
    /// Winner-candidate and cluster fields that changed.
    pub fields: Vec<FieldDelta>,
    /// Stage-level changes: modifications in *before* stage order, then
    /// removals, then additions in *after* stage order.
    pub stages: Vec<StageDelta>,
    /// Timeline-summary changes.
    pub timeline: Vec<FieldDelta>,
    /// Decomposition changes ([`crate::profile::PlanAnalysis`]): totals
    /// of compute/comm/idle, per-phase idle, cp imbalance, and (same
    /// cluster only) per-group utilization.
    pub analysis: Vec<FieldDelta>,
}

fn push_delta(
    out: &mut Vec<FieldDelta>,
    field: &'static str,
    before: String,
    after: String,
) {
    if before != after {
        out.push(FieldDelta { field, before, after });
    }
}

/// Relative change suffix, e.g. `" (-10.9%)"`; empty when the base is 0.
fn pct(before: f64, after: f64) -> String {
    if before == 0.0 {
        return String::new();
    }
    format!(" ({:+.1}%)", (after - before) / before * 100.0)
}

/// Render-granularity floors for continuous quantities: a change the
/// rendering cannot show (`24.00 GB -> 24.00 GB`) is noise, not a
/// difference, so anything smaller is not reported. Discrete fields
/// (counts, names, assignments) always compare exactly.
const ITERATION_EPS_MS: f64 = 0.05; // rendered at {:.1} ms
const THROUGHPUT_EPS: f64 = 0.005; // rendered at {:.2} input/s
const PEAK_EPS_BYTES: u64 = 10_000_000; // rendered at {:.2} GB

impl PlanDiff {
    /// Compute the delta from `before` to `after`. Discrete fields
    /// compare exactly; continuous quantities (times, throughput, peak
    /// memory) compare at render granularity, so a reported delta always
    /// *shows* a difference. A report diffed against itself is empty,
    /// and the output order is deterministic, so the same pair of
    /// reports always renders the same text.
    pub fn between(before: &PlanReport, after: &PlanReport) -> PlanDiff {
        let mut fields = Vec::new();
        let same_cluster =
            before.provenance.cluster == after.provenance.cluster;
        push_delta(
            &mut fields,
            "cluster",
            before.provenance.cluster.clone(),
            after.provenance.cluster.clone(),
        );
        let a = &before.winner().candidate;
        let b = &after.winner().candidate;
        push_delta(
            &mut fields,
            "strategy",
            a.strategy.key().to_string(),
            b.strategy.key().to_string(),
        );
        push_delta(
            &mut fields,
            "policy",
            a.frozen.key().to_string(),
            b.frozen.key().to_string(),
        );
        push_delta(
            &mut fields,
            "llm_pp",
            a.llm_pp.to_string(),
            b.llm_pp.to_string(),
        );
        push_delta(
            &mut fields,
            "enc_pp",
            format!("{:?}", a.enc_pps),
            format!("{:?}", b.enc_pps),
        );
        push_delta(&mut fields, "tp", a.tp.to_string(), b.tp.to_string());
        push_delta(&mut fields, "cp", a.cp.to_string(), b.cp.to_string());
        push_delta(
            &mut fields,
            "microbatches",
            a.num_microbatches.to_string(),
            b.num_microbatches.to_string(),
        );
        // Chain-group indices are relative to each report's own cluster
        // group list; across two *different* clusters (a fleet re-carve)
        // comparing raw indices would mislead — there the per-stage
        // [`StageDelta::Moved`] entries, which name device classes, tell
        // the true story.
        if same_cluster {
            push_delta(
                &mut fields,
                "groups",
                format!("{:?}", a.chain_groups),
                format!("{:?}", b.chain_groups),
            );
        }

        // Stage deltas, keyed by stage name.
        let before_by_name: HashMap<&str, usize> = before
            .stage_verdicts
            .iter()
            .enumerate()
            .map(|(i, v)| (v.stage.as_str(), i))
            .collect();
        let after_by_name: HashMap<&str, usize> = after
            .stage_verdicts
            .iter()
            .enumerate()
            .map(|(i, v)| (v.stage.as_str(), i))
            .collect();
        let mut stages = Vec::new();
        for v in &before.stage_verdicts {
            if let Some(&j) = after_by_name.get(v.stage.as_str()) {
                let w = &after.stage_verdicts[j];
                if v.device != w.device {
                    stages.push(StageDelta::Moved {
                        stage: v.stage.clone(),
                        from: v.device.clone(),
                        to: w.device.clone(),
                    });
                }
                if v.peak_bytes.abs_diff(w.peak_bytes) >= PEAK_EPS_BYTES {
                    stages.push(StageDelta::Resized {
                        stage: v.stage.clone(),
                        from_bytes: v.peak_bytes,
                        to_bytes: w.peak_bytes,
                    });
                }
            }
        }
        for v in &before.stage_verdicts {
            if !after_by_name.contains_key(v.stage.as_str()) {
                stages.push(StageDelta::Removed {
                    stage: v.stage.clone(),
                    device: v.device.clone(),
                });
            }
        }
        for w in &after.stage_verdicts {
            if !before_by_name.contains_key(w.stage.as_str()) {
                stages.push(StageDelta::Added {
                    stage: w.stage.clone(),
                    device: w.device.clone(),
                });
            }
        }

        // Timeline deltas (exact compares; formatting only for display).
        let ta = &before.timeline;
        let tb = &after.timeline;
        let mut timeline = Vec::new();
        if (ta.iteration_ms - tb.iteration_ms).abs() >= ITERATION_EPS_MS {
            timeline.push(FieldDelta {
                field: "iteration",
                before: format!("{:.1} ms", ta.iteration_ms),
                after: format!(
                    "{:.1} ms{}",
                    tb.iteration_ms,
                    pct(ta.iteration_ms, tb.iteration_ms)
                ),
            });
        }
        if (ta.throughput - tb.throughput).abs() >= THROUGHPUT_EPS {
            timeline.push(FieldDelta {
                field: "throughput",
                before: format!("{:.2} input/s", ta.throughput),
                after: format!(
                    "{:.2} input/s{}",
                    tb.throughput,
                    pct(ta.throughput, tb.throughput)
                ),
            });
        }
        push_delta(
            &mut timeline,
            "gpus",
            ta.n_gpus.to_string(),
            tb.n_gpus.to_string(),
        );
        if ta.peak_device_bytes.abs_diff(tb.peak_device_bytes)
            >= PEAK_EPS_BYTES
        {
            timeline.push(FieldDelta {
                field: "peak memory",
                before: format!("{:.2} GB/GPU", memory::gb(ta.peak_device_bytes)),
                after: format!("{:.2} GB/GPU", memory::gb(tb.peak_device_bytes)),
            });
        }

        // Decomposition deltas: device-summed compute/comm/idle, idle per
        // 1F1B phase, cp imbalance, and per-group utilization. Continuous
        // ms quantities share the timeline's render-granularity floor.
        let aa = &before.analysis;
        let ab = &after.analysis;
        let mut analysis = Vec::new();
        let ms_pair = |field: &'static str, x: f64, y: f64, out: &mut Vec<FieldDelta>| {
            if (x - y).abs() >= ITERATION_EPS_MS {
                out.push(FieldDelta {
                    field,
                    before: format!("{x:.1} ms"),
                    after: format!("{:.1} ms{}", y, pct(x, y)),
                });
            }
        };
        ms_pair("compute", aa.total_compute_ms(), ab.total_compute_ms(), &mut analysis);
        ms_pair("comm", aa.total_comm_ms(), ab.total_comm_ms(), &mut analysis);
        ms_pair("idle", aa.total_idle_ms(), ab.total_idle_ms(), &mut analysis);
        for (pa, pb) in aa.phases.iter().zip(&ab.phases) {
            let field = match pa.phase {
                "warm-up" => "warm-up idle",
                "steady" => "steady idle",
                _ => "cool-down idle",
            };
            ms_pair(field, pa.idle_ms, pb.idle_ms, &mut analysis);
        }
        let cp_label = |a: &crate::profile::PlanAnalysis| match a.stage_cp.first() {
            Some(c) => format!("{} x{} ({:.3})", c.algorithm, c.cp, c.imbalance),
            None => "none".to_string(),
        };
        push_delta(
            &mut analysis,
            "cp imbalance",
            cp_label(aa),
            cp_label(ab),
        );
        // Group indices are cluster-relative — only comparable when both
        // reports plan the same pool (same reasoning as `groups` above).
        if same_cluster {
            for (ga, gb) in aa.groups.iter().zip(&ab.groups) {
                const UTIL_EPS: f64 = 0.0005; // rendered at {:.1}%
                if (ga.utilization - gb.utilization).abs() >= UTIL_EPS {
                    analysis.push(FieldDelta {
                        field: "utilization",
                        before: format!(
                            "{} {:.1}%",
                            ga.device_class,
                            ga.utilization * 100.0
                        ),
                        after: format!("{:.1}%", gb.utilization * 100.0),
                    });
                }
            }
        }

        PlanDiff { fields, stages, timeline, analysis }
    }

    /// True when the two reports agree on every compared field — the
    /// guarantee a re-plan that changed nothing renders as
    /// `"plan diff: no differences"`.
    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
            && self.stages.is_empty()
            && self.timeline.is_empty()
            && self.analysis.is_empty()
    }

    /// Total compared entries that changed, across every section — the
    /// scalar the elastic fleet path minimizes (and the CLI reports)
    /// when holding a re-plan close to its incumbent.
    pub fn delta_count(&self) -> usize {
        self.fields.len()
            + self.stages.len()
            + self.timeline.len()
            + self.analysis.len()
    }

    /// Deterministic human-readable rendering: configuration fields,
    /// then stage changes, then timeline changes.
    pub fn render(&self) -> String {
        if self.is_empty() {
            return "plan diff: no differences\n".to_string();
        }
        let mut s = String::from("plan diff:\n");
        for f in &self.fields {
            let _ = writeln!(s, "  {}: {} -> {}", f.field, f.before, f.after);
        }
        if !self.stages.is_empty() {
            s.push_str("  stages:\n");
            for d in &self.stages {
                match d {
                    StageDelta::Moved { stage, from, to } => {
                        let _ = writeln!(s, "    ~ {stage}: {from} -> {to}");
                    }
                    StageDelta::Resized { stage, from_bytes, to_bytes } => {
                        let _ = writeln!(
                            s,
                            "    ~ {stage}: peak {:.2} GB -> {:.2} GB",
                            memory::gb(*from_bytes),
                            memory::gb(*to_bytes)
                        );
                    }
                    StageDelta::Removed { stage, device } => {
                        let _ = writeln!(s, "    - {stage} ({device})");
                    }
                    StageDelta::Added { stage, device } => {
                        let _ = writeln!(s, "    + {stage} ({device})");
                    }
                }
            }
        }
        if !self.timeline.is_empty() {
            s.push_str("  timeline:\n");
            for f in &self.timeline {
                let _ =
                    writeln!(s, "    {}: {} -> {}", f.field, f.before, f.after);
            }
        }
        if !self.analysis.is_empty() {
            s.push_str("  analysis:\n");
            for f in &self.analysis {
                let _ =
                    writeln!(s, "    {}: {} -> {}", f.field, f.before, f.after);
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{PlanRequest, PlanningService};
    use crate::model::{MllmSpec, Size};

    #[test]
    fn self_diff_is_empty_and_renders_the_fixed_line() {
        let req = PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::S))
            .devices(8)
            .threads(2);
        let report = PlanningService::new().plan(&req).unwrap();
        let d = PlanDiff::between(&report, &report);
        assert!(d.is_empty());
        assert_eq!(d.render(), "plan diff: no differences\n");
    }

    #[test]
    fn different_pools_produce_a_stable_nonempty_diff() {
        let service = PlanningService::new();
        let small = service
            .plan(
                &PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::S))
                    .devices(8)
                    .threads(2),
            )
            .unwrap();
        let big = service
            .plan(
                &PlanRequest::default_for(MllmSpec::vlm(Size::M, Size::S))
                    .devices(16)
                    .threads(2),
            )
            .unwrap();
        let d = PlanDiff::between(&small, &big);
        assert!(!d.is_empty());
        // the cluster fingerprint always distinguishes the two pools
        assert!(d.fields.iter().any(|f| f.field == "cluster"));
        let text = d.render();
        assert!(text.contains("->"), "{text}");
        // deterministic: the same pair renders the same text
        assert_eq!(text, PlanDiff::between(&small, &big).render());
        // and the reverse diff swaps direction, not content volume
        let rev = PlanDiff::between(&big, &small);
        assert_eq!(rev.fields.len(), d.fields.len());
    }

    #[test]
    fn render_sections_are_shaped_and_ordered() {
        let d = PlanDiff {
            fields: vec![FieldDelta {
                field: "tp",
                before: "1".to_string(),
                after: "2".to_string(),
            }],
            stages: vec![
                StageDelta::Moved {
                    stage: "llm[0]".to_string(),
                    from: "A40".to_string(),
                    to: "A100-80G".to_string(),
                },
                StageDelta::Resized {
                    stage: "llm[0]".to_string(),
                    from_bytes: 24_000_000_000,
                    to_bytes: 30_000_000_000,
                },
                StageDelta::Removed {
                    stage: "enc:vision[1]".to_string(),
                    device: "A40".to_string(),
                },
                StageDelta::Added {
                    stage: "llm[3]".to_string(),
                    device: "A100-80G".to_string(),
                },
            ],
            timeline: vec![FieldDelta {
                field: "iteration",
                before: "123.4 ms".to_string(),
                after: "110.0 ms (-10.9%)".to_string(),
            }],
            analysis: vec![FieldDelta {
                field: "idle",
                before: "40.0 ms".to_string(),
                after: "20.0 ms (-50.0%)".to_string(),
            }],
        };
        assert!(!d.is_empty());
        let text = d.render();
        let fields_at = text.find("tp: 1 -> 2").unwrap();
        let stages_at = text.find("stages:").unwrap();
        let timeline_at = text.find("timeline:").unwrap();
        let analysis_at = text.find("analysis:").unwrap();
        assert!(
            fields_at < stages_at && stages_at < timeline_at && timeline_at < analysis_at,
            "{text}"
        );
        assert!(text.contains("idle: 40.0 ms -> 20.0 ms (-50.0%)"), "{text}");
        assert!(text.contains("~ llm[0]: A40 -> A100-80G"), "{text}");
        assert!(text.contains("~ llm[0]: peak 24.00 GB -> 30.00 GB"), "{text}");
        assert!(text.contains("- enc:vision[1] (A40)"), "{text}");
        assert!(text.contains("+ llm[3] (A100-80G)"), "{text}");
    }

    #[test]
    fn pct_handles_zero_base() {
        assert_eq!(pct(0.0, 5.0), "");
        assert_eq!(pct(100.0, 90.0), " (-10.0%)");
    }
}
