//! `PlanReport` — everything a planning answer carries: the chosen
//! executable [`Plan`], the ranked frontier it was drawn from, per-stage
//! memory verdicts against the cluster budget, the simulated timeline
//! summary, and provenance (which planner produced it, whether the cache
//! answered, how much was searched).

use crate::memory;
use crate::modality::Plan;
use crate::telemetry::{key as tkey, Snapshot};
use crate::tuner::PlanSummary;
use crate::util::json::Json;

/// One stage's memory verdict against the budget of the device it lands
/// on — on a heterogeneous pool different stages answer to different
/// budgets.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageVerdict {
    /// Stage name (`enc:vision[0]`, `llm[2]`, …).
    pub stage: String,
    /// Device-class name of the group this stage landed on (`A40`,
    /// `A100-80G`, …).
    pub device: String,
    /// Modeled peak per-GPU bytes of this stage.
    pub peak_bytes: u64,
    /// The per-device budget of the stage's group.
    pub budget_bytes: u64,
}

impl StageVerdict {
    pub fn fits(&self) -> bool {
        self.peak_bytes <= self.budget_bytes
    }

    /// Bytes of headroom (negative when over budget).
    pub fn headroom_bytes(&self) -> i64 {
        self.budget_bytes as i64 - self.peak_bytes as i64
    }
}

/// Simulated-iteration summary of the chosen plan.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelineSummary {
    pub iteration_ms: f64,
    /// Samples per second (whole job).
    pub throughput: f64,
    /// The paper's normalized metric: input/s per GPU.
    pub throughput_per_gpu: f64,
    /// 1 − mean(device busy / makespan).
    pub bubble_ratio: f64,
    pub n_gpus: usize,
    /// Modeled peak per-GPU bytes over all stages.
    pub peak_device_bytes: u64,
}

/// Deterministic search counters for one planning call, sourced from
/// the [`crate::telemetry`] registry (the delta the call produced).
/// Same request, same numbers — timings live in the trace, never here.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Raw configurations the space enumeration produced.
    pub candidates_enumerated: u64,
    /// Candidates cut by the cost-model lower bound / budget.
    pub pruned_lower_bound: u64,
    /// Candidates cut by the per-device memory model.
    pub pruned_memory: u64,
    /// Hetero placements cut for oversubscribing a device group.
    pub pruned_group_capacity: u64,
    /// Candidates simulated.
    pub evaluated: u64,
    /// Plan-cache lookups answered without a search.
    pub cache_hits: u64,
    /// Of those, answered from the in-process tier (no disk touched).
    pub cache_mem_hits: u64,
    /// Plan-cache lookups that fell through to a search.
    pub cache_misses: u64,
    /// Plan-cache persists to disk.
    pub cache_writes: u64,
    /// Requests that joined an identical in-flight search instead of
    /// launching their own (counted as hits above).
    pub inflight_joins: u64,
}

impl SearchStats {
    /// Read the stats out of a scoped counter delta
    /// ([`Snapshot::delta_since`]).
    pub fn from_delta(d: &Snapshot) -> SearchStats {
        SearchStats {
            candidates_enumerated: d.get(tkey::CANDIDATES_ENUMERATED),
            pruned_lower_bound: d.get(tkey::PRUNED_LOWER_BOUND),
            pruned_memory: d.get(tkey::PRUNED_MEMORY),
            pruned_group_capacity: d.get(tkey::PRUNED_GROUP_CAPACITY),
            evaluated: d.get(tkey::EVALUATED),
            cache_hits: d.get(tkey::CACHE_HIT),
            cache_mem_hits: d.get(tkey::CACHE_MEM_HIT),
            cache_misses: d.get(tkey::CACHE_MISS),
            cache_writes: d.get(tkey::CACHE_WRITE),
            inflight_joins: d.get(tkey::INFLIGHT_JOIN),
        }
    }

    /// Every prune reason summed.
    pub fn pruned_total(&self) -> u64 {
        self.pruned_lower_bound
            + self.pruned_memory
            + self.pruned_group_capacity
    }

    /// The one-line rendering embedded in report provenance.
    pub fn render_line(&self) -> String {
        format!(
            "{} enumerated | {} pruned ({} bound, {} memory, {} \
             capacity) | {} simulated | cache {} hit ({} mem) / {} \
             miss / {} write | {} joined in-flight",
            self.candidates_enumerated,
            self.pruned_total(),
            self.pruned_lower_bound,
            self.pruned_memory,
            self.pruned_group_capacity,
            self.evaluated,
            self.cache_hits,
            self.cache_mem_hits,
            self.cache_misses,
            self.cache_writes,
            self.inflight_joins,
        )
    }

    /// JSON object with one integer field per counter.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "candidates_enumerated",
                Json::Int(self.candidates_enumerated as i64),
            ),
            (
                "pruned_lower_bound",
                Json::Int(self.pruned_lower_bound as i64),
            ),
            ("pruned_memory", Json::Int(self.pruned_memory as i64)),
            (
                "pruned_group_capacity",
                Json::Int(self.pruned_group_capacity as i64),
            ),
            ("evaluated", Json::Int(self.evaluated as i64)),
            ("cache_hits", Json::Int(self.cache_hits as i64)),
            ("cache_mem_hits", Json::Int(self.cache_mem_hits as i64)),
            ("cache_misses", Json::Int(self.cache_misses as i64)),
            ("cache_writes", Json::Int(self.cache_writes as i64)),
            ("inflight_joins", Json::Int(self.inflight_joins as i64)),
        ])
    }
}

/// Where the answer came from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Provenance {
    /// Which planner produced the answer (`"tuner"` today; the field
    /// exists so future planners can be told apart).
    pub planner: &'static str,
    /// True when the persistent cache answered without a search.
    pub cache_hit: bool,
    /// The cache signature the request resolved to.
    pub signature: String,
    /// The [`super::ClusterSpec::fingerprint`] the plan is valid for.
    pub cluster: String,
    /// Search statistics — all zero on a cache hit.
    pub total_candidates: usize,
    pub evaluated: usize,
    pub pruned: usize,
    /// True when the static verifier ([`crate::verify`]) found no
    /// Error-severity lints in the returned plan. Always true on a
    /// report the service actually returned — the gate refuses
    /// otherwise — but recorded so downstream consumers of a serialized
    /// report can tell a verified plan from a hand-assembled one.
    pub verifier_clean: bool,
    /// Warn-severity lints the verifier attached to the returned plan.
    pub verifier_warnings: usize,
    /// The telemetry counters this call fired (deterministic; the
    /// search-side numbers above are cross-checked against it).
    pub stats: SearchStats,
}

/// The planning service's answer (see [`super::PlanningService::plan`]).
#[derive(Clone, Debug)]
pub struct PlanReport {
    /// The chosen, executable stage DAG.
    pub plan: Plan,
    /// Ranked alternatives, best first; `frontier[0]` is the winner.
    /// At most the request's `top` entries, even when the cache holds a
    /// deeper frontier — the same request answers with the same shape
    /// warm or cold.
    pub frontier: Vec<PlanSummary>,
    /// Per-stage memory verdicts, parallel to `plan.stage_names`.
    pub stage_verdicts: Vec<StageVerdict>,
    pub timeline: TimelineSummary,
    pub provenance: Provenance,
    /// Where every simulated millisecond went: per-device
    /// compute/comm/idle, 1F1B phase bubbles, cp imbalance, group
    /// utilization (`cornstarch explain` renders it; see
    /// [`crate::profile`]).
    pub analysis: crate::profile::PlanAnalysis,
}

impl PlanReport {
    /// The winning plan's summary (candidate + scored metrics).
    pub fn winner(&self) -> &PlanSummary {
        &self.frontier[0]
    }

    /// Does every stage fit the cluster's per-device budget?
    pub fn fits_budget(&self) -> bool {
        self.stage_verdicts.iter().all(StageVerdict::fits)
    }

    /// Human-readable rendering (the CLI's `tune` output core).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let w = self.winner();
        let _ = writeln!(s, "plan: {}", w.candidate.label());
        let _ = writeln!(
            s,
            "  provenance: {} ({}) | {} candidates, {} simulated, {} pruned",
            self.provenance.planner,
            if self.provenance.cache_hit { "cache hit" } else { "searched" },
            self.provenance.total_candidates,
            self.provenance.evaluated,
            self.provenance.pruned,
        );
        let _ = writeln!(
            s,
            "  search stats: {}",
            self.provenance.stats.render_line()
        );
        let _ = writeln!(
            s,
            "  verifier: {}{}",
            if self.provenance.verifier_clean { "clean" } else { "FAILED" },
            if self.provenance.verifier_warnings > 0 {
                format!(" ({} warning(s))", self.provenance.verifier_warnings)
            } else {
                String::new()
            },
        );
        let _ = writeln!(s, "  cluster: {}", self.provenance.cluster);
        let _ = writeln!(
            s,
            "  iteration {:.1} ms | {:.3} input/s/GPU | {} GPUs | bubble \
             {:.1}% | peak {:.2} GB/GPU",
            self.timeline.iteration_ms,
            self.timeline.throughput_per_gpu,
            self.timeline.n_gpus,
            self.timeline.bubble_ratio * 100.0,
            memory::gb(self.timeline.peak_device_bytes),
        );
        for v in &self.stage_verdicts {
            let _ = writeln!(
                s,
                "    {:<16} {:<10} {:>7.2} GB / {:.0} GB {}",
                v.stage,
                v.device,
                memory::gb(v.peak_bytes),
                memory::gb(v.budget_bytes),
                if v.fits() { "fits" } else { "OOM" },
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_verdict_headroom_signs() {
        let fits = StageVerdict {
            stage: "llm[0]".to_string(),
            device: "A100-80G".to_string(),
            peak_bytes: 30,
            budget_bytes: 40,
        };
        assert!(fits.fits());
        assert_eq!(fits.headroom_bytes(), 10);
        let oom = StageVerdict {
            stage: "llm[0]".to_string(),
            device: "A40".to_string(),
            peak_bytes: 50,
            budget_bytes: 40,
        };
        assert!(!oom.fits());
        assert_eq!(oom.headroom_bytes(), -10);
    }
}
