//! The carve search engines behind [`PlanningService::plan_fleet`].
//!
//! Three modes, one evaluator:
//!
//! * **Exact** — enumerate every carve ([`super::enumerate_partitions`])
//!   and keep the best. Only available while the carve count stays under
//!   [`super::MAX_PARTITIONS`].
//! * **Branch-and-bound** — depth-first over per-group compositions,
//!   pruning subtrees with the *same* static device/memory tests the
//!   exact path applies per carve, lifted to partial carves: a subtree
//!   dies only when some tenant cannot reach a non-empty slice (or its
//!   model-weight bytes) in *any* completion, so the bound is admissible
//!   and a completed run returns the exhaustive optimum. An LPT-seeded
//!   incumbent means even a budget-truncated run returns a real carve.
//! * **Local search** — an LPT-seeded hill-climb over single-device
//!   moves and cross-group swaps between tenants, for carve spaces no
//!   tree search should walk. Never returns an infeasible carve; when
//!   nothing feasible is ever seen the caller surfaces
//!   [`PlanError::InfeasibleFleet`](crate::api::PlanError::InfeasibleFleet).
//!
//! All three share [`CarveSearch`]: per-tenant plans are memoized on the
//! sub-pool fingerprint, static pruning and the fairness floor are
//! applied identically, and the telemetry counters
//! (`carves_considered/pruned/feasible`, `bnb_nodes/bnb_pruned`,
//! `local_moves`) are the provenance every mode reports through.

use std::collections::{HashMap, HashSet};

use crate::telemetry::{self, key as tkey};

use super::super::error::PlanError;
use super::super::report::PlanReport;
use super::super::PlanningService;
use super::{
    enumerate_partitions, slice_mem_bytes, FleetPartition, FleetRequest,
    MAX_PARTITIONS,
};

/// Auto-mode threshold: carve spaces up to this size run branch-and-bound
/// (bounded by [`MAX_SEARCH_EVALS`]); anything larger goes straight to
/// LPT-seeded local search.
pub const MAX_BNB_CARVES: u128 = 1_000_000;

/// Default cap on carves the heuristic modes may *evaluate* (plan every
/// tenant's sub-pool). Statically pruned carves are cheap and don't
/// count. Override per request with [`FleetRequest::search_evals`].
pub const MAX_SEARCH_EVALS: usize = MAX_PARTITIONS;

/// Default move budget for warm-started (elastic) re-planning: how many
/// single-device moves the repair may drift from the incumbent carve.
/// Override per request with [`FleetRequest::elastic_moves`].
pub const ELASTIC_MOVE_BUDGET: usize = 8;

/// Accept a local-search move only when it beats the incumbent by more
/// than this (absolute samples/s) — blocks float-noise cycling.
const IMPROVE_EPS: f64 = 1e-9;

/// Which engine produced a fleet answer — recorded in
/// [`FleetProvenance::search_mode`](super::FleetProvenance::search_mode).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SearchMode {
    /// Exhaustive enumeration (carve count within [`MAX_PARTITIONS`]).
    Exact,
    /// Depth-first branch-and-bound with admissible static bounds.
    BranchAndBound,
    /// LPT-seeded hill-climb over single-device moves and swaps.
    LocalSearch,
}

impl SearchMode {
    /// Stable wire/provenance name (`exact | branch_and_bound |
    /// local_search`).
    pub fn name(self) -> &'static str {
        match self {
            SearchMode::Exact => "exact",
            SearchMode::BranchAndBound => "branch_and_bound",
            SearchMode::LocalSearch => "local_search",
        }
    }

    /// Parse a mode name (accepts the provenance names plus the short
    /// CLI spellings `bnb` and `local`). `auto` is not a mode — callers
    /// map it to `None`.
    pub fn parse(s: &str) -> Option<SearchMode> {
        match s {
            "exact" => Some(SearchMode::Exact),
            "bnb" | "branch_and_bound" | "branch-and-bound" => {
                Some(SearchMode::BranchAndBound)
            }
            "local" | "local_search" | "local-search" => {
                Some(SearchMode::LocalSearch)
            }
            _ => None,
        }
    }
}

/// A carve the search decided to keep: aggregate throughput plus the
/// per-tenant reports that justify it.
pub(super) struct BestCarve {
    pub aggregate: f64,
    pub partition: FleetPartition,
    pub reports: Vec<PlanReport>,
}

/// Shared evaluation state for every search mode: the static prune, the
/// per-(tenant, sub-pool) plan memo, the fairness floor, and the
/// evaluation budget.
pub(super) struct CarveSearch<'a> {
    pub service: &'a PlanningService,
    pub req: &'a FleetRequest,
    /// Solo (whole-pool) throughput per tenant — the fairness baseline;
    /// all zeros when the floor is disabled.
    pub solo_tput: &'a [f64],
    /// Minimum slice memory per tenant (bf16 model weights).
    pub min_bytes: &'a [u64],
    memo: HashMap<(usize, String), Option<PlanReport>>,
    /// Carves fully evaluated (planned) so far, vs the cap.
    evals: usize,
    eval_cap: usize,
}

impl<'a> CarveSearch<'a> {
    pub fn new(
        service: &'a PlanningService,
        req: &'a FleetRequest,
        solo_tput: &'a [f64],
        min_bytes: &'a [u64],
        eval_cap: usize,
    ) -> Self {
        CarveSearch {
            service,
            req,
            solo_tput,
            min_bytes,
            memo: HashMap::new(),
            evals: 0,
            eval_cap: eval_cap.max(1),
        }
    }

    /// May another carve be planned, or is the evaluation budget spent?
    pub fn budget_left(&self) -> bool {
        self.evals < self.eval_cap
    }

    /// The static carve prune: every tenant needs a non-empty slice with
    /// at least its model-weight bytes of pool memory.
    pub fn statically_feasible(&self, part: &FleetPartition) -> bool {
        (0..self.req.tenants.len()).all(|t| {
            part.tenant_devices(t) > 0
                && slice_mem_bytes(part, &self.req.cluster, t)
                    >= self.min_bytes[t]
        })
    }

    /// How far `part` is from static feasibility, in bytes of missing
    /// tenant memory (device-less tenants count their full weight
    /// bytes). Zero iff [`CarveSearch::statically_feasible`]. Local
    /// search walks downhill on this when nothing plans yet.
    fn static_deficit(&self, part: &FleetPartition) -> u64 {
        (0..self.req.tenants.len())
            .map(|t| {
                if part.tenant_devices(t) == 0 {
                    return self.min_bytes[t].max(1);
                }
                self.min_bytes[t].saturating_sub(slice_mem_bytes(
                    part,
                    &self.req.cluster,
                    t,
                ))
            })
            .sum()
    }

    /// Evaluate one carve end to end: static prune, per-tenant planning
    /// (memoized on the sub-pool fingerprint), fairness floor. `None`
    /// means the carve is infeasible somewhere along that chain; errors
    /// other than per-tenant infeasibility propagate.
    pub fn evaluate(
        &mut self,
        part: &FleetPartition,
    ) -> Result<Option<(f64, Vec<PlanReport>)>, PlanError> {
        telemetry::incr(tkey::CARVES_CONSIDERED);
        if !self.statically_feasible(part) {
            telemetry::incr(tkey::CARVES_PRUNED);
            return Ok(None);
        }
        self.evals += 1;
        let n = self.req.tenants.len();
        let mut reports = Vec::with_capacity(n);
        for (t, tenant) in self.req.tenants.iter().enumerate() {
            let sub = part
                .subpool(&self.req.cluster, t, &tenant.name)
                .expect("statically feasible slices are non-empty");
            let key = (t, sub.fingerprint());
            let cached = match self.memo.get(&key) {
                Some(r) => r.clone(),
                None => {
                    let r = match self
                        .service
                        .plan(&tenant.request.clone().cluster(sub))
                    {
                        Ok(rep) => Some(rep),
                        Err(PlanError::NoFeasiblePlan { .. }) => None,
                        Err(e) => return Err(e),
                    };
                    telemetry::incr(tkey::PLANS_SEARCHED);
                    self.memo.insert(key, r.clone());
                    r
                }
            };
            match cached {
                Some(rep) => reports.push(rep),
                None => return Ok(None),
            }
        }
        if reports.iter().zip(self.solo_tput).any(|(r, &s)| {
            r.timeline.throughput < self.req.fairness_floor * s
        }) {
            return Ok(None);
        }
        telemetry::incr(tkey::CARVES_FEASIBLE);
        let agg = reports.iter().map(|r| r.timeline.throughput).sum();
        Ok(Some((agg, reports)))
    }

    /// Evaluate `part` and fold it into `best` under the search's
    /// first-wins tie-break (`agg` must beat the incumbent by more than
    /// `1e-12` to replace it).
    fn consider(
        &mut self,
        part: &FleetPartition,
        best: &mut Option<BestCarve>,
    ) -> Result<bool, PlanError> {
        let Some((aggregate, reports)) = self.evaluate(part)? else {
            return Ok(false);
        };
        if best
            .as_ref()
            .is_none_or(|b| aggregate > b.aggregate + 1e-12)
        {
            *best = Some(BestCarve {
                aggregate,
                partition: part.clone(),
                reports,
            });
        }
        Ok(true)
    }
}

/// Exhaustive search: evaluate every enumerated carve. The caller
/// guarantees the carve count is within [`MAX_PARTITIONS`].
pub(super) fn exact(
    cs: &mut CarveSearch,
) -> Result<Option<BestCarve>, PlanError> {
    let mut best = None;
    for part in
        enumerate_partitions(&cs.req.cluster, cs.req.tenants.len())
    {
        cs.consider(&part, &mut best)?;
    }
    Ok(best)
}

/// Branch-and-bound: depth-first over groups, one composition of the
/// current group's devices per branch, in the same lexicographic order
/// the exact enumeration uses. A node is pruned when some tenant cannot
/// reach feasibility in any completion (its devices-so-far plus every
/// remaining group's devices stay zero, or its memory-so-far plus every
/// remaining group's bytes stay under its weight bytes) — the carve
/// analogue of the tuner's capacity/memory filters, and admissible by
/// construction: a pruned subtree contains no feasible leaf. With the
/// budget unexhausted the result therefore equals the exhaustive
/// optimum; a truncated run still returns the best carve seen (the
/// `seed` incumbent guarantees there is one whenever the seed is
/// feasible).
pub(super) fn branch_and_bound(
    cs: &mut CarveSearch,
    seed: &FleetPartition,
) -> Result<Option<BestCarve>, PlanError> {
    let groups = &cs.req.cluster.groups;
    let n_tenants = cs.req.tenants.len();
    let n_groups = groups.len();
    // Suffix sums: devices / bytes still assignable at depth g and below.
    let mut suffix_devices = vec![0usize; n_groups + 1];
    let mut suffix_bytes = vec![0u64; n_groups + 1];
    for g in (0..n_groups).rev() {
        suffix_devices[g] = suffix_devices[g + 1] + groups[g].count;
        suffix_bytes[g] = suffix_bytes[g + 1]
            + groups[g].device.mem_bytes * groups[g].count as u64;
    }

    let mut best = None;
    cs.consider(seed, &mut best)?;

    // Iterative DFS: each frame is (depth, per-tenant composition of
    // group `depth-1`). Children are pushed in reverse so they pop in
    // the exact enumeration's lexicographic order.
    struct Node {
        depth: usize,
        slices: Vec<Vec<usize>>,
        devs: Vec<usize>,
        bytes: Vec<u64>,
    }
    let root = Node {
        depth: 0,
        slices: vec![Vec::new(); n_tenants],
        devs: vec![0; n_tenants],
        bytes: vec![0; n_tenants],
    };
    let mut stack = vec![root];
    while let Some(node) = stack.pop() {
        telemetry::incr(tkey::BNB_NODES);
        // Admissible bound: the best any completion can do for tenant t
        // is everything still unassigned; if even that is too little,
        // no leaf below is feasible.
        let doomed = (0..n_tenants).any(|t| {
            node.devs[t] + suffix_devices[node.depth] == 0
                || node.bytes[t] + suffix_bytes[node.depth]
                    < cs.min_bytes[t]
        });
        if doomed {
            telemetry::incr(tkey::BNB_PRUNED);
            continue;
        }
        if node.depth == n_groups {
            let part = FleetPartition { slices: node.slices };
            cs.consider(&part, &mut best)?;
            if !cs.budget_left() {
                break;
            }
            continue;
        }
        if !cs.budget_left() {
            break;
        }
        let g = node.depth;
        let opts = super::compositions(groups[g].count, n_tenants);
        for opt in opts.iter().rev() {
            let mut slices = node.slices.clone();
            let mut devs = node.devs.clone();
            let mut bytes = node.bytes.clone();
            for t in 0..n_tenants {
                slices[t].push(opt[t]);
                devs[t] += opt[t];
                bytes[t] += groups[g].device.mem_bytes * opt[t] as u64;
            }
            stack.push(Node { depth: g + 1, slices, devs, bytes });
        }
    }
    Ok(best)
}

/// The LPT-style initial carve: hand out one device at a time, always
/// from the group with the most devices left, to the tenant with the
/// lowest *normalized* load (slice bytes over weight bytes) — the
/// longest-processing-time rule with tenants as machines and their
/// weight bytes as the job sizes. Deterministic (ties break on the
/// lowest index); every device is assigned, and with at least as many
/// devices as tenants every tenant gets one.
pub(super) fn lpt_seed(
    req: &FleetRequest,
    min_bytes: &[u64],
) -> FleetPartition {
    let groups = &req.cluster.groups;
    let n_tenants = req.tenants.len();
    let mut remaining: Vec<usize> =
        groups.iter().map(|g| g.count).collect();
    let mut slices = vec![vec![0usize; groups.len()]; n_tenants];
    let mut bytes = vec![0u64; n_tenants];
    let total: usize = remaining.iter().sum();
    for _ in 0..total {
        let g = (0..groups.len())
            .max_by_key(|&g| (remaining[g], std::cmp::Reverse(g)))
            .expect("clusters have at least one group");
        let t = (0..n_tenants)
            .min_by(|&a, &b| {
                let la = bytes[a] as f64 / min_bytes[a].max(1) as f64;
                let lb = bytes[b] as f64 / min_bytes[b].max(1) as f64;
                la.partial_cmp(&lb)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            })
            .expect("fleets have at least one tenant");
        slices[t][g] += 1;
        bytes[t] += groups[g].device.mem_bytes;
        remaining[g] -= 1;
    }
    FleetPartition { slices }
}

/// Every carve one single-device move or cross-group swap away from
/// `cur`, in a fixed deterministic order: moves (group-major, then
/// giving tenant, then receiving tenant), then swaps (ordered group
/// pairs, then the two tenants).
fn neighbors(
    cur: &FleetPartition,
    n_groups: usize,
) -> Vec<FleetPartition> {
    let n_tenants = cur.slices.len();
    let mut out = Vec::new();
    // Single-device moves: one device of group g from tenant a to b.
    for g in 0..n_groups {
        for a in 0..n_tenants {
            if cur.slices[a][g] == 0 {
                continue;
            }
            for b in 0..n_tenants {
                if a == b {
                    continue;
                }
                let mut nb = cur.clone();
                nb.slices[a][g] -= 1;
                nb.slices[b][g] += 1;
                out.push(nb);
            }
        }
    }
    // Cross-group swaps: tenant a trades a group-g device for tenant
    // b's group-h device (net device counts unchanged, memory mix not).
    for g in 0..n_groups {
        for h in 0..n_groups {
            if g == h {
                continue;
            }
            for a in 0..n_tenants {
                if cur.slices[a][g] == 0 {
                    continue;
                }
                for b in 0..n_tenants {
                    if a == b || cur.slices[b][h] == 0 {
                        continue;
                    }
                    let mut nb = cur.clone();
                    nb.slices[a][g] -= 1;
                    nb.slices[b][g] += 1;
                    nb.slices[b][h] -= 1;
                    nb.slices[a][h] += 1;
                    out.push(nb);
                }
            }
        }
    }
    out
}

/// Hill-climb from `seed` over [`neighbors`], first-improvement, up to
/// `move_budget` accepted moves. While the current carve is infeasible
/// the climb accepts the first feasible neighbor outright, then walks
/// unvisited statically-feasible neighbors (and, failing that, strictly
/// deficit-reducing ones) to escape dead seeds. With `stability` set —
/// the warm-started / elastic mode — a feasible incumbent is returned
/// untouched: moves are spent only to *restore* feasibility, which is
/// what keeps a 1-GPU loss from reshuffling unaffected tenants.
pub(super) fn local_search(
    cs: &mut CarveSearch,
    seed: FleetPartition,
    move_budget: usize,
    stability: bool,
) -> Result<Option<BestCarve>, PlanError> {
    let n_groups = cs.req.cluster.groups.len();
    let mut best = None;
    let mut cur = seed;
    let mut cur_agg: Option<f64> = None;
    if cs.consider(&cur, &mut best)? {
        cur_agg = best.as_ref().map(|b| b.aggregate);
    }
    let mut visited: HashSet<String> = HashSet::new();
    visited.insert(cur.label());
    let mut moves = 0;
    while moves < move_budget && cs.budget_left() {
        if stability && cur_agg.is_some() {
            break;
        }
        let cur_deficit = cs.static_deficit(&cur);
        let mut accepted: Option<(FleetPartition, Option<f64>)> = None;
        let mut walk: Option<FleetPartition> = None;
        let mut downhill: Option<(u64, FleetPartition)> = None;
        for nb in neighbors(&cur, n_groups) {
            if !cs.statically_feasible(&nb) {
                let d = cs.static_deficit(&nb);
                if d < cur_deficit
                    && downhill.as_ref().is_none_or(|(bd, _)| d < *bd)
                    && !visited.contains(&nb.label())
                {
                    downhill = Some((d, nb));
                }
                continue;
            }
            if !cs.budget_left() {
                break;
            }
            match cs.evaluate(&nb)? {
                Some((agg, reports)) => {
                    if best
                        .as_ref()
                        .is_none_or(|b| agg > b.aggregate + 1e-12)
                    {
                        best = Some(BestCarve {
                            aggregate: agg,
                            partition: nb.clone(),
                            reports,
                        });
                    }
                    let better = match cur_agg {
                        Some(ca) => agg > ca + IMPROVE_EPS,
                        None => true,
                    };
                    if better {
                        accepted = Some((nb, Some(agg)));
                        break;
                    }
                }
                None => {
                    if cur_agg.is_none()
                        && walk.is_none()
                        && !visited.contains(&nb.label())
                    {
                        walk = Some(nb);
                    }
                }
            }
        }
        let step = accepted.or_else(|| {
            if cur_agg.is_some() {
                return None; // feasible and locally optimal: done
            }
            walk.or(downhill.map(|(_, nb)| nb)).map(|nb| (nb, None))
        });
        let Some((nb, agg)) = step else { break };
        visited.insert(nb.label());
        cur = nb;
        cur_agg = agg;
        moves += 1;
        telemetry::incr(tkey::LOCAL_MOVES);
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::super::super::cluster::ClusterSpec;
    use super::*;

    fn two_tenant_req(cluster: ClusterSpec) -> FleetRequest {
        use crate::api::PlanRequest;
        use crate::model::{MllmSpec, Size};
        FleetRequest::new(cluster)
            .tenant(
                "a",
                PlanRequest::default_for(MllmSpec::vlm(Size::S, Size::S)),
            )
            .tenant(
                "b",
                PlanRequest::default_for(MllmSpec::alm(Size::S, Size::S)),
            )
    }

    #[test]
    fn search_mode_names_round_trip() {
        for m in [
            SearchMode::Exact,
            SearchMode::BranchAndBound,
            SearchMode::LocalSearch,
        ] {
            assert_eq!(SearchMode::parse(m.name()), Some(m));
        }
        assert_eq!(
            SearchMode::parse("bnb"),
            Some(SearchMode::BranchAndBound)
        );
        assert_eq!(
            SearchMode::parse("local"),
            Some(SearchMode::LocalSearch)
        );
        assert_eq!(SearchMode::parse("auto"), None);
        assert_eq!(SearchMode::parse("??"), None);
    }

    #[test]
    fn lpt_seed_assigns_every_device_and_favors_the_heavy_tenant() {
        let req = two_tenant_req(ClusterSpec::a40_a100_demo());
        // Tenant 0 wants 3x the memory of tenant 1.
        let min_bytes = [30_000_000_000u64, 10_000_000_000];
        let part = lpt_seed(&req, &min_bytes);
        assert!(part.respects(&req.cluster));
        let total: usize =
            (0..2).map(|t| part.tenant_devices(t)).sum();
        assert_eq!(total, 8, "{}", part.label());
        let heavy_mem = slice_mem_bytes(&part, &req.cluster, 0);
        let light_mem = slice_mem_bytes(&part, &req.cluster, 1);
        assert!(
            heavy_mem > light_mem,
            "heavy tenant got {heavy_mem} vs light {light_mem} ({})",
            part.label()
        );
        // deterministic
        assert_eq!(part, lpt_seed(&req, &min_bytes));
    }

    #[test]
    fn neighbors_preserve_the_device_total() {
        let cur = FleetPartition {
            slices: vec![vec![2, 1], vec![2, 3]],
        };
        let nbs = neighbors(&cur, 2);
        assert!(!nbs.is_empty());
        let total = |p: &FleetPartition| -> usize {
            p.slices.iter().flatten().sum()
        };
        for nb in &nbs {
            assert_eq!(total(nb), total(&cur), "{}", nb.label());
            assert_ne!(nb, &cur);
        }
        // deterministic order
        let again = neighbors(&cur, 2);
        assert_eq!(nbs, again);
    }
}
