//! Elastic fleet events: incremental re-planning inputs.
//!
//! A running fleet does not get to re-solve from scratch every time the
//! pool wobbles — DistTrain's disaggregated-resource story (PAPERS.md)
//! is exactly that devices fail and tenants come and go *while the
//! fleet runs*. This module folds a queue of [`ElasticEvent`]s into a
//! [`FleetRequest`] before the carve search sees it: the cluster
//! shrinks, the tenant list updates, and — when the request carries a
//! [`FleetRequest::warm_start`] incumbent — the incumbent carve is
//! *repaired in place* (lost devices taken from whichever tenant holds
//! the most of that group) so the warm-started search begins one step
//! from the old answer, not at zero. The stability-first local search
//! then keeps every repaired-but-feasible slice exactly where it was,
//! which is what makes a 1-GPU loss relocate one tenant's stages
//! instead of the fleet's.

use crate::api::PlanRequest;
use crate::telemetry::{self, key as tkey};

use super::super::error::PlanError;
use super::{FleetPartition, FleetRequest};

/// One change to a running fleet, applied in queue order by
/// [`apply_events`].
#[derive(Clone, Debug)]
pub enum ElasticEvent {
    /// `n` devices of cluster group `group` failed or were reclaimed.
    DeviceLost { group: usize, n: usize },
    /// A new named tenant wants in (the fleet-wide cache policy is
    /// applied to its request, same as [`FleetRequest::tenant`]).
    TenantJoined { name: String, request: Box<PlanRequest> },
    /// A tenant finished or was evicted.
    TenantLeft { name: String },
}

/// Fold `req.events` into a resolved request: shrink the cluster, edit
/// the tenant list, repair the warm-start incumbent, and return the
/// event-free request the carve search actually plans. Invalid events
/// (unknown group, losing a whole group, duplicate join, unknown
/// leaver) surface as [`PlanError::InvalidElasticEvent`].
pub(super) fn apply_events(
    req: &FleetRequest,
) -> Result<FleetRequest, PlanError> {
    let mut out = req.clone();
    let events = std::mem::take(&mut out.events);
    for ev in &events {
        telemetry::incr(tkey::ELASTIC_EVENTS);
        match ev {
            ElasticEvent::DeviceLost { group, n } => {
                let g = *group;
                let Some(grp) = out.cluster.groups.get_mut(g) else {
                    return Err(PlanError::InvalidElasticEvent(format!(
                        "device_lost group {g} does not exist in {}",
                        out.cluster.name
                    )));
                };
                if *n >= grp.count {
                    return Err(PlanError::InvalidElasticEvent(format!(
                        "device_lost({g}, {n}) would empty group {:?} \
                         ({} devices)",
                        grp.device.name, grp.count
                    )));
                }
                grp.count -= n;
                if let Some(warm) = &mut out.warm {
                    repair_loss(warm, g, *n);
                }
            }
            ElasticEvent::TenantJoined { name, request } => {
                if out.tenants.iter().any(|t| &t.name == name) {
                    return Err(PlanError::InvalidElasticEvent(format!(
                        "tenant {name:?} joined twice"
                    )));
                }
                let groups = out.cluster.groups.len();
                out = out.tenant(name, (**request).clone());
                if let Some(warm) = &mut out.warm {
                    // the newcomer starts device-less; the warm search's
                    // feasibility-restoring moves grant it a slice
                    warm.slices.push(vec![0; groups]);
                }
            }
            ElasticEvent::TenantLeft { name } => {
                let Some(idx) =
                    out.tenants.iter().position(|t| &t.name == name)
                else {
                    return Err(PlanError::InvalidElasticEvent(format!(
                        "tenant {name:?} left but was never in the fleet"
                    )));
                };
                out.tenants.remove(idx);
                if let Some(warm) = &mut out.warm {
                    if idx < warm.slices.len() {
                        warm.slices.remove(idx);
                    }
                }
            }
        }
    }
    if let Some(warm) = &out.warm {
        if warm.slices.len() != out.tenants.len()
            || !warm.respects(&out.cluster)
        {
            return Err(PlanError::InvalidElasticEvent(format!(
                "warm-start carve {} does not fit {} tenants on {}",
                warm.label(),
                out.tenants.len(),
                out.cluster.name
            )));
        }
    }
    Ok(out)
}

/// Take `n` group-`g` devices back from the incumbent carve, one at a
/// time from whichever tenant holds the most of that group (ties to the
/// lowest tenant index) — the deterministic minimal repair that touches
/// as few tenants as possible. A carve that held fewer than `n` (legal:
/// `respects` allows under-assignment) just ends up holding zero.
fn repair_loss(warm: &mut FleetPartition, g: usize, n: usize) {
    for _ in 0..n {
        let richest = (0..warm.slices.len())
            .filter(|&t| g < warm.slices[t].len())
            .max_by_key(|&t| (warm.slices[t][g], std::cmp::Reverse(t)));
        match richest {
            Some(t) if warm.slices[t][g] > 0 => warm.slices[t][g] -= 1,
            _ => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::cluster::ClusterSpec;
    use super::*;
    use crate::model::{MllmSpec, Size};

    fn req2() -> FleetRequest {
        FleetRequest::new(ClusterSpec::a40_a100_demo())
            .tenant(
                "a",
                PlanRequest::default_for(MllmSpec::vlm(Size::S, Size::S)),
            )
            .tenant(
                "b",
                PlanRequest::default_for(MllmSpec::alm(Size::S, Size::S)),
            )
    }

    #[test]
    fn device_loss_shrinks_the_pool_and_repairs_the_warm_carve() {
        let warm = FleetPartition {
            slices: vec![vec![3, 1], vec![1, 3]],
        };
        let req = req2().warm_start(&warm).device_lost(0, 1);
        let resolved = apply_events(&req).unwrap();
        assert_eq!(resolved.cluster.groups[0].count, 3);
        assert!(resolved.events.is_empty());
        // tenant 0 held the most of group 0 — it pays
        let w = resolved.warm.unwrap();
        assert_eq!(w.slices, vec![vec![2, 1], vec![1, 3]]);
    }

    #[test]
    fn losing_a_whole_group_is_a_typed_error() {
        let req = req2().device_lost(0, 4);
        assert!(matches!(
            apply_events(&req),
            Err(PlanError::InvalidElasticEvent(_))
        ));
        let bad_group = req2().device_lost(9, 1);
        assert!(matches!(
            apply_events(&bad_group),
            Err(PlanError::InvalidElasticEvent(_))
        ));
    }

    #[test]
    fn joins_and_leaves_edit_tenants_and_warm_rows_together() {
        let warm = FleetPartition {
            slices: vec![vec![2, 2], vec![2, 2]],
        };
        let req = req2()
            .warm_start(&warm)
            .tenant_joined(
                "c",
                PlanRequest::default_for(MllmSpec::vlm(Size::S, Size::S)),
            )
            .tenant_left("a");
        let resolved = apply_events(&req).unwrap();
        let names: Vec<&str> =
            resolved.tenants.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, vec!["b", "c"]);
        let w = resolved.warm.unwrap();
        assert_eq!(w.slices, vec![vec![2, 2], vec![0, 0]]);

        let dup = req2().tenant_joined(
            "a",
            PlanRequest::default_for(MllmSpec::vlm(Size::S, Size::S)),
        );
        assert!(matches!(
            apply_events(&dup),
            Err(PlanError::InvalidElasticEvent(_))
        ));
        let ghost = req2().tenant_left("nobody");
        assert!(matches!(
            apply_events(&ghost),
            Err(PlanError::InvalidElasticEvent(_))
        ));
    }

    #[test]
    fn stale_warm_shapes_are_refused() {
        let warm = FleetPartition { slices: vec![vec![4, 4]] };
        let req = req2().warm_start(&warm); // 2 tenants, 1 warm row
        assert!(matches!(
            apply_events(&req),
            Err(PlanError::InvalidElasticEvent(_))
        ));
    }
}
