//! Fleet planning: N named tenants sharing one heterogeneous pool.
//!
//! A single [`PlanRequest`] claims its whole [`ClusterSpec`]. But a real
//! pool serves *concurrent* jobs — say a VLM-L finetune and a
//! Whisper-encoder pretrain — and the frozen-aware planner makes the
//! split interesting: the finetune's frozen encoder barely needs the big
//! cards, so handing it every A100 while the pretrain rides the A40s can
//! beat a naive even split on both jobs at once.
//!
//! The fleet layer makes that carve a search:
//!
//! ```text
//! FleetRequest ──► PlanningService::plan_fleet() ──► FleetReport
//!   tenants: name → PlanRequest     search the carve space       per-tenant PlanReports,
//!   shared ClusterSpec              (exact / branch-and-bound /  the chosen FleetPartition,
//!   fairness floor                  LPT-seeded local search),    aggregate throughput,
//!   warm start + elastic events     plan each sub-pool,          provenance incl. search_mode
//!                                   maximize Σ throughput
//! ```
//!
//! A [`FleetPartition`] hands each tenant a per-group device count; every
//! device is assigned to exactly one tenant (a tenant's plan need not
//! *use* its whole slice). Carves are pruned the way
//! [`crate::tuner::space`] prunes chain→group assignments — a tenant
//! slice with zero devices, or with less total memory than the tenant's
//! model weights, is discarded before any search runs. Each surviving
//! sub-pool is planned through the ordinary [`PlanningService::plan`], so
//! the persistent plan cache applies: a tenant's cache entry is keyed by
//! its sub-pool's [`ClusterSpec::fingerprint`], i.e. **fleet entries
//! fingerprint the carve**, and re-carving a pool re-uses every sub-pool
//! plan it has seen before.
//!
//! Three search engines share that evaluation path (see [`search`]):
//! pools within [`MAX_PARTITIONS`] carves are solved **exactly** by
//! enumeration; bigger pools degrade — by plan, not by error — to
//! **branch-and-bound** (admissible static bounds, equal to the exact
//! optimum when it completes) and past [`MAX_BNB_CARVES`] to
//! **LPT-seeded local search**. [`FleetProvenance::search_mode`] records
//! which engine answered. Re-planning is incremental: a
//! [`FleetRequest::warm_start`] incumbent plus [`ElasticEvent`]s
//! (device loss, tenant join/leave) runs a stability-first local search
//! from the repaired incumbent carve, so a 1-GPU loss relocates one
//! tenant's stages, not the fleet's (see [`elastic`]).
//!
//! The winner maximizes aggregate simulated throughput (Σ samples/s)
//! subject to a per-tenant *fairness floor*: each tenant must keep at
//! least `floor ×` the throughput it would get running **alone** on the
//! whole pool. `cornstarch fleet` is the CLI front-end, `reproduce fleet`
//! the demo (two tenants on the 4×A40 + 4×A100 pool beating the naive
//! static halving), and [`PlanDiff`](super::PlanDiff) renders what a
//! re-carve changed.

pub mod elastic;
pub mod search;

pub use elastic::ElasticEvent;
pub use search::{
    SearchMode, ELASTIC_MOVE_BUDGET, MAX_BNB_CARVES, MAX_SEARCH_EVALS,
};

use std::fmt::Write as _;

use crate::memory;
use crate::model::MllmSpec;
use crate::telemetry::{self, key as tkey};
use crate::util::json::Json;

use super::cluster::{ClusterSpec, DeviceGroup};
use super::diff::PlanDiff;
use super::error::PlanError;
use super::report::{PlanReport, SearchStats};
use super::{CachePolicy, PlanRequest, PlanningService};

/// Exhaustive-enumeration cap: a pool whose carve count exceeds this is
/// never enumerated. Auto mode degrades to the heuristic engines past
/// it; only an explicitly forced [`SearchMode::Exact`] still refuses
/// with [`PlanError::InvalidRequest`].
pub const MAX_PARTITIONS: usize = 20_000;

/// One named tenant of a [`FleetRequest`]: a workload plus its planning
/// options. The request's own `cluster` is ignored — the fleet search
/// replaces it with each candidate sub-pool carve (cache policy,
/// objective, budget, threads, and frontier depth are honored as-is).
#[derive(Clone, Debug)]
pub struct Tenant {
    pub name: String,
    pub request: PlanRequest,
}

/// A multi-tenant planning query over one shared pool.
#[derive(Clone, Debug)]
pub struct FleetRequest {
    /// The shared hardware truth all tenants carve.
    pub cluster: ClusterSpec,
    pub tenants: Vec<Tenant>,
    /// Fairness floor in `[0, 1]`: each tenant's carved throughput must
    /// be at least this fraction of its *solo* throughput (the whole
    /// pool to itself). `0.0` disables the floor — and with it the
    /// solo baseline planning runs.
    pub fairness_floor: f64,
    /// Fleet-wide plan-cache policy, applied to every tenant — those
    /// already added *and* those added later, so the builder order does
    /// not matter (see [`FleetRequest::cache_file`]).
    pub cache: Option<CachePolicy>,
    /// Force a search engine; `None` picks by carve count (exact within
    /// [`MAX_PARTITIONS`], branch-and-bound within [`MAX_BNB_CARVES`],
    /// local search beyond — and local search whenever a
    /// [`FleetRequest::warm_start`] incumbent is present).
    pub search_mode: Option<SearchMode>,
    /// Cap on carves the heuristic engines may fully evaluate (plan
    /// every tenant sub-pool). `None` → [`MAX_SEARCH_EVALS`].
    pub search_evals: Option<usize>,
    /// Incumbent carve from a previous answer — the warm start the
    /// elastic path repairs and re-plans from.
    pub warm: Option<FleetPartition>,
    /// Elastic events folded in (in order) before the search runs.
    pub events: Vec<ElasticEvent>,
    /// Move budget for warm-started local search — how far the repair
    /// may drift from the incumbent. `None` → [`ELASTIC_MOVE_BUDGET`].
    pub elastic_moves: Option<usize>,
}

impl FleetRequest {
    pub fn new(cluster: ClusterSpec) -> Self {
        FleetRequest {
            cluster,
            tenants: Vec::new(),
            fairness_floor: 0.0,
            cache: None,
            search_mode: None,
            search_evals: None,
            warm: None,
            events: Vec::new(),
            elastic_moves: None,
        }
    }

    /// Add a named tenant (names must be unique within the request). A
    /// fleet-wide cache policy set earlier is applied to the new
    /// tenant's request.
    pub fn tenant(mut self, name: &str, mut request: PlanRequest) -> Self {
        if let Some(policy) = &self.cache {
            request.cache = policy.clone();
        }
        self.tenants.push(Tenant { name: name.to_string(), request });
        self
    }

    /// Set the per-tenant fairness floor (see [`FleetRequest::fairness_floor`]).
    pub fn fairness_floor(mut self, floor: f64) -> Self {
        self.fairness_floor = floor;
        self
    }

    /// Apply one cache policy to every tenant — tenants already added
    /// are rewritten and tenants added later inherit it, so this
    /// composes with [`FleetRequest::tenant`] in either order. Entries
    /// are keyed by each sub-pool carve's fingerprint, so tenants
    /// sharing one store never alias each other's answers.
    pub fn cache_policy(mut self, policy: CachePolicy) -> Self {
        for t in &mut self.tenants {
            t.request.cache = policy.clone();
        }
        self.cache = Some(policy);
        self
    }

    /// Point every tenant's plan cache at the JSON file `path` (see
    /// [`FleetRequest::cache_policy`]).
    pub fn cache_file(self, path: &str) -> Self {
        self.cache_policy(CachePolicy::File(path.to_string()))
    }

    /// Route every tenant through the process-wide in-memory plan store
    /// (see [`FleetRequest::cache_policy`]).
    pub fn cache_memory(self) -> Self {
        self.cache_policy(CachePolicy::Memory)
    }

    /// Force a search engine instead of the carve-count auto pick.
    pub fn search_mode(mut self, mode: SearchMode) -> Self {
        self.search_mode = Some(mode);
        self
    }

    /// Cap heuristic-engine carve evaluations (see
    /// [`FleetRequest::search_evals`]).
    pub fn search_evals(mut self, cap: usize) -> Self {
        self.search_evals = Some(cap);
        self
    }

    /// Warm-start from an incumbent carve — typically
    /// `prev_report.partition` from the last [`FleetReport`]. Switches
    /// the auto engine pick to stability-first local search so the new
    /// answer stays as close to the incumbent as feasibility allows.
    pub fn warm_start(mut self, prev: &FleetPartition) -> Self {
        self.warm = Some(prev.clone());
        self
    }

    /// Queue an elastic event: `n` devices of cluster group `group` are
    /// gone. Folded in (and the warm carve repaired) before the search.
    pub fn device_lost(mut self, group: usize, n: usize) -> Self {
        self.events.push(ElasticEvent::DeviceLost { group, n });
        self
    }

    /// Queue an elastic event: a new named tenant joins the fleet.
    pub fn tenant_joined(
        mut self,
        name: &str,
        request: PlanRequest,
    ) -> Self {
        self.events.push(ElasticEvent::TenantJoined {
            name: name.to_string(),
            request: Box::new(request),
        });
        self
    }

    /// Queue an elastic event: the named tenant leaves the fleet.
    pub fn tenant_left(mut self, name: &str) -> Self {
        self.events
            .push(ElasticEvent::TenantLeft { name: name.to_string() });
        self
    }

    /// Bound warm-started re-planning's drift from the incumbent (see
    /// [`FleetRequest::elastic_moves`]).
    pub fn elastic_moves(mut self, moves: usize) -> Self {
        self.elastic_moves = Some(moves);
        self
    }

    /// The baseline carve operators reach for without a search: split
    /// every group's devices evenly across tenants (earlier tenants
    /// absorb the remainder). For two tenants this is the naive static
    /// halving `reproduce fleet` compares against. On a tenant-less
    /// request this returns an empty (invalid) partition so the planning
    /// entry points can answer with their typed
    /// [`PlanError::InvalidRequest`] instead of panicking here.
    pub fn naive_partition(&self) -> FleetPartition {
        if self.tenants.is_empty() {
            return FleetPartition { slices: Vec::new() };
        }
        FleetPartition::even(&self.cluster, self.tenants.len())
    }

    fn validate(&self) -> Result<(), PlanError> {
        self.cluster.validate()?;
        if self.tenants.is_empty() {
            return Err(PlanError::InvalidRequest(
                "a fleet request needs at least one tenant".to_string(),
            ));
        }
        for (i, t) in self.tenants.iter().enumerate() {
            if self.tenants[..i].iter().any(|o| o.name == t.name) {
                return Err(PlanError::InvalidRequest(format!(
                    "duplicate tenant name {:?}",
                    t.name
                )));
            }
        }
        if !self.fairness_floor.is_finite()
            || !(0.0..=1.0).contains(&self.fairness_floor)
        {
            return Err(PlanError::InvalidRequest(format!(
                "fairness floor must be in [0, 1], got {}",
                self.fairness_floor
            )));
        }
        Ok(())
    }
}

/// One way of splitting a shared pool across tenants:
/// `slices[tenant][group]` devices of cluster group `group` go to tenant
/// `tenant`. The carves [`enumerate_partitions`] produces assign every
/// device of every group to exactly one tenant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FleetPartition {
    pub slices: Vec<Vec<usize>>,
}

impl FleetPartition {
    /// The even split (see [`FleetRequest::naive_partition`]).
    pub fn even(cluster: &ClusterSpec, tenants: usize) -> Self {
        assert!(tenants >= 1, "a partition needs at least one tenant");
        let slices = (0..tenants)
            .map(|t| {
                cluster
                    .groups
                    .iter()
                    .map(|g| {
                        g.count / tenants
                            + usize::from(t < g.count % tenants)
                    })
                    .collect()
            })
            .collect();
        FleetPartition { slices }
    }

    /// Total devices tenant `t` holds across all groups.
    pub fn tenant_devices(&self, t: usize) -> usize {
        self.slices[t].iter().sum()
    }

    /// Does this carve fit `cluster` — slice widths matching the group
    /// list and no group's devices double-assigned (per-group sums within
    /// the group's count)?
    pub fn respects(&self, cluster: &ClusterSpec) -> bool {
        let n_groups = cluster.groups.len();
        if self.slices.iter().any(|s| s.len() != n_groups) {
            return false;
        }
        cluster.groups.iter().enumerate().all(|(g, grp)| {
            self.slices.iter().map(|s| s[g]).sum::<usize>() <= grp.count
        })
    }

    /// Tenant `t`'s slice as a standalone [`ClusterSpec`] (zero-count
    /// groups dropped — [`ClusterSpec::validate`] rejects empty groups).
    /// `None` when the slice holds no devices at all. The sub-pool keeps
    /// each group's device class and link, so its fingerprint — and with
    /// it every cache entry planned against it — identifies the carve.
    pub fn subpool(
        &self,
        cluster: &ClusterSpec,
        t: usize,
        tenant_name: &str,
    ) -> Option<ClusterSpec> {
        let groups: Vec<DeviceGroup> = cluster
            .groups
            .iter()
            .zip(&self.slices[t])
            .filter(|(_, &count)| count > 0)
            .map(|(g, &count)| DeviceGroup {
                device: g.device.clone(),
                count,
                link_gbps: g.link_gbps,
            })
            .collect();
        if groups.is_empty() {
            return None;
        }
        Some(ClusterSpec {
            name: format!("{}:{}", cluster.name, tenant_name),
            groups,
        })
    }

    /// Compact stable form for provenance and logs, e.g. `[0,4]+[4,0]`
    /// (tenant-major, group-minor).
    pub fn label(&self) -> String {
        self.slices
            .iter()
            .map(|s| {
                let cells: Vec<String> =
                    s.iter().map(|c| c.to_string()).collect();
                format!("[{}]", cells.join(","))
            })
            .collect::<Vec<_>>()
            .join("+")
    }
}

/// All length-`t` vectors of non-negative counts summing exactly to `n`.
fn compositions(n: usize, t: usize) -> Vec<Vec<usize>> {
    if t == 1 {
        return vec![vec![n]];
    }
    let mut out = Vec::new();
    for first in 0..=n {
        for mut rest in compositions(n - first, t - 1) {
            let mut v = Vec::with_capacity(t);
            v.push(first);
            v.append(&mut rest);
            out.push(v);
        }
    }
    out
}

/// `C(n + t - 1, t - 1)` — how many compositions [`compositions`] yields,
/// computed without materializing them (the enumeration guard).
fn compositions_count(n: usize, t: usize) -> u128 {
    let a = (n + t - 1) as u128;
    let mut b = (t - 1) as u128;
    if b > a - b {
        b = a - b;
    }
    let mut r: u128 = 1;
    for i in 1..=b {
        r = r.saturating_mul(a - b + i) / i;
    }
    r
}

/// Every exact carve of `cluster` across `tenants`: the cross product of
/// per-group compositions. Each group's devices are fully assigned (sum
/// over tenants equals the group count), so no device is ever idle by
/// construction and none is double-assigned — the invariants
/// `tests/fleet_checks.rs` holds this enumeration to.
pub fn enumerate_partitions(
    cluster: &ClusterSpec,
    tenants: usize,
) -> Vec<FleetPartition> {
    assert!(tenants >= 1, "a partition needs at least one tenant");
    let per_group: Vec<Vec<Vec<usize>>> = cluster
        .groups
        .iter()
        .map(|g| compositions(g.count, tenants))
        .collect();
    let mut parts: Vec<Vec<Vec<usize>>> = vec![vec![Vec::new(); tenants]];
    for options in &per_group {
        let mut next = Vec::with_capacity(parts.len() * options.len());
        for base in &parts {
            for opt in options {
                let mut p = base.clone();
                for (t, slice) in p.iter_mut().enumerate() {
                    slice.push(opt[t]);
                }
                next.push(p);
            }
        }
        parts = next;
    }
    parts
        .into_iter()
        .map(|slices| FleetPartition { slices })
        .collect()
}

/// How many carves [`enumerate_partitions`] would produce for this pool
/// and tenant count, computed without materializing them (saturating —
/// the comparison against the caps is all callers need).
pub fn carve_count(cluster: &ClusterSpec, tenants: usize) -> u128 {
    cluster
        .groups
        .iter()
        .map(|g| compositions_count(g.count, tenants))
        .fold(1u128, |acc, c| acc.saturating_mul(c))
}

/// A lower bound on the pool memory a tenant's workload needs anywhere:
/// its model weights (bf16), which must all be resident at least once
/// regardless of sharding or frozen policy. Slices whose total memory
/// cannot even hold the weights are pruned before any search runs.
fn min_weight_bytes(spec: &MllmSpec) -> u64 {
    let mut params = spec.llm.params();
    if let Some(v) = &spec.vision {
        params += v.params();
    }
    if let Some(a) = &spec.audio {
        params += a.params();
    }
    params * memory::PARAM_BYTES
}

/// Total memory (bytes) of tenant `t`'s slice under `part`.
fn slice_mem_bytes(
    part: &FleetPartition,
    cluster: &ClusterSpec,
    t: usize,
) -> u64 {
    cluster
        .groups
        .iter()
        .zip(&part.slices[t])
        .map(|(g, &count)| g.device.mem_bytes * count as u64)
        .sum()
}

/// One tenant's share of a [`FleetReport`].
#[derive(Clone, Debug)]
pub struct TenantReport {
    pub name: String,
    /// Devices granted per cluster group (this tenant's row of the
    /// chosen [`FleetPartition`]).
    pub slice: Vec<usize>,
    /// Throughput (samples/s) the tenant would get with the whole pool
    /// to itself — the fairness baseline. Zero when the floor is
    /// disabled (the baselines are then never planned).
    pub solo_throughput: f64,
    pub report: PlanReport,
}

impl TenantReport {
    /// Simulated whole-job throughput under the carve (samples/s).
    pub fn throughput(&self) -> f64 {
        self.report.timeline.throughput
    }

    /// Carved throughput as a fraction of solo throughput — the quantity
    /// the fairness floor constrains.
    pub fn fairness(&self) -> f64 {
        if self.solo_throughput > 0.0 {
            self.throughput() / self.solo_throughput
        } else {
            0.0
        }
    }
}

/// How a fleet answer was found.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetProvenance {
    /// Fingerprint of the shared pool.
    pub cluster: String,
    pub fairness_floor: f64,
    /// Which engine answered: exact enumeration, branch-and-bound, or
    /// LPT-seeded local search.
    pub search_mode: SearchMode,
    /// True when the answer was warm-started from an incumbent carve
    /// (the elastic re-planning path).
    pub warm_start: bool,
    /// Carves examined (evaluated or statically pruned).
    pub partitions_considered: usize,
    /// Carves discarded by the static device/memory filter.
    pub partitions_pruned: usize,
    /// Distinct (tenant, sub-pool) planning queries actually issued
    /// (memoized within the search; cache hits still count).
    pub plans_searched: usize,
    /// Carves where every tenant was feasible and above the floor.
    pub partitions_feasible: usize,
    /// True when the returned carve passed the static verifier's fleet
    /// lints (no device double-assigned across tenants, slice widths
    /// matching the pool) — see [`crate::verify::verify_partition`].
    pub verifier_clean: bool,
    /// The aggregate search counters the whole fleet call fired
    /// (summed over every per-tenant sub-pool search), sourced from
    /// the [`crate::telemetry`] registry. Deterministic.
    pub stats: SearchStats,
}

/// The fleet search's answer (see [`PlanningService::plan_fleet`]).
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Display name of the shared pool.
    pub cluster_name: String,
    /// Device-class display name per cluster group, for rendering the
    /// carve (`["A40", "A100-80G"]`).
    pub group_names: Vec<String>,
    /// Per-tenant answers, in request order.
    pub tenants: Vec<TenantReport>,
    /// The chosen carve (rows parallel to `tenants`).
    pub partition: FleetPartition,
    /// Σ tenant throughput (samples/s) — the searched objective.
    pub aggregate_throughput: f64,
    pub provenance: FleetProvenance,
}

impl FleetReport {
    /// Per-tenant [`PlanDiff`]s from `baseline`'s allocation to this one.
    /// Tenants are matched **by name** (not position), so reports whose
    /// requests listed tenants in different orders still pair correctly;
    /// tenants absent from the baseline are skipped. The front-end of
    /// `cornstarch diff fleet`.
    pub fn diff_from(
        &self,
        baseline: &FleetReport,
    ) -> Vec<(String, PlanDiff)> {
        self.tenants
            .iter()
            .filter_map(|s| {
                baseline
                    .tenants
                    .iter()
                    .find(|b| b.name == s.name)
                    .map(|b| {
                        (
                            s.name.clone(),
                            PlanDiff::between(&b.report, &s.report),
                        )
                    })
            })
            .collect()
    }

    /// Human-readable rendering: the carve, each tenant's plan line, the
    /// aggregate, and provenance.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let total: usize = self
            .partition
            .slices
            .iter()
            .map(|sl| sl.iter().sum::<usize>())
            .sum();
        let _ = writeln!(
            s,
            "fleet plan — {} tenants on {} ({} GPUs, fairness floor {:.2})",
            self.tenants.len(),
            self.cluster_name,
            total,
            self.provenance.fairness_floor
        );
        s.push_str("  carve:\n");
        for t in &self.tenants {
            let cells: Vec<String> = t
                .slice
                .iter()
                .zip(&self.group_names)
                .map(|(c, g)| format!("{c}x {g}"))
                .collect();
            let _ = writeln!(s, "    {:<18} {}", t.name, cells.join(" + "));
        }
        s.push_str("  tenants:\n");
        for t in &self.tenants {
            let _ = writeln!(
                s,
                "    {:<18} {} | iteration {:.1} ms | {:.2} input/s | \
                 {:.2}x solo",
                t.name,
                t.report.winner().candidate.label(),
                t.report.timeline.iteration_ms,
                t.throughput(),
                t.fairness()
            );
        }
        let _ = writeln!(
            s,
            "  aggregate: {:.2} input/s",
            self.aggregate_throughput
        );
        let _ = writeln!(
            s,
            "  provenance: {} search{} — {} carves considered, {} pruned, \
             {} sub-pool plans, {} feasible | verifier {}",
            self.provenance.search_mode.name(),
            if self.provenance.warm_start { " (warm start)" } else { "" },
            self.provenance.partitions_considered,
            self.provenance.partitions_pruned,
            self.provenance.plans_searched,
            self.provenance.partitions_feasible,
            if self.provenance.verifier_clean { "clean" } else { "FAILED" }
        );
        let _ = writeln!(
            s,
            "  search stats: {}",
            self.provenance.stats.render_line()
        );
        s
    }

    /// Machine-readable form for `cornstarch fleet --json` and the serve
    /// line protocol: the carve, per-tenant plans, the aggregate, and
    /// the search provenance — including `search_mode`, which the CI
    /// fleet-smoke asserts heuristic degradation on.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cluster", Json::Str(self.cluster_name.clone())),
            ("carve", Json::Str(self.partition.label())),
            (
                "aggregate_throughput",
                Json::Num(self.aggregate_throughput),
            ),
            (
                "search_mode",
                Json::Str(self.provenance.search_mode.name().to_string()),
            ),
            ("warm_start", Json::Bool(self.provenance.warm_start)),
            (
                "tenants",
                Json::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("name", Json::Str(t.name.clone())),
                                (
                                    "slice",
                                    Json::Arr(
                                        t.slice
                                            .iter()
                                            .map(|&c| Json::Int(c as i64))
                                            .collect(),
                                    ),
                                ),
                                (
                                    "plan",
                                    Json::Str(
                                        t.report
                                            .winner()
                                            .candidate
                                            .label(),
                                    ),
                                ),
                                (
                                    "iteration_ms",
                                    Json::Num(
                                        t.report.timeline.iteration_ms,
                                    ),
                                ),
                                ("throughput", Json::Num(t.throughput())),
                                (
                                    "solo_throughput",
                                    Json::Num(t.solo_throughput),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "provenance",
                Json::obj(vec![
                    (
                        "carves_considered",
                        Json::Int(
                            self.provenance.partitions_considered as i64,
                        ),
                    ),
                    (
                        "carves_pruned",
                        Json::Int(
                            self.provenance.partitions_pruned as i64,
                        ),
                    ),
                    (
                        "plans_searched",
                        Json::Int(self.provenance.plans_searched as i64),
                    ),
                    (
                        "carves_feasible",
                        Json::Int(
                            self.provenance.partitions_feasible as i64,
                        ),
                    ),
                    (
                        "verifier_clean",
                        Json::Bool(self.provenance.verifier_clean),
                    ),
                ]),
            ),
            ("stats", self.provenance.stats.to_json()),
        ])
    }
}

impl PlanningService {
    /// Each tenant's throughput alone on the whole shared pool — the
    /// fairness baselines. Skipped (all zeros) when the floor is
    /// disabled: nothing constrains on them, and on pools large enough
    /// to need the heuristic engines the baseline plans would dwarf the
    /// carve search itself. A tenant that cannot run even solo makes
    /// the fleet infeasible outright.
    fn solo_throughputs(
        &self,
        req: &FleetRequest,
    ) -> Result<Vec<f64>, PlanError> {
        if req.fairness_floor <= 0.0 {
            return Ok(vec![0.0; req.tenants.len()]);
        }
        req.tenants
            .iter()
            .map(|t| {
                self.plan(
                    &t.request.clone().cluster(req.cluster.clone()),
                )
                .map(|r| r.timeline.throughput)
                .map_err(|e| match e {
                    PlanError::NoFeasiblePlan { .. } => {
                        PlanError::InfeasibleFleet(format!(
                            "tenant {:?} is infeasible even with the whole \
                             pool to itself: {e}",
                            t.name
                        ))
                    }
                    other => other,
                })
            })
            .collect()
    }

    /// Search the carve space and keep the feasible carve with the
    /// highest aggregate throughput that honors the fairness floor.
    /// The engine is picked by carve count (or forced via
    /// [`FleetRequest::search_mode`]): exact enumeration within
    /// [`MAX_PARTITIONS`], branch-and-bound within [`MAX_BNB_CARVES`],
    /// LPT-seeded local search beyond — and stability-first local
    /// search whenever a [`FleetRequest::warm_start`] incumbent is
    /// present. Queued [`ElasticEvent`]s are folded in first.
    pub fn plan_fleet(
        &self,
        req: &FleetRequest,
    ) -> Result<FleetReport, PlanError> {
        let resolved = elastic::apply_events(req)?;
        let req = &resolved;
        req.validate()?;
        let n_tenants = req.tenants.len();
        let _fleet_span = telemetry::span(&format!(
            "plan_fleet {} tenants={n_tenants}",
            req.cluster.name
        ));
        // Provenance is re-sourced from the telemetry registry: the
        // search engines bump the named counters at the sites bespoke
        // locals used to live, and the delta over this call becomes the
        // report's FleetProvenance — same numbers, one accounting door.
        let counters_before = telemetry::snapshot();
        let carves = carve_count(&req.cluster, n_tenants);
        let mode = match req.search_mode {
            Some(m) => m,
            None if req.warm.is_some() => SearchMode::LocalSearch,
            None if carves <= MAX_PARTITIONS as u128 => SearchMode::Exact,
            None if carves <= MAX_BNB_CARVES => SearchMode::BranchAndBound,
            None => SearchMode::LocalSearch,
        };
        if mode == SearchMode::Exact && carves > MAX_PARTITIONS as u128 {
            // Only a *forced* exact search can still trip this: auto
            // mode degrades to the heuristic engines instead.
            return Err(PlanError::InvalidRequest(format!(
                "{carves} carves of {} across {n_tenants} tenants exceed \
                 the exhaustive-search cap of {MAX_PARTITIONS}; drop the \
                 forced exact search mode to plan heuristically",
                req.cluster.name
            )));
        }
        let solo = self.solo_throughputs(req)?;
        let min_bytes: Vec<u64> = req
            .tenants
            .iter()
            .map(|t| min_weight_bytes(&t.request.mllm))
            .collect();
        let eval_cap = req.search_evals.unwrap_or(MAX_SEARCH_EVALS);
        let mut cs = search::CarveSearch::new(
            self, req, &solo, &min_bytes, eval_cap,
        );
        let best = match mode {
            SearchMode::Exact => search::exact(&mut cs)?,
            SearchMode::BranchAndBound => {
                let seed = req.warm.clone().unwrap_or_else(|| {
                    search::lpt_seed(req, &min_bytes)
                });
                search::branch_and_bound(&mut cs, &seed)?
            }
            SearchMode::LocalSearch => {
                let stability = req.warm.is_some();
                let seed = req.warm.clone().unwrap_or_else(|| {
                    search::lpt_seed(req, &min_bytes)
                });
                let moves = req.elastic_moves.unwrap_or(if stability {
                    ELASTIC_MOVE_BUDGET
                } else {
                    eval_cap
                });
                search::local_search(&mut cs, seed, moves, stability)?
            }
        };
        let fired = telemetry::snapshot().delta_since(&counters_before);
        let Some(best) = best else {
            return Err(PlanError::InfeasibleFleet(format!(
                "no carve of {} hosts all {n_tenants} tenants within the \
                 {:.2} fairness floor ({} search: {} considered, {} pruned)",
                req.cluster.name,
                req.fairness_floor,
                mode.name(),
                fired.get(tkey::CARVES_CONSIDERED),
                fired.get(tkey::CARVES_PRUNED),
            )));
        };
        // Verification gate: the winning carve must pass the fleet
        // lints (no double-assignment, slice widths matching the pool)
        // before a report leaves the facade. Idle headroom is a Warn
        // and rides along; Errors refuse the report.
        let carve_verdict = crate::verify::verify_partition(
            &best.partition,
            &req.cluster,
        );
        if !carve_verdict.is_clean() {
            return Err(PlanError::FailedVerification(
                carve_verdict.error_summary(),
            ));
        }
        Ok(self.assemble(
            req,
            best.partition,
            best.reports,
            &solo,
            FleetProvenance {
                cluster: req.cluster.fingerprint(),
                fairness_floor: req.fairness_floor,
                search_mode: mode,
                warm_start: req.warm.is_some(),
                partitions_considered: fired.get(tkey::CARVES_CONSIDERED)
                    as usize,
                partitions_pruned: fired.get(tkey::CARVES_PRUNED) as usize,
                plans_searched: fired.get(tkey::PLANS_SEARCHED) as usize,
                partitions_feasible: fired.get(tkey::CARVES_FEASIBLE)
                    as usize,
                verifier_clean: true,
                stats: SearchStats::from_delta(&fired),
            },
        ))
    }

    /// Evaluate one *fixed* carve (e.g. the naive even split) through the
    /// same per-tenant planning path, without enforcing the fairness
    /// floor — the floor constrains the *search*; a handed-in carve is
    /// reported as-is so baselines can be compared and diffed.
    pub fn plan_fleet_partition(
        &self,
        req: &FleetRequest,
        partition: &FleetPartition,
    ) -> Result<FleetReport, PlanError> {
        req.validate()?;
        if partition.slices.len() != req.tenants.len()
            || !partition.respects(&req.cluster)
        {
            return Err(PlanError::InvalidRequest(format!(
                "partition {} does not fit {} tenants on {}",
                partition.label(),
                req.tenants.len(),
                req.cluster.name
            )));
        }
        // The handed-in carve goes through the same static verifier the
        // search path gates on. `respects()` above already refused the
        // Error cases with a typed InvalidRequest; this keeps the gate
        // mandatory even if the two checks ever drift, and surfaces
        // idle-headroom warnings under `-v`.
        let carve_verdict =
            crate::verify::verify_partition(partition, &req.cluster);
        if !carve_verdict.is_clean() {
            return Err(PlanError::FailedVerification(
                carve_verdict.error_summary(),
            ));
        }
        for d in &carve_verdict.diagnostics {
            telemetry::debug(&format!("fleet carve: {}", d.render_line()));
        }
        let _carve_span = telemetry::span(&format!(
            "plan_fleet_partition {}",
            partition.label()
        ));
        let counters_before = telemetry::snapshot();
        let solo = self.solo_throughputs(req)?;
        let mut reports = Vec::with_capacity(req.tenants.len());
        for (t, tenant) in req.tenants.iter().enumerate() {
            let Some(sub) =
                partition.subpool(&req.cluster, t, &tenant.name)
            else {
                return Err(PlanError::InfeasibleFleet(format!(
                    "tenant {:?} holds no devices under carve {}",
                    tenant.name,
                    partition.label()
                )));
            };
            telemetry::incr(tkey::PLANS_SEARCHED);
            let rep = self
                .plan(&tenant.request.clone().cluster(sub))
                .map_err(|e| match e {
                    PlanError::NoFeasiblePlan { .. } => {
                        PlanError::InfeasibleFleet(format!(
                            "tenant {:?} is infeasible on its slice under \
                             carve {}: {e}",
                            tenant.name,
                            partition.label()
                        ))
                    }
                    other => other,
                })?;
            reports.push(rep);
        }
        let fired = telemetry::snapshot().delta_since(&counters_before);
        let provenance = FleetProvenance {
            cluster: req.cluster.fingerprint(),
            // a handed-in carve is evaluated floor-free; recording the
            // request's floor here would render a below-floor baseline
            // as a violated constraint rather than one never applied
            fairness_floor: 0.0,
            search_mode: SearchMode::Exact,
            warm_start: false,
            partitions_considered: 1,
            partitions_pruned: 0,
            plans_searched: fired.get(tkey::PLANS_SEARCHED) as usize,
            partitions_feasible: 1,
            verifier_clean: true,
            stats: SearchStats::from_delta(&fired),
        };
        Ok(self.assemble(req, partition.clone(), reports, &solo, provenance))
    }

    fn assemble(
        &self,
        req: &FleetRequest,
        partition: FleetPartition,
        reports: Vec<PlanReport>,
        solo: &[f64],
        provenance: FleetProvenance,
    ) -> FleetReport {
        let aggregate_throughput =
            reports.iter().map(|r| r.timeline.throughput).sum();
        let tenants = req
            .tenants
            .iter()
            .zip(reports)
            .zip(solo)
            .enumerate()
            .map(|(t, ((tenant, report), &s))| TenantReport {
                name: tenant.name.clone(),
                slice: partition.slices[t].clone(),
                solo_throughput: s,
                report,
            })
            .collect();
        FleetReport {
            cluster_name: req.cluster.name.clone(),
            group_names: req
                .cluster
                .groups
                .iter()
                .map(|g| g.device.name.clone())
                .collect(),
            tenants,
            partition,
            aggregate_throughput,
            provenance,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Size;

    fn small_request(spec: MllmSpec) -> PlanRequest {
        PlanRequest::default_for(spec).threads(2)
    }

    fn tiny_fleet(devices: usize) -> FleetRequest {
        FleetRequest::new(
            ClusterSpec::a40_default().with_devices(devices),
        )
        .tenant("a", small_request(MllmSpec::vlm(Size::S, Size::S)))
        .tenant("b", small_request(MllmSpec::alm(Size::S, Size::S)))
        .fairness_floor(0.1)
    }

    #[test]
    fn compositions_cover_exactly_and_count_matches() {
        let c = compositions(4, 2);
        assert_eq!(c.len(), 5);
        assert_eq!(compositions_count(4, 2), 5);
        for v in &c {
            assert_eq!(v.len(), 2);
            assert_eq!(v.iter().sum::<usize>(), 4);
        }
        assert_eq!(compositions(3, 1), vec![vec![3]]);
        assert_eq!(compositions_count(3, 1), 1);
        assert_eq!(compositions(2, 3).len(), 6); // C(4, 2)
        assert_eq!(compositions_count(2, 3), 6);
    }

    #[test]
    fn carve_count_matches_the_enumeration() {
        let cluster = ClusterSpec::a40_a100_demo();
        assert_eq!(
            carve_count(&cluster, 2),
            enumerate_partitions(&cluster, 2).len() as u128
        );
    }

    #[test]
    fn partitions_assign_every_device_exactly_once() {
        let cluster = ClusterSpec::a40_a100_demo();
        let parts = enumerate_partitions(&cluster, 2);
        assert_eq!(parts.len(), 25); // 5 splits of each 4-device group
        for p in &parts {
            assert!(p.respects(&cluster));
            for (g, grp) in cluster.groups.iter().enumerate() {
                let sum: usize = p.slices.iter().map(|s| s[g]).sum();
                assert_eq!(sum, grp.count, "{}", p.label());
            }
        }
        // all distinct
        for (i, p) in parts.iter().enumerate() {
            assert!(!parts[..i].contains(p));
        }
    }

    #[test]
    fn even_partition_is_the_naive_halving() {
        let cluster = ClusterSpec::a40_a100_demo();
        let p = FleetPartition::even(&cluster, 2);
        assert_eq!(p.slices, vec![vec![2, 2], vec![2, 2]]);
        assert!(p.respects(&cluster));
        // remainders go to earlier tenants
        let odd = ClusterSpec::a40_default().with_devices(5);
        let p3 = FleetPartition::even(&odd, 3);
        assert_eq!(p3.slices, vec![vec![2], vec![2], vec![1]]);
        assert_eq!(p3.label(), "[2]+[2]+[1]");
    }

    #[test]
    fn subpool_keeps_device_classes_and_drops_empty_groups() {
        let cluster = ClusterSpec::a40_a100_demo();
        let p = FleetPartition { slices: vec![vec![0, 4], vec![4, 0]] };
        let sub = p.subpool(&cluster, 0, "llm-job").unwrap();
        assert_eq!(sub.groups.len(), 1);
        assert_eq!(sub.groups[0].device.name, "A100-80G");
        assert_eq!(sub.groups[0].count, 4);
        assert!(sub.validate().is_ok());
        assert!(sub.name.contains("llm-job"));
        let empty = FleetPartition { slices: vec![vec![0, 0]] };
        assert!(empty.subpool(&cluster, 0, "x").is_none());
        // two different carves of the same pool have different
        // fingerprints — what keys the plan cache per carve
        let q = FleetPartition { slices: vec![vec![1, 3], vec![3, 1]] };
        assert_ne!(
            sub.fingerprint(),
            q.subpool(&cluster, 0, "llm-job").unwrap().fingerprint()
        );
    }

    #[test]
    fn fleet_request_validation_catches_nonsense() {
        let cluster = ClusterSpec::a40_default().with_devices(4);
        let empty = FleetRequest::new(cluster.clone());
        assert!(matches!(
            PlanningService::new().plan_fleet(&empty),
            Err(PlanError::InvalidRequest(_))
        ));
        let dup = FleetRequest::new(cluster.clone())
            .tenant("t", small_request(MllmSpec::vlm(Size::S, Size::S)))
            .tenant("t", small_request(MllmSpec::alm(Size::S, Size::S)));
        assert!(matches!(
            PlanningService::new().plan_fleet(&dup),
            Err(PlanError::InvalidRequest(_))
        ));
        let bad_floor = tiny_fleet(4).fairness_floor(1.5);
        assert!(matches!(
            PlanningService::new().plan_fleet(&bad_floor),
            Err(PlanError::InvalidRequest(_))
        ));
    }

    #[test]
    fn tiny_pool_fleet_carves_and_aggregates() {
        let req = tiny_fleet(4);
        let service = PlanningService::new();
        let report = service.plan_fleet(&req).unwrap();
        assert_eq!(report.tenants.len(), 2);
        assert!(report.partition.respects(&req.cluster));
        // every device assigned, none double-assigned
        let total: usize =
            (0..2).map(|t| report.partition.tenant_devices(t)).sum();
        assert_eq!(total, 4);
        for t in &report.tenants {
            assert!(t.throughput() > 0.0);
            assert!(t.report.fits_budget());
            assert!(
                t.fairness() >= req.fairness_floor,
                "{} below floor",
                t.name
            );
            // the plan fits inside the granted slice
            assert!(t.report.plan.n_gpus <= t.slice.iter().sum::<usize>());
        }
        let agg: f64 =
            report.tenants.iter().map(TenantReport::throughput).sum();
        assert!((agg - report.aggregate_throughput).abs() < 1e-9);
        assert!(report.provenance.partitions_feasible >= 1);
        assert_eq!(report.provenance.partitions_considered, 5);
        assert_eq!(report.provenance.search_mode, SearchMode::Exact);
        assert!(!report.provenance.warm_start);
        let text = report.render();
        assert!(text.contains("carve:"), "{text}");
        assert!(text.contains("aggregate:"), "{text}");
        assert!(text.contains("exact search"), "{text}");
    }

    #[test]
    fn searched_carve_never_loses_to_the_even_split() {
        let req = tiny_fleet(4);
        let service = PlanningService::new();
        let searched = service.plan_fleet(&req).unwrap();
        let naive = service
            .plan_fleet_partition(&req, &req.naive_partition())
            .unwrap();
        assert!(
            searched.aggregate_throughput
                >= naive.aggregate_throughput - 1e-9,
            "searched {:.3} vs naive {:.3}",
            searched.aggregate_throughput,
            naive.aggregate_throughput
        );
        // diffing the two allocations is stable and structured
        let diffs = searched.diff_from(&naive);
        assert_eq!(diffs.len(), 2);
        let again = searched.diff_from(&naive);
        for ((name, d), (name2, d2)) in diffs.iter().zip(&again) {
            assert!(!name.is_empty());
            assert_eq!(name, name2);
            assert_eq!(d.render(), d2.render());
        }
    }

    #[test]
    fn one_device_pool_cannot_host_two_tenants() {
        let req = tiny_fleet(1);
        match PlanningService::new().plan_fleet(&req) {
            Err(PlanError::InfeasibleFleet(m)) => {
                assert!(m.contains("carve") || m.contains("tenant"), "{m}")
            }
            other => panic!("expected InfeasibleFleet, got {other:?}"),
        }
    }

    #[test]
    fn partition_mode_rejects_misshapen_carves() {
        let req = tiny_fleet(4);
        let service = PlanningService::new();
        // wrong tenant arity
        let bad = FleetPartition { slices: vec![vec![4]] };
        assert!(matches!(
            service.plan_fleet_partition(&req, &bad),
            Err(PlanError::InvalidRequest(_))
        ));
        // over-assigned group
        let over = FleetPartition { slices: vec![vec![3], vec![3]] };
        assert!(matches!(
            service.plan_fleet_partition(&req, &over),
            Err(PlanError::InvalidRequest(_))
        ));
        // empty slice surfaces as an infeasible fleet, not a panic
        let empty = FleetPartition { slices: vec![vec![4], vec![0]] };
        assert!(matches!(
            service.plan_fleet_partition(&req, &empty),
            Err(PlanError::InfeasibleFleet(_))
        ));
    }

    #[test]
    fn forced_exact_past_the_cap_is_a_typed_error() {
        // 3 groups of 40 devices and 6 tenants: astronomically many
        // carves. Auto mode degrades to the heuristic engines (pinned
        // by tests/fleet_search_checks.rs); *forcing* exact must stay a
        // typed refusal, not an enumeration attempt.
        let mut cluster = ClusterSpec::a40_a100_demo();
        cluster.groups[0].count = 40;
        cluster.groups[1].count = 40;
        cluster.groups.push(cluster.groups[0].clone());
        let mut req =
            FleetRequest::new(cluster).search_mode(SearchMode::Exact);
        for i in 0..6 {
            req = req.tenant(
                &format!("t{i}"),
                small_request(MllmSpec::vlm(Size::S, Size::S)),
            );
        }
        match PlanningService::new().plan_fleet(&req) {
            Err(PlanError::InvalidRequest(m)) => {
                assert!(m.contains("carves"), "{m}")
            }
            other => panic!("expected InvalidRequest, got {other:?}"),
        }
    }

    #[test]
    fn cache_file_applies_regardless_of_builder_order() {
        let cluster = ClusterSpec::a40_default().with_devices(4);
        let before = FleetRequest::new(cluster.clone())
            .cache_file("/tmp/fleet.json")
            .tenant("a", small_request(MllmSpec::vlm(Size::S, Size::S)));
        let after = FleetRequest::new(cluster)
            .tenant("a", small_request(MllmSpec::vlm(Size::S, Size::S)))
            .cache_file("/tmp/fleet.json");
        for req in [&before, &after] {
            assert_eq!(
                req.tenants[0].request.cache,
                CachePolicy::File("/tmp/fleet.json".to_string())
            );
        }
    }

    #[test]
    fn cache_memory_routes_every_tenant_through_the_store() {
        let cluster = ClusterSpec::a40_default().with_devices(4);
        let req = FleetRequest::new(cluster)
            .tenant("a", small_request(MllmSpec::vlm(Size::S, Size::S)))
            .cache_memory()
            .tenant("b", small_request(MllmSpec::alm(Size::S, Size::S)));
        for t in &req.tenants {
            assert_eq!(t.request.cache, CachePolicy::Memory);
        }
    }

    #[test]
    fn min_weight_bytes_is_the_bf16_model_footprint() {
        let spec = MllmSpec::vlm(Size::S, Size::S);
        let mut want = spec.llm.params();
        want += spec.vision.as_ref().unwrap().params();
        assert_eq!(min_weight_bytes(&spec), want * 2);
        // pruning threshold: one tiny slice cannot host an L-sized LLM
        let big = MllmSpec::vlm(Size::L, Size::L);
        assert!(min_weight_bytes(&big) > 40_000_000_000);
    }
}
