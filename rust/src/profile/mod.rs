//! Plan explainability + sim-to-real calibration.
//!
//! Two halves, one seam:
//!
//! * [`analysis`] — *why did this plan win?* Decomposes a plan's
//!   simulated 1F1B trace into per-device compute / comm / idle (summing
//!   exactly to the makespan), frozen-aware bubble attribution per 1F1B
//!   phase (warm-up / steady / cool-down), the winner's cp
//!   token-imbalance ([`crate::cp`]), and per-group utilization on
//!   heterogeneous pools. Every [`crate::api::PlanReport`] carries a
//!   [`PlanAnalysis`]; `cornstarch explain` renders it (or emits it as
//!   JSON), and `explain --vs-*` diffs two decompositions through
//!   [`crate::api::PlanDiff`].
//! * [`calibration`] — *is the simulator honest?* `cornstarch calibrate`
//!   records measured per-stage fwd/bwd/update wall times from the real
//!   PJRT 1F1B executor ([`crate::train::PipelineTrainer`]) into a
//!   [`CalibrationProfile`] (JSON, per device class; needs `make
//!   artifacts`). [`drift`] scores the flops model against a profile per
//!   stage, and [`recost`] re-prices a plan with measured times via
//!   [`crate::cost::MeasuredTimes`] — the profile format is the seam
//!   future backends feed timings through.

pub mod analysis;
pub mod calibration;

pub use analysis::{
    analyze, CpStageImbalance, DeviceDecomposition, GroupUtilization, PhaseBubble,
    PlanAnalysis, PHASES,
};
pub use calibration::{
    drift, recost, CalibrationProfile, DriftReport, StageDrift, StageSample,
    DRIFT_TOLERANCE, SCHEMA,
};
