//! Plan decomposition: where every simulated millisecond went.
//!
//! [`analyze`] replays a plan's 1F1B trace ([`crate::sim::TaskTrace`],
//! enriched with device / stage / task kind) into an exact per-device
//! accounting — compute + comm + idle sums to the makespan *by
//! construction* (a property `tests/profile_checks.rs` holds to 1e-9) —
//! then attributes every idle millisecond to a 1F1B phase (warm-up /
//! steady / cool-down, frozen-aware), scores the winner's cp token
//! distribution via [`crate::cp::metrics`], and reports per-group
//! utilization on heterogeneous pools.

use std::fmt::Write as _;

use crate::api::ClusterSpec;
use crate::cp::rank_loads;
use crate::modality::Plan;
use crate::pipeline::{onef1b_tasks, TaskKind};
use crate::sim::SimResult;
use crate::tuner::evaluate::{cp_block_workloads, pick_cp_over, CP_PICK_SEED};
use crate::util::json::Json;

/// The three 1F1B phases gaps are attributed to, in schedule order.
pub const PHASES: [&str; 3] = ["warm-up", "steady", "cool-down"];

/// One device's exact share of the makespan.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceDecomposition {
    pub device: usize,
    /// Cluster group index this device's stages were assigned to.
    pub group: usize,
    /// Device-class name of that group (`A40`, …).
    pub device_class: String,
    /// Executing fwd/bwd tasks.
    pub compute_ms: f64,
    /// Waiting on an activation in flight: the dependency had finished
    /// but its edge latency had not yet been paid.
    pub comm_ms: f64,
    /// Waiting with nothing in flight — pipeline bubble.
    pub idle_ms: f64,
    /// Every backward on this device is a skipped frozen backward
    /// (0 ms) — its bubbles are the cheap kind §4.2 exploits.
    pub frozen: bool,
}

impl DeviceDecomposition {
    /// `compute + comm + idle` — equals the makespan exactly.
    pub fn total_ms(&self) -> f64 {
        self.compute_ms + self.comm_ms + self.idle_ms
    }
}

/// Device-summed bubble time inside one 1F1B phase.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseBubble {
    /// One of [`PHASES`].
    pub phase: &'static str,
    pub idle_ms: f64,
    pub comm_ms: f64,
    /// Total device-time inside this phase's windows (summed across
    /// devices — each device gets its own phase boundaries).
    pub span_ms: f64,
}

/// cp token-imbalance of one LLM stage's distribution.
#[derive(Clone, Debug, PartialEq)]
pub struct CpStageImbalance {
    pub stage: String,
    /// Winning algorithm name (`LPT`, `Zigzag`, `Naive Ring`).
    pub algorithm: String,
    pub cp: usize,
    /// max rank load / mean rank load; 1.0 = perfectly balanced.
    pub imbalance: f64,
}

/// Mean utilization of one cluster device group.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupUtilization {
    pub group: usize,
    pub device_class: String,
    /// Simulated pipeline devices the plan placed in this group.
    pub devices: usize,
    /// Mean over those devices of `busy / makespan` (0 when the plan
    /// left the group unused).
    pub utilization: f64,
}

/// The full decomposition of one plan's simulated iteration.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanAnalysis {
    pub makespan_ms: f64,
    pub devices: Vec<DeviceDecomposition>,
    pub phases: Vec<PhaseBubble>,
    /// One entry per LLM pipeline stage when `cp > 1`, empty otherwise.
    pub stage_cp: Vec<CpStageImbalance>,
    pub groups: Vec<GroupUtilization>,
}

impl PlanAnalysis {
    pub fn total_compute_ms(&self) -> f64 {
        self.devices.iter().map(|d| d.compute_ms).sum()
    }

    pub fn total_comm_ms(&self) -> f64 {
        self.devices.iter().map(|d| d.comm_ms).sum()
    }

    pub fn total_idle_ms(&self) -> f64 {
        self.devices.iter().map(|d| d.idle_ms).sum()
    }

    /// Machine-readable form (the `explain --json` payload). Field
    /// values are exactly the struct's — no rounding — so double runs
    /// are byte-identical.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("makespan_ms", Json::Num(self.makespan_ms)),
            (
                "devices",
                Json::Arr(
                    self.devices
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("device", Json::Int(d.device as i64)),
                                ("group", Json::Int(d.group as i64)),
                                ("device_class", Json::Str(d.device_class.clone())),
                                ("compute_ms", Json::Num(d.compute_ms)),
                                ("comm_ms", Json::Num(d.comm_ms)),
                                ("idle_ms", Json::Num(d.idle_ms)),
                                ("frozen", Json::Bool(d.frozen)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "phases",
                Json::Arr(
                    self.phases
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("phase", Json::Str(p.phase.to_string())),
                                ("idle_ms", Json::Num(p.idle_ms)),
                                ("comm_ms", Json::Num(p.comm_ms)),
                                ("span_ms", Json::Num(p.span_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "cp",
                Json::Arr(
                    self.stage_cp
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("stage", Json::Str(c.stage.clone())),
                                ("algorithm", Json::Str(c.algorithm.clone())),
                                ("cp", Json::Int(c.cp as i64)),
                                ("imbalance", Json::Num(c.imbalance)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "groups",
                Json::Arr(
                    self.groups
                        .iter()
                        .map(|g| {
                            Json::obj(vec![
                                ("group", Json::Int(g.group as i64)),
                                ("device_class", Json::Str(g.device_class.clone())),
                                ("devices", Json::Int(g.devices as i64)),
                                ("utilization", Json::Num(g.utilization)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Human-readable table (the default `explain` output).
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "  analysis (makespan {:.2} ms):", self.makespan_ms);
        let _ = writeln!(
            s,
            "    per-device decomposition (compute + comm + idle = makespan):"
        );
        for d in &self.devices {
            let _ = writeln!(
                s,
                "      dev {:>2} {:<10} compute {:>9.2}  comm {:>8.2}  idle {:>9.2}{}",
                d.device,
                d.device_class,
                d.compute_ms,
                d.comm_ms,
                d.idle_ms,
                if d.frozen { "  [frozen bwd]" } else { "" }
            );
        }
        let _ = writeln!(s, "    1F1B bubbles by phase (idle / window, device-summed):");
        for p in &self.phases {
            let _ = writeln!(
                s,
                "      {:<10} {:>9.2} / {:>9.2} ms (comm {:>7.2})",
                p.phase, p.idle_ms, p.span_ms, p.comm_ms
            );
        }
        match self.stage_cp.first() {
            Some(c) => {
                let _ = writeln!(
                    s,
                    "    cp distribution: {} over {} ranks, imbalance {:.3} (max/mean) \
                     on {} llm stage(s)",
                    c.algorithm,
                    c.cp,
                    c.imbalance,
                    self.stage_cp.len()
                );
            }
            None => {
                let _ = writeln!(s, "    cp distribution: none (cp = 1)");
            }
        }
        let _ = writeln!(s, "    group utilization:");
        for g in &self.groups {
            let _ = writeln!(
                s,
                "      {:<10} x{:<2} {:>6.1}%",
                g.device_class,
                g.devices,
                g.utilization * 100.0
            );
        }
        s
    }
}

/// Decompose `sim` (the trace of `plan`'s 1F1B schedule) into
/// [`PlanAnalysis`]. `llm_tokens` and `cp` come from the workload and the
/// winning candidate; they parameterize the cp-imbalance score, which
/// reuses the tuner's deterministic pick
/// ([`crate::tuner::evaluate::pick_cp_algorithm`] internals, same seed).
///
/// The task graph is rebuilt with [`onef1b_tasks`] — deterministic and
/// index-aligned with `sim.trace`, because [`Plan::simulate`] uses the
/// same constructor — to read each task's dependency edges back.
pub fn analyze(
    plan: &Plan,
    sim: &SimResult,
    cluster: &ClusterSpec,
    llm_tokens: usize,
    cp: usize,
) -> PlanAnalysis {
    let tasks = onef1b_tasks(&plan.graph, plan.num_microbatches);
    debug_assert_eq!(tasks.len(), sim.trace.len());
    let makespan = sim.makespan_ms;
    let n_dev = sim.device_busy_ms.len();

    // Device -> cluster group: stages sharing a device share a group.
    let mut dev_group = vec![0usize; n_dev];
    for (i, node) in plan.graph.nodes.iter().enumerate() {
        if node.device < n_dev {
            dev_group[node.device] = plan.stage_groups.get(i).copied().unwrap_or(0);
        }
    }
    let class_of = |g: usize| -> String {
        cluster
            .groups
            .get(g)
            .map(|gr| gr.device.name.clone())
            .unwrap_or_else(|| "?".to_string())
    };

    // Tasks per device in execution order (ties broken by task index —
    // zero-duration frozen backwards can share a timestamp).
    let mut per_dev: Vec<Vec<usize>> = vec![Vec::new(); n_dev];
    for (i, tr) in sim.trace.iter().enumerate() {
        if tr.device < n_dev {
            per_dev[tr.device].push(i);
        }
    }
    for order in &mut per_dev {
        order.sort_by(|&a, &b| {
            let (ta, tb) = (&sim.trace[a], &sim.trace[b]);
            ta.start_ms
                .total_cmp(&tb.start_ms)
                .then(ta.end_ms.total_cmp(&tb.end_ms))
                .then(a.cmp(&b))
        });
    }

    let mut devices = Vec::with_capacity(n_dev);
    let mut phases: Vec<PhaseBubble> = PHASES
        .iter()
        .map(|&phase| PhaseBubble { phase, idle_ms: 0.0, comm_ms: 0.0, span_ms: 0.0 })
        .collect();

    for d in 0..n_dev {
        // This device's 1F1B phase boundaries: warm-up until its first
        // backward starts, cool-down after its last forward ends. A
        // frozen stage's 0 ms backwards still mark the boundary — the
        // steady window exists, its bubbles are just cheap.
        let mut first_bwd = makespan;
        let mut last_fwd = 0.0f64;
        let mut frozen = true;
        for &i in &per_dev[d] {
            let tr = &sim.trace[i];
            match tr.kind {
                TaskKind::Fwd => last_fwd = last_fwd.max(tr.end_ms),
                TaskKind::Bwd => {
                    first_bwd = first_bwd.min(tr.start_ms);
                    if tasks[i].dur_ms > 0.0 {
                        frozen = false;
                    }
                }
            }
        }
        let warm_end = first_bwd.min(makespan);
        let cool_start = last_fwd.max(warm_end).min(makespan);
        let windows = [(0.0, warm_end), (warm_end, cool_start), (cool_start, makespan)];
        for (p, &(a, b)) in windows.iter().enumerate() {
            phases[p].span_ms += (b - a).max(0.0);
        }

        // A gap splits into comm vs idle by dependency latency, then
        // across phase windows proportionally by interval overlap.
        let mut split_gap = |a: f64, b: f64, comm_w: f64| {
            let len = b - a;
            if len <= 0.0 {
                return;
            }
            let idle_w = len - comm_w;
            for (p, &(p0, p1)) in windows.iter().enumerate() {
                let ov = (b.min(p1) - a.max(p0)).max(0.0);
                if ov <= 0.0 {
                    continue;
                }
                let frac = ov / len;
                phases[p].comm_ms += comm_w * frac;
                phases[p].idle_ms += idle_w * frac;
            }
        };

        let mut compute = 0.0f64;
        let mut comm = 0.0f64;
        let mut idle = 0.0f64;
        let mut prev_end = 0.0f64;
        for &i in &per_dev[d] {
            let tr = &sim.trace[i];
            let gap = tr.start_ms - prev_end;
            if gap > 0.0 {
                // How much of the gap was spent waiting on an in-flight
                // activation? The device could not have started earlier
                // than when all deps *with* their edge latency were in —
                // the slice past max(prev task end, deps-without-latency)
                // is comm-bound; the rest is bubble.
                let mut ready_no_comm = 0.0f64;
                let mut ready_with_comm = 0.0f64;
                for &(dep, lat) in &tasks[i].deps {
                    ready_no_comm = ready_no_comm.max(sim.trace[dep].end_ms);
                    ready_with_comm = ready_with_comm.max(sim.trace[dep].end_ms + lat);
                }
                let comm_w =
                    (ready_with_comm - prev_end.max(ready_no_comm)).clamp(0.0, gap);
                comm += comm_w;
                idle += gap - comm_w;
                split_gap(prev_end, tr.start_ms, comm_w);
            }
            compute += tr.end_ms - tr.start_ms;
            prev_end = prev_end.max(tr.end_ms);
        }
        if makespan > prev_end {
            idle += makespan - prev_end;
            split_gap(prev_end, makespan, 0.0);
        }

        let group = dev_group[d];
        devices.push(DeviceDecomposition {
            device: d,
            group,
            device_class: class_of(group),
            compute_ms: compute,
            comm_ms: comm,
            idle_ms: idle,
            frozen,
        });
    }

    // cp imbalance: same mask, seed, and winner rule as the tuner's
    // cp_algorithm pick, so `explain` names the distribution the cached
    // plan actually reports.
    let mut stage_cp = Vec::new();
    if cp > 1 {
        let w = cp_block_workloads(llm_tokens, CP_PICK_SEED);
        let alg = pick_cp_over(&w, cp);
        let loads = rank_loads(&w, &alg.assign(&w, cp), cp);
        let max = loads.iter().copied().max().unwrap_or(0) as f64;
        let mean = loads.iter().sum::<u64>() as f64 / cp as f64;
        let imbalance = if mean > 0.0 { max / mean } else { 1.0 };
        for name in &plan.stage_names {
            if name.starts_with("llm") {
                stage_cp.push(CpStageImbalance {
                    stage: name.clone(),
                    algorithm: alg.name().to_string(),
                    cp,
                    imbalance,
                });
            }
        }
    }

    let groups = cluster
        .groups
        .iter()
        .enumerate()
        .map(|(gi, gr)| {
            let devs: Vec<usize> = (0..n_dev).filter(|&d| dev_group[d] == gi).collect();
            let utilization = if devs.is_empty() || makespan <= 0.0 {
                0.0
            } else {
                devs.iter().map(|&d| sim.device_busy_ms[d] / makespan).sum::<f64>()
                    / devs.len() as f64
            };
            GroupUtilization {
                group: gi,
                device_class: gr.device.name.clone(),
                devices: devs.len(),
                utilization,
            }
        })
        .collect();

    PlanAnalysis { makespan_ms: makespan, devices, phases, stage_cp, groups }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::PlanRequest;
    use crate::model::{MllmSpec, Size};
    use crate::tuner::{build_plan, Candidate, FrozenSetting};

    fn analyzed(cand: &Candidate) -> (Plan, PlanAnalysis) {
        let spec = MllmSpec::vlm(Size::S, Size::S);
        let cluster = PlanRequest::default_for(spec.clone()).cluster;
        let plan = build_plan(&spec, cand, &cluster);
        let m = plan.simulate();
        let a = analyze(&plan, &m.sim, &cluster, spec.llm_tokens(), cand.cp);
        (plan, a)
    }

    fn cand(cp: usize, frozen: FrozenSetting) -> Candidate {
        Candidate {
            strategy: crate::modality::Strategy::Cornstarch,
            enc_pps: vec![1],
            llm_pp: 2,
            tp: 1,
            cp,
            num_microbatches: 4,
            frozen,
            chain_groups: Vec::new(),
        }
    }

    #[test]
    fn decomposition_sums_to_makespan() {
        let (_, a) = analyzed(&cand(1, FrozenSetting::Paper));
        assert!(!a.devices.is_empty());
        for d in &a.devices {
            assert!(
                (d.total_ms() - a.makespan_ms).abs() < 1e-9,
                "dev {}: {} vs {}",
                d.device,
                d.total_ms(),
                a.makespan_ms
            );
            assert!(d.compute_ms >= 0.0 && d.comm_ms >= 0.0 && d.idle_ms >= 0.0);
        }
    }

    #[test]
    fn phase_windows_cover_all_devices() {
        let (_, a) = analyzed(&cand(1, FrozenSetting::Paper));
        let span: f64 = a.phases.iter().map(|p| p.span_ms).sum();
        let expect = a.makespan_ms * a.devices.len() as f64;
        assert!((span - expect).abs() < 1e-6, "{span} vs {expect}");
        let phase_idle: f64 = a.phases.iter().map(|p| p.idle_ms).sum();
        assert!((phase_idle - a.total_idle_ms()).abs() < 1e-6);
        let phase_comm: f64 = a.phases.iter().map(|p| p.comm_ms).sum();
        assert!((phase_comm - a.total_comm_ms()).abs() < 1e-6);
    }

    #[test]
    fn cp_entries_only_when_distributing() {
        let (_, off) = analyzed(&cand(1, FrozenSetting::Paper));
        assert!(off.stage_cp.is_empty());
        let (plan, on) = analyzed(&cand(2, FrozenSetting::Paper));
        let n_llm = plan.stage_names.iter().filter(|n| n.starts_with("llm")).count();
        assert_eq!(on.stage_cp.len(), n_llm);
        for c in &on.stage_cp {
            assert!(c.imbalance >= 1.0 - 1e-12, "{}", c.imbalance);
            assert_eq!(c.cp, 2);
        }
    }

    #[test]
    fn frozen_encoder_devices_are_flagged() {
        // Paper policy freezes the vision encoder: its device runs only
        // 0 ms backwards. The trainable LLM devices must not be flagged.
        let (plan, a) = analyzed(&cand(1, FrozenSetting::Paper));
        let enc_dev = plan.graph.nodes[plan
            .stage_names
            .iter()
            .position(|n| n.starts_with("enc:"))
            .unwrap()]
        .device;
        let llm_dev = plan.graph.nodes[plan
            .stage_names
            .iter()
            .position(|n| n.starts_with("llm"))
            .unwrap()]
        .device;
        assert!(a.devices[enc_dev].frozen);
        assert!(!a.devices[llm_dev].frozen);
    }

    #[test]
    fn json_roundtrips_and_is_deterministic() {
        let (_, a) = analyzed(&cand(2, FrozenSetting::Paper));
        let (_, b) = analyzed(&cand(2, FrozenSetting::Paper));
        assert_eq!(a, b);
        let text = a.to_json().render();
        assert_eq!(text, b.to_json().render());
        let parsed = Json::parse(&text).expect("explain JSON parses");
        assert!(parsed.get("devices").is_some());
        assert!(!a.render().is_empty());
    }
}
