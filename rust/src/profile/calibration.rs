//! Sim-to-real calibration: measured PJRT stage times vs the flops model.
//!
//! A [`CalibrationProfile`] records per-stage forward / backward / update
//! wall times from a real [`crate::train::PipelineTrainer`] run
//! (`cornstarch calibrate`), serialized as JSON keyed by device class.
//! [`drift`] joins a profile against a plan's modeled
//! [`crate::pipeline::StageCost`]s and reports the per-stage relative
//! error plus the makespan under each timing source; [`recost`] produces
//! a plan whose stage times come from the profile instead of the model
//! (through [`crate::cost::MeasuredTimes`]), so the simulator can replay
//! the same schedule on measured reality.

use std::fmt::Write as _;
use std::path::Path;

use crate::cost::MeasuredTimes;
use crate::modality::Plan;
use crate::pipeline::StageCost;
use crate::train::PipelineTrainer;
use crate::util::json::Json;

/// Schema tag every profile JSON carries (validated on parse and in CI).
pub const SCHEMA: &str = "cornstarch-calibration/v1";

/// Max per-stage relative fwd+bwd error the golden drift test tolerates.
pub const DRIFT_TOLERANCE: f64 = 0.05;

/// Measured times of one pipeline stage, per microbatch (`upd_ms` is
/// per step — the optimizer runs once however many microbatches flow).
#[derive(Clone, Debug, PartialEq)]
pub struct StageSample {
    /// Planner-style stage name (`enc:vision[0]`, `llm[1]`, …).
    pub stage: String,
    pub fwd_ms: f64,
    pub bwd_ms: f64,
    pub upd_ms: f64,
}

/// A set of measured stage times for one device class.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CalibrationProfile {
    /// Device class the measurements were taken on (`A40`, `cpu-pjrt`, …).
    pub device_class: String,
    pub samples: Vec<StageSample>,
}

impl CalibrationProfile {
    /// Snapshot the last completed step of a live pipeline: cumulative
    /// fwd/bwd divided by the step's microbatch count, update as-is.
    pub fn from_pipeline(pipe: &PipelineTrainer, device_class: &str) -> CalibrationProfile {
        let m = pipe.last_microbatches.max(1) as f64;
        let samples = pipe
            .stage_names()
            .into_iter()
            .enumerate()
            .map(|(i, stage)| StageSample {
                stage,
                fwd_ms: pipe.stage_fwd_ms.get(i).copied().unwrap_or(0.0) / m,
                bwd_ms: pipe.stage_bwd_ms.get(i).copied().unwrap_or(0.0) / m,
                upd_ms: pipe.stage_upd_ms.get(i).copied().unwrap_or(0.0),
            })
            .collect();
        CalibrationProfile { device_class: device_class.to_string(), samples }
    }

    pub fn stage(&self, name: &str) -> Option<&StageSample> {
        self.samples.iter().find(|s| s.stage == name)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Str(SCHEMA.to_string())),
            ("device_class", Json::Str(self.device_class.clone())),
            (
                "stages",
                Json::Arr(
                    self.samples
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("stage", Json::Str(s.stage.clone())),
                                ("fwd_ms", Json::Num(s.fwd_ms)),
                                ("bwd_ms", Json::Num(s.bwd_ms)),
                                ("upd_ms", Json::Num(s.upd_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Validate + decode a parsed profile document; rejects wrong or
    /// missing schema tags and negative / non-finite times.
    pub fn from_json(j: &Json) -> Result<CalibrationProfile, String> {
        let schema = j
            .get("schema")
            .and_then(Json::as_str)
            .ok_or("profile missing `schema`")?;
        if schema != SCHEMA {
            return Err(format!("unsupported profile schema {schema:?} (want {SCHEMA})"));
        }
        let device_class = j
            .get("device_class")
            .and_then(Json::as_str)
            .ok_or("profile missing `device_class`")?
            .to_string();
        let stages = j
            .get("stages")
            .and_then(Json::as_arr)
            .ok_or("profile missing `stages` array")?;
        let mut samples = Vec::with_capacity(stages.len());
        for s in stages {
            let stage = s
                .get("stage")
                .and_then(Json::as_str)
                .ok_or("stage entry missing `stage`")?
                .to_string();
            let num = |k: &str| -> Result<f64, String> {
                let v = s
                    .get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("stage {stage:?} missing `{k}`"))?;
                if !v.is_finite() || v < 0.0 {
                    return Err(format!("stage {stage:?} `{k}` must be finite and >= 0"));
                }
                Ok(v)
            };
            let fwd_ms = num("fwd_ms")?;
            let bwd_ms = num("bwd_ms")?;
            let upd_ms = num("upd_ms")?;
            samples.push(StageSample { stage, fwd_ms, bwd_ms, upd_ms });
        }
        Ok(CalibrationProfile { device_class, samples })
    }

    pub fn parse(text: &str) -> Result<CalibrationProfile, String> {
        Self::from_json(&Json::parse(text)?)
    }

    pub fn load(path: &Path) -> Result<CalibrationProfile, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().render())
    }

    /// The per-stage override table `cost` consumes ([`MeasuredTimes`]):
    /// fwd/bwd only — update time is off the 1F1B critical path the
    /// simulator models.
    pub fn measured_times(&self) -> MeasuredTimes {
        let mut t = MeasuredTimes::default();
        for s in &self.samples {
            t.insert(&s.stage, StageCost { fwd_ms: s.fwd_ms, bwd_ms: s.bwd_ms });
        }
        t
    }
}

/// One stage's modeled-vs-measured comparison (fwd+bwd, per microbatch).
#[derive(Clone, Debug, PartialEq)]
pub struct StageDrift {
    pub stage: String,
    /// Flops-model fwd+bwd of the plan's stage.
    pub sim_ms: f64,
    /// Profiled fwd+bwd.
    pub measured_ms: f64,
    /// `|sim - measured| / measured` (1.0 when only one side is zero).
    pub rel_err: f64,
}

/// Sim-vs-measured report for a whole plan.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftReport {
    pub device_class: String,
    pub stages: Vec<StageDrift>,
    /// Worst per-stage relative error (0 when nothing matched).
    pub max_rel_err: f64,
    /// Simulated makespan under the flops model…
    pub sim_makespan_ms: f64,
    /// …and under the measured stage times ([`recost`]).
    pub measured_makespan_ms: f64,
    /// Plan stages with no sample in the profile (excluded from drift).
    pub unmatched: Vec<String>,
}

impl DriftReport {
    pub fn within(&self, tol: f64) -> bool {
        self.max_rel_err <= tol
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "  drift vs profile ({}): max stage error {:.1}%, makespan {:.2} ms \
             (model) vs {:.2} ms (measured)",
            self.device_class,
            self.max_rel_err * 100.0,
            self.sim_makespan_ms,
            self.measured_makespan_ms
        );
        for d in &self.stages {
            let _ = writeln!(
                s,
                "      {:<16} model {:>9.2} ms  measured {:>9.2} ms  err {:>6.1}%",
                d.stage,
                d.sim_ms,
                d.measured_ms,
                d.rel_err * 100.0
            );
        }
        if !self.unmatched.is_empty() {
            let _ = writeln!(s, "      unmatched stages: {}", self.unmatched.join(", "));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("device_class", Json::Str(self.device_class.clone())),
            ("max_rel_err", Json::Num(self.max_rel_err)),
            ("sim_makespan_ms", Json::Num(self.sim_makespan_ms)),
            ("measured_makespan_ms", Json::Num(self.measured_makespan_ms)),
            (
                "stages",
                Json::Arr(
                    self.stages
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("stage", Json::Str(d.stage.clone())),
                                ("sim_ms", Json::Num(d.sim_ms)),
                                ("measured_ms", Json::Num(d.measured_ms)),
                                ("rel_err", Json::Num(d.rel_err)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "unmatched",
                Json::Arr(self.unmatched.iter().map(|u| Json::Str(u.clone())).collect()),
            ),
        ])
    }
}

/// Compare `plan`'s modeled stage times against `profile`, stage name by
/// stage name, and simulate the plan under both timing sources.
pub fn drift(plan: &Plan, profile: &CalibrationProfile) -> DriftReport {
    let mut stages = Vec::new();
    let mut unmatched = Vec::new();
    let mut max_rel = 0.0f64;
    for (name, node) in plan.stage_names.iter().zip(&plan.graph.nodes) {
        match profile.stage(name) {
            Some(s) => {
                let sim_ms = node.cost.total();
                let measured_ms = s.fwd_ms + s.bwd_ms;
                let rel_err = if measured_ms > 0.0 {
                    (sim_ms - measured_ms).abs() / measured_ms
                } else if sim_ms > 0.0 {
                    1.0
                } else {
                    0.0
                };
                max_rel = max_rel.max(rel_err);
                stages.push(StageDrift { stage: name.clone(), sim_ms, measured_ms, rel_err });
            }
            None => unmatched.push(name.clone()),
        }
    }
    DriftReport {
        device_class: profile.device_class.clone(),
        stages,
        max_rel_err: max_rel,
        sim_makespan_ms: plan.simulate().iteration_ms,
        measured_makespan_ms: recost(plan, profile).simulate().iteration_ms,
        unmatched,
    }
}

/// A copy of `plan` whose matched stage costs come from `profile` instead
/// of the flops model. Unmatched stages keep their modeled cost.
pub fn recost(plan: &Plan, profile: &CalibrationProfile) -> Plan {
    let mut out = plan.clone();
    profile.measured_times().apply(&mut out.graph, &plan.stage_names);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> CalibrationProfile {
        CalibrationProfile {
            device_class: "A40".to_string(),
            samples: vec![
                StageSample {
                    stage: "llm[0]".to_string(),
                    fwd_ms: 10.0,
                    bwd_ms: 20.0,
                    upd_ms: 3.0,
                },
                StageSample {
                    stage: "enc:vision[0]".to_string(),
                    fwd_ms: 5.0,
                    bwd_ms: 0.0,
                    upd_ms: 0.5,
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip_preserves_profile() {
        let p = profile();
        let text = p.to_json().render();
        let back = CalibrationProfile::parse(&text).expect("parses");
        assert_eq!(p, back);
    }

    #[test]
    fn wrong_schema_is_rejected() {
        let mut j = profile().to_json();
        if let Json::Obj(pairs) = &mut j {
            for (k, v) in pairs.iter_mut() {
                if k == "schema" {
                    *v = Json::Str("cornstarch-calibration/v0".to_string());
                }
            }
        }
        let err = CalibrationProfile::from_json(&j).unwrap_err();
        assert!(err.contains("unsupported"), "{err}");
    }

    #[test]
    fn negative_times_are_rejected() {
        let text = r#"{"schema": "cornstarch-calibration/v1",
            "device_class": "A40",
            "stages": [{"stage": "llm[0]", "fwd_ms": -1, "bwd_ms": 0, "upd_ms": 0}]}"#;
        assert!(CalibrationProfile::parse(text).is_err());
    }

    #[test]
    fn measured_times_keep_fwd_bwd_only() {
        let t = profile().measured_times();
        assert_eq!(t.len(), 2);
        let c = t.get("llm[0]").unwrap();
        assert_eq!(c.fwd_ms, 10.0);
        assert_eq!(c.bwd_ms, 20.0);
    }
}
