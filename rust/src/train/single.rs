//! Single-process trainer: one PJRT client, microbatches in sequence.
//!
//! This is the numerics oracle for the thread-per-stage executor
//! ([`super::pipeline`]) — both run the *same artifacts* in the *same
//! order*, so their losses must agree bit-for-bit — and the reference the
//! pytest suite checks against the pure-JAX model.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::runtime::{HostTensor, Manifest, ModelRuntime, Role};

use super::{
    BamTensors, Callbacks, FrozenPolicy, GradAction, GradStore, Sample,
    StepStats,
};

/// Stashed forward inputs of one component for one microbatch (gradient
/// checkpointing: the backward artifacts recompute activations from these;
/// no residuals ever cross the wire).
type Stash = HashMap<String, Vec<HostTensor>>;

/// Sequential trainer over one model's artifacts.
pub struct Trainer {
    rt: ModelRuntime,
    policy: FrozenPolicy,
    bam: BamTensors,
    /// AdamW slots per parameter-owning trainable component.
    opt: HashMap<String, (Vec<f32>, Vec<f32>)>,
    step: usize,
    pub lr: f32,
    /// Encoder names in manifest order (`vision`, `audio`, ...).
    enc_names: Vec<String>,
    n_stages: usize,
    /// §5.1 inter-module hooks (Listing 2).
    pub callbacks: Callbacks,
}

impl Trainer {
    pub fn new(
        manifest: &Manifest,
        model: &str,
        policy: FrozenPolicy,
        lr: f32,
    ) -> Result<Trainer> {
        let rt = ModelRuntime::load_all(manifest, model)?;
        let m = rt.model().clone();
        let bam = BamTensors::of(&m)?;
        let mut opt = HashMap::new();
        for c in &m.components {
            if policy.trainable(&c.kind) && c.shares_params_with.is_none() {
                let n = c.n_params;
                opt.insert(c.name.clone(), (vec![0.0; n], vec![0.0; n]));
            }
        }
        Ok(Trainer {
            rt,
            policy,
            bam,
            opt,
            step: 0,
            lr,
            enc_names: m.encoder_names(),
            n_stages: m.n_llm_stages(),
            callbacks: Callbacks::none(),
        })
    }

    pub fn runtime(&self) -> &ModelRuntime {
        &self.rt
    }

    pub fn runtime_mut(&mut self) -> &mut ModelRuntime {
        &mut self.rt
    }

    pub fn policy(&self) -> FrozenPolicy {
        self.policy
    }

    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// Forward one sample end-to-end; returns (loss, stash for backward).
    fn forward(&mut self, s: &Sample) -> Result<(f32, Stash)> {
        let mut stash: Stash = HashMap::new();
        let m = self.rt.model().clone();
        // encoders + projectors (modality-parallel in the pipeline
        // executor; sequential here — same numbers either way)
        let mut mod_hs = Vec::new();
        for name in self.enc_names.clone() {
            let enc = format!("enc:{name}");
            let proj = format!("proj:{name}");
            let x = s
                .encoder_inputs
                .iter()
                .find(|(n, _)| *n == enc)
                .map(|(_, t)| t.clone())
                .ok_or_else(|| anyhow!("sample missing input for {enc}"))?;
            // cb_before_encoder (Listing 2): e.g. AnyRes block splitting
            let x = Callbacks::apply(&self.callbacks.before_encoder, &name, x);
            let ins = vec![x];
            let feats =
                self.rt.execute(&enc, Role::Fwd, &ins)?.remove(0);
            stash.insert(enc, ins);
            let feats =
                Callbacks::apply(&self.callbacks.after_encoder, &name, feats);
            let pins = vec![feats];
            let mod_h =
                self.rt.execute(&proj, Role::Fwd, &pins)?.remove(0);
            stash.insert(proj, pins);
            let mod_h = Callbacks::apply(
                &self.callbacks.after_projector,
                &name,
                mod_h,
            );
            mod_hs.push(mod_h);
        }
        // llm stage 0 (embeds text + splices modality tokens)
        let mut ins = vec![HostTensor::i32(&[m.text_len], s.text_ids.clone())];
        ins.extend(mod_hs);
        ins.push(self.bam.bits.clone());
        ins.push(self.bam.pos.clone());
        let mut h = self.rt.execute("llm:0", Role::Fwd, &ins)?.remove(0);
        stash.insert("llm:0".to_string(), ins);
        // middle/last stages
        for i in 1..self.n_stages {
            let name = format!("llm:{i}");
            let ins =
                vec![h, self.bam.bits.clone(), self.bam.pos.clone()];
            h = self.rt.execute(&name, Role::Fwd, &ins)?.remove(0);
            stash.insert(name, ins);
        }
        // head (loss)
        let ins = vec![
            h,
            HostTensor::i32(&[m.total_tokens], s.labels.clone()),
        ];
        let loss = self
            .rt
            .execute("llm:head", Role::Fwd, &ins)?
            .remove(0)
            .scalar()?;
        stash.insert("llm:head".to_string(), ins);
        Ok((loss, stash))
    }

    /// Backward one microbatch per the §4.2 frozen rule, accumulating
    /// parameter grads into `grads`.
    fn backward(&mut self, stash: &Stash, grads: &mut GradStore) -> Result<()> {
        let head_action = self.policy.grad_action("llm_head");
        let Some(head_role) = head_action.role() else {
            return Ok(()); // everything frozen: the 0x path for all
        };
        // --- head: loss is the root, no incoming cotangent
        let ins = &stash["llm:head"];
        let mut outs = self.rt.execute("llm:head", head_role, ins)?;
        let mut g = if head_action == GradAction::Full {
            let dflat = outs.remove(0);
            // head shares the last LLM stage's params
            let owner = format!("llm:{}", self.n_stages - 1);
            grads.add(&owner, dflat.as_f32()?);
            outs.remove(0)
        } else {
            outs.remove(0)
        };
        // --- llm stages in reverse
        let stage_action = self.policy.grad_action("llm_stage");
        for i in (0..self.n_stages).rev() {
            let name = format!("llm:{i}");
            let role = stage_action
                .role()
                .expect("llm stage action follows head action");
            let mut ins = stash[&name].clone();
            ins.push(g.clone());
            let mut outs = self.rt.execute(&name, role, &ins)?;
            if stage_action == GradAction::Full {
                let dflat = outs.remove(0);
                grads.add(&name, dflat.as_f32()?);
            }
            if i > 0 {
                g = outs.remove(0); // d h
            } else {
                // d mod_h per encoder, in declared order
                let proj_action = self.policy.grad_action("projector");
                let enc_action = self.policy.grad_action("encoder");
                for name in self.enc_names.clone() {
                    let d_mod_h = outs.remove(0);
                    let Some(proj_role) = proj_action.role() else {
                        continue;
                    };
                    let proj = format!("proj:{name}");
                    let mut pins = stash[&proj].clone();
                    pins.push(d_mod_h);
                    let mut pouts =
                        self.rt.execute(&proj, proj_role, &pins)?;
                    if proj_action == GradAction::Full {
                        let dflat = pouts.remove(0);
                        grads.add(&proj, dflat.as_f32()?);
                    }
                    let d_feats = pouts.remove(0);
                    let Some(enc_role) = enc_action.role() else {
                        continue;
                    };
                    let enc = format!("enc:{name}");
                    let mut eins = stash[&enc].clone();
                    eins.push(d_feats);
                    let mut eouts =
                        self.rt.execute(&enc, enc_role, &eins)?;
                    if enc_action == GradAction::Full {
                        let dflat = eouts.remove(0);
                        grads.add(&enc, dflat.as_f32()?);
                    }
                }
            }
        }
        Ok(())
    }

    /// One optimizer step over `samples` (= one iteration of `samples.len()`
    /// gradient-accumulated microbatches).
    pub fn train_step(&mut self, samples: &[Sample]) -> Result<StepStats> {
        anyhow::ensure!(!samples.is_empty());
        let t0 = Instant::now();
        let mut grads = GradStore::default();
        let mut loss_sum = 0.0f32;
        for s in samples {
            let (loss, stash) = self.forward(s)?;
            anyhow::ensure!(loss.is_finite(), "non-finite loss {loss}");
            loss_sum += loss;
            self.backward(&stash, &mut grads)?;
        }
        self.step += 1;
        let step_f = self.step as f32;
        for (owner, g) in grads.drain_scaled(samples.len()) {
            let (m, v) = self
                .opt
                .get_mut(&owner)
                .ok_or_else(|| anyhow!("grads for non-trainable {owner}"))?;
            let mut m_t = std::mem::take(m);
            let mut v_t = std::mem::take(v);
            self.rt
                .adamw_step(&owner, &g, &mut m_t, &mut v_t, step_f, self.lr)?;
            let slot = self.opt.get_mut(&owner).unwrap();
            slot.0 = m_t;
            slot.1 = v_t;
        }
        Ok(StepStats {
            step: self.step,
            loss: loss_sum / samples.len() as f32,
            microbatches: samples.len(),
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Loss without training (eval).
    pub fn eval_loss(&mut self, s: &Sample) -> Result<f32> {
        Ok(self.forward(s)?.0)
    }
}

// Gated with the integration tests: these drive real PJRT execution over
// `make artifacts` output.
#[cfg(all(test, feature = "artifacts"))]
mod tests {
    use super::*;
    use crate::train::SyntheticDataset;

    fn manifest() -> Manifest {
        Manifest::load(Manifest::default_root()).unwrap()
    }

    #[test]
    fn tiny_loss_decreases_under_paper_policy() {
        let mf = manifest();
        let mut tr =
            Trainer::new(&mf, "tiny", FrozenPolicy::paper(), 3e-3).unwrap();
        let ds = SyntheticDataset::new(tr.runtime().model(), 42);
        let batch: Vec<_> = (0..2).map(|i| ds.sample(i)).collect();
        let first = tr.train_step(&batch).unwrap();
        let mut last = first.clone();
        for _ in 0..8 {
            last = tr.train_step(&batch).unwrap();
        }
        assert!(
            last.loss < first.loss,
            "loss did not decrease: {} -> {}",
            first.loss,
            last.loss
        );
    }

    #[test]
    fn frozen_components_do_not_change() {
        let mf = manifest();
        let mut tr =
            Trainer::new(&mf, "tiny", FrozenPolicy::paper(), 1e-2).unwrap();
        let enc_before = tr.runtime().params("enc:vision").unwrap().to_vec();
        let llm_before = tr.runtime().params("llm:0").unwrap().to_vec();
        let proj_before =
            tr.runtime().params("proj:vision").unwrap().to_vec();
        let ds = SyntheticDataset::new(tr.runtime().model(), 1);
        tr.train_step(&[ds.sample(0)]).unwrap();
        assert_eq!(
            tr.runtime().params("enc:vision").unwrap(),
            &enc_before[..],
            "frozen encoder must not move"
        );
        assert_eq!(
            tr.runtime().params("llm:0").unwrap(),
            &llm_before[..],
            "frozen llm must not move"
        );
        assert_ne!(
            tr.runtime().params("proj:vision").unwrap(),
            &proj_before[..],
            "trainable projector must move"
        );
    }

    #[test]
    fn all_frozen_trains_nothing_and_loss_constant() {
        let mf = manifest();
        let mut tr =
            Trainer::new(&mf, "tiny", FrozenPolicy::all_frozen(), 1e-2)
                .unwrap();
        let ds = SyntheticDataset::new(tr.runtime().model(), 5);
        let s1 = tr.train_step(&[ds.sample(0)]).unwrap();
        let s2 = tr.train_step(&[ds.sample(0)]).unwrap();
        assert_eq!(s1.loss, s2.loss);
    }

    #[test]
    fn all_trainable_updates_everything() {
        let mf = manifest();
        let mut tr =
            Trainer::new(&mf, "tiny", FrozenPolicy::all_trainable(), 1e-3)
                .unwrap();
        let before: Vec<Vec<f32>> = ["enc:vision", "proj:vision", "llm:0", "llm:1"]
            .iter()
            .map(|c| tr.runtime().params(c).unwrap().to_vec())
            .collect();
        let ds = SyntheticDataset::new(tr.runtime().model(), 2);
        tr.train_step(&[ds.sample(0)]).unwrap();
        for (c, b) in
            ["enc:vision", "proj:vision", "llm:0", "llm:1"].iter().zip(before)
        {
            assert_ne!(
                tr.runtime().params(c).unwrap(),
                &b[..],
                "{c} should have moved"
            );
        }
    }

    #[test]
    fn callbacks_fire_and_identity_is_neutral() {
        use crate::runtime::HostTensor;
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let mf = manifest();
        let ds = {
            let m = mf.model("tiny").unwrap().clone();
            crate::train::SyntheticDataset::new(&m, 4)
        };
        let s = ds.sample(0);

        // identity callbacks must not change the loss
        let mut plain =
            Trainer::new(&mf, "tiny", FrozenPolicy::paper(), 1e-3).unwrap();
        let base = plain.eval_loss(&s).unwrap();
        let mut with_id =
            Trainer::new(&mf, "tiny", FrozenPolicy::paper(), 1e-3).unwrap();
        let calls = Arc::new(AtomicUsize::new(0));
        let c2 = calls.clone();
        with_id.callbacks.before_encoder = Some(Arc::new(move |_n, t| {
            c2.fetch_add(1, Ordering::SeqCst);
            t
        }));
        assert_eq!(with_id.eval_loss(&s).unwrap(), base);
        assert_eq!(calls.load(Ordering::SeqCst), 1);

        // a real preprocessing hook (input normalization) changes the loss
        let mut with_norm =
            Trainer::new(&mf, "tiny", FrozenPolicy::paper(), 1e-3).unwrap();
        with_norm.callbacks.before_encoder = Some(Arc::new(|_n, t| {
            let dims = t.dims().to_vec();
            let data = t.as_f32().unwrap();
            let mu = data.iter().sum::<f32>() / data.len() as f32;
            HostTensor::f32(
                &dims,
                data.iter().map(|x| (x - mu) * 2.0).collect(),
            )
        }));
        assert_ne!(with_norm.eval_loss(&s).unwrap(), base);
    }

    #[test]
    fn multi_encoder_model_trains() {
        let mf = manifest();
        let mut tr =
            Trainer::new(&mf, "tiny_va", FrozenPolicy::paper(), 3e-3)
                .unwrap();
        assert_eq!(tr.enc_names, vec!["vision", "audio"]);
        let ds = SyntheticDataset::new(tr.runtime().model(), 11);
        let batch: Vec<_> = (0..2).map(|i| ds.sample(i)).collect();
        let first = tr.train_step(&batch).unwrap();
        let mut last = first.clone();
        for _ in 0..6 {
            last = tr.train_step(&batch).unwrap();
        }
        assert!(last.loss < first.loss, "{} -> {}", first.loss, last.loss);
    }
}
