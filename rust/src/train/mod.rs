//! Real distributed training over PJRT artifacts — the executable half of
//! the paper's system (the planners in [`crate::modality`] decide *how* to
//! split; this module actually *runs* the split model).
//!
//! Two executors share the same per-component programs and must produce
//! bit-identical losses:
//!
//! * [`single::Trainer`] — one PJRT client, sequential microbatches. The
//!   numerics oracle (pytest checks it against the pure-JAX model) and the
//!   quickstart path.
//! * [`pipeline::PipelineTrainer`] — the paper's execution model: one OS
//!   thread per pipeline stage, each owning its own PJRT client and only
//!   its own components' executables; activations/gradients cross stages
//!   as [`HostTensor`] messages (modality parallelism: encoder stages run
//!   concurrently; 1F1B: stages prefer backward work in steady state).
//!
//! The §4.2 frozen rule is executed literally via artifact choice
//! ([`GradAction`]): `Full` runs `bwd` (param+input grads, the 2× path),
//! `InputOnly` runs `bwdin` (the 1× path), `Skip` runs nothing (the 0×
//! path).

pub mod data;
pub mod pipeline;
pub mod single;

pub use data::{Sample, SyntheticDataset, IGNORE_LABEL};
pub use pipeline::PipelineTrainer;
pub use single::Trainer;

use std::collections::HashMap;

use anyhow::Result;

use crate::runtime::{ComponentSpec, HostTensor, ModelManifest, Role};

/// Which constituent models are frozen (the paper's Listing 1 `train()`
/// toggles). Default = the §6.1 recipe: encoders+LLM frozen, projectors
/// trainable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrozenPolicy {
    pub encoders_frozen: bool,
    pub llm_frozen: bool,
    pub projectors_frozen: bool,
}

impl FrozenPolicy {
    /// The paper's default MLLM alignment recipe.
    pub fn paper() -> Self {
        FrozenPolicy {
            encoders_frozen: true,
            llm_frozen: true,
            projectors_frozen: false,
        }
    }

    /// Full fine-tuning: everything trainable.
    pub fn all_trainable() -> Self {
        FrozenPolicy {
            encoders_frozen: false,
            llm_frozen: false,
            projectors_frozen: false,
        }
    }

    /// Everything frozen (no training happens; inference-like).
    pub fn all_frozen() -> Self {
        FrozenPolicy {
            encoders_frozen: true,
            llm_frozen: true,
            projectors_frozen: true,
        }
    }

    fn any_encoder_side_trainable(&self) -> bool {
        !self.encoders_frozen || !self.projectors_frozen
    }

    /// Is a component's own parameter set trainable?
    pub fn trainable(&self, kind: &str) -> bool {
        match kind {
            "encoder" => !self.encoders_frozen,
            "projector" => !self.projectors_frozen,
            "llm_stage" | "llm_head" => !self.llm_frozen,
            _ => false,
        }
    }

    /// The backward action for a component — the §4.2 rule as code.
    pub fn grad_action(&self, kind: &str) -> GradAction {
        match kind {
            "encoder" => {
                if !self.encoders_frozen {
                    GradAction::Full
                } else {
                    // nothing upstream of an encoder: 0x path
                    GradAction::Skip
                }
            }
            "projector" => {
                if !self.projectors_frozen {
                    GradAction::Full
                } else if !self.encoders_frozen {
                    GradAction::InputOnly
                } else {
                    GradAction::Skip
                }
            }
            "llm_stage" | "llm_head" => {
                if !self.llm_frozen {
                    GradAction::Full
                } else if self.any_encoder_side_trainable() {
                    // frozen but must propagate input grads (1x path)
                    GradAction::InputOnly
                } else {
                    GradAction::Skip
                }
            }
            _ => GradAction::Skip,
        }
    }
}

/// Which backward program (if any) a component runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradAction {
    /// `bwd`: parameter + input gradients (the 2× path).
    Full,
    /// `bwdin`: input gradients only (the 1× path).
    InputOnly,
    /// No backward at all (the 0× path).
    Skip,
}

impl GradAction {
    pub fn role(&self) -> Option<Role> {
        match self {
            GradAction::Full => Some(Role::Bwd),
            GradAction::InputOnly => Some(Role::BwdIn),
            GradAction::Skip => None,
        }
    }
}

/// Per-step training statistics.
#[derive(Clone, Debug)]
pub struct StepStats {
    pub step: usize,
    /// Mean loss over the step's microbatches.
    pub loss: f32,
    pub microbatches: usize,
    /// Wall time of the whole step (ms).
    pub wall_ms: f64,
}

/// Accumulates flat gradients per parameter-owning component.
#[derive(Default, Debug)]
pub struct GradStore {
    grads: HashMap<String, Vec<f32>>,
}

impl GradStore {
    pub fn add(&mut self, owner: &str, g: &[f32]) {
        match self.grads.get_mut(owner) {
            Some(acc) => {
                debug_assert_eq!(acc.len(), g.len());
                for (a, x) in acc.iter_mut().zip(g) {
                    *a += x;
                }
            }
            None => {
                self.grads.insert(owner.to_string(), g.to_vec());
            }
        }
    }

    /// Drain, scaling by `1/microbatches` (loss is microbatch-mean).
    pub fn drain_scaled(
        &mut self,
        microbatches: usize,
    ) -> Vec<(String, Vec<f32>)> {
        let s = 1.0 / microbatches as f32;
        let mut out: Vec<(String, Vec<f32>)> = self
            .grads
            .drain()
            .map(|(k, mut v)| {
                for x in &mut v {
                    *x *= s;
                }
                (k, v)
            })
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    pub fn is_empty(&self) -> bool {
        self.grads.is_empty()
    }

    pub fn get(&self, owner: &str) -> Option<&[f32]> {
        self.grads.get(owner).map(|v| v.as_slice())
    }
}

/// The owner of a component's parameters (resolving `shares_params_with`).
pub fn param_owner(comp: &ComponentSpec) -> &str {
    comp.shares_params_with.as_deref().unwrap_or(&comp.name)
}

/// User-defined inter-module callbacks — the paper's §5.1 / Listing 2
/// interface ("useful when modules are not designed for multimodality"):
///
/// * `before_encoder(name, x)` — preprocess an encoder's raw input (the
///   paper's example: LLaVA-Next AnyRes image-block splitting that the
///   underlying CLIP encoder does not support);
/// * `after_encoder(name, feats)` — postprocess encoder features before
///   the projector;
/// * `after_projector(name, mod_h)` — postprocess projected tokens before
///   they are embedded into the LLM (the paper's modality-token merge
///   hook; the *placement* of merged tokens is the manifest's segment
///   layout, which the artifacts bake in).
///
/// Callbacks run on host tensors on the stage that owns the module, so
/// they are `Send + Sync` closures. The backward pass treats them as
/// identity (gradients flow through unchanged) — appropriate for the
/// re-layout / token-merge style hooks of the paper's examples; a hook
/// with its own parameters should instead be a proper component with
/// exported artifacts.
#[derive(Clone, Default)]
pub struct Callbacks {
    pub before_encoder: Option<CbTensor>,
    pub after_encoder: Option<CbTensor>,
    pub after_projector: Option<CbTensor>,
}

/// `(module name, tensor) -> tensor` host-side hook.
pub type CbTensor =
    std::sync::Arc<dyn Fn(&str, HostTensor) -> HostTensor + Send + Sync>;

impl Callbacks {
    pub fn none() -> Self {
        Callbacks::default()
    }

    pub fn apply(
        which: &Option<CbTensor>,
        name: &str,
        t: HostTensor,
    ) -> HostTensor {
        match which {
            Some(cb) => cb(name, t),
            None => t,
        }
    }
}

/// Fixed per-model tensors fed to every LLM-stage call: the BAM bits and
/// positions of the (static) token layout.
#[derive(Clone, Debug)]
pub struct BamTensors {
    pub bits: HostTensor,
    pub pos: HostTensor,
}

impl BamTensors {
    pub fn of(model: &ModelManifest) -> Result<BamTensors> {
        let t = model.total_tokens;
        let bits64 = model.bam_bits();
        let bits: Vec<i32> = bits64
            .iter()
            .map(|&b| {
                anyhow::ensure!(
                    b <= i32::MAX as u64,
                    "bitfield {b:#x} exceeds the kernel's 32-bit lanes"
                );
                Ok(b as i32)
            })
            .collect::<Result<_>>()?;
        let pos: Vec<i32> = (0..t as i32).collect();
        Ok(BamTensors {
            bits: HostTensor::i32(&[t], bits),
            pos: HostTensor::i32(&[t], pos),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_policy_maps_to_paper_rule() {
        // Figure 3b / §4.2: frozen encoder+LLM, trainable projector.
        let p = FrozenPolicy::paper();
        assert_eq!(p.grad_action("encoder"), GradAction::Skip); // 0x
        assert_eq!(p.grad_action("projector"), GradAction::Full); // 2x
        assert_eq!(p.grad_action("llm_stage"), GradAction::InputOnly); // 1x
        assert_eq!(p.grad_action("llm_head"), GradAction::InputOnly);
        assert!(!p.trainable("encoder"));
        assert!(p.trainable("projector"));
        assert!(!p.trainable("llm_stage"));
    }

    #[test]
    fn all_trainable_runs_full_backward_everywhere() {
        let p = FrozenPolicy::all_trainable();
        for k in ["encoder", "projector", "llm_stage", "llm_head"] {
            assert_eq!(p.grad_action(k), GradAction::Full, "{k}");
        }
    }

    #[test]
    fn all_frozen_skips_everything() {
        let p = FrozenPolicy::all_frozen();
        for k in ["encoder", "projector", "llm_stage", "llm_head"] {
            assert_eq!(p.grad_action(k), GradAction::Skip, "{k}");
        }
    }

    #[test]
    fn trainable_encoder_forces_llm_input_grads() {
        // Even a fully-frozen LLM must propagate if the encoder trains.
        let p = FrozenPolicy {
            encoders_frozen: false,
            llm_frozen: true,
            projectors_frozen: true,
        };
        assert_eq!(p.grad_action("llm_stage"), GradAction::InputOnly);
        assert_eq!(p.grad_action("projector"), GradAction::InputOnly);
        assert_eq!(p.grad_action("encoder"), GradAction::Full);
    }

    #[test]
    fn grad_store_accumulates_and_scales() {
        let mut gs = GradStore::default();
        gs.add("a", &[1.0, 2.0]);
        gs.add("a", &[3.0, 4.0]);
        gs.add("b", &[10.0]);
        let out = gs.drain_scaled(2);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].0, "a");
        assert_eq!(out[0].1, vec![2.0, 3.0]);
        assert_eq!(out[1].1, vec![5.0]);
        assert!(gs.is_empty());
    }

    #[test]
    fn action_roles() {
        assert_eq!(GradAction::Full.role(), Some(Role::Bwd));
        assert_eq!(GradAction::InputOnly.role(), Some(Role::BwdIn));
        assert_eq!(GradAction::Skip.role(), None);
    }
}
