//! Thread-per-stage pipeline-parallel trainer — the paper's execution
//! model, real: every pipeline stage is an OS thread owning its own PJRT
//! CPU client and ONLY its own components' compiled executables (model
//! parallelism: no stage ever holds another stage's parameters).
//! Activations and gradients cross stages as [`HostTensor`] messages over
//! mpsc channels, standing in for NVLink/IB transfers.
//!
//! Topology (modality parallelism, §4.1): one stage per encoder chain
//! (`enc:X` + `proj:X`) — encoder stages run **concurrently** on their own
//! threads — plus one stage per LLM pipeline stage; the loss head is
//! colocated with the last LLM stage. The LLM's first stage gathers every
//! encoder's projected tokens before it can run a microbatch forward
//! (Figure 6b), and its backward fans `d mod_h` back out to every encoder
//! stage in parallel.
//!
//! Schedule: stages drain their inbox preferring **backward** messages
//! (1F1B steady-state priority), and the feeder caps in-flight
//! microbatches at the stage depth (the 1F1B activation-memory bound), so
//! the stash held per stage stays ≤ depth, not ≤ #microbatches.
//!
//! Frozen rule (§4.2): each stage picks `bwd` / `bwdin` / nothing per its
//! components' [`GradAction`] — the `2×/1×/0×` paths are different
//! artifacts, not scaled estimates.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{HostTensor, Manifest, ModelRuntime, Role};

use super::{
    BamTensors, FrozenPolicy, GradAction, GradStore, Sample, StepStats,
};

/// Inter-stage message.
enum Msg {
    /// Forward activation (or source data) for microbatch `mb`.
    Fwd { mb: usize, from: String, tensor: HostTensor },
    /// Gradient w.r.t. this stage's output for microbatch `mb`.
    Bwd { mb: usize, tensor: HostTensor },
    /// All microbatches of the step have been fed; run the optimizer once
    /// local work drains, then report `StageDone`.
    StepEnd { microbatches: usize },
    /// Shut the stage thread down.
    Stop,
}

/// Stage -> coordinator report.
enum Report {
    Loss {
        mb: usize,
        loss: f32,
    },
    /// Per-role wall times are the raw material for a
    /// [`crate::profile::CalibrationProfile`]: fwd/bwd are cumulative
    /// over the step's microbatches, upd is the one optimizer pass.
    StageDone {
        stage: usize,
        peak_stash: usize,
        exec_ms: f64,
        fwd_ms: f64,
        bwd_ms: f64,
        upd_ms: f64,
    },
    Error {
        stage: usize,
        message: String,
    },
}

/// What one stage runs.
#[derive(Clone, Debug)]
enum StageKind {
    /// `enc:X` + `proj:X`.
    Encoder { name: String },
    /// `llm:i`; the last stage also owns `llm:head`.
    Llm { index: usize, is_last: bool },
}

struct StageCtx {
    stage_id: usize,
    kind: StageKind,
    rt: ModelRuntime,
    policy: FrozenPolicy,
    bam: BamTensors,
    #[allow(dead_code)]
    n_llm_stages: usize,
    enc_names: Vec<String>,
    /// Senders to successor/predecessor stages and the coordinator.
    to_next: Vec<Sender<Msg>>, // fwd direction
    to_prev: Vec<Sender<Msg>>, // bwd direction (encoder stages: empty)
    report: Sender<Report>,
    lr: f32,
}

/// The coordinator handle: owns the stage threads and drives steps.
pub struct PipelineTrainer {
    feeders: Vec<(String, Sender<Msg>)>, // (encoder comp name, sender)
    llm0_tx: Sender<Msg>,
    last_tx: Sender<Msg>,
    all_tx: Vec<Sender<Msg>>,
    report_rx: Receiver<Report>,
    handles: Vec<JoinHandle<()>>,
    n_stages: usize,
    step: usize,
    model_name: String,
    /// Max in-flight microbatches (the 1F1B memory bound).
    pub inflight_limit: usize,
    /// Peak stash (microbatches buffered) per stage, last step.
    pub peak_stash: Vec<usize>,
    /// Cumulative PJRT execute ms per stage, last step.
    pub stage_exec_ms: Vec<f64>,
    /// Cumulative forward PJRT ms per stage, last step (all microbatches).
    pub stage_fwd_ms: Vec<f64>,
    /// Cumulative backward PJRT ms per stage, last step (`Bwd` + `BwdIn`).
    pub stage_bwd_ms: Vec<f64>,
    /// Optimizer (AdamW) PJRT ms per stage, last step.
    pub stage_upd_ms: Vec<f64>,
    /// Microbatch count of the last completed step (normalizes the
    /// cumulative fwd/bwd times to per-microbatch samples).
    pub last_microbatches: usize,
}

impl PipelineTrainer {
    /// Spawn one thread per stage. Compilation happens inside each thread
    /// (each has a private PJRT client), concurrently.
    pub fn new(
        manifest: &Manifest,
        model: &str,
        policy: FrozenPolicy,
        lr: f32,
    ) -> Result<PipelineTrainer> {
        let mm = manifest.model(model)?.clone();
        let enc_names = mm.encoder_names();
        let n_llm = mm.n_llm_stages();
        let n_stages = enc_names.len() + n_llm;

        // Static verification before any stage thread spawns: build a
        // unit-cost stage graph mirroring exactly the channel topology
        // wired below (encoders fan into llm[0], llm chain linear) and
        // run the schedule lints over its 1F1B task graph. A cycle or a
        // 1F1B-window violation here would deadlock real threads
        // holding real PJRT clients — the verifier refuses first.
        {
            let mut g = crate::pipeline::StageGraph::default();
            let unit = crate::pipeline::StageCost { fwd_ms: 1.0, bwd_ms: 1.0 };
            let enc_ids: Vec<usize> = enc_names
                .iter()
                .enumerate()
                .map(|(e, name)| {
                    g.add_chain(&format!("enc:{name}"), &[unit], e, &[])[0]
                })
                .collect();
            g.add_chain(
                "llm",
                &vec![unit; n_llm],
                enc_names.len(),
                &enc_ids,
            );
            let m = n_stages + 1; // the feeder's in-flight cap
            let tasks = crate::pipeline::onef1b_tasks(&g, m);
            let verdict = crate::verify::verify_schedule(&tasks, &g, m);
            if !verdict.is_clean() {
                bail!(
                    "stage topology for {model} failed verification: {}",
                    verdict.error_summary()
                );
            }
        }

        // Channels: one inbox per stage + one report channel.
        let mut txs = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..n_stages {
            let (tx, rx) = channel::<Msg>();
            txs.push(tx);
            rxs.push(rx);
        }
        let (report_tx, report_rx) = channel::<Report>();

        // Stage ids: encoders 0..E, llm stages E..E+n_llm.
        let llm_stage_id = |i: usize| enc_names.len() + i;
        let mut handles = Vec::new();
        for (sid, rx) in rxs.into_iter().enumerate() {
            let kind = if sid < enc_names.len() {
                StageKind::Encoder { name: enc_names[sid].clone() }
            } else {
                let i = sid - enc_names.len();
                StageKind::Llm { index: i, is_last: i == n_llm - 1 }
            };
            let (to_next, to_prev) = match &kind {
                StageKind::Encoder { .. } => {
                    (vec![txs[llm_stage_id(0)].clone()], vec![])
                }
                StageKind::Llm { index, is_last } => {
                    let next = if *is_last {
                        vec![]
                    } else {
                        vec![txs[llm_stage_id(index + 1)].clone()]
                    };
                    let prev = if *index == 0 {
                        (0..enc_names.len()).map(|e| txs[e].clone()).collect()
                    } else {
                        vec![txs[llm_stage_id(index - 1)].clone()]
                    };
                    (next, prev)
                }
            };
            let manifest = manifest.clone();
            let model = model.to_string();
            let report = report_tx.clone();
            let kind_c = kind.clone();
            let enc_names_c = enc_names.clone();
            handles.push(std::thread::spawn(move || {
                match stage_main(
                    sid, kind_c, &manifest, &model, policy, lr, rx, to_next,
                    to_prev, report.clone(), n_llm, enc_names_c,
                ) {
                    Ok(()) => {}
                    Err(e) => {
                        let _ = report.send(Report::Error {
                            stage: sid,
                            message: format!("{e:#}"),
                        });
                    }
                }
            }));
        }

        Ok(PipelineTrainer {
            feeders: enc_names
                .iter()
                .enumerate()
                .map(|(i, n)| (format!("enc:{n}"), txs[i].clone()))
                .collect(),
            llm0_tx: txs[llm_stage_id(0)].clone(),
            last_tx: txs[llm_stage_id(n_llm - 1)].clone(),
            all_tx: txs,
            report_rx,
            handles,
            n_stages,
            step: 0,
            model_name: model.to_string(),
            inflight_limit: n_stages + 1,
            peak_stash: vec![0; n_stages],
            stage_exec_ms: vec![0.0; n_stages],
            stage_fwd_ms: vec![0.0; n_stages],
            stage_bwd_ms: vec![0.0; n_stages],
            stage_upd_ms: vec![0.0; n_stages],
            last_microbatches: 0,
        })
    }

    pub fn n_stages(&self) -> usize {
        self.n_stages
    }

    /// Stage names in stage-id order, matching the planner's naming
    /// (`enc:vision[0]`, `llm[0]`, …) so a calibration profile recorded
    /// here joins a plan's `stage_names` by exact string
    /// ([`crate::profile::CalibrationProfile`]).
    pub fn stage_names(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.feeders.iter().map(|(comp, _)| format!("{comp}[0]")).collect();
        for i in 0..self.n_stages - self.feeders.len() {
            names.push(format!("llm[{i}]"));
        }
        names
    }

    pub fn model_name(&self) -> &str {
        &self.model_name
    }

    /// One training step over `samples` microbatches. Returns the mean
    /// loss; losses equal the single-process [`super::Trainer`]'s exactly.
    pub fn train_step(&mut self, samples: &[Sample]) -> Result<StepStats> {
        anyhow::ensure!(!samples.is_empty());
        let m = samples.len();
        let t0 = Instant::now();
        let mut losses = vec![f32::NAN; m];
        let mut got_losses = 0usize;
        let mut fed = 0usize;

        let feed = |mb: usize, trainer: &Self| -> Result<()> {
            let s = &samples[mb];
            for (comp, tx) in &trainer.feeders {
                let x = s
                    .encoder_inputs
                    .iter()
                    .find(|(n, _)| n == comp)
                    .map(|(_, t)| t.clone())
                    .ok_or_else(|| anyhow!("sample missing {comp}"))?;
                tx.send(Msg::Fwd { mb, from: "data".into(), tensor: x })
                    .map_err(|_| anyhow!("stage hung up"))?;
            }
            trainer
                .llm0_tx
                .send(Msg::Fwd {
                    mb,
                    from: "text".into(),
                    tensor: HostTensor::i32(
                        &[s.text_ids.len()],
                        s.text_ids.clone(),
                    ),
                })
                .map_err(|_| anyhow!("stage hung up"))?;
            trainer
                .last_tx
                .send(Msg::Fwd {
                    mb,
                    from: "labels".into(),
                    tensor: HostTensor::i32(
                        &[s.labels.len()],
                        s.labels.clone(),
                    ),
                })
                .map_err(|_| anyhow!("stage hung up"))?;
            Ok(())
        };

        // Warmup window: at most `inflight_limit` microbatches in flight
        // (the 1F1B activation-memory bound).
        while fed < m.min(self.inflight_limit) {
            feed(fed, self)?;
            fed += 1;
        }

        // Drain losses; feed one more microbatch per completed one (1F1B
        // steady state: one forward admitted per backward completed).
        while got_losses < m {
            match self.report_rx.recv() {
                Ok(Report::Loss { mb, loss }) => {
                    losses[mb] = loss;
                    got_losses += 1;
                    if fed < m {
                        feed(fed, self)?;
                        fed += 1;
                    }
                }
                Ok(Report::Error { stage, message }) => {
                    bail!("stage {stage} failed: {message}")
                }
                Ok(Report::StageDone { .. }) => {
                    bail!("unexpected StageDone before StepEnd")
                }
                Err(_) => bail!("all stages hung up"),
            }
        }

        // End of step: every stage runs its optimizer then reports done.
        for tx in &self.all_tx {
            tx.send(Msg::StepEnd { microbatches: m })
                .map_err(|_| anyhow!("stage hung up"))?;
        }
        let mut done = 0usize;
        while done < self.n_stages {
            match self.report_rx.recv() {
                Ok(Report::StageDone {
                    stage,
                    peak_stash,
                    exec_ms,
                    fwd_ms,
                    bwd_ms,
                    upd_ms,
                }) => {
                    self.peak_stash[stage] = peak_stash;
                    self.stage_exec_ms[stage] = exec_ms;
                    self.stage_fwd_ms[stage] = fwd_ms;
                    self.stage_bwd_ms[stage] = bwd_ms;
                    self.stage_upd_ms[stage] = upd_ms;
                    done += 1;
                }
                Ok(Report::Error { stage, message }) => {
                    bail!("stage {stage} failed: {message}")
                }
                Ok(Report::Loss { .. }) => bail!("loss after step end"),
                Err(_) => bail!("all stages hung up"),
            }
        }

        self.step += 1;
        self.last_microbatches = m;
        let loss = losses.iter().sum::<f32>() / m as f32;
        anyhow::ensure!(loss.is_finite(), "non-finite step loss");
        Ok(StepStats {
            step: self.step,
            loss,
            microbatches: m,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }
}

impl Drop for PipelineTrainer {
    fn drop(&mut self) {
        for tx in &self.all_tx {
            let _ = tx.send(Msg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Pending forward inputs of one microbatch at the llm:0 gather point.
#[derive(Default)]
struct Gather {
    text: Option<HostTensor>,
    mod_h: HashMap<String, HostTensor>,
}

#[allow(clippy::too_many_arguments)]
fn stage_main(
    stage_id: usize,
    kind: StageKind,
    manifest: &Manifest,
    model: &str,
    policy: FrozenPolicy,
    lr: f32,
    rx: Receiver<Msg>,
    to_next: Vec<Sender<Msg>>,
    to_prev: Vec<Sender<Msg>>,
    report: Sender<Report>,
    n_llm_stages: usize,
    enc_names: Vec<String>,
) -> Result<()> {
    // Compile ONLY this stage's components (model-parallel placement).
    let comps: Vec<String> = match &kind {
        StageKind::Encoder { name } => {
            vec![format!("enc:{name}"), format!("proj:{name}")]
        }
        StageKind::Llm { index, is_last } => {
            let mut v = vec![format!("llm:{index}")];
            if *is_last {
                v.push("llm:head".to_string());
            }
            v
        }
    };
    let comp_refs: Vec<&str> = comps.iter().map(|s| s.as_str()).collect();
    let rt = ModelRuntime::load(manifest, model, Some(&comp_refs), &Role::ALL)?;
    let bam = BamTensors::of(rt.model())?;
    let mut ctx = StageCtx {
        stage_id,
        kind,
        rt,
        policy,
        bam,
        n_llm_stages,
        enc_names,
        to_next,
        to_prev,
        report,
        lr,
    };
    stage_loop(&mut ctx, rx)
}

fn stage_loop(ctx: &mut StageCtx, rx: Receiver<Msg>) -> Result<()> {
    // Optimizer slots for owned trainable components.
    let mut opt: HashMap<String, (Vec<f32>, Vec<f32>)> = HashMap::new();
    for c in ctx.rt.model().components.clone() {
        let owned = match &ctx.kind {
            StageKind::Encoder { name } => {
                c.name == format!("enc:{name}") || c.name == format!("proj:{name}")
            }
            StageKind::Llm { index, .. } => c.name == format!("llm:{index}"),
        };
        if owned && ctx.policy.trainable(&c.kind) && c.shares_params_with.is_none()
        {
            opt.insert(c.name.clone(), (vec![0.0; c.n_params], vec![0.0; c.n_params]));
        }
    }
    let mut step = 0usize;

    // Per-step state.
    let mut stash: HashMap<usize, Vec<HostTensor>> = HashMap::new(); // fwd ins per mb
    let mut gather: HashMap<usize, Gather> = HashMap::new(); // llm:0 only
    let mut labels: HashMap<usize, HostTensor> = HashMap::new(); // last only
    let mut grads = GradStore::default();
    let mut fwd_done = 0usize;
    let mut bwd_done = 0usize;
    let mut peak_stash = 0usize;
    let mut pending_end: Option<usize> = None;
    // Local queue with backward-first priority (1F1B steady state).
    let mut queue: VecDeque<Msg> = VecDeque::new();

    'outer: loop {
        // Fill the local queue: block for one message, then drain.
        if queue.is_empty() {
            match rx.recv() {
                Ok(m) => push_prio(&mut queue, m),
                Err(_) => break 'outer, // coordinator dropped
            }
        }
        while let Ok(m) = rx.try_recv() {
            push_prio(&mut queue, m);
        }
        let Some(msg) = queue.pop_front() else { continue };
        match msg {
            Msg::Stop => break 'outer,
            Msg::Fwd { mb, from, tensor } => {
                handle_fwd(ctx, mb, &from, tensor, &mut stash, &mut gather, &mut labels, &mut grads, &mut fwd_done, &mut bwd_done)?;
                peak_stash = peak_stash.max(stash.len());
            }
            Msg::Bwd { mb, tensor } => {
                handle_bwd(ctx, mb, tensor, &mut stash, &mut grads)?;
                bwd_done += 1;
            }
            Msg::StepEnd { microbatches } => pending_end = Some(microbatches),
        }
        // Step completion check: all fwd and all expected bwd done.
        if let Some(m) = pending_end {
            let expect_bwd = expected_bwd(ctx, m);
            if fwd_done >= m && bwd_done >= expect_bwd {
                step += 1;
                for (owner, g) in grads.drain_scaled(m) {
                    if let Some((mm, vv)) = opt.get_mut(&owner) {
                        let mut m_t = std::mem::take(mm);
                        let mut v_t = std::mem::take(vv);
                        ctx.rt.adamw_step(
                            &owner, &g, &mut m_t, &mut v_t, step as f32,
                            ctx.lr,
                        )?;
                        let slot = opt.get_mut(&owner).unwrap();
                        slot.0 = m_t;
                        slot.1 = v_t;
                    }
                }
                let exec_ms: f64 = ctx.rt.exec_ms.values().sum();
                let role_ms = |r: Role| ctx.rt.exec_ms.get(&r).copied().unwrap_or(0.0);
                let fwd_ms = role_ms(Role::Fwd);
                let bwd_ms = role_ms(Role::Bwd) + role_ms(Role::BwdIn);
                let upd_ms = role_ms(Role::Upd);
                ctx.rt.exec_ms.clear();
                ctx.report
                    .send(Report::StageDone {
                        stage: ctx.stage_id,
                        peak_stash,
                        exec_ms,
                        fwd_ms,
                        bwd_ms,
                        upd_ms,
                    })
                    .ok();
                stash.clear();
                gather.clear();
                labels.clear();
                fwd_done = 0;
                bwd_done = 0;
                peak_stash = 0;
                pending_end = None;
            }
        }
    }
    Ok(())
}

fn push_prio(q: &mut VecDeque<Msg>, m: Msg) {
    match m {
        Msg::Bwd { .. } => q.push_front(m), // backward first (1F1B)
        other => q.push_back(other),
    }
}

/// How many Bwd messages this stage receives per step of `m` microbatches.
fn expected_bwd(ctx: &StageCtx, m: usize) -> usize {
    match &ctx.kind {
        // Encoder stages receive d mod_h iff the LLM propagates input
        // grads (its action is not Skip).
        StageKind::Encoder { .. } => {
            if ctx.policy.grad_action("llm_stage") != GradAction::Skip {
                m
            } else {
                0
            }
        }
        // The last LLM stage self-triggers backward from the loss; other
        // stages receive dh from their successor.
        StageKind::Llm { is_last, .. } => {
            if *is_last || ctx.policy.grad_action("llm_stage") == GradAction::Skip
            {
                0
            } else {
                m
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_fwd(
    ctx: &mut StageCtx,
    mb: usize,
    from: &str,
    tensor: HostTensor,
    stash: &mut HashMap<usize, Vec<HostTensor>>,
    gather: &mut HashMap<usize, Gather>,
    labels: &mut HashMap<usize, HostTensor>,
    grads: &mut GradStore,
    fwd_done: &mut usize,
    bwd_done: &mut usize,
) -> Result<()> {
    match ctx.kind.clone() {
        StageKind::Encoder { name } => {
            let enc = format!("enc:{name}");
            let proj = format!("proj:{name}");
            let ins = vec![tensor];
            let feats = ctx.rt.execute(&enc, Role::Fwd, &ins)?.remove(0);
            let pins = vec![feats];
            let mod_h = ctx.rt.execute(&proj, Role::Fwd, &pins)?.remove(0);
            // stash = [enc_x, proj_feats] for backward
            let mut st = ins;
            st.extend(pins);
            stash.insert(mb, st);
            *fwd_done += 1;
            ctx.to_next[0]
                .send(Msg::Fwd { mb, from: proj, tensor: mod_h })
                .ok();
        }
        StageKind::Llm { index: 0, is_last } => {
            if from == "labels" {
                labels.insert(mb, tensor);
                if is_last {
                    try_run_head(ctx, mb, labels, stash, grads, bwd_done)?;
                }
                return Ok(());
            }
            // Gather text + every encoder's mod_h before running.
            {
                let g = gather.entry(mb).or_default();
                if from == "text" {
                    g.text = Some(tensor);
                } else {
                    let enc = from
                        .strip_prefix("proj:")
                        .ok_or_else(|| anyhow!("unexpected fwd from {from}"))?;
                    g.mod_h.insert(enc.to_string(), tensor);
                }
            }
            let complete = {
                let g = &gather[&mb];
                g.text.is_some() && g.mod_h.len() == ctx.enc_names.len()
            };
            if complete {
                let g = gather.remove(&mb).unwrap();
                let mut ins = vec![g.text.unwrap()];
                for n in &ctx.enc_names {
                    ins.push(
                        g.mod_h
                            .get(n)
                            .ok_or_else(|| anyhow!("missing mod_h {n}"))?
                            .clone(),
                    );
                }
                ins.push(ctx.bam.bits.clone());
                ins.push(ctx.bam.pos.clone());
                let h = ctx.rt.execute("llm:0", Role::Fwd, &ins)?.remove(0);
                stash.insert(mb, ins);
                *fwd_done += 1;
                finish_llm_fwd(ctx, mb, h, is_last, labels, stash, grads, bwd_done)?;
            }
        }
        StageKind::Llm { index, is_last } => {
            if from == "labels" {
                labels.insert(mb, tensor);
                // The head runs once both the parked stage output and the
                // labels are present, whichever arrives last.
                try_run_head(ctx, mb, labels, stash, grads, bwd_done)?;
                return Ok(());
            }
            let name = format!("llm:{index}");
            let ins =
                vec![tensor, ctx.bam.bits.clone(), ctx.bam.pos.clone()];
            let h = ctx.rt.execute(&name, Role::Fwd, &ins)?.remove(0);
            stash.insert(mb, ins);
            *fwd_done += 1;
            finish_llm_fwd(ctx, mb, h, is_last, labels, stash, grads, bwd_done)?;
        }
    }
    Ok(())
}

/// Forward the stage output downstream, or — on the last stage — park it
/// in the stash (after the fwd inputs) until the labels arrive.
#[allow(clippy::too_many_arguments)]
fn finish_llm_fwd(
    ctx: &mut StageCtx,
    mb: usize,
    h: HostTensor,
    is_last: bool,
    labels: &mut HashMap<usize, HostTensor>,
    stash: &mut HashMap<usize, Vec<HostTensor>>,
    grads: &mut GradStore,
    bwd_done: &mut usize,
) -> Result<()> {
    if !is_last {
        ctx.to_next[0]
            .send(Msg::Fwd { mb, from: "llm".into(), tensor: h })
            .ok();
        return Ok(());
    }
    // Park the output h for the head (labels may not have arrived yet).
    stash.get_mut(&mb).unwrap().push(h);
    try_run_head(ctx, mb, labels, stash, grads, bwd_done)
}

/// Run head fwd (loss) + the stage's own backward as soon as both the
/// stage output and the labels are available (the last stage starts the
/// backward wave itself — 1F1B's "backward begins immediately").
fn try_run_head(
    ctx: &mut StageCtx,
    mb: usize,
    labels: &mut HashMap<usize, HostTensor>,
    stash: &mut HashMap<usize, Vec<HostTensor>>,
    grads: &mut GradStore,
    bwd_done: &mut usize,
) -> Result<()> {
    let n_ins = ctx.rt.artifact(&llm_name(ctx)?, Role::Fwd)?.ins.len() - 1;
    let ready = labels.contains_key(&mb)
        && stash.get(&mb).map(|s| s.len() == n_ins + 1).unwrap_or(false);
    if !ready {
        return Ok(());
    }
    let lab = labels.remove(&mb).unwrap();
    let h = stash.get_mut(&mb).unwrap().pop().unwrap(); // parked output
    let head_ins = vec![h, lab];
    let loss = ctx
        .rt
        .execute("llm:head", Role::Fwd, &head_ins)?
        .remove(0)
        .scalar()?;
    ctx.report.send(Report::Loss { mb, loss }).ok();

    // Immediately run backward for this microbatch (head + own stage).
    let head_action = ctx.policy.grad_action("llm_head");
    let Some(head_role) = head_action.role() else {
        stash.remove(&mb);
        return Ok(());
    };
    let mut outs = ctx.rt.execute("llm:head", head_role, &head_ins)?;
    let g = if head_action == GradAction::Full {
        let dflat = outs.remove(0);
        grads.add(&llm_name(ctx)?, dflat.as_f32()?);
        outs.remove(0)
    } else {
        outs.remove(0)
    };
    run_stage_bwd(ctx, mb, g, stash, grads)?;
    *bwd_done += 1;
    Ok(())
}

fn llm_name(ctx: &StageCtx) -> Result<String> {
    match &ctx.kind {
        StageKind::Llm { index, .. } => Ok(format!("llm:{index}")),
        _ => bail!("not an llm stage"),
    }
}

fn handle_bwd(
    ctx: &mut StageCtx,
    mb: usize,
    g: HostTensor,
    stash: &mut HashMap<usize, Vec<HostTensor>>,
    grads: &mut GradStore,
) -> Result<()> {
    match ctx.kind.clone() {
        StageKind::Encoder { name } => {
            let proj = format!("proj:{name}");
            let enc = format!("enc:{name}");
            let proj_action = ctx.policy.grad_action("projector");
            let enc_action = ctx.policy.grad_action("encoder");
            let st = stash
                .remove(&mb)
                .ok_or_else(|| anyhow!("bwd for unknown mb {mb}"))?;
            // st = [enc_x, proj_feats]
            let Some(proj_role) = proj_action.role() else {
                return Ok(());
            };
            let pins = vec![st[1].clone(), g];
            let mut pouts = ctx.rt.execute(&proj, proj_role, &pins)?;
            if proj_action == GradAction::Full {
                let dflat = pouts.remove(0);
                grads.add(&proj, dflat.as_f32()?);
            }
            let d_feats = pouts.remove(0);
            if let Some(enc_role) = enc_action.role() {
                let eins = vec![st[0].clone(), d_feats];
                let mut eouts = ctx.rt.execute(&enc, enc_role, &eins)?;
                if enc_action == GradAction::Full {
                    let dflat = eouts.remove(0);
                    grads.add(&enc, dflat.as_f32()?);
                }
            }
        }
        StageKind::Llm { index, .. } => {
            run_stage_bwd_from_stash(ctx, mb, g, index, stash, grads)?;
        }
    }
    Ok(())
}

/// Backward of this LLM stage given the output-gradient `g`, fanning
/// results to predecessors.
fn run_stage_bwd(
    ctx: &mut StageCtx,
    mb: usize,
    g: HostTensor,
    stash: &mut HashMap<usize, Vec<HostTensor>>,
    grads: &mut GradStore,
) -> Result<()> {
    let index = match &ctx.kind {
        StageKind::Llm { index, .. } => *index,
        _ => bail!("run_stage_bwd on non-llm stage"),
    };
    run_stage_bwd_from_stash(ctx, mb, g, index, stash, grads)
}

fn run_stage_bwd_from_stash(
    ctx: &mut StageCtx,
    mb: usize,
    g: HostTensor,
    index: usize,
    stash: &mut HashMap<usize, Vec<HostTensor>>,
    grads: &mut GradStore,
) -> Result<()> {
    let action = ctx.policy.grad_action("llm_stage");
    let Some(role) = action.role() else {
        stash.remove(&mb);
        return Ok(());
    };
    let name = format!("llm:{index}");
    let mut ins = stash
        .remove(&mb)
        .ok_or_else(|| anyhow!("bwd for unknown mb {mb}"))?;
    ins.push(g);
    let mut outs = ctx.rt.execute(&name, role, &ins)?;
    if action == GradAction::Full {
        let dflat = outs.remove(0);
        grads.add(&name, dflat.as_f32()?);
    }
    if index > 0 {
        let dh = outs.remove(0);
        ctx.to_prev[0].send(Msg::Bwd { mb, tensor: dh }).ok();
    } else {
        // fan d mod_h out to every encoder stage (parallel backward)
        for (e, _) in ctx.enc_names.clone().iter().enumerate() {
            let d_mod_h = outs.remove(0);
            ctx.to_prev[e].send(Msg::Bwd { mb, tensor: d_mod_h }).ok();
        }
    }
    Ok(())
}

// Gated with the integration tests: these drive real PJRT execution over
// `make artifacts` output.
#[cfg(all(test, feature = "artifacts"))]
mod tests {
    use super::*;
    use crate::profile::CalibrationProfile;
    use crate::train::{SyntheticDataset, Trainer};

    fn manifest() -> Manifest {
        Manifest::load(Manifest::default_root()).unwrap()
    }

    /// The pipeline executor must match the single-process trainer
    /// loss-for-loss: same artifacts, same order, same numerics.
    #[test]
    fn pipeline_matches_single_process_losses() {
        let mf = manifest();
        let policy = FrozenPolicy::paper();
        let mut single = Trainer::new(&mf, "tiny", policy, 3e-3).unwrap();
        let mut pipe =
            PipelineTrainer::new(&mf, "tiny", policy, 3e-3).unwrap();
        let ds = SyntheticDataset::new(single.runtime().model(), 77);
        let batch: Vec<_> = (0..3).map(|i| ds.sample(i)).collect();
        for step in 0..3 {
            let a = single.train_step(&batch).unwrap();
            let b = pipe.train_step(&batch).unwrap();
            assert_eq!(
                a.loss, b.loss,
                "step {step}: single {} vs pipeline {}",
                a.loss, b.loss
            );
        }
    }

    #[test]
    fn multi_encoder_pipeline_runs_and_learns() {
        let mf = manifest();
        let mut pipe = PipelineTrainer::new(
            &mf,
            "tiny_va",
            FrozenPolicy::paper(),
            3e-3,
        )
        .unwrap();
        assert_eq!(pipe.n_stages(), 4); // vision, audio, llm:0, llm:1
        let model = mf.model("tiny_va").unwrap().clone();
        let ds = SyntheticDataset::new(&model, 5);
        let batch: Vec<_> = (0..2).map(|i| ds.sample(i)).collect();
        let first = pipe.train_step(&batch).unwrap();
        let mut last = first.clone();
        for _ in 0..6 {
            last = pipe.train_step(&batch).unwrap();
        }
        assert!(last.loss < first.loss, "{} -> {}", first.loss, last.loss);
    }

    /// The per-role wall times carried back by `StageDone` must account
    /// for the whole cumulative exec time and normalize into a
    /// [`crate::profile::CalibrationProfile`] keyed by planner-style
    /// stage names.
    #[test]
    fn calibration_profile_records_per_role_times() {
        let mf = manifest();
        let mut pipe =
            PipelineTrainer::new(&mf, "tiny", FrozenPolicy::paper(), 1e-3)
                .unwrap();
        let model = mf.model("tiny").unwrap().clone();
        let ds = SyntheticDataset::new(&model, 9);
        let batch: Vec<_> = (0..2).map(|i| ds.sample(i)).collect();
        pipe.train_step(&batch).unwrap();
        assert_eq!(pipe.last_microbatches, 2);
        let prof = CalibrationProfile::from_pipeline(&pipe, "cpu-pjrt");
        assert_eq!(prof.samples.len(), pipe.n_stages());
        assert!(prof.samples.iter().any(|s| s.stage.starts_with("llm[")));
        for (i, s) in prof.samples.iter().enumerate() {
            let whole = pipe.stage_exec_ms[i];
            let parts = pipe.stage_fwd_ms[i]
                + pipe.stage_bwd_ms[i]
                + pipe.stage_upd_ms[i];
            assert!(
                (whole - parts).abs() < 1e-6,
                "stage {}: exec {whole} ms vs role sum {parts} ms",
                s.stage
            );
            assert!(s.fwd_ms > 0.0, "stage {} measured no fwd time", s.stage);
        }
        // round-trips through the JSON schema
        let back =
            CalibrationProfile::parse(&prof.to_json().render()).unwrap();
        assert_eq!(prof, back);
    }

    #[test]
    fn inflight_limit_bounds_stash() {
        let mf = manifest();
        let mut pipe =
            PipelineTrainer::new(&mf, "tiny", FrozenPolicy::paper(), 1e-3)
                .unwrap();
        pipe.inflight_limit = 2;
        let model = mf.model("tiny").unwrap().clone();
        let ds = SyntheticDataset::new(&model, 9);
        let batch: Vec<_> = (0..6).map(|i| ds.sample(i)).collect();
        pipe.train_step(&batch).unwrap();
        // Credit-based feeding: the coordinator admits one new microbatch
        // per completed loss, so per-stage stash is bounded by the limit
        // plus the backward-propagation lag (≤ pipeline depth in the worst
        // case; ≤ 2 in practice with backward-first priority). The key
        // property: far below the unthrottled bound of 6 microbatches.
        for (s, &peak) in pipe.peak_stash.iter().enumerate() {
            assert!(
                peak <= 2 + 2,
                "stage {s} stash peaked at {peak} with limit 2"
            );
        }
    }
}
