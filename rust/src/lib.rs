//! # Cornstarch (reproduction): multimodality-aware distributed MLLM training
//!
//! Rust L3 coordinator of the three-layer stack (see `DESIGN.md`):
//!
//! * [`modality`] — the paper's programming model: `MultimodalModule`
//!   execution DAGs, `ParallelSpec`s, and the loosely-coupled
//!   auto-parallelization of §5.2 (Algorithm 1), plus the two baseline
//!   planners (encoders-colocated, encoders-replicated).
//! * [`pipeline`] — frozen-status-aware pipeline partitioning (§4.2) and
//!   heterogeneous 1F1B schedule construction over the modality-parallel
//!   DAG (§4.1).
//! * [`bam`] — the Bitfield Attention Mask (§4.3.1): `u64` bitfields,
//!   mask semantics identical to `python/compile/kernels/ref.py`, EP/EE/MP
//!   mask generators, and O(T·V) workload computation that never
//!   materializes the `[T,T]` mask.
//! * [`cp`] — context-parallel token distribution (§4.3.2): greedy LPT,
//!   random, zigzag and naive-ring baselines, and an exact branch-and-bound
//!   solver for small instances (the ILP of §4.3.2).
//! * [`cost`] — the analytic execution-time model (flops-derived, frozen
//!   rule backward times) calibrated against the paper's Figure 3b.
//! * [`memory`] — the analytic per-device memory model (Appendix D):
//!   frozen-aware parameter/gradient/optimizer bytes, TP/CP-sharded
//!   activation footprints under the 1F1B warm-up window, and the
//!   capacity checks that prune OOM-infeasible plans from the tuner's
//!   search space.
//! * [`sim`] — a discrete-event cluster simulator that replays pipeline
//!   schedules to produce the paper's tables and figures.
//! * [`runtime`] — PJRT execution of the AOT artifacts emitted by
//!   `python/compile/aot.py` (HLO text; python never runs at train time).
//! * [`train`] — the real thing: a thread-per-stage 1F1B training executor
//!   over PJRT with frozen-aware backward selection and AdamW.
//! * [`tuner`] — the plan-search autotuner: bounded best-first search of
//!   the joint configuration space (policy × encoder placement × LLM
//!   pipeline depth × TP/CP × microbatches × frozen policy) with
//!   cost-model lower-bound pruning, multi-threaded simulation, and a
//!   JSON-persisted plan cache keyed by a workload/cluster signature.
//! * [`api`] — the planning-service facade: [`api::PlanRequest`] →
//!   [`api::PlanningService::plan`] → [`api::PlanReport`], with
//!   [`api::ClusterSpec`] as the single source of hardware truth
//!   (per-device memory, flops/MFU, interconnect bandwidth) and typed
//!   [`api::PlanError`]s at the boundary; [`api::fleet`] carves one
//!   shared pool across N tenants and [`api::PlanDiff`] renders what a
//!   re-plan changed. The CLI, the coordinator hook, and the examples
//!   are thin wrappers over it.
//! * [`coordinator`] — leader entrypoint gluing plan → build → run, and
//!   the `reproduce` harness that regenerates every evaluation table and
//!   figure of the paper.
//! * [`telemetry`] — zero-dependency observability: per-thread counters
//!   with deterministic snapshots, RAII wall-clock spans exported as
//!   Chrome trace-event JSON (`--trace`), and the one leveled-logging
//!   door (`--quiet` / `-v`) every progress print goes through.
//! * [`verify`] — static plan/schedule verification: typed lints with
//!   stable codes (`V001`–`V008`) over a plan, its 1F1B task graph, and
//!   its candidate config, gating cache admission, the service boundary
//!   (`plan` / `plan_fleet`), and trainer setup; surfaced as
//!   `cornstarch verify`.
//! * [`profile`] — plan explainability + sim-to-real calibration: exact
//!   per-device compute/comm/idle decomposition of every plan's
//!   simulated trace ([`profile::PlanAnalysis`], `cornstarch explain`)
//!   and measured-vs-modeled stage-time drift from real PJRT runs
//!   ([`profile::CalibrationProfile`], `cornstarch calibrate`).
//! * [`serve`] — planning as a long-lived service: a zero-dependency
//!   newline-delimited-JSON TCP server over the facade (`cornstarch
//!   serve`). One process, many requests: warm repeats answer from the
//!   in-process tier of the two-tier plan store and identical
//!   concurrent requests coalesce onto a single search.

pub mod api;
pub mod util;
pub mod model;
pub mod bam;
pub mod cp;
pub mod cost;
pub mod memory;
pub mod modality;
pub mod pipeline;
pub mod sim;
pub mod verify;
pub mod profile;
pub mod tuner;
pub mod runtime;
pub mod serve;
pub mod train;
pub mod coordinator;
pub mod bench;
pub mod telemetry;
