//! Offline stub of the `xla` crate surface [`super`] uses.
//!
//! The vendored offline crate set does not ship the `xla` crate (it needs
//! the native XLA extension at build time), so this module provides the
//! exact API shape the runtime compiles against. Every entry point that
//! would touch PJRT returns a descriptive error at *runtime*; everything
//! else in the crate — planning, simulation, the autotuner, the
//! `reproduce` harness — is pure rust and unaffected. Swapping the real
//! crate back in is a one-line change (delete the `mod xla;` declaration
//! in `runtime/mod.rs` and add the dependency): the call sites are
//! written against the real signatures.

use std::borrow::Borrow;
use std::path::Path;

/// Error type with the `Display` the call sites format with `{e}`.
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

type XlaResult<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> XlaResult<T> {
    Err(Error(format!(
        "{what}: PJRT/XLA backend not available in this build (offline \
         `xla` stub — vendor the xla crate to execute AOT artifacts)"
    )))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> XlaResult<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> XlaResult<PjRtBuffer> {
        unavailable("buffer_from_host_buffer")
    }

    pub fn compile(
        &self,
        _c: &XlaComputation,
    ) -> XlaResult<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        unavailable("to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        unavailable("execute_b")
    }

    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        unavailable("execute")
    }
}

pub struct Literal;

impl Literal {
    pub fn scalar<T: Copy>(_x: T) -> Literal {
        Literal
    }

    pub fn vec1<T: Copy>(_xs: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> XlaResult<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T: Copy>(&self) -> XlaResult<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> XlaResult<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_tuple1(&self) -> XlaResult<Literal> {
        unavailable("Literal::to_tuple1")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> XlaResult<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_a_clear_error() {
        let e = PjRtClient::cpu().err().unwrap();
        let msg = format!("{e}");
        assert!(msg.contains("not available"), "{msg}");
        assert!(msg.contains("PjRtClient::cpu"), "{msg}");
    }

    #[test]
    fn literal_constructors_are_pure() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        let s = Literal::scalar(1i32);
        assert!(s.to_vec::<i32>().is_err());
    }
}
