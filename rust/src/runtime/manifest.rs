//! Parser for `artifacts/manifest.txt` — the contract between the python
//! AOT exporter (`python/compile/aot.py`, the only place python runs) and
//! the rust runtime. Line-oriented; grammar documented in `aot.py`.

use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Element type of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unknown dtype {s:?}"),
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        })
    }
}

/// One input or output of an artifact: `name:dtype:AxBxC` (`_` = scalar).
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl IoSpec {
    pub fn parse(s: &str) -> Result<Self> {
        let mut it = s.split(':');
        let name = it.next().ok_or_else(|| anyhow!("empty io spec"))?;
        let dtype = DType::parse(it.next().context("io spec missing dtype")?)?;
        let dims_s = it.next().context("io spec missing dims")?;
        let dims = if dims_s == "_" {
            Vec::new()
        } else {
            dims_s
                .split('x')
                .map(|d| d.parse::<usize>().context("bad dim"))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(IoSpec { name: name.to_string(), dtype, dims })
    }

    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

/// The compute role of an artifact (§4.2's `0/1×/2×` rule as programs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Role {
    /// Forward pass.
    Fwd,
    /// Full backward: param grads + input grads (trainable, `2×`).
    Bwd,
    /// Input-grads-only backward (frozen but must propagate, `1×`).
    BwdIn,
    /// AdamW parameter update.
    Upd,
}

impl Role {
    pub const ALL: [Role; 4] = [Role::Fwd, Role::Bwd, Role::BwdIn, Role::Upd];

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fwd" => Ok(Role::Fwd),
            "bwd" => Ok(Role::Bwd),
            "bwdin" => Ok(Role::BwdIn),
            "upd" => Ok(Role::Upd),
            _ => bail!("unknown artifact role {s:?}"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Role::Fwd => "fwd",
            Role::Bwd => "bwd",
            Role::BwdIn => "bwdin",
            Role::Upd => "upd",
        }
    }
}

/// One AOT-compiled HLO program.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub role: Role,
    /// Path relative to the artifacts root.
    pub rel_path: String,
    pub ins: Vec<IoSpec>,
    pub outs: Vec<IoSpec>,
}

/// One pipeline component (encoder, projector, LLM stage, or head).
#[derive(Clone, Debug)]
pub struct ComponentSpec {
    pub name: String,
    pub kind: String,
    pub n_params: usize,
    /// `llm:head` shares the last LLM stage's parameter vector.
    pub shares_params_with: Option<String>,
    /// (rel_path, n_elems) of the deterministic f32 init.
    pub params: Option<(String, usize)>,
    pub artifacts: HashMap<Role, ArtifactSpec>,
}

impl ComponentSpec {
    pub fn artifact(&self, role: Role) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(&role)
            .ok_or_else(|| anyhow!("{}: no {} artifact", self.name, role.as_str()))
    }
}

/// A BAM token segment: `[start, end)` tokens carry `bits`.
#[derive(Clone, Debug, PartialEq)]
pub struct SegmentSpec {
    pub name: String,
    pub start: usize,
    pub end: usize,
    pub bits: u64,
}

/// One exported model (a DAG of components).
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub total_tokens: usize,
    pub text_len: usize,
    pub insert_at: usize,
    pub vocab: usize,
    pub segments: Vec<SegmentSpec>,
    pub components: Vec<ComponentSpec>,
    pub edges: Vec<(String, String)>,
}

impl ModelManifest {
    pub fn component(&self, name: &str) -> Result<&ComponentSpec> {
        self.components
            .iter()
            .find(|c| c.name == name)
            .ok_or_else(|| anyhow!("model {}: no component {name}", self.name))
    }

    /// Encoder names in declaration order (e.g. `["vision", "audio"]`).
    pub fn encoder_names(&self) -> Vec<String> {
        self.components
            .iter()
            .filter(|c| c.kind == "encoder")
            .map(|c| c.name.trim_start_matches("enc:").to_string())
            .collect()
    }

    /// Number of LLM pipeline stages (excluding the head).
    pub fn n_llm_stages(&self) -> usize {
        self.components.iter().filter(|c| c.kind == "llm_stage").count()
    }

    /// Successors of `name` in the execution DAG.
    pub fn successors(&self, name: &str) -> Vec<&str> {
        self.edges
            .iter()
            .filter(|(f, _)| f == name)
            .map(|(_, t)| t.as_str())
            .collect()
    }

    /// Predecessors of `name` in the execution DAG.
    pub fn predecessors(&self, name: &str) -> Vec<&str> {
        self.edges
            .iter()
            .filter(|(_, t)| t == name)
            .map(|(f, _)| f.as_str())
            .collect()
    }

    /// The per-token BAM bitfields of this model's (fixed) token layout.
    pub fn bam_bits(&self) -> Vec<u64> {
        let mut bits = vec![0u64; self.total_tokens];
        for s in &self.segments {
            for b in &mut bits[s.start..s.end] {
                *b = s.bits;
            }
        }
        bits
    }
}

/// A standalone attention artifact (CP benches).
#[derive(Clone, Debug)]
pub struct AttnSpec {
    pub name: String,
    pub rel_path: String,
    pub tokens: usize,
    pub heads: usize,
    pub head_dim: usize,
}

/// The whole artifacts directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub models: Vec<ModelManifest>,
    pub attn: Vec<AttnSpec>,
}

impl Manifest {
    /// Load `<root>/manifest.txt`.
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, root)
    }

    /// Default artifacts root: `$CORNSTARCH_ARTIFACTS` or `./artifacts`.
    pub fn default_root() -> PathBuf {
        std::env::var_os("CORNSTARCH_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn parse(text: &str, root: PathBuf) -> Result<Manifest> {
        let mut models: Vec<ModelManifest> = Vec::new();
        let mut attn = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut f = line.split_whitespace();
            let tag = f.next().unwrap();
            let ctx = || format!("manifest line {}: {line}", lineno + 1);
            match tag {
                "model" => {
                    models.push(ModelManifest {
                        name: f.next().with_context(ctx)?.to_string(),
                        total_tokens: 0,
                        text_len: 0,
                        insert_at: 0,
                        vocab: 0,
                        segments: Vec::new(),
                        components: Vec::new(),
                        edges: Vec::new(),
                    });
                }
                "tokens" => {
                    let m = models.last_mut().with_context(ctx)?;
                    m.total_tokens = f.next().with_context(ctx)?.parse()?;
                    anyhow::ensure!(f.next() == Some("text"), "{}", ctx());
                    m.text_len = f.next().with_context(ctx)?.parse()?;
                    anyhow::ensure!(f.next() == Some("insert"), "{}", ctx());
                    m.insert_at = f.next().with_context(ctx)?.parse()?;
                    anyhow::ensure!(f.next() == Some("vocab"), "{}", ctx());
                    m.vocab = f.next().with_context(ctx)?.parse()?;
                }
                "segment" => {
                    let m = models.last_mut().with_context(ctx)?;
                    m.segments.push(SegmentSpec {
                        name: f.next().with_context(ctx)?.to_string(),
                        start: f.next().with_context(ctx)?.parse()?,
                        end: f.next().with_context(ctx)?.parse()?,
                        bits: f.next().with_context(ctx)?.parse()?,
                    });
                }
                "component" => {
                    let m = models.last_mut().with_context(ctx)?;
                    let name = f.next().with_context(ctx)?.to_string();
                    let kind = f.next().with_context(ctx)?.to_string();
                    let n_params: usize =
                        f.next().with_context(ctx)?.parse()?;
                    let shares = f
                        .next()
                        .with_context(ctx)?
                        .strip_prefix("shares=")
                        .with_context(ctx)?;
                    m.components.push(ComponentSpec {
                        name,
                        kind,
                        n_params,
                        shares_params_with: if shares == "-" {
                            None
                        } else {
                            Some(shares.to_string())
                        },
                        params: None,
                        artifacts: HashMap::new(),
                    });
                }
                "params" => {
                    let m = models.last_mut().with_context(ctx)?;
                    let comp = f.next().with_context(ctx)?.to_string();
                    let rel = f.next().with_context(ctx)?.to_string();
                    let n: usize = f.next().with_context(ctx)?.parse()?;
                    m.components
                        .iter_mut()
                        .find(|c| c.name == comp)
                        .with_context(ctx)?
                        .params = Some((rel, n));
                }
                "artifact" => {
                    let m = models.last_mut().with_context(ctx)?;
                    let comp = f.next().with_context(ctx)?.to_string();
                    let role = Role::parse(f.next().with_context(ctx)?)?;
                    let rel_path = f.next().with_context(ctx)?.to_string();
                    let ins_s = f
                        .next()
                        .with_context(ctx)?
                        .strip_prefix("ins=")
                        .with_context(ctx)?;
                    let outs_s = f
                        .next()
                        .with_context(ctx)?
                        .strip_prefix("outs=")
                        .with_context(ctx)?;
                    let parse_specs = |s: &str| -> Result<Vec<IoSpec>> {
                        if s.is_empty() {
                            return Ok(Vec::new());
                        }
                        s.split(';').map(IoSpec::parse).collect()
                    };
                    let art = ArtifactSpec {
                        role,
                        rel_path,
                        ins: parse_specs(ins_s)?,
                        outs: parse_specs(outs_s)?,
                    };
                    m.components
                        .iter_mut()
                        .find(|c| c.name == comp)
                        .with_context(ctx)?
                        .artifacts
                        .insert(role, art);
                }
                "edge" => {
                    let m = models.last_mut().with_context(ctx)?;
                    m.edges.push((
                        f.next().with_context(ctx)?.to_string(),
                        f.next().with_context(ctx)?.to_string(),
                    ));
                }
                "attn" => {
                    attn.push(AttnSpec {
                        name: f.next().with_context(ctx)?.to_string(),
                        rel_path: f.next().with_context(ctx)?.to_string(),
                        tokens: f.next().with_context(ctx)?.parse()?,
                        heads: f.next().with_context(ctx)?.parse()?,
                        head_dim: f.next().with_context(ctx)?.parse()?,
                    });
                }
                _ => bail!("unknown manifest record {tag:?} at line {}", lineno + 1),
            }
        }
        Ok(Manifest { root, models, attn })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("manifest has no model {name:?}"))
    }

    pub fn abs(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }
}

/// Read a little-endian f32 binary blob (the exported param init).
pub fn read_f32_bin(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "{}: length {} not a multiple of 4",
        path.as_ref().display(),
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
model tiny
tokens 32 text 24 insert 4 vocab 512
segment text 0 4 3
segment vision 4 12 2
segment text 12 32 3
component enc:vision encoder 40080 shares=-
params enc:vision tiny/params/enc_vision.f32.bin 40080
artifact enc:vision fwd tiny/enc_vision.fwd.hlo.txt ins=flat:f32:40080;x:f32:8x48 outs=o0:f32:8x48
component llm:head llm_head 98944 shares=llm:1
artifact llm:head fwd tiny/llm_head.fwd.hlo.txt ins=flat:f32:98944;h:f32:32x64;labels:i32:32 outs=o0:f32:_
edge enc:vision llm:head
attn attn128 attn/attn128.fwd.hlo.txt 128 4 32
";

    fn sample() -> Manifest {
        Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap()
    }

    #[test]
    fn parses_model_headers() {
        let m = sample();
        let t = m.model("tiny").unwrap();
        assert_eq!(t.total_tokens, 32);
        assert_eq!(t.text_len, 24);
        assert_eq!(t.insert_at, 4);
        assert_eq!(t.vocab, 512);
        assert_eq!(t.segments.len(), 3);
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn parses_components_and_artifacts() {
        let m = sample();
        let t = m.model("tiny").unwrap();
        let enc = t.component("enc:vision").unwrap();
        assert_eq!(enc.kind, "encoder");
        assert_eq!(enc.n_params, 40080);
        assert_eq!(
            enc.params,
            Some(("tiny/params/enc_vision.f32.bin".to_string(), 40080))
        );
        let fwd = enc.artifact(Role::Fwd).unwrap();
        assert_eq!(fwd.ins.len(), 2);
        assert_eq!(fwd.ins[1].dims, vec![8, 48]);
        assert_eq!(fwd.outs[0].dims, vec![8, 48]);
        assert!(enc.artifact(Role::Bwd).is_err());
    }

    #[test]
    fn scalar_dims_parse_as_empty() {
        let m = sample();
        let head = m.model("tiny").unwrap().component("llm:head").unwrap();
        let fwd = head.artifact(Role::Fwd).unwrap();
        assert!(fwd.outs[0].dims.is_empty());
        assert_eq!(fwd.outs[0].elements(), 1);
        assert_eq!(
            head.shares_params_with.as_deref(),
            Some("llm:1")
        );
        assert_eq!(fwd.ins[2].dtype, DType::I32);
    }

    #[test]
    fn bam_bits_reconstructs_segments() {
        let m = sample();
        let bits = m.model("tiny").unwrap().bam_bits();
        assert_eq!(bits.len(), 32);
        assert_eq!(bits[0], 3);
        assert_eq!(bits[4], 2);
        assert_eq!(bits[11], 2);
        assert_eq!(bits[12], 3);
    }

    #[test]
    fn edges_and_queries() {
        let m = sample();
        let t = m.model("tiny").unwrap();
        assert_eq!(t.successors("enc:vision"), vec!["llm:head"]);
        assert_eq!(t.predecessors("llm:head"), vec!["enc:vision"]);
        assert_eq!(t.encoder_names(), vec!["vision"]);
    }

    #[test]
    fn attn_records() {
        let m = sample();
        assert_eq!(m.attn.len(), 1);
        assert_eq!(m.attn[0].tokens, 128);
        assert_eq!(m.attn[0].heads, 4);
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // The repo's own artifacts (built by `make artifacts`).
        let root = Manifest::default_root();
        if root.join("manifest.txt").exists() {
            let m = Manifest::load(&root).unwrap();
            assert!(m.model("tiny").is_ok());
            let tiny = m.model("tiny").unwrap();
            assert!(tiny.n_llm_stages() >= 2);
            for c in &tiny.components {
                assert!(c.artifacts.contains_key(&Role::Fwd), "{}", c.name);
            }
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("bogus line", PathBuf::from(".")).is_err());
        assert!(IoSpec::parse("x:f99:2x2").is_err());
        assert!(Role::parse("sideways").is_err());
    }
}
