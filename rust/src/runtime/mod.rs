//! PJRT runtime — loads the AOT artifacts emitted by `python/compile/aot.py`
//! and executes them from rust. Python never runs here: the HLO text files
//! are parsed, compiled, and executed through the `xla` crate
//! (`PjRtClient::cpu()`), exactly as `/opt/xla-example/load_hlo` does.
//!
//! Layering:
//!
//! * [`manifest`] — the artifact contract (components, roles, I/O specs).
//! * [`HostTensor`] — `Send` host-side tensors that cross stage-thread
//!   channels in [`crate::train`] (PJRT handles are not `Send`).
//! * [`ModelRuntime`] — one PJRT client owning the compiled executables of
//!   a subset of a model's components (a pipeline stage owns only its own
//!   components — the paper's model-parallel placement).

pub mod manifest;
// Offline stand-in for the external `xla` crate: the child module shadows
// the crate name, so every `xla::` path below resolves here (public
// because `compile_hlo`'s signature exposes its types). See `xla.rs` for
// how to swap the real backend in.
pub mod xla;

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context as _, Result};

pub use manifest::{
    ArtifactSpec, AttnSpec, ComponentSpec, DType, IoSpec, Manifest,
    ModelManifest, Role, SegmentSpec,
};

/// A host-side tensor (always dense, row-major). `Send + Sync`, unlike the
/// PJRT handles, so activations/gradients can cross stage threads.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(dims: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>().max(1), data.len());
        HostTensor::F32 { dims: dims.to_vec(), data }
    }

    pub fn i32(dims: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>().max(1), data.len());
        HostTensor::I32 { dims: dims.to_vec(), data }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::F32 { dims: vec![], data: vec![x] }
    }

    pub fn zeros_f32(dims: &[usize]) -> Self {
        let n = dims.iter().product::<usize>().max(1);
        HostTensor::F32 { dims: dims.to_vec(), data: vec![0.0; n] }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            HostTensor::F32 { dims, .. } | HostTensor::I32 { dims, .. } => dims,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
        }
    }

    pub fn elements(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => bail!("expected i32 tensor, got f32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("expected f32 tensor, got i32"),
        }
    }

    /// Scalar value (loss etc).
    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        anyhow::ensure!(d.len() == 1, "not a scalar: {} elems", d.len());
        Ok(d[0])
    }

    /// Does this tensor match an artifact I/O spec?
    pub fn matches(&self, spec: &IoSpec) -> bool {
        self.dtype() == spec.dtype && self.dims() == spec.dims.as_slice()
    }

    /// Upload to a device buffer (one host→device copy; no intermediate
    /// Literal). The hot path: `execute_b` with resident parameter buffers.
    fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        match self {
            HostTensor::F32 { dims, data } => client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow!("buffer_from_host_buffer: {e}")),
            HostTensor::I32 { dims, data } => client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow!("buffer_from_host_buffer: {e}")),
        }
    }

    #[allow(dead_code)]
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32 { dims, data } => {
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                    xla::Literal::vec1(data)
                        .reshape(&d)
                        .map_err(|e| anyhow!("reshape: {e}"))?
                }
            }
            HostTensor::I32 { dims, data } => {
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                    xla::Literal::vec1(data)
                        .reshape(&d)
                        .map_err(|e| anyhow!("reshape: {e}"))?
                }
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal, spec: &IoSpec) -> Result<HostTensor> {
        Ok(match spec.dtype {
            DType::F32 => HostTensor::F32 {
                dims: spec.dims.clone(),
                data: lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?,
            },
            DType::I32 => HostTensor::I32 {
                dims: spec.dims.clone(),
                data: lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e}"))?,
            },
        })
    }
}

/// One compiled component: its spec plus one PJRT executable per exported
/// role and the authoritative host copy of the flat parameter vector
/// (`llm:head` aliases its sharing target at execute time).
struct CompiledComponent {
    spec: ComponentSpec,
    exes: HashMap<Role, xla::PjRtLoadedExecutable>,
    params: Vec<f32>,
    /// Device-resident copy of `params`, uploaded lazily and invalidated
    /// by `set_params` — the perf-pass optimization that removes the
    /// per-call host→device copy of the (large) flat parameter vector.
    params_buf: Option<xla::PjRtBuffer>,
}

/// A PJRT runtime holding compiled executables for a subset of one model's
/// components. Create one per pipeline-stage thread ([`crate::train`]) or
/// one for everything (tests, single-process examples).
pub struct ModelRuntime {
    client: xla::PjRtClient,
    model: ModelManifest,
    comps: HashMap<String, CompiledComponent>,
    /// Cumulative wall time spent inside PJRT execute calls, per role.
    pub exec_ms: HashMap<Role, f64>,
}

impl ModelRuntime {
    /// Compile `components` (by name; `None` = all) of `model` for `roles`.
    pub fn load(
        manifest: &Manifest,
        model_name: &str,
        components: Option<&[&str]>,
        roles: &[Role],
    ) -> Result<ModelRuntime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        let model = manifest.model(model_name)?.clone();
        let mut comps = HashMap::new();
        for spec in &model.components {
            if let Some(filter) = components {
                if !filter.contains(&spec.name.as_str()) {
                    continue;
                }
            }
            let mut exes = HashMap::new();
            for role in roles {
                let Some(art) = spec.artifacts.get(role) else {
                    continue;
                };
                let path = manifest.abs(&art.rel_path);
                exes.insert(*role, compile_hlo(&client, &path)?);
            }
            let params = match &spec.params {
                Some((rel, n)) => {
                    let p = manifest::read_f32_bin(manifest.abs(rel))?;
                    anyhow::ensure!(
                        p.len() == *n,
                        "{}: params file has {} elems, manifest says {n}",
                        spec.name,
                        p.len()
                    );
                    p
                }
                None => Vec::new(),
            };
            comps.insert(
                spec.name.clone(),
                CompiledComponent {
                    spec: spec.clone(),
                    exes,
                    params,
                    params_buf: None,
                },
            );
        }
        Ok(ModelRuntime { client, model, comps, exec_ms: HashMap::new() })
    }

    /// Convenience: load every component of `model` with all roles.
    pub fn load_all(manifest: &Manifest, model_name: &str) -> Result<Self> {
        Self::load(manifest, model_name, None, &Role::ALL)
    }

    pub fn model(&self) -> &ModelManifest {
        &self.model
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The flat parameter vector of `comp` (resolving parameter sharing).
    pub fn params(&self, comp: &str) -> Result<&[f32]> {
        let c = self.comp(comp)?;
        match &c.spec.shares_params_with {
            Some(owner) => self.params(owner),
            None => Ok(&c.params),
        }
    }

    /// Overwrite the flat parameter vector of `comp` (optimizer step).
    pub fn set_params(&mut self, comp: &str, new: Vec<f32>) -> Result<()> {
        let owner = {
            let c = self.comp(comp)?;
            c.spec
                .shares_params_with
                .clone()
                .unwrap_or_else(|| comp.to_string())
        };
        let c = self
            .comps
            .get_mut(&owner)
            .ok_or_else(|| anyhow!("no component {owner}"))?;
        anyhow::ensure!(
            new.len() == c.params.len(),
            "{owner}: param size mismatch {} vs {}",
            new.len(),
            c.params.len()
        );
        c.params = new;
        c.params_buf = None; // re-uploaded lazily on next execute
        Ok(())
    }

    /// Name of the component owning `comp`'s parameters.
    fn owner_of(&self, comp: &str) -> Result<String> {
        let c = self.comp(comp)?;
        Ok(c.spec
            .shares_params_with
            .clone()
            .unwrap_or_else(|| comp.to_string()))
    }

    /// Ensure the owner's parameter vector is resident on device.
    fn ensure_param_buffer(&mut self, comp: &str) -> Result<String> {
        let owner = self.owner_of(comp)?;
        let c = self
            .comps
            .get_mut(&owner)
            .ok_or_else(|| anyhow!("no component {owner}"))?;
        if c.params_buf.is_none() {
            let buf = self
                .client
                .buffer_from_host_buffer(&c.params, &[c.params.len()], None)
                .map_err(|e| anyhow!("{owner}: param upload: {e}"))?;
            c.params_buf = Some(buf);
        }
        Ok(owner)
    }

    fn comp(&self, name: &str) -> Result<&CompiledComponent> {
        self.comps
            .get(name)
            .ok_or_else(|| anyhow!("component {name:?} not loaded"))
    }

    /// The artifact spec of a loaded component.
    pub fn artifact(&self, comp: &str, role: Role) -> Result<&ArtifactSpec> {
        self.comp(comp)?.spec.artifact(role)
    }

    /// Execute `comp`'s `role` program. `inputs` are the artifact inputs
    /// *after* the leading `flat` parameter vector, which stays resident
    /// on the device (perf pass: the large param vector is uploaded once,
    /// not per call). Shapes are validated against the manifest.
    pub fn execute(
        &mut self,
        comp: &str,
        role: Role,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let owner = self.ensure_param_buffer(comp)?;
        let art = {
            let c = self.comp(comp)?;
            c.spec.artifact(role)?.clone()
        };
        anyhow::ensure!(
            inputs.len() + 1 == art.ins.len(),
            "{comp}/{}: expected {} inputs after flat, got {}",
            role.as_str(),
            art.ins.len() - 1,
            inputs.len()
        );
        for (t, spec) in inputs.iter().zip(&art.ins[1..]) {
            anyhow::ensure!(
                t.matches(spec),
                "{comp}/{}: input {} expects {}:{:?}, got {}:{:?}",
                role.as_str(),
                spec.name,
                spec.dtype,
                spec.dims,
                t.dtype(),
                t.dims()
            );
        }
        let act_bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| t.to_buffer(&self.client))
            .collect::<Result<_>>()?;
        let (parts, elapsed) = {
            let pbuf = self
                .comps
                .get(&owner)
                .and_then(|c| c.params_buf.as_ref())
                .expect("ensure_param_buffer uploaded it");
            let c = self.comp(comp)?;
            let exe = c.exes.get(&role).ok_or_else(|| {
                anyhow!("{comp}: role {} not compiled", role.as_str())
            })?;
            let mut refs: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(act_bufs.len() + 1);
            refs.push(pbuf);
            refs.extend(act_bufs.iter());
            let t0 = Instant::now();
            let out = exe
                .execute_b::<&xla::PjRtBuffer>(&refs)
                .map_err(|e| anyhow!("{comp}/{} execute: {e}", role.as_str()))?;
            let result = out[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal_sync: {e}"))?;
            let elapsed = t0.elapsed().as_secs_f64() * 1e3;
            // aot.py lowers with return_tuple=True: root is always a tuple.
            let parts = result
                .to_tuple()
                .map_err(|e| anyhow!("decompose tuple: {e}"))?;
            (parts, elapsed)
        };
        *self.exec_ms.entry(role).or_insert(0.0) += elapsed;
        anyhow::ensure!(
            parts.len() == art.outs.len(),
            "{comp}/{}: {} outputs, manifest says {}",
            role.as_str(),
            parts.len(),
            art.outs.len()
        );
        parts
            .iter()
            .zip(&art.outs)
            .map(|(l, s)| HostTensor::from_literal(l, s))
            .collect()
    }

    /// Execute with the full explicit input list (including `flat`) —
    /// used by the optimizer path and tests. Every input is uploaded.
    pub fn execute_raw(
        &mut self,
        comp: &str,
        role: Role,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let art = {
            let c = self.comp(comp)?;
            c.spec.artifact(role)?.clone()
        };
        anyhow::ensure!(
            inputs.len() == art.ins.len(),
            "{comp}/{}: expected {} inputs, got {}",
            role.as_str(),
            art.ins.len(),
            inputs.len()
        );
        for (t, spec) in inputs.iter().zip(&art.ins) {
            anyhow::ensure!(
                t.matches(spec),
                "{comp}/{}: input {} expects {}:{:?}, got {}:{:?}",
                role.as_str(),
                spec.name,
                spec.dtype,
                spec.dims,
                t.dtype(),
                t.dims()
            );
        }
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| t.to_buffer(&self.client))
            .collect::<Result<_>>()?;
        let (parts, elapsed) = {
            let c = self.comp(comp)?;
            let exe = c.exes.get(&role).ok_or_else(|| {
                anyhow!("{comp}: role {} not compiled", role.as_str())
            })?;
            let t0 = Instant::now();
            let out = exe
                .execute_b::<&xla::PjRtBuffer>(
                    &bufs.iter().collect::<Vec<_>>(),
                )
                .map_err(|e| anyhow!("{comp}/{} execute: {e}", role.as_str()))?;
            let result = out[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal_sync: {e}"))?;
            let elapsed = t0.elapsed().as_secs_f64() * 1e3;
            let parts = result
                .to_tuple()
                .map_err(|e| anyhow!("decompose tuple: {e}"))?;
            (parts, elapsed)
        };
        *self.exec_ms.entry(role).or_insert(0.0) += elapsed;
        anyhow::ensure!(
            parts.len() == art.outs.len(),
            "{comp}/{}: {} outputs, manifest says {}",
            role.as_str(),
            parts.len(),
            art.outs.len()
        );
        parts
            .iter()
            .zip(&art.outs)
            .map(|(l, s)| HostTensor::from_literal(l, s))
            .collect()
    }

    /// One AdamW step for `comp`: runs the `upd` artifact and installs the
    /// new parameters. Optimizer slots (`m`, `v`) are owned by the caller.
    pub fn adamw_step(
        &mut self,
        comp: &str,
        grad: &[f32],
        m: &mut Vec<f32>,
        v: &mut Vec<f32>,
        step: f32,
        lr: f32,
    ) -> Result<()> {
        let n = self.params(comp)?.len();
        anyhow::ensure!(grad.len() == n && m.len() == n && v.len() == n);
        let owner = self.ensure_param_buffer(comp)?;
        let parts = {
            // grad/m/v upload (unavoidable: they are step inputs); the
            // flat vector itself stays resident.
            let up = |data: &[f32]| {
                self.client
                    .buffer_from_host_buffer(data, &[data.len()], None)
                    .map_err(|e| anyhow!("upd upload: {e}"))
            };
            let gbuf = up(grad)?;
            let mbuf = up(m)?;
            let vbuf = up(v)?;
            // step/lr are 0-d scalars in the artifact signature
            let sbuf = self
                .client
                .buffer_from_host_buffer(&[step], &[], None)
                .map_err(|e| anyhow!("upd upload: {e}"))?;
            let lbuf = self
                .client
                .buffer_from_host_buffer(&[lr], &[], None)
                .map_err(|e| anyhow!("upd upload: {e}"))?;
            let c = self.comps.get(&owner).unwrap();
            let pbuf = c.params_buf.as_ref().unwrap();
            let exe = c
                .exes
                .get(&Role::Upd)
                .ok_or_else(|| anyhow!("{owner}: upd not compiled"))?;
            let refs: Vec<&xla::PjRtBuffer> =
                vec![pbuf, &gbuf, &mbuf, &vbuf, &sbuf, &lbuf];
            let t0 = Instant::now();
            let out = exe
                .execute_b::<&xla::PjRtBuffer>(&refs)
                .map_err(|e| anyhow!("{owner}/upd execute: {e}"))?;
            let result = out[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal_sync: {e}"))?;
            let elapsed = t0.elapsed().as_secs_f64() * 1e3;
            *self.exec_ms.entry(Role::Upd).or_insert(0.0) += elapsed;
            result.to_tuple().map_err(|e| anyhow!("tuple: {e}"))?
        };
        anyhow::ensure!(parts.len() == 3, "upd returns (flat', m', v')");
        let new = parts[0].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        *m = parts[1].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        *v = parts[2].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        // Install new params on host; the device copy is invalidated and
        // lazily re-uploaded on the next execute. (Re-uploading from the
        // output literal via `buffer_from_host_literal` would save that
        // copy, but the CPU plugin aliases the literal's memory, which is
        // freed when `parts` drops — use-after-free.)
        self.set_params(comp, new)?;
        Ok(())
    }
}

/// Compile one HLO-text file on `client`.
pub fn compile_hlo(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {}: {e}", path.display()))
}

/// Standalone BAM-attention runner for the CP benches: executes the
/// `attn<T>` artifact on (q, k, v, bits, pos) host tensors.
pub struct AttnRuntime {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    pub spec: AttnSpec,
}

impl AttnRuntime {
    pub fn load(manifest: &Manifest, name: &str) -> Result<AttnRuntime> {
        let spec = manifest
            .attn
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("no attn artifact {name:?}"))?
            .clone();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        let exe = compile_hlo(&client, &manifest.abs(&spec.rel_path))
            .context("compiling attention artifact")?;
        Ok(AttnRuntime { client, exe, spec })
    }

    /// Run attention over the full (un-sharded) token set; returns the
    /// output `[T*H*D]` and the execute wall time in ms.
    pub fn run(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        bits: &[i32],
        pos: &[i32],
    ) -> Result<(Vec<f32>, f64)> {
        let t = self.spec.tokens;
        let h = self.spec.heads;
        let d = self.spec.head_dim;
        let qd = [t, h, d];
        let mk = |x: &[f32]| HostTensor::f32(&qd, x.to_vec()).to_literal();
        let lits = vec![
            mk(q)?,
            mk(k)?,
            mk(v)?,
            HostTensor::i32(&[t], bits.to_vec()).to_literal()?,
            HostTensor::i32(&[t], pos.to_vec()).to_literal()?,
            HostTensor::i32(&[t], bits.to_vec()).to_literal()?,
            HostTensor::i32(&[t], pos.to_vec()).to_literal()?,
        ];
        let t0 = Instant::now();
        let out = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("attn execute: {e}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal_sync: {e}"))?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let o = lit
            .to_tuple1()
            .map_err(|e| anyhow!("tuple1: {e}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec: {e}"))?;
        Ok((o, ms))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_roundtrip_and_validation() {
        let t = HostTensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.dims(), &[2, 3]);
        assert_eq!(t.elements(), 6);
        assert!(t.as_i32().is_err());
        let spec =
            IoSpec { name: "x".into(), dtype: DType::F32, dims: vec![2, 3] };
        assert!(t.matches(&spec));
        let bad =
            IoSpec { name: "x".into(), dtype: DType::I32, dims: vec![2, 3] };
        assert!(!t.matches(&bad));
        let s = HostTensor::scalar_f32(4.5);
        assert_eq!(s.scalar().unwrap(), 4.5);
        assert!(t.scalar().is_err());
    }

    #[test]
    #[should_panic]
    fn host_tensor_rejects_bad_dims() {
        HostTensor::f32(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn zeros_shape() {
        let z = HostTensor::zeros_f32(&[4, 8]);
        assert_eq!(z.elements(), 32);
        assert!(z.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }
}
