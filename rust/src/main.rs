//! `cornstarch` — the leader CLI.
//!
//! ```text
//! cornstarch reproduce <exp|all>        regenerate a paper table/figure
//! cornstarch train [opts]               train a model over the artifacts
//! cornstarch plan <mllm> [opts]         print a parallelization plan
//! cornstarch tune <mllm> [opts]         autotune the fastest plan
//! cornstarch stats <mllm> [opts]        deterministic search counters
//! cornstarch verify <mllm> [opts]       static lints over the tuned plan
//! cornstarch explain <mllm> [opts]      why the plan won (decomposition)
//! cornstarch calibrate [opts]           measure PJRT stage times -> profile
//! cornstarch memory <mllm> [opts]       per-stage memory model verdict
//! cornstarch fleet [opts]               carve one pool across N tenants
//! cornstarch serve [opts]               long-lived planning server (JSON lines)
//! cornstarch diff [fleet|<mllm>] [opts] what a re-plan changed
//! cornstarch auto <mllm> [--groups N]   Algorithm 1 frontier
//! cornstarch attn-check [--artifact A]  PJRT cross-check of the CP model
//! cornstarch list-models                artifacts available to `train`
//! ```
//!
//! Global flags (any command): `--trace <file>` exports spans as Chrome
//! trace-event JSON (Perfetto / `chrome://tracing`); `--quiet`/`-q`
//! suppresses progress lines (report output stays on stdout); `-v`
//! adds per-wave search and cache-IO detail. Every progress print goes
//! through the one [`cornstarch::telemetry::log`] door.
//!
//! `<mllm>` names follow §6.1: `VLM-M`, `ALM-L`, `VALM-SM`…, optionally
//! prefixed with an LLM size (`llm=S`).
//!
//! `plan`, `tune`, `memory`, `fleet`, and `diff` accept `--cluster
//! <file>` (a JSON `ClusterSpec`: per-device memory, flops/MFU,
//! interconnect bandwidth — see `examples/clusters/README.md`); without
//! it the single-job commands plan for the paper's 16 × A40 testbed and
//! the fleet commands carve the mixed 4×A40 + 4×A100-80G demo pool. All
//! of them are thin wrappers over the planning facade
//! (`cornstarch::api`).

use anyhow::{anyhow, bail, Context, Result};

use cornstarch::api::{
    ClusterSpec, FleetRequest, PlanDiff, PlanReport, PlanRequest,
    PlanningService, SearchMode,
};
use cornstarch::coordinator::{self, TrainOpts};
use cornstarch::memory;
use cornstarch::modality::{
    planner, MultimodalModule, MultimodalParallelSpec, Plan, Strategy,
};
use cornstarch::model::{MllmSpec, Size};
use cornstarch::runtime::Manifest;
use cornstarch::telemetry::{self, Verbosity};
use cornstarch::train::{FrozenPolicy, PipelineTrainer, SyntheticDataset};
use cornstarch::tuner::{FrozenSetting, Objective};

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    // Global flags are stripped before dispatch so every command's
    // positional parsing (`rest.first()` is the MLLM name) is unaffected.
    let had_trace = has_flag(&args, "--trace");
    let trace_path = take_flag_value(&mut args, "--trace");
    if had_trace && trace_path.is_none() {
        telemetry::error("error: --trace wants an output file path");
        std::process::exit(2);
    }
    if take_flag(&mut args, "-v") || take_flag(&mut args, "--verbose") {
        telemetry::set_verbosity(Verbosity::Verbose);
    }
    if take_flag(&mut args, "--quiet") || take_flag(&mut args, "-q") {
        telemetry::set_verbosity(Verbosity::Quiet);
    }
    if trace_path.is_some() {
        telemetry::enable_trace();
    }
    let outcome = run(&args);
    if let Some(path) = &trace_path {
        match telemetry::write_trace(path) {
            Ok(()) => telemetry::info(&format!(
                "wrote {} trace events to {path} (load in Perfetto or \
                 chrome://tracing)",
                telemetry::trace_len()
            )),
            Err(e) => telemetry::error(&format!(
                "error: writing trace {path}: {e}"
            )),
        }
    }
    if let Err(e) = outcome {
        telemetry::error(&format!("error: {e:#}"));
        std::process::exit(1);
    }
}

/// Remove every occurrence of a bare global flag; `true` if it appeared.
fn take_flag(args: &mut Vec<String>, name: &str) -> bool {
    let before = args.len();
    args.retain(|a| a != name);
    args.len() != before
}

/// Remove the first `name <value>` pair and return the value.
fn take_flag_value(args: &mut Vec<String>, name: &str) -> Option<String> {
    let i = args.iter().position(|a| a == name)?;
    if i + 1 >= args.len() {
        return None;
    }
    let value = args.remove(i + 1);
    args.remove(i);
    Some(value)
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        bail!("missing command (try `cornstarch help`)");
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "reproduce" => {
            let which = rest.first().map(|s| s.as_str()).unwrap_or("all");
            telemetry::report(coordinator::reproduce(which)?.trim_end());
        }
        "train" => {
            let opts = parse_train(rest)?;
            let losses = coordinator::train(&opts)?;
            let first = losses.first().copied().unwrap_or(f32::NAN);
            let last = losses.last().copied().unwrap_or(f32::NAN);
            telemetry::report(&format!(
                "loss: {first:.4} -> {last:.4} over {} steps",
                losses.len()
            ));
        }
        "plan" => {
            let spec = parse_mllm(rest.first().map(|s| s.as_str()).unwrap_or("VLM-M"), rest)?;
            let cluster =
                parse_cluster(rest)?.unwrap_or_else(ClusterSpec::a40_default);
            let strategy_flag = flag(rest, "--strategy");
            if strategy_flag.as_deref() == Some("tuned") {
                // Thin wrapper over the planning facade (same request
                // the programmatic `PlanningService::plan` answers).
                let mut req =
                    PlanRequest::default_for(spec.clone()).cluster(cluster);
                if let Some(d) = flag_num(rest, "--devices")? {
                    // on a multi-group pool the facade answers this
                    // with a typed InvalidRequest
                    req = req.devices(d);
                }
                if let Some(c) = flag(rest, "--cache") {
                    req = req.cache_file(&c);
                }
                let report = PlanningService::new().plan(&req)?;
                telemetry::report(&format!(
                    "{} / tuned on {} GPUs ({})",
                    spec.name(),
                    req.cluster.devices(),
                    if report.provenance.cache_hit {
                        "cache hit"
                    } else {
                        "searched"
                    }
                ));
                telemetry::report(&format!(
                    "  {}",
                    report.winner().candidate.label()
                ));
                print_plan(&report.plan);
                return Ok(());
            }
            let strategy = match strategy_flag.as_deref() {
                None => Strategy::Cornstarch,
                Some(s) => Strategy::from_key(s)
                    .ok_or_else(|| anyhow!("unknown strategy {s}"))?,
            };
            anyhow::ensure!(
                !cluster.is_heterogeneous(),
                "fixed-strategy plans price a single device class; use \
                 `--strategy tuned` to search placements on a \
                 heterogeneous pool"
            );
            let llm_pp = flag_num(rest, "--llm-pp")?.unwrap_or(4);
            let enc_pp = flag_num(rest, "--enc-pp")?.unwrap_or(1);
            let mm = MultimodalModule::from_spec(&spec);
            let n_enc = mm.encoders.len();
            let ps = MultimodalParallelSpec::for_cluster(
                &vec![enc_pp; n_enc],
                llm_pp,
                flag_num(rest, "--tp")?.unwrap_or(2),
                flag_num(rest, "--cp")?.unwrap_or(2),
                &cluster,
            );
            let plan =
                planner::plan(strategy, &mm, &ps, cluster.device_model());
            telemetry::report(&format!(
                "{} / {}",
                spec.name(),
                strategy.name()
            ));
            print_plan(&plan);
        }
        "tune" => {
            let spec = parse_mllm(
                rest.first().map(|s| s.as_str()).unwrap_or("VLM-M"),
                rest,
            )?;
            let cluster =
                parse_cluster(rest)?.unwrap_or_else(ClusterSpec::a40_default);
            let mut req =
                PlanRequest::default_for(spec.clone()).cluster(cluster);
            if let Some(d) = flag_num(rest, "--devices")? {
                // on a multi-group pool the facade answers this with a
                // typed InvalidRequest
                req = req.devices(d);
            }
            if let Some(b) = flag_num(rest, "--budget")? {
                req = req.budget(b);
            }
            if let Some(t) = flag_num(rest, "--threads")? {
                req = req.threads(t);
            }
            if let Some(c) = flag(rest, "--cache") {
                req = req.cache_file(&c);
            }
            if let Some(o) = flag(rest, "--objective") {
                req = req.objective(Objective::parse(&o).ok_or_else(|| {
                    anyhow!("bad --objective {o:?} (makespan|tput-per-gpu)")
                })?);
            }
            if let Some(p) = flag(rest, "--policy") {
                let f = FrozenSetting::parse(&p).ok_or_else(|| {
                    anyhow!("bad --policy {p:?} (paper|all|frozen)")
                })?;
                let mut space = req.resolved_space();
                space.frozen_choices = vec![f];
                req = req.space(space);
            }
            if has_flag(rest, "--sweep-policies") {
                let mut space = req.resolved_space();
                space.frozen_choices = FrozenSetting::ALL.to_vec();
                req = req.space(space);
            }
            let top = flag_num(rest, "--top")?.unwrap_or(1).max(1);
            let depth = req.top.max(top);
            req = req.top(depth);
            let t0 = std::time::Instant::now();
            let report = PlanningService::new().plan(&req)?;
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            let e = report.winner();
            telemetry::report(&format!(
                "{} on {} ({} GPUs) — objective {}",
                spec.name(),
                req.cluster.name,
                req.cluster.devices(),
                req.objective.key()
            ));
            for g in &req.cluster.groups {
                telemetry::info(&format!(
                    "  group {}×{}: {:.0} GB/device, {:.1} TF peak × {} \
                     MFU, {} GB/s link",
                    g.count,
                    g.device.name,
                    memory::gb(g.device.mem_bytes),
                    g.device.peak_flops / 1e12,
                    g.device.mfu,
                    g.link_gbps
                ));
            }
            if report.provenance.cache_hit {
                telemetry::info(&format!(
                    "  cache hit ({}) — no search",
                    flag(rest, "--cache").as_deref().unwrap_or("in-memory")
                ));
            } else {
                telemetry::info(&format!(
                    "  searched {} candidates: {} simulated, {} pruned \
                     by lower bound ({:.0} ms wall)",
                    report.provenance.total_candidates,
                    report.provenance.evaluated,
                    report.provenance.pruned,
                    wall_ms
                ));
            }
            telemetry::debug(&format!(
                "  search stats: {}",
                report.provenance.stats.render_line()
            ));
            telemetry::report(&format!("  best: {}", e.candidate.label()));
            telemetry::report(&format!(
                "  iteration {:.1} ms | {:.3} input/s/GPU | {} GPUs | \
                 peak {:.1} GB/GPU | cp dist: {}",
                e.iteration_ms,
                e.throughput_per_gpu,
                e.n_gpus,
                memory::gb(e.peak_mem_bytes),
                e.cp_algorithm
            ));
            if top > 1 {
                telemetry::report(&format!(
                    "  frontier (top {}):",
                    top.min(report.frontier.len())
                ));
                for (i, p) in report.frontier.iter().take(top).enumerate()
                {
                    telemetry::report(&format!(
                        "    #{}: {:.1} ms | {:.3} in/s/GPU | {} GPUs | \
                         peak {:.1} GB | {}",
                        i + 1,
                        p.iteration_ms,
                        p.throughput_per_gpu,
                        p.n_gpus,
                        memory::gb(p.peak_mem_bytes),
                        p.candidate.label()
                    ));
                }
            }
            print_plan(&report.plan);
        }
        "stats" => {
            // Deterministic search counters for one `plan()` call: the
            // `SearchStats` provenance block plus the raw counter delta
            // the call fired. `--json` prints the stats object alone,
            // machine-readable (pair with `--quiet` for clean stdout).
            let spec = parse_mllm(
                rest.first().map(|s| s.as_str()).unwrap_or("VLM-M"),
                rest,
            )?;
            let cluster =
                parse_cluster(rest)?.unwrap_or_else(ClusterSpec::a40_default);
            let mut req =
                PlanRequest::default_for(spec.clone()).cluster(cluster);
            if let Some(d) = flag_num(rest, "--devices")? {
                req = req.devices(d);
            }
            if let Some(b) = flag_num(rest, "--budget")? {
                req = req.budget(b);
            }
            if let Some(t) = flag_num(rest, "--threads")? {
                req = req.threads(t);
            }
            if let Some(c) = flag(rest, "--cache") {
                req = req.cache_file(&c);
            }
            let before = telemetry::snapshot();
            let t0 = std::time::Instant::now();
            let report = PlanningService::new().plan(&req)?;
            let wall_s = t0.elapsed().as_secs_f64();
            let delta = telemetry::snapshot().delta_since(&before);
            let stats = report.provenance.stats;
            if has_flag(rest, "--json") {
                telemetry::report(&stats.to_json().render());
                return Ok(());
            }
            telemetry::report(&format!(
                "{} on {} ({} GPUs) — {}",
                spec.name(),
                req.cluster.name,
                req.cluster.devices(),
                if report.provenance.cache_hit {
                    "cache hit"
                } else {
                    "searched"
                }
            ));
            telemetry::report(&format!("  {}", stats.render_line()));
            if !delta.is_empty() {
                telemetry::report("  counters:");
                for line in delta.render().lines() {
                    telemetry::report(&format!("  {line}"));
                }
            }
            if !report.provenance.cache_hit && wall_s > 0.0 {
                // wall-clock rates are machine-dependent: info, not report
                telemetry::info(&format!(
                    "  rate: {:.0} candidates/s enumerated, {:.0} sims/s \
                     ({:.0} ms wall)",
                    stats.candidates_enumerated as f64 / wall_s,
                    stats.evaluated as f64 / wall_s,
                    wall_s * 1e3
                ));
            }
            telemetry::report(&format!(
                "  best: {}",
                report.winner().candidate.label()
            ));
        }
        "verify" => {
            // Static plan/schedule analyzer: plan (cache-aware), then
            // run the typed lints over the winner and render the
            // verdict. The facade's own gate already refuses plans with
            // Error-severity lints, so a report that reaches here
            // re-verifies clean; the command exists to *show* the
            // verdict and its warnings — machine-readably (and
            // byte-stably) under `--json`.
            let name = match rest.first() {
                Some(s) if !s.starts_with("--") => s.as_str(),
                _ => "VLM-M",
            };
            let spec = parse_mllm(name, rest)?;
            let cluster =
                parse_cluster(rest)?.unwrap_or_else(ClusterSpec::a40_default);
            let mut req =
                PlanRequest::default_for(spec.clone()).cluster(cluster);
            if let Some(d) = flag_num(rest, "--devices")? {
                req = req.devices(d);
            }
            if let Some(b) = flag_num(rest, "--budget")? {
                req = req.budget(b);
            }
            if let Some(t) = flag_num(rest, "--threads")? {
                req = req.threads(t);
            }
            if let Some(c) = flag(rest, "--cache") {
                req = req.cache_file(&c);
            }
            let report = PlanningService::new().plan(&req)?;
            let verdict = cornstarch::verify::verify_plan(
                &report.plan,
                &req.cluster,
                Some(&report.winner().candidate),
                spec.llm_tokens(),
            );
            if has_flag(rest, "--json") {
                use cornstarch::util::json::Json;
                telemetry::report(
                    &Json::obj(vec![
                        ("mllm", Json::Str(spec.name())),
                        ("cluster", Json::Str(req.cluster.fingerprint())),
                        (
                            "plan",
                            Json::Str(report.winner().candidate.label()),
                        ),
                        ("verify", verdict.to_json()),
                    ])
                    .render(),
                );
            } else {
                telemetry::report(&format!(
                    "{} on {} ({} GPUs) — {}",
                    spec.name(),
                    req.cluster.name,
                    req.cluster.devices(),
                    report.winner().candidate.label()
                ));
                telemetry::report(verdict.render().trim_end());
            }
            anyhow::ensure!(verdict.is_clean(), "plan failed verification");
        }
        "explain" => {
            // Why the plan won: per-device compute/comm/idle decomposition
            // (sums exactly to the makespan), 1F1B phase bubbles, cp token
            // imbalance, per-group utilization. `--json` emits the
            // analysis alone, machine-readable and byte-stable;
            // `--vs-cluster`/`--vs-devices` diff two plans'
            // decompositions; `--profile F` scores the flops model
            // against a measured CalibrationProfile.
            let name = match rest.first() {
                Some(s) if !s.starts_with("--") => s.as_str(),
                _ => "VLM-M",
            };
            let spec = parse_mllm(name, rest)?;
            let base_cluster =
                parse_cluster(rest)?.unwrap_or_else(ClusterSpec::a40_default);
            let service = PlanningService::new();
            let build = |cluster: ClusterSpec,
                         devices: Option<usize>|
             -> Result<PlanReport> {
                let mut req =
                    PlanRequest::default_for(spec.clone()).cluster(cluster);
                if let Some(d) = devices {
                    req = req.devices(d);
                }
                if let Some(b) = flag_num(rest, "--budget")? {
                    req = req.budget(b);
                }
                if let Some(t) = flag_num(rest, "--threads")? {
                    req = req.threads(t);
                }
                if let Some(c) = flag(rest, "--cache") {
                    req = req.cache_file(&c);
                }
                if let Some(k) = flag_num(rest, "--top")? {
                    req = req.top(k);
                }
                Ok(service.plan(&req)?)
            };
            let report =
                build(base_cluster.clone(), flag_num(rest, "--devices")?)?;
            let vs_cluster = flag(rest, "--vs-cluster");
            let vs_devices = flag_num(rest, "--vs-devices")?;
            if vs_cluster.is_some() || vs_devices.is_some() {
                let cluster2 = match vs_cluster {
                    Some(p) => ClusterSpec::load(std::path::Path::new(&p))
                        .with_context(|| {
                            format!("loading cluster spec {p}")
                        })?,
                    None => base_cluster,
                };
                let after = build(cluster2, vs_devices)?;
                telemetry::report(&format!(
                    "{} — before -> after",
                    spec.name()
                ));
                telemetry::report(
                    PlanDiff::between(&report, &after).render().trim_end(),
                );
                return Ok(());
            }
            if has_flag(rest, "--json") {
                telemetry::report(&report.analysis.to_json().render());
                return Ok(());
            }
            telemetry::report(&format!(
                "{} — {} ({} GPUs, {:.1} ms/iter)",
                spec.name(),
                report.winner().candidate.label(),
                report.timeline.n_gpus,
                report.timeline.iteration_ms
            ));
            telemetry::report(report.analysis.render().trim_end());
            if let Some(p) = flag(rest, "--profile") {
                let prof = cornstarch::profile::CalibrationProfile::load(
                    std::path::Path::new(&p),
                )
                .map_err(|e| anyhow!(e))?;
                let d = cornstarch::profile::drift(&report.plan, &prof);
                telemetry::report(d.render().trim_end());
            }
        }
        "calibrate" => {
            // Sim-to-real: run the real PJRT 1F1B executor for a few
            // steps and write the measured per-stage fwd/bwd/update wall
            // times as a CalibrationProfile JSON. `explain --profile F`
            // (or profile::drift) then scores the flops model against
            // it. Needs `make artifacts`.
            let model = flag(rest, "--model").unwrap_or_else(|| {
                rest.first()
                    .filter(|s| !s.starts_with("--"))
                    .cloned()
                    .unwrap_or_else(|| "tiny".to_string())
            });
            let steps = flag_num(rest, "--steps")?.unwrap_or(3).max(1);
            let microbatches =
                flag_num(rest, "--microbatches")?.unwrap_or(4).max(1);
            let device_class = flag(rest, "--device-class")
                .unwrap_or_else(|| "cpu-pjrt".to_string());
            let out = flag(rest, "--out")
                .unwrap_or_else(|| format!("profile-{model}.json"));
            let manifest = Manifest::load(Manifest::default_root())
                .context("run `make artifacts` first (calibration drives \
                          the real PJRT executor)")?;
            let model_spec = manifest.model(&model)?.clone();
            let mut pipe = PipelineTrainer::new(
                &manifest,
                &model,
                parse_train(rest)?.policy,
                1e-3,
            )?;
            let ds = SyntheticDataset::new(&model_spec, 7);
            let batch: Vec<_> =
                (0..microbatches as u64).map(|i| ds.sample(i)).collect();
            for s in 0..steps {
                let st = pipe.train_step(&batch)?;
                telemetry::info(&format!(
                    "  step {}/{steps}: loss {:.4} ({:.0} ms wall)",
                    s + 1,
                    st.loss,
                    st.wall_ms
                ));
            }
            let prof = cornstarch::profile::CalibrationProfile::from_pipeline(
                &pipe,
                &device_class,
            );
            prof.save(std::path::Path::new(&out))
                .with_context(|| format!("writing {out}"))?;
            telemetry::report(&format!(
                "wrote {out}: {} stages on device class {device_class} \
                 (last step, {} microbatches)",
                prof.samples.len(),
                pipe.last_microbatches
            ));
            for s in &prof.samples {
                telemetry::report(&format!(
                    "  {:<16} fwd {:>8.2} ms  bwd {:>8.2} ms  upd {:>8.2} ms",
                    s.stage, s.fwd_ms, s.bwd_ms, s.upd_ms
                ));
            }
        }
        "memory" => {
            let spec = parse_mllm(
                rest.first().map(|s| s.as_str()).unwrap_or("VLM-L"),
                rest,
            )?;
            let cluster =
                parse_cluster(rest)?.unwrap_or_else(ClusterSpec::a40_default);
            let strategy = match flag(rest, "--strategy").as_deref() {
                None => Strategy::Cornstarch,
                Some(s) => Strategy::from_key(s)
                    .ok_or_else(|| anyhow!("unknown strategy {s}"))?,
            };
            anyhow::ensure!(
                !cluster.is_heterogeneous(),
                "`memory` judges one device class at a time; on a \
                 heterogeneous pool use `plan --strategy tuned`, whose \
                 report holds every stage to the budget of the group it \
                 lands on"
            );
            let llm_pp = flag_num(rest, "--llm-pp")?.unwrap_or(4);
            let enc_pp = flag_num(rest, "--enc-pp")?.unwrap_or(1);
            let microbatches =
                flag_num(rest, "--microbatches")?.unwrap_or(24);
            let budget = flag_num(rest, "--budget-gb")?
                .map(|g| g as u64 * 1_000_000_000)
                .unwrap_or_else(|| cluster.mem_budget_bytes());
            let plan = planner::plan_uniform(
                strategy,
                &spec,
                enc_pp,
                llm_pp,
                flag_num(rest, "--tp")?.unwrap_or(2),
                flag_num(rest, "--cp")?.unwrap_or(2),
                microbatches,
                cluster.device_model(),
            );
            telemetry::report(&format!(
                "{} / {} — {} microbatches",
                spec.name(),
                strategy.name(),
                microbatches
            ));
            print_memory(&plan, budget);
        }
        "fleet" => {
            let cluster = parse_cluster(rest)?
                .unwrap_or_else(ClusterSpec::a40_a100_demo);
            let freq = parse_fleet(rest, cluster)?;
            let service = PlanningService::new();
            let report = service.plan_fleet(&freq)?;
            if has_flag(rest, "--elastic") {
                // Incremental re-plan: warm-start from the carve just
                // found, fold in the elastic device loss, and show what
                // actually moved — the stability-first search keeps
                // every unaffected tenant's slice (and plan) in place.
                let (group, n) = parse_lose(rest)?;
                let replan = service.plan_fleet(
                    &freq
                        .clone()
                        .warm_start(&report.partition)
                        .device_lost(group, n),
                )?;
                if has_flag(rest, "--json") {
                    telemetry::report(&replan.to_json().render());
                    return Ok(());
                }
                telemetry::report(&format!(
                    "elastic re-plan after losing {n} device(s) of group \
                     {group}: carve {} -> {}",
                    report.partition.label(),
                    replan.partition.label()
                ));
                telemetry::report(replan.render().trim_end());
                for (name, d) in replan.diff_from(&report) {
                    telemetry::report(&format!(
                        "tenant {name}: {} change(s)",
                        d.delta_count()
                    ));
                    if !d.is_empty() {
                        telemetry::report(d.render().trim_end());
                    }
                }
                return Ok(());
            }
            if has_flag(rest, "--json") {
                telemetry::report(&report.to_json().render());
                return Ok(());
            }
            telemetry::report(report.render().trim_end());
            if has_flag(rest, "--vs-naive") {
                let naive = service
                    .plan_fleet_partition(&freq, &freq.naive_partition())?;
                telemetry::report(&format!(
                    "naive static split {}: {:.2} input/s -> searched \
                     carve {}: {:.2} input/s ({:+.1}%)",
                    naive.partition.label(),
                    naive.aggregate_throughput,
                    report.partition.label(),
                    report.aggregate_throughput,
                    (report.aggregate_throughput
                        / naive.aggregate_throughput
                        - 1.0)
                        * 100.0
                ));
            }
        }
        "diff" => {
            let service = PlanningService::new();
            let first = rest.first().map(|s| s.as_str()).unwrap_or("fleet");
            anyhow::ensure!(
                !first.starts_with("--"),
                "`cornstarch diff` wants `fleet` or an MLLM name before \
                 the flags (e.g. `diff fleet --cluster F` or `diff VLM-M \
                 --vs-devices 8`)"
            );
            if first == "fleet" {
                // Fleet mode: what the searched carve changed vs the
                // naive static split, tenant by tenant.
                let cluster = parse_cluster(rest)?
                    .unwrap_or_else(ClusterSpec::a40_a100_demo);
                let freq = parse_fleet(rest, cluster)?;
                let searched = service.plan_fleet(&freq)?;
                let naive = service
                    .plan_fleet_partition(&freq, &freq.naive_partition())?;
                telemetry::report(&format!(
                    "fleet diff on {} — naive static split {} -> searched \
                     carve {}",
                    freq.cluster.name,
                    naive.partition.label(),
                    searched.partition.label()
                ));
                for (name, d) in searched.diff_from(&naive) {
                    telemetry::report(&format!("tenant {name}:"));
                    telemetry::report(d.render().trim_end());
                }
                telemetry::report(&format!(
                    "aggregate: {:.2} -> {:.2} input/s ({:+.1}%)",
                    naive.aggregate_throughput,
                    searched.aggregate_throughput,
                    (searched.aggregate_throughput
                        / naive.aggregate_throughput
                        - 1.0)
                        * 100.0
                ));
            } else {
                // Single-model mode: the same workload tuned on two
                // clusters (or two pool sizes).
                let spec = parse_mllm(first, rest)?;
                let base_cluster = parse_cluster(rest)?
                    .unwrap_or_else(ClusterSpec::a40_default);
                let vs_cluster = match flag(rest, "--vs-cluster") {
                    Some(p) => ClusterSpec::load(std::path::Path::new(&p))
                        .with_context(|| {
                            format!("loading cluster spec {p}")
                        })?,
                    None => base_cluster.clone(),
                };
                let build = |cluster: ClusterSpec,
                             devices: Option<usize>|
                 -> Result<PlanReport> {
                    let mut req = PlanRequest::default_for(spec.clone())
                        .cluster(cluster);
                    if let Some(d) = devices {
                        req = req.devices(d);
                    }
                    if let Some(b) = flag_num(rest, "--budget")? {
                        req = req.budget(b);
                    }
                    if let Some(t) = flag_num(rest, "--threads")? {
                        req = req.threads(t);
                    }
                    if let Some(c) = flag(rest, "--cache") {
                        req = req.cache_file(&c);
                    }
                    Ok(service.plan(&req)?)
                };
                let before =
                    build(base_cluster, flag_num(rest, "--devices")?)?;
                let after =
                    build(vs_cluster, flag_num(rest, "--vs-devices")?)?;
                telemetry::report(&format!(
                    "{} — before -> after",
                    spec.name()
                ));
                telemetry::report(
                    PlanDiff::between(&before, &after).render().trim_end(),
                );
            }
        }
        "auto" => {
            let spec = parse_mllm(
                rest.first().map(|s| s.as_str()).unwrap_or("VALM-MM"),
                rest,
            )?;
            let groups = flag_num(rest, "--groups")?.unwrap_or(6);
            telemetry::report(
                coordinator::experiments::auto_frontier(&spec, groups)
                    .render()
                    .trim_end(),
            );
        }
        "attn-check" => {
            let artifact =
                flag(rest, "--artifact").unwrap_or_else(|| "attn512".into());
            let repeats = flag_num(rest, "--repeats")?.unwrap_or(5);
            telemetry::report(
                coordinator::attn_crosscheck(&artifact, repeats)?.trim_end(),
            );
        }
        "serve" => {
            // Planning as a long-lived service: newline-delimited JSON
            // over TCP (see `cornstarch::serve` for the protocol).
            // Requests share one process, so repeat queries answer from
            // the in-process plan-store tier and identical concurrent
            // queries coalesce onto one search.
            let addr =
                flag(rest, "--addr").unwrap_or_else(|| "127.0.0.1:7070".into());
            let opts = cornstarch::serve::ServeOpts {
                cache: flag(rest, "--cache"),
                cluster: parse_cluster(rest)?
                    .unwrap_or_else(ClusterSpec::a40_default),
                threads: flag_num(rest, "--threads")?.unwrap_or(0),
                max_requests: flag_num(rest, "--max-requests")?
                    .map(|n| n as u64),
            };
            let server = cornstarch::serve::Server::bind(&addr, opts)
                .with_context(|| format!("binding {addr}"))?;
            server.run().context("serving")?;
        }
        "list-models" => {
            let m = Manifest::load(Manifest::default_root())
                .context("run `make artifacts` first")?;
            for model in &m.models {
                telemetry::report(&format!(
                    "{:<10} tokens={} components={} llm_stages={}",
                    model.name,
                    model.total_tokens,
                    model.components.len(),
                    model.n_llm_stages()
                ));
            }
        }
        "help" | "--help" | "-h" => print_help(),
        other => bail!("unknown command {other:?} (try `cornstarch help`)"),
    }
    Ok(())
}

fn print_plan(plan: &Plan) {
    let m = plan.simulate();
    telemetry::report("  stages:");
    for (name, node) in plan.stage_names.iter().zip(&plan.graph.nodes) {
        telemetry::report(&format!(
            "    {:<16} dev {:<2} fwd {:>8.2} ms  bwd {:>8.2} ms",
            name, node.device, node.cost.fwd_ms, node.cost.bwd_ms
        ));
    }
    let (lo, hi) = plan.stage_time_range();
    telemetry::report(&format!(
        "  stage fwd+bwd range: {lo:.1} ~ {hi:.1} ms"
    ));
    telemetry::report(&format!(
        "  iteration {:.1} ms | {:.2} input/s | {:.3} input/s/GPU ({} GPUs) | bubble {:.1}%",
        m.iteration_ms,
        m.throughput,
        m.throughput_per_gpu,
        plan.n_gpus,
        m.bubble_ratio * 100.0
    ));
    telemetry::report(&format!(
        "  peak memory {:.1} GB/GPU (modeled)",
        memory::gb(plan.peak_device_bytes())
    ));
}

fn print_memory(plan: &Plan, budget_bytes: u64) {
    telemetry::report("  stages (per-GPU bytes from the memory model):");
    for (name, sm) in plan.stage_names.iter().zip(&plan.stage_mem) {
        telemetry::report(&format!(
            "    {:<16} params {:>6.2} GB  grads {:>6.2} GB  optim \
             {:>6.2} GB  act {:>6.2} GB/mb x{:<2}  peak {:>6.2} GB",
            name,
            memory::gb(sm.param_bytes),
            memory::gb(sm.grad_bytes),
            memory::gb(sm.optim_bytes),
            memory::gb(sm.act_bytes_per_mb),
            sm.in_flight,
            memory::gb(sm.peak_bytes())
        ));
    }
    let peak = plan.peak_device_bytes();
    match memory::check(plan, budget_bytes) {
        Ok(()) => telemetry::report(&format!(
            "  peak {:.2} GB/GPU — fits the {:.0} GB budget \
             ({:.1} GB headroom)",
            memory::gb(peak),
            memory::gb(budget_bytes),
            memory::gb(budget_bytes - peak)
        )),
        Err(e) => telemetry::report(&format!("  OOM: {e}")),
    }
}

fn print_help() {
    telemetry::report(
        "cornstarch — multimodality-aware distributed MLLM training \
         (paper reproduction)\n\n\
         commands:\n  \
         reproduce <exp|all>   regenerate paper tables/figures\n  \
         train [--model M] [--steps N] [--microbatches N] [--lr X]\n        \
         [--single-process] [--policy paper|all|frozen] [--log-json P]\n  \
         plan <MLLM> [--strategy S|tuned] [--llm-pp N] [--enc-pp N] [--tp N] [--cp N]\n        \
         [--cluster F] [--devices N] [--cache P]   (tuned strategy only)\n  \
         tune <MLLM> [--cluster F] [--devices N] [--budget K] [--cache P] [--threads N]\n        \
         [--objective makespan|tput-per-gpu] [--policy paper|all|frozen]\n        \
         [--sweep-policies] [--top N]   (top-N frontier from one search)\n  \
         stats <MLLM> [--cluster F] [--devices N] [--budget K] [--cache P] [--threads N]\n        \
         [--json]   (deterministic search counters for one plan() call)\n  \
         verify <MLLM> [--cluster F] [--devices N] [--budget K] [--cache P] [--threads N]\n        \
         [--json]   (static V001-V008 lints over the tuned plan; nonzero exit on Error)\n  \
         explain <MLLM> [--cluster F] [--devices N] [--budget K] [--cache P] [--threads N]\n        \
         [--json] [--vs-cluster F2] [--vs-devices M] [--profile F]\n        \
         (per-device compute/comm/idle, 1F1B phase bubbles, cp imbalance)\n  \
         calibrate [<model>] [--steps N] [--microbatches M] [--out F]\n        \
         [--device-class NAME] [--policy paper|all|frozen]\n        \
         (measure PJRT stage times into a CalibrationProfile JSON)\n  \
         memory <MLLM> [--strategy S] [--llm-pp N] [--enc-pp N] [--tp N] [--cp N]\n        \
         [--cluster F] [--microbatches N] [--budget-gb G]\n  \
         fleet [--cluster F] [--tenants VLM-L,ALM-M] [--floor X] [--budget K]\n        \
         [--cache P] [--threads N] [--vs-naive] [--json]\n        \
         [--search-mode exact|bnb|local|auto] [--search-evals N]\n        \
         [--elastic [--lose G:N]]   (multi-tenant pool carve; past the\n        \
         exhaustive cap the search degrades to bnb/local instead of\n        \
         erroring; --elastic warm-starts a re-plan after losing N\n        \
         devices of group G and diffs it against the incumbent)\n  \
         serve [--addr H:P] [--cluster F] [--cache P] [--threads N] [--max-requests N]\n        \
         (long-lived planning server: one JSON request/response per line)\n  \
         diff fleet [--cluster F] [--tenants ...] [--floor X]   (carve vs naive split)\n  \
         diff <MLLM> [--cluster F] [--vs-cluster F2] [--devices N] [--vs-devices M]\n        \
         (mode word or model first, then flags; bare `diff` = `diff fleet`)\n  \
         auto <MLLM> [--groups N]\n  \
         attn-check [--artifact attn512] [--repeats N]\n  \
         list-models\n\n\
         global flags (any command):\n  \
         --trace <file>        export spans/counters as Chrome trace-event JSON\n  \
         --quiet, -q           progress lines off (report output stays on stdout)\n  \
         -v, --verbose         per-wave search + cache-IO detail",
    );
}

/// `--cluster <file>`: load a JSON `ClusterSpec` (`None` when the flag is
/// absent — callers fall back to the A40 testbed default).
fn parse_cluster(args: &[String]) -> Result<Option<ClusterSpec>> {
    match flag(args, "--cluster") {
        Some(p) => {
            let spec = ClusterSpec::load(std::path::Path::new(&p))
                .with_context(|| format!("loading cluster spec {p}"))?;
            Ok(Some(spec))
        }
        None => Ok(None),
    }
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn flag_num(args: &[String], name: &str) -> Result<Option<usize>> {
    flag(args, name)
        .map(|v| v.parse::<usize>().map_err(|_| anyhow!("{name} wants a number, got {v:?}")))
        .transpose()
}

fn flag_f64(args: &[String], name: &str) -> Result<Option<f64>> {
    flag(args, name)
        .map(|v| {
            v.parse::<f64>()
                .map_err(|_| anyhow!("{name} wants a number, got {v:?}"))
        })
        .transpose()
}

/// Build a `FleetRequest` from `--tenants <MLLM,MLLM,…>` (default
/// `VLM-L,ALM-M` — the motivating pair: a VLM-L finetune sharing the
/// pool with a Whisper-encoder pretrain), `--floor`, and the usual
/// planning flags applied to every tenant. Duplicate workload names get
/// a `#i` suffix so tenant names stay unique. Without `--cache` the
/// fleet uses a shared temp-dir cache file, so `--vs-naive`, `diff
/// fleet`, and repeated runs reuse every sub-pool and solo plan instead
/// of re-searching (entries are keyed by the carve's fingerprint).
fn parse_fleet(rest: &[String], cluster: ClusterSpec) -> Result<FleetRequest> {
    let list = flag(rest, "--tenants")
        .unwrap_or_else(|| "VLM-L,ALM-M".to_string());
    let floor = flag_f64(rest, "--floor")?.unwrap_or(0.25);
    let cache = flag(rest, "--cache").unwrap_or_else(|| {
        // per-user default path: a fixed temp-dir name would collide
        // (and fail on permissions) between users sharing one machine
        let user = std::env::var("USER")
            .or_else(|_| std::env::var("USERNAME"))
            .unwrap_or_else(|_| "default".to_string());
        std::env::temp_dir()
            .join(format!("cornstarch-fleet-cache-{user}.json"))
            .to_string_lossy()
            .into_owned()
    });
    let mut freq = FleetRequest::new(cluster)
        .fairness_floor(floor)
        .cache_file(&cache);
    let mut names: Vec<String> = Vec::new();
    for (i, raw) in list.split(',').enumerate() {
        let mllm = raw.trim();
        anyhow::ensure!(
            !mllm.is_empty(),
            "empty tenant in --tenants {list:?}"
        );
        let spec = parse_mllm(mllm, rest)?;
        let name = if names.iter().any(|n| n.as_str() == mllm) {
            format!("{mllm}#{i}")
        } else {
            mllm.to_string()
        };
        names.push(name.clone());
        let mut preq = PlanRequest::default_for(spec);
        if let Some(b) = flag_num(rest, "--budget")? {
            preq = preq.budget(b);
        }
        if let Some(t) = flag_num(rest, "--threads")? {
            preq = preq.threads(t);
        }
        freq = freq.tenant(&name, preq);
    }
    if let Some(m) = flag(rest, "--search-mode") {
        if m != "auto" {
            let mode = SearchMode::parse(&m).ok_or_else(|| {
                anyhow!("bad --search-mode {m:?} (exact|bnb|local|auto)")
            })?;
            freq = freq.search_mode(mode);
        }
    }
    if let Some(cap) = flag_num(rest, "--search-evals")? {
        freq = freq.search_evals(cap);
    }
    Ok(freq)
}

/// `--lose G:N` for `fleet --elastic`: N devices of cluster group G are
/// gone (default `0:1` — one device of the first group).
fn parse_lose(args: &[String]) -> Result<(usize, usize)> {
    let raw = flag(args, "--lose").unwrap_or_else(|| "0:1".to_string());
    let parsed = raw.split_once(':').and_then(|(g, n)| {
        Some((g.trim().parse().ok()?, n.trim().parse().ok()?))
    });
    parsed.ok_or_else(|| anyhow!("--lose wants GROUP:N, got {raw:?}"))
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn parse_train(args: &[String]) -> Result<TrainOpts> {
    let mut o = TrainOpts::default();
    if let Some(m) = flag(args, "--model") {
        o.model = m;
    }
    if let Some(s) = flag_num(args, "--steps")? {
        o.steps = s;
    }
    if let Some(m) = flag_num(args, "--microbatches")? {
        o.microbatches = m;
    }
    if let Some(lr) = flag(args, "--lr") {
        o.lr = lr.parse().map_err(|_| anyhow!("bad --lr {lr:?}"))?;
    }
    if let Some(s) = flag_num(args, "--seed")? {
        o.seed = s as u64;
    }
    o.pipelined = !has_flag(args, "--single-process");
    o.log_json = flag(args, "--log-json");
    o.policy = match flag(args, "--policy").as_deref() {
        None | Some("paper") => FrozenPolicy::paper(),
        Some("all") => FrozenPolicy::all_trainable(),
        Some("frozen") => FrozenPolicy::all_frozen(),
        Some(p) => bail!("unknown policy {p:?} (paper|all|frozen)"),
    };
    Ok(o)
}

/// Parse `VLM-M` / `ALM-S` / `VALM-ML` (+ optional `--llm S|M|L`).
fn parse_mllm(name: &str, args: &[String]) -> Result<MllmSpec> {
    let llm = match flag(args, "--llm") {
        Some(s) => Size::parse(&s).ok_or_else(|| anyhow!("bad --llm {s:?}"))?,
        None => Size::M,
    };
    MllmSpec::parse_name(name, llm).map_err(|e| anyhow!(e))
}
