//! `cornstarch` — the leader CLI.
//!
//! ```text
//! cornstarch reproduce <exp|all>        regenerate a paper table/figure
//! cornstarch train [opts]               train a model over the artifacts
//! cornstarch plan <mllm> [opts]         print a parallelization plan
//! cornstarch auto <mllm> [--groups N]   Algorithm 1 frontier
//! cornstarch attn-check [--artifact A]  PJRT cross-check of the CP model
//! cornstarch list-models                artifacts available to `train`
//! ```
//!
//! `<mllm>` names follow §6.1: `VLM-M`, `ALM-L`, `VALM-SM`…, optionally
//! prefixed with an LLM size (`llm=S`).

use anyhow::{anyhow, bail, Context, Result};

use cornstarch::coordinator::{self, TrainOpts};
use cornstarch::cost::Device;
use cornstarch::modality::{
    planner, MultimodalModule, MultimodalParallelSpec, Strategy,
};
use cornstarch::model::{MllmSpec, Size};
use cornstarch::runtime::Manifest;
use cornstarch::train::FrozenPolicy;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "reproduce" => {
            let which = rest.first().map(|s| s.as_str()).unwrap_or("all");
            print!("{}", coordinator::reproduce(which)?);
        }
        "train" => {
            let opts = parse_train(rest)?;
            let losses = coordinator::train(&opts)?;
            let first = losses.first().copied().unwrap_or(f32::NAN);
            let last = losses.last().copied().unwrap_or(f32::NAN);
            println!("loss: {first:.4} -> {last:.4} over {} steps", losses.len());
        }
        "plan" => {
            let spec = parse_mllm(rest.first().map(|s| s.as_str()).unwrap_or("VLM-M"), rest)?;
            let strategy = match flag(rest, "--strategy").as_deref() {
                None | Some("cornstarch") => Strategy::Cornstarch,
                Some("colocated") => Strategy::Colocated,
                Some("replicated") => Strategy::Replicated,
                Some(s) => bail!("unknown strategy {s}"),
            };
            let llm_pp = flag_num(rest, "--llm-pp")?.unwrap_or(4);
            let enc_pp = flag_num(rest, "--enc-pp")?.unwrap_or(1);
            let mm = MultimodalModule::from_spec(&spec);
            let n_enc = mm.encoders.len();
            let ps = MultimodalParallelSpec::paper_default(
                &vec![enc_pp; n_enc],
                llm_pp,
                flag_num(rest, "--tp")?.unwrap_or(2),
                flag_num(rest, "--cp")?.unwrap_or(2),
            );
            let plan = planner::plan(strategy, &mm, &ps, Device::a40());
            let m = plan.simulate();
            println!("{} / {}", spec.name(), strategy.name());
            println!("  stages:");
            for (name, node) in plan.stage_names.iter().zip(&plan.graph.nodes)
            {
                println!(
                    "    {:<16} dev {:<2} fwd {:>8.2} ms  bwd {:>8.2} ms",
                    name, node.device, node.cost.fwd_ms, node.cost.bwd_ms
                );
            }
            let (lo, hi) = plan.stage_time_range();
            println!("  stage fwd+bwd range: {lo:.1} ~ {hi:.1} ms");
            println!(
                "  iteration {:.1} ms | {:.2} input/s | {:.3} input/s/GPU ({} GPUs) | bubble {:.1}%",
                m.iteration_ms,
                m.throughput,
                m.throughput_per_gpu,
                plan.n_gpus,
                m.bubble_ratio * 100.0
            );
        }
        "auto" => {
            let spec = parse_mllm(
                rest.first().map(|s| s.as_str()).unwrap_or("VALM-MM"),
                rest,
            )?;
            let groups = flag_num(rest, "--groups")?.unwrap_or(6);
            print!(
                "{}",
                coordinator::experiments::auto_frontier(&spec, groups)
                    .render()
            );
        }
        "attn-check" => {
            let artifact =
                flag(rest, "--artifact").unwrap_or_else(|| "attn512".into());
            let repeats = flag_num(rest, "--repeats")?.unwrap_or(5);
            print!("{}", coordinator::attn_crosscheck(&artifact, repeats)?);
        }
        "list-models" => {
            let m = Manifest::load(Manifest::default_root())
                .context("run `make artifacts` first")?;
            for model in &m.models {
                println!(
                    "{:<10} tokens={} components={} llm_stages={}",
                    model.name,
                    model.total_tokens,
                    model.components.len(),
                    model.n_llm_stages()
                );
            }
        }
        "help" | "--help" | "-h" => print_help(),
        other => bail!("unknown command {other:?} (try `cornstarch help`)"),
    }
    Ok(())
}

fn print_help() {
    println!(
        "cornstarch — multimodality-aware distributed MLLM training \
         (paper reproduction)\n\n\
         commands:\n  \
         reproduce <exp|all>   regenerate paper tables/figures\n  \
         train [--model M] [--steps N] [--microbatches N] [--lr X]\n        \
         [--single-process] [--policy paper|all|frozen] [--log-json P]\n  \
         plan <MLLM> [--strategy S] [--llm-pp N] [--enc-pp N] [--tp N] [--cp N]\n  \
         auto <MLLM> [--groups N]\n  \
         attn-check [--artifact attn512] [--repeats N]\n  \
         list-models"
    );
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn flag_num(args: &[String], name: &str) -> Result<Option<usize>> {
    flag(args, name)
        .map(|v| v.parse::<usize>().map_err(|_| anyhow!("{name} wants a number, got {v:?}")))
        .transpose()
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn parse_train(args: &[String]) -> Result<TrainOpts> {
    let mut o = TrainOpts::default();
    if let Some(m) = flag(args, "--model") {
        o.model = m;
    }
    if let Some(s) = flag_num(args, "--steps")? {
        o.steps = s;
    }
    if let Some(m) = flag_num(args, "--microbatches")? {
        o.microbatches = m;
    }
    if let Some(lr) = flag(args, "--lr") {
        o.lr = lr.parse().map_err(|_| anyhow!("bad --lr {lr:?}"))?;
    }
    if let Some(s) = flag_num(args, "--seed")? {
        o.seed = s as u64;
    }
    o.pipelined = !has_flag(args, "--single-process");
    o.log_json = flag(args, "--log-json");
    o.policy = match flag(args, "--policy").as_deref() {
        None | Some("paper") => FrozenPolicy::paper(),
        Some("all") => FrozenPolicy::all_trainable(),
        Some("frozen") => FrozenPolicy::all_frozen(),
        Some(p) => bail!("unknown policy {p:?} (paper|all|frozen)"),
    };
    Ok(o)
}

/// Parse `VLM-M` / `ALM-S` / `VALM-ML` (+ optional `--llm S|M|L`).
fn parse_mllm(name: &str, args: &[String]) -> Result<MllmSpec> {
    let llm = match flag(args, "--llm") {
        Some(s) => Size::parse(&s).ok_or_else(|| anyhow!("bad --llm {s:?}"))?,
        None => Size::M,
    };
    let (kind, sizes) = name
        .split_once('-')
        .ok_or_else(|| anyhow!("bad MLLM name {name:?} (e.g. VLM-M, VALM-SL)"))?;
    let parse1 = |s: &str| {
        Size::parse(s).ok_or_else(|| anyhow!("bad size {s:?} in {name:?}"))
    };
    Ok(match kind {
        "VLM" => MllmSpec::vlm(llm, parse1(sizes)?),
        "ALM" => MllmSpec::alm(llm, parse1(sizes)?),
        "VALM" => {
            anyhow::ensure!(sizes.len() == 2, "VALM wants two sizes (e.g. VALM-ML)");
            MllmSpec::valm(llm, parse1(&sizes[0..1])?, parse1(&sizes[1..2])?)
        }
        _ => bail!("unknown MLLM kind {kind:?}"),
    })
}
