//! Per-layer byte-count formulas — the geometry-level core of the memory
//! model.
//!
//! Every figure is **per GPU**. Parameter-class bytes (weights, gradients,
//! AdamW states) shard by TP; CP ranks replicate weights, so CP never
//! appears in the static terms. Activation bytes shard by CP through the
//! token dimension and only *partially* by TP: with sequence parallelism
//! off (the paper's §6.1 setup) the residual/norm stream is replicated
//! across TP ranks while the attention/MLP internals shard.
//!
//! The activation footprint follows the per-layer accounting of
//! "Reducing Activation Recomputation in Large Transformer Models"
//! (Korthikanti et al., 2022); see [`layer_act_bytes`].

use crate::model::ModuleGeom;

/// Weights are bf16 (§6.1).
pub const PARAM_BYTES: u64 = 2;
/// Gradients live in the parameter dtype.
pub const GRAD_BYTES: u64 = 2;
/// AdamW keeps 2 fp32 states (first + second moment) per trainable
/// parameter. The fp32 master copy of full mixed-precision recipes is
/// deliberately not counted (see DESIGN.md "what is ignored").
pub const ADAMW_STATE_BYTES: u64 = 8;

/// Activation bytes per token per hidden unit that every TP rank keeps
/// (residual stream, layernorm inputs — unsharded without sequence
/// parallelism).
const ACT_REPLICATED_PER_HIDDEN: f64 = 10.0;
/// Activation bytes per token per hidden unit inside the attention/MLP
/// blocks, which shard by TP.
const ACT_SHARDED_PER_HIDDEN: f64 = 24.0;
/// Score/softmax/dropout working-set bytes per (query, key) pair per
/// head; shards by TP's head split.
const ACT_ATTN_PER_PAIR: f64 = 5.0;

/// One layer's per-GPU memory footprint — the memory-side mirror of
/// [`crate::pipeline::LayerCost`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LayerMemory {
    pub param_bytes: u64,
    /// 0 for frozen layers (§4.2: no parameter gradients are produced).
    pub grad_bytes: u64,
    /// 0 for frozen layers (no optimizer states are allocated).
    pub optim_bytes: u64,
    /// Resident bytes per in-flight microbatch.
    pub act_bytes: u64,
}

impl LayerMemory {
    /// Bytes resident regardless of schedule position.
    pub fn static_bytes(&self) -> u64 {
        self.param_bytes + self.grad_bytes + self.optim_bytes
    }
}

/// Parameters of ONE dense transformer layer — the same `4h² + 2·h·ff`
/// counting as [`ModuleGeom::params`], per layer.
pub fn layer_param_count(geom: &ModuleGeom) -> u64 {
    let h = geom.hidden as u64;
    let f = geom.d_ff as u64;
    4 * h * h + 2 * h * f
}

/// Activation bytes one microbatch keeps resident on one GPU for one
/// transformer layer:
///
/// ```text
/// h·t_local·(10 + 24/tp)  +  5·heads·t_local·t_full/tp
/// ```
///
/// * `t_local = ceil(tokens/cp)` — CP shards the token dimension;
/// * the residual/norm stream (`10·h` bytes/token) is replicated across
///   TP ranks, the attention/MLP internals (`24·h`) shard by TP;
/// * the score/softmax/dropout working set (`5` bytes per (query, key)
///   pair per head) shards by TP's head split; its key side spans the
///   full sequence — CP ranks stream K/V but keep their local score rows
///   resident for backward.
///
/// Gradient checkpointing is charged on the *time* side only
/// ([`crate::cost::GradFlow::bwd_ms`]); its memory saving is deliberately
/// not modeled — the conservative choice that reproduces Appendix D's
/// OOM verdicts (see DESIGN.md).
pub fn layer_act_bytes(
    geom: &ModuleGeom,
    tokens: usize,
    tp: usize,
    cp: usize,
    microbatch_size: usize,
) -> u64 {
    let t_local = tokens.div_ceil(cp) as f64;
    let h = geom.hidden as f64;
    let heads = geom.n_heads as f64;
    let tp_f = tp as f64;
    let linear = h
        * t_local
        * (ACT_REPLICATED_PER_HIDDEN + ACT_SHARDED_PER_HIDDEN / tp_f);
    let attn = ACT_ATTN_PER_PAIR * heads * t_local * tokens as f64 / tp_f;
    ((linear + attn) * microbatch_size as f64).round() as u64
}

/// Memory of one transformer body layer on one GPU.
pub fn body_layer_memory(
    geom: &ModuleGeom,
    tokens: usize,
    tp: usize,
    cp: usize,
    microbatch_size: usize,
    trainable: bool,
) -> LayerMemory {
    let p = layer_param_count(geom).div_ceil(tp as u64);
    LayerMemory {
        param_bytes: p * PARAM_BYTES,
        grad_bytes: if trainable { p * GRAD_BYTES } else { 0 },
        optim_bytes: if trainable { p * ADAMW_STATE_BYTES } else { 0 },
        act_bytes: layer_act_bytes(geom, tokens, tp, cp, microbatch_size),
    }
}

/// The projector pseudo-layer (one `d_in × d_out` linear, §6.1). Its
/// input and output activations sit on the boundary between modules and
/// are kept unsharded; its weight shards by TP like any linear.
pub fn projector_memory(
    d_in: usize,
    d_out: usize,
    tokens: usize,
    tp: usize,
    cp: usize,
    microbatch_size: usize,
    trainable: bool,
) -> LayerMemory {
    let p = (d_in as u64 * d_out as u64).div_ceil(tp as u64);
    let t_local = tokens.div_ceil(cp) as u64;
    LayerMemory {
        param_bytes: p * PARAM_BYTES,
        grad_bytes: if trainable { p * GRAD_BYTES } else { 0 },
        optim_bytes: if trainable { p * ADAMW_STATE_BYTES } else { 0 },
        act_bytes: t_local
            * (d_in + d_out) as u64
            * PARAM_BYTES
            * microbatch_size as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{llama, Size};

    #[test]
    fn llama_8b_weights_are_params_times_two_bytes() {
        // Table 1: Llama-3.1-M (≈8b) is 32 layers of
        // 4·4096² + 2·4096·16384 = 201,326,592 params; bf16 weights are
        // 2 bytes each.
        let g = llama(Size::M);
        assert_eq!(layer_param_count(&g), 201_326_592);
        let l = body_layer_memory(&g, 2024, 1, 1, 1, false);
        assert_eq!(l.param_bytes, 2 * 201_326_592);
        // whole-module weights equal ModuleGeom::params × 2 bytes
        assert_eq!(32 * l.param_bytes, 2 * g.params());
    }

    #[test]
    fn frozen_layer_is_weights_only() {
        let g = llama(Size::M);
        let l = body_layer_memory(&g, 1000, 2, 1, 1, false);
        assert_eq!(l.grad_bytes, 0);
        assert_eq!(l.optim_bytes, 0);
        assert_eq!(l.static_bytes(), l.param_bytes);
    }

    #[test]
    fn trainable_layer_pays_grads_and_two_adamw_states() {
        let g = llama(Size::M);
        let l = body_layer_memory(&g, 1000, 2, 1, 1, true);
        let p = layer_param_count(&g).div_ceil(2);
        assert_eq!(l.grad_bytes, GRAD_BYTES * p);
        // AdamW: m + v in fp32 = 8 bytes per trainable param.
        assert_eq!(l.optim_bytes, 8 * p);
        assert_eq!(l.optim_bytes, ADAMW_STATE_BYTES * p);
    }

    #[test]
    fn tp_shards_weights_cp_does_not() {
        let g = llama(Size::L);
        let t1 = body_layer_memory(&g, 2024, 1, 1, 1, false);
        let t4 = body_layer_memory(&g, 2024, 4, 1, 1, false);
        assert_eq!(t1.param_bytes, 4 * t4.param_bytes);
        let c2 = body_layer_memory(&g, 2024, 1, 2, 1, false);
        assert_eq!(t1.param_bytes, c2.param_bytes);
        // ...while CP halves the activation footprint's token dimension.
        assert!(c2.act_bytes < t1.act_bytes);
    }

    #[test]
    fn tp_shards_activations_only_partially() {
        // Doubling TP must shrink activations by LESS than 2x: the
        // residual stream is replicated (sequence parallelism off).
        let g = llama(Size::M);
        let t1 = body_layer_memory(&g, 2024, 1, 1, 1, false);
        let t2 = body_layer_memory(&g, 2024, 2, 1, 1, false);
        assert!(t2.act_bytes < t1.act_bytes);
        assert!(2 * t2.act_bytes > t1.act_bytes);
    }

    #[test]
    fn projector_is_small_and_follows_trainability() {
        let frozen = projector_memory(1024, 4096, 577, 2, 1, 1, false);
        let train = projector_memory(1024, 4096, 577, 2, 1, 1, true);
        assert_eq!(frozen.param_bytes, train.param_bytes);
        assert_eq!(frozen.grad_bytes, 0);
        assert!(train.grad_bytes > 0 && train.optim_bytes > 0);
        // a single linear is megabytes, not gigabytes
        assert!(train.static_bytes() < 100_000_000);
    }

    #[test]
    fn act_bytes_scale_with_microbatch_size() {
        let g = llama(Size::S);
        let a1 = layer_act_bytes(&g, 1500, 2, 2, 1);
        let a3 = layer_act_bytes(&g, 1500, 2, 2, 3);
        assert_eq!(a3, 3 * a1);
    }
}
