//! Frozen-aware, sharding-aware per-device memory accounting — the
//! capacity side of §4.2 and Appendix D.
//!
//! The time model ([`crate::cost`]) decides how *fast* a plan is; this
//! module decides whether a plan **fits** at all. For every pipeline
//! stage it estimates peak per-GPU bytes as
//!
//! ```text
//! peak = params + grads + optimizer states      (static; frozen ⇒ weights
//!        ---------------------------------       only, all ÷ TP degree)
//!      + act_per_microbatch × in_flight          (1F1B warm-up window:
//!                                                 in_flight = min(m, depth
//!                                                 to sink), tokens ÷ CP)
//! ```
//!
//! Consumers:
//!
//! * [`crate::modality::planner`] fills [`Plan::stage_mem`] for every
//!   plan it builds, so every simulated configuration carries its memory
//!   verdict;
//! * [`crate::tuner::space::enumerate`] rejects candidates whose modeled
//!   peak exceeds the device budget *before* they are ever simulated —
//!   what makes the joint microbatch sweep meaningful;
//! * `cornstarch memory <mllm>` prints the per-stage breakdown, and
//!   `reproduce memory` regenerates the Appendix D feasibility verdicts
//!   (LLM-L at tp=4: CP off exceeds the 40 GB A40 budget, cp=2 fits).
//!
//! [`Plan::stage_mem`]: crate::modality::planner::Plan

pub mod model;

pub use model::{
    body_layer_memory, layer_act_bytes, layer_param_count,
    projector_memory, LayerMemory, ADAMW_STATE_BYTES, GRAD_BYTES,
    PARAM_BYTES,
};

use anyhow::{bail, Result};

use crate::modality::planner::Plan;
use crate::modality::{ModalityModule, MultimodalModule, ParallelSpec};
use crate::model::ModuleGeom;
use crate::pipeline::StageGraph;

/// Bytes → decimal gigabytes, for tables and error messages.
pub fn gb(bytes: u64) -> f64 {
    bytes as f64 / 1e9
}

/// Aggregate memory of one pipeline stage on ONE of its GPUs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageMemory {
    pub param_bytes: u64,
    pub grad_bytes: u64,
    pub optim_bytes: u64,
    /// Activation bytes per in-flight microbatch.
    pub act_bytes_per_mb: u64,
    /// In-flight microbatches under 1F1B (`min(m, depth-to-sink)`); set
    /// by [`assign_in_flight`] once the stage DAG is known.
    pub in_flight: usize,
}

impl StageMemory {
    pub fn add_layer(&mut self, l: &LayerMemory) {
        self.param_bytes += l.param_bytes;
        self.grad_bytes += l.grad_bytes;
        self.optim_bytes += l.optim_bytes;
        self.act_bytes_per_mb += l.act_bytes;
    }

    /// Accumulate another stage's whole footprint (colocated stage
    /// fusion, encoders-replicated redundancy).
    pub fn absorb(&mut self, o: &StageMemory) {
        self.param_bytes += o.param_bytes;
        self.grad_bytes += o.grad_bytes;
        self.optim_bytes += o.optim_bytes;
        self.act_bytes_per_mb += o.act_bytes_per_mb;
    }

    /// Bytes resident regardless of schedule position.
    pub fn static_bytes(&self) -> u64 {
        self.param_bytes + self.grad_bytes + self.optim_bytes
    }

    /// Peak activation bytes (warm-up window full).
    pub fn activation_bytes(&self) -> u64 {
        self.act_bytes_per_mb * self.in_flight as u64
    }

    /// Peak per-GPU bytes of this stage.
    pub fn peak_bytes(&self) -> u64 {
        self.static_bytes() + self.activation_bytes()
    }
}

/// Per-layer memory rows of one encoder: body layers then the trailing
/// projector pseudo-layer — index-aligned with
/// [`crate::modality::planner::encoder_layer_costs`], so the same
/// partition bounds can sum both time and memory.
pub fn encoder_layer_memory(
    e: &ModalityModule,
    llm_geom: &ModuleGeom,
    ps: &ParallelSpec,
    microbatch_size: usize,
) -> Vec<LayerMemory> {
    let mut out: Vec<LayerMemory> = (0..e.geom.n_layers)
        .map(|_| {
            body_layer_memory(
                &e.geom,
                e.tokens,
                ps.tp,
                ps.cp,
                microbatch_size,
                !e.frozen,
            )
        })
        .collect();
    out.push(projector_memory(
        e.geom.hidden,
        llm_geom.hidden,
        e.tokens,
        ps.tp,
        ps.cp,
        microbatch_size,
        e.projector_trainable,
    ));
    out
}

/// Per-layer memory rows of the LLM — aligned with
/// [`crate::modality::planner::llm_layer_costs`].
pub fn llm_layer_memory(
    mm: &MultimodalModule,
    ps: &ParallelSpec,
    microbatch_size: usize,
) -> Vec<LayerMemory> {
    (0..mm.llm.geom.n_layers)
        .map(|_| {
            body_layer_memory(
                &mm.llm.geom,
                mm.llm.tokens,
                ps.tp,
                ps.cp,
                microbatch_size,
                !mm.llm.frozen,
            )
        })
        .collect()
}

/// Sum per-layer rows into per-stage footprints for the partition
/// `bounds` (same convention as [`crate::pipeline::stage_sums`]).
/// `in_flight` is left 0 — call [`assign_in_flight`] once the DAG exists.
pub fn stage_sums(
    mems: &[LayerMemory],
    bounds: &[usize],
) -> Vec<StageMemory> {
    bounds
        .windows(2)
        .map(|w| {
            let mut s = StageMemory::default();
            for l in &mems[w[0]..w[1]] {
                s.add_layer(l);
            }
            s
        })
        .collect()
}

/// 1F1B warm-up accounting: stage `s` admits `min(m, depth_to_sink(s))`
/// microbatches before its first backward frees an activation set —
/// exactly the schedule's activation token
/// ([`crate::pipeline::onef1b_tasks`] gates `Fwd(s, m)` on
/// `Bwd(s, m - depth_to_sink(s))`).
pub fn assign_in_flight(
    mem: &mut [StageMemory],
    graph: &StageGraph,
    microbatches: usize,
) {
    debug_assert_eq!(mem.len(), graph.nodes.len());
    for (sm, depth) in mem.iter_mut().zip(graph.depth_to_sink()) {
        sm.in_flight = microbatches.min(depth);
    }
}

/// Peak per-GPU bytes across a set of stages (each stage is one `tp×cp`
/// device group; all figures are already per GPU).
pub fn peak_device_bytes(stage_mem: &[StageMemory]) -> u64 {
    stage_mem.iter().map(|s| s.peak_bytes()).max().unwrap_or(0)
}

/// Hold a plan to a per-GPU budget; the error names the worst stage and
/// its breakdown, so a failed check reads like an OOM report.
pub fn check(plan: &Plan, budget_bytes: u64) -> Result<()> {
    let Some((idx, worst)) = plan
        .stage_mem
        .iter()
        .enumerate()
        .max_by_key(|(_, s)| s.peak_bytes())
    else {
        return Ok(());
    };
    if worst.peak_bytes() > budget_bytes {
        let name = plan
            .stage_names
            .get(idx)
            .map(String::as_str)
            .unwrap_or("?");
        bail!(
            "stage {idx} ({name}) needs {:.2} GB ({:.2} GB static + \
             {:.2} GB/microbatch × {} in flight) > {:.2} GB budget",
            gb(worst.peak_bytes()),
            gb(worst.static_bytes()),
            gb(worst.act_bytes_per_mb),
            worst.in_flight,
            gb(budget_bytes)
        );
    }
    Ok(())
}

/// Per-stage memory budgets under a (possibly heterogeneous) cluster:
/// each stage's verdict is held to the budget of the device group it
/// actually lands on (`Plan::stage_groups`). A plan without recorded
/// groups (legacy homogeneous construction) is budgeted on group 0.
pub fn stage_budgets(
    plan: &Plan,
    cluster: &crate::api::ClusterSpec,
) -> Vec<u64> {
    (0..plan.stage_mem.len())
        .map(|i| {
            let g = plan.stage_groups.get(i).copied().unwrap_or(0);
            cluster.group_mem_bytes(g)
        })
        .collect()
}

/// Does every stage fit both the budget of the device group it lands on
/// AND an optional caller-imposed cap (`None` disables the check
/// entirely)? This is the tuner's heterogeneous capacity filter — the
/// cap is the search space's scalar `memory_budget_bytes`, which a
/// caller may set *tighter* than any group's budget; the per-stage
/// budget is always the minimum of the two.
pub fn fits_assigned(
    plan: &Plan,
    cluster: &crate::api::ClusterSpec,
    cap: Option<u64>,
) -> bool {
    let Some(cap) = cap else {
        return true;
    };
    plan.stage_mem
        .iter()
        .zip(stage_budgets(plan, cluster))
        .all(|(sm, budget)| sm.peak_bytes() <= budget.min(cap))
}

/// Hold every stage of a plan to the budget of the device it lands on —
/// the heterogeneous-pools generalization of [`check`]. The error names
/// the first over-budget stage and the group whose budget it broke.
pub fn check_assigned(
    plan: &Plan,
    cluster: &crate::api::ClusterSpec,
) -> Result<()> {
    let budgets = stage_budgets(plan, cluster);
    for (idx, (sm, &budget)) in
        plan.stage_mem.iter().zip(&budgets).enumerate()
    {
        if sm.peak_bytes() > budget {
            let name = plan
                .stage_names
                .get(idx)
                .map(String::as_str)
                .unwrap_or("?");
            let g = plan.stage_groups.get(idx).copied().unwrap_or(0);
            bail!(
                "stage {idx} ({name}) needs {:.2} GB ({:.2} GB static + \
                 {:.2} GB/microbatch × {} in flight) > {:.2} GB budget of \
                 group {g} ({})",
                gb(sm.peak_bytes()),
                gb(sm.static_bytes()),
                gb(sm.act_bytes_per_mb),
                sm.in_flight,
                gb(budget),
                cluster.groups[g].device.name
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::Device;
    use crate::modality::{planner, MultimodalParallelSpec, Strategy};
    use crate::model::{MllmSpec, Size};
    use crate::util::check::check as prop_check;

    fn plan_for(
        spec: &MllmSpec,
        strategy: Strategy,
        enc_pp: usize,
        llm_pp: usize,
        tp: usize,
        cp: usize,
        m: usize,
    ) -> Plan {
        planner::plan_uniform(
            strategy,
            spec,
            enc_pp,
            llm_pp,
            tp,
            cp,
            m,
            Device::a40(),
        )
    }

    #[test]
    fn frozen_recipe_holds_weights_but_no_optimizer_for_bodies() {
        // Paper recipe: encoder + LLM frozen, projector trainable. Only
        // the projector may contribute grads/optimizer bytes anywhere.
        let p = plan_for(
            &MllmSpec::vlm(Size::M, Size::M),
            Strategy::Cornstarch,
            1,
            3,
            2,
            2,
            24,
        );
        for sm in &p.stage_mem {
            assert!(sm.param_bytes > 0);
            // grads/optim only ever come from the tiny projector
            assert!(sm.grad_bytes + sm.optim_bytes < sm.param_bytes / 10);
        }
    }

    #[test]
    fn stage_memory_is_aligned_with_the_graph_and_warmup() {
        let p = plan_for(
            &MllmSpec::valm(Size::M, Size::M, Size::M),
            Strategy::Cornstarch,
            1,
            4,
            2,
            2,
            24,
        );
        assert_eq!(p.stage_mem.len(), p.graph.nodes.len());
        for (sm, depth) in p.stage_mem.iter().zip(p.graph.depth_to_sink())
        {
            assert_eq!(sm.in_flight, depth.min(24));
            assert!(sm.act_bytes_per_mb > 0);
        }
        // a 2-microbatch run caps every window at 2
        let p2 = plan_for(
            &MllmSpec::valm(Size::M, Size::M, Size::M),
            Strategy::Cornstarch,
            1,
            4,
            2,
            2,
            2,
        );
        assert!(p2.stage_mem.iter().all(|s| s.in_flight <= 2));
        assert!(p2.peak_device_bytes() <= p.peak_device_bytes());
    }

    #[test]
    fn replicated_pays_encoder_weights_on_every_stage() {
        let spec = MllmSpec::vlm(Size::M, Size::L);
        let rep =
            plan_for(&spec, Strategy::Replicated, 0, 4, 2, 2, 24);
        let cs = plan_for(&spec, Strategy::Cornstarch, 1, 4, 2, 2, 24);
        // cornstarch's LLM stages hold a quarter of the LLM each; every
        // replicated stage additionally holds the WHOLE encoder.
        let cs_llm_params = cs.stage_mem.last().unwrap().param_bytes;
        for sm in &rep.stage_mem {
            assert!(
                sm.param_bytes > cs_llm_params,
                "replicated stage {} vs cornstarch llm stage {}",
                sm.param_bytes,
                cs_llm_params
            );
        }
    }

    #[test]
    fn check_reports_the_worst_stage() {
        let p = plan_for(
            &MllmSpec::vlm(Size::M, Size::M),
            Strategy::Cornstarch,
            1,
            3,
            2,
            2,
            24,
        );
        assert!(check(&p, u64::MAX).is_ok());
        let err = check(&p, 1).unwrap_err().to_string();
        assert!(err.contains("GB budget"), "{err}");
        assert!(err.contains("in flight"), "{err}");
    }

    #[test]
    fn assigned_check_uses_each_stages_group_budget() {
        use crate::api::ClusterSpec;
        use crate::modality::MultimodalModule;

        let cluster = ClusterSpec::a40_a100_demo();
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let mm = MultimodalModule::from_spec(&spec);
        let ps = MultimodalParallelSpec::paper_default(&[1], 2, 2, 2);
        let plan = planner::plan_assigned(
            Strategy::Cornstarch,
            &mm,
            &ps,
            &cluster,
            &[0, 1],
        );
        let budgets = stage_budgets(&plan, &cluster);
        assert_eq!(budgets.len(), plan.stage_mem.len());
        assert_eq!(budgets[0], cluster.group_mem_bytes(0));
        assert_eq!(budgets[1], cluster.group_mem_bytes(1));
        assert!(budgets[1] > budgets[0], "demo premise: A100 has more");
        // shrink the A40 group below the encoder stage's peak: the
        // assigned check must name the encoder stage and the A40 group,
        // while the flat check against the pool max would still pass
        let mut tight = cluster.clone();
        tight.groups[0].device.mem_bytes =
            plan.stage_mem[0].peak_bytes() - 1;
        let err = check_assigned(&plan, &tight).unwrap_err().to_string();
        assert!(err.contains("enc:vision[0]"), "{err}");
        assert!(err.contains("group 0"), "{err}");
        assert!(check(&plan, tight.mem_budget_bytes()).is_ok());
        assert!(check_assigned(&plan, &cluster).is_ok());
    }

    #[test]
    fn trainable_policy_costs_more_than_frozen() {
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let mm_frozen = MultimodalModule::from_spec(&spec);
        let mut mm_train = mm_frozen.clone();
        mm_train.llm.frozen = false;
        for e in &mut mm_train.encoders {
            e.frozen = false;
        }
        let ps = MultimodalParallelSpec::paper_default(&[1], 3, 2, 2);
        let d = Device::a40();
        let frozen =
            planner::plan(Strategy::Cornstarch, &mm_frozen, &ps, d);
        let train = planner::plan(Strategy::Cornstarch, &mm_train, &ps, d);
        assert!(
            train.peak_device_bytes() > frozen.peak_device_bytes(),
            "full fine-tuning must need more memory"
        );
    }

    #[test]
    fn peak_is_monotone_in_microbatches_and_antitone_in_tp_cp() {
        prop_check("memory monotonicity", 30, |g| {
            let spec = match g.usize(0, 3) {
                0 => MllmSpec::vlm(Size::M, Size::M),
                1 => MllmSpec::alm(Size::M, Size::L),
                _ => MllmSpec::valm(Size::S, Size::M, Size::M),
            };
            let enc_pp = g.usize(1, 4);
            let llm_pp = g.usize(1, 5);
            let tp = 1 << g.usize(0, 3);
            let cp = 1 << g.usize(0, 2);
            let m = g.usize(1, 33);
            let peak = |tp: usize, cp: usize, m: usize| {
                plan_for(
                    &spec,
                    Strategy::Cornstarch,
                    enc_pp,
                    llm_pp,
                    tp,
                    cp,
                    m,
                )
                .peak_device_bytes()
            };
            let base = peak(tp, cp, m);
            assert!(peak(tp, cp, m + 1) >= base, "peak not monotone in m");
            assert!(
                peak(2 * tp, cp, m) <= base,
                "peak increased with TP degree"
            );
            assert!(
                peak(tp, 2 * cp, m) <= base,
                "peak increased with CP degree"
            );
        });
    }
}
