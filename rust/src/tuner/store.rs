//! The two-tier plan store: an in-process concurrent map over the
//! persistent JSON cache, plus in-flight search dedupe.
//!
//! [`super::cache::PlanCache`] stays the durable tier — one JSON file,
//! atomic rename, per-path save lock — but every `tune` used to re-read
//! and re-parse that whole file, and two identical queries racing each
//! other both paid a full search. A [`PlanStore`] fixes both:
//!
//! * **Tier 1** — a sharded `RwLock` map keyed by the full
//!   `(signature, cluster)` pair (the same key
//!   [`PlanCache::lookup`] requires), warmed from disk once per
//!   process and per external invalidation
//!   ([`PlanStore::invalidate_path`]); hits never touch disk, and the
//!   per-entry verification gate (the V005 assignment lints) is
//!   memoized so a hot entry is linted once, not per request.
//! * **Tier 2** — writes batch through [`PlanCache::save`]'s existing
//!   per-path lock: publishers enqueue, one flusher drains the queue
//!   into a single load-merge-rename; a failed flush re-enqueues so a
//!   later publish retries.
//! * **Flights** — concurrent requests for the same `(signature, top)`
//!   coalesce: the first becomes the *leader* and searches; followers
//!   block on the flight and clone the leader's outcome (counted as
//!   [`crate::telemetry::key::INFLIGHT_JOIN`] + a cache hit — K
//!   identical requests cost exactly one search). A leader that
//!   unwinds without completing (panic) fails its followers instead of
//!   deadlocking them.
//!
//! Stores are process-wide: [`PlanStore::for_path`] returns the one
//! store for a given file (keyed by the same canonicalized path as the
//! save lock), [`PlanStore::process_memory`] the one disk-less store
//! shared by everything that opted into in-memory sharing, and
//! [`PlanStore::private`] a fresh throwaway (the `cache_path: None`
//! "search every time" contract).

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};

use crate::api::ClusterSpec;
use crate::telemetry;

use super::cache::{lock_key, CacheEntry, PlanCache};
use super::{TuneError, TuneOutcome};

/// Shard count for the tier-1 map; a power of two comfortably above
/// the thread counts we serve so hot signatures rarely contend.
const SHARDS: usize = 16;

/// A tier-1 entry with its verification verdict memoized: the V005
/// assignment lints run once per entry per process (the first lookup
/// pays), not once per request. Sound because the map key includes the
/// cluster fingerprint — every lookup that can reach this entry
/// presents a cluster the lints price identically.
struct VerifiedEntry {
    entry: CacheEntry,
    verified: OnceLock<bool>,
}

/// One in-flight search other identical requests can join. Followers
/// hold one via [`FlightHandle`] and block in [`Flight::wait_outcome`].
#[derive(Default)]
pub struct Flight {
    done: Mutex<Option<Result<TuneOutcome, TuneError>>>,
    cvar: Condvar,
}

impl Flight {
    fn wait(&self) -> Result<TuneOutcome, TuneError> {
        let mut slot = self.done.lock().unwrap();
        while slot.is_none() {
            slot = self.cvar.wait(slot).unwrap();
        }
        slot.clone().expect("flight completed")
    }

    fn complete(&self, result: Result<TuneOutcome, TuneError>) {
        *self.done.lock().unwrap() = Some(result);
        self.cvar.notify_all();
    }
}

/// One tier-1 shard: `(signature, cluster-fingerprint)` → entry.
type Shard = RwLock<HashMap<(String, String), Arc<VerifiedEntry>>>;

struct StoreInner {
    path: Option<PathBuf>,
    shards: Vec<Shard>,
    /// Tier-1 reflects the disk tier (fast-flag + warm lock so exactly
    /// one thread pays the load).
    warmed: AtomicBool,
    warm_lock: Mutex<()>,
    /// Entries published but not yet flushed to disk.
    pending: Mutex<Vec<CacheEntry>>,
    /// Serializes flushers so concurrent publishers batch: whoever
    /// holds it drains everything pending into one load-merge-rename.
    io: Mutex<()>,
    /// In-flight searches by `(signature, top)`.
    flights: Mutex<HashMap<(String, usize), Arc<Flight>>>,
}

impl StoreInner {
    fn new(path: Option<PathBuf>) -> StoreInner {
        StoreInner {
            path,
            shards: (0..SHARDS)
                .map(|_| RwLock::new(HashMap::new()))
                .collect(),
            warmed: AtomicBool::new(false),
            warm_lock: Mutex::new(()),
            pending: Mutex::new(Vec::new()),
            io: Mutex::new(()),
            flights: Mutex::new(HashMap::new()),
        }
    }
}

/// Handle to a two-tier plan store; clones share the store.
#[derive(Clone)]
pub struct PlanStore {
    inner: Arc<StoreInner>,
}

fn registry() -> &'static Mutex<HashMap<PathBuf, PlanStore>> {
    static REGISTRY: OnceLock<Mutex<HashMap<PathBuf, PlanStore>>> =
        OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

impl PlanStore {
    fn new(path: Option<PathBuf>) -> PlanStore {
        PlanStore { inner: Arc::new(StoreInner::new(path)) }
    }

    /// The process-wide store for a cache file. Every spelling of one
    /// path — relative, absolute, through symlinks — resolves to the
    /// same store (same canonicalization as the save lock).
    pub fn for_path(path: &str) -> PlanStore {
        let key = lock_key(Path::new(path));
        registry()
            .lock()
            .unwrap()
            .entry(key)
            .or_insert_with(|| {
                PlanStore::new(Some(PathBuf::from(path)))
            })
            .clone()
    }

    /// The process-wide disk-less store (`cornstarch serve` without
    /// `--cache`, and [`crate::api::CachePolicy::Memory`]).
    pub fn process_memory() -> PlanStore {
        static MEMORY: OnceLock<PlanStore> = OnceLock::new();
        MEMORY.get_or_init(|| PlanStore::new(None)).clone()
    }

    /// A fresh store nothing else shares: no disk, no registry entry.
    /// This is the `cache_path: None` contract — every call searches —
    /// kept because a private store can never hold a prior answer.
    pub fn private() -> PlanStore {
        PlanStore::new(None)
    }

    /// Forget everything tier-1 holds for `path`: the next lookup
    /// re-reads the file. The hook for *external* writers — another
    /// process rewrote (or corrupted, or deleted) the file and this
    /// process must not keep serving its stale in-memory image. A
    /// path never seen by this process is a no-op. Unflushed pending
    /// writes survive (they re-merge on the next flush).
    pub fn invalidate_path(path: &str) {
        let key = lock_key(Path::new(path));
        let store = registry().lock().unwrap().get(&key).cloned();
        let Some(store) = store else { return };
        // Drop the warmed flag first: a racing lookup that sees the
        // old map either re-warms (flag already down) or reads entries
        // we are about to clear — never a post-clear empty map with
        // the flag still up.
        store.inner.warmed.store(false, Ordering::Release);
        for shard in &store.inner.shards {
            shard.write().unwrap().clear();
        }
    }

    fn shard_of(&self, signature: &str) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        signature.hash(&mut h);
        (h.finish() as usize) % SHARDS
    }

    /// Populate tier-1 from the disk tier, once. Returns whether this
    /// call performed the load (i.e. the answer touched disk).
    fn warm(&self) -> bool {
        let Some(path) = &self.inner.path else { return false };
        if self.inner.warmed.load(Ordering::Acquire) {
            return false;
        }
        let _g = self.inner.warm_lock.lock().unwrap();
        if self.inner.warmed.load(Ordering::Acquire) {
            return false;
        }
        for e in PlanCache::load(path).into_entries() {
            let key = (e.signature.clone(), e.cluster.clone());
            let ve = Arc::new(VerifiedEntry {
                entry: e,
                verified: OnceLock::new(),
            });
            // Never displace an entry already in tier-1: anything this
            // process published is at least as fresh as the file.
            self.inner.shards[self.shard_of(&key.0)]
                .write()
                .unwrap()
                .entry(key)
                .or_insert(ve);
        }
        self.inner.warmed.store(true, Ordering::Release);
        true
    }

    /// Find a verified entry for the `(signature, cluster-fingerprint)`
    /// pair that satisfies depth `top`. First call per process (and
    /// per invalidation) warms tier-1 from disk; after that, hits are
    /// lock-shared map reads and count
    /// [`crate::telemetry::key::CACHE_MEM_HIT`].
    pub fn lookup(
        &self,
        signature: &str,
        fingerprint: &str,
        cluster: &ClusterSpec,
        top: usize,
    ) -> Option<CacheEntry> {
        let warmed_now = self.warm();
        let key = (signature.to_string(), fingerprint.to_string());
        let shard = self.inner.shards[self.shard_of(signature)]
            .read()
            .unwrap();
        let ve = shard.get(&key)?;
        // Cache admission gate, memoized: every stored candidate must
        // verify clean against the cluster (the V005 assignment lints)
        // — a corrupted entry that passed the schema check degrades to
        // a re-search, never a downstream panic at instantiation.
        let clean = *ve.verified.get_or_init(|| {
            ve.entry.frontier.iter().all(|p| {
                let vr = crate::verify::verify_candidate(
                    &p.candidate,
                    cluster,
                );
                if !vr.is_clean() {
                    telemetry::debug(&format!(
                        "cache: rejecting stored plan for {signature}: {}",
                        vr.error_summary()
                    ));
                }
                vr.is_clean()
            })
        });
        if !clean || !ve.entry.satisfies_top(top) {
            return None;
        }
        if !warmed_now {
            telemetry::incr(telemetry::key::CACHE_MEM_HIT);
        }
        Some(ve.entry.clone())
    }

    /// Make a fresh search result visible: tier-1 immediately (marked
    /// verified — the search only emits lint-clean candidates), then
    /// the disk tier through the batching flush.
    pub fn publish(&self, entry: CacheEntry) -> Result<(), TuneError> {
        let key = (entry.signature.clone(), entry.cluster.clone());
        let verified = OnceLock::new();
        let _ = verified.set(true);
        let ve = Arc::new(VerifiedEntry { entry: entry.clone(), verified });
        self.inner.shards[self.shard_of(&key.0)]
            .write()
            .unwrap()
            .insert(key, ve);
        if self.inner.path.is_some() {
            self.inner.pending.lock().unwrap().push(entry);
        }
        self.flush()
    }

    /// Drain pending entries into one load-merge-save under the flush
    /// lock. An empty queue (someone else's flush covered us) is a
    /// successful no-op; a failed save re-enqueues the batch so the
    /// next publish retries.
    fn flush(&self) -> Result<(), TuneError> {
        let Some(path) = &self.inner.path else { return Ok(()) };
        let _io = self.inner.io.lock().unwrap();
        let batch: Vec<CacheEntry> = {
            let mut pending = self.inner.pending.lock().unwrap();
            pending.drain(..).collect()
        };
        if batch.is_empty() {
            return Ok(());
        }
        let mut disk = PlanCache::load(path);
        for e in &batch {
            disk.insert(e.clone());
        }
        if let Err(e) = disk.save() {
            self.inner.pending.lock().unwrap().extend(batch);
            return Err(TuneError::CacheIo(format!("{e:#}")));
        }
        Ok(())
    }

    /// Join the in-flight search for `(signature, top)`, or become its
    /// leader. A leader MUST resolve its [`FlightLease`] (normally via
    /// [`FlightLease::complete`]; dropping it unresolved fails the
    /// flight so followers never hang).
    pub fn lead_or_join(&self, signature: &str, top: usize) -> FlightRole {
        let key = (signature.to_string(), top);
        let mut flights = self.inner.flights.lock().unwrap();
        if let Some(f) = flights.get(&key) {
            return FlightRole::Follower(f.clone());
        }
        let f = Arc::new(Flight::default());
        flights.insert(key.clone(), f.clone());
        FlightRole::Leader(FlightLease {
            store: self.clone(),
            key,
            flight: f,
            resolved: false,
        })
    }
}

/// What [`PlanStore::lead_or_join`] made of this request.
pub enum FlightRole {
    /// This request searches; complete the lease with the outcome.
    Leader(FlightLease),
    /// An identical search is already running;
    /// [`Flight::wait_outcome`] blocks until the leader completes and
    /// clones its outcome.
    Follower(FlightHandle),
}

/// A follower's handle on someone else's in-flight search.
pub type FlightHandle = Arc<Flight>;

impl Flight {
    /// Block until the leader completes, then clone its outcome.
    pub fn wait_outcome(
        self: &Arc<Flight>,
    ) -> Result<TuneOutcome, TuneError> {
        self.wait()
    }
}

/// The leader's obligation: exactly one [`FlightLease::complete`]
/// call. Dropping the lease unresolved (leader panicked / unwound)
/// completes the flight with an error so followers fail fast instead
/// of blocking forever, and removes it from the flight table so the
/// next request starts fresh.
pub struct FlightLease {
    store: PlanStore,
    key: (String, usize),
    flight: Arc<Flight>,
    resolved: bool,
}

impl FlightLease {
    /// Publish the leader's outcome to every follower and retire the
    /// flight. Call *after* [`PlanStore::publish`] so a request that
    /// misses the retired flight finds the entry in tier-1.
    pub fn complete(
        mut self,
        result: Result<TuneOutcome, TuneError>,
    ) {
        self.resolve(result);
    }

    fn resolve(&mut self, result: Result<TuneOutcome, TuneError>) {
        if self.resolved {
            return;
        }
        self.resolved = true;
        // Retire from the table before waking followers: a request
        // arriving now leads its own (fresh) flight — and finds the
        // published entry in tier-1 first anyway.
        self.store
            .inner
            .flights
            .lock()
            .unwrap()
            .remove(&self.key);
        self.flight.complete(result);
    }
}

impl Drop for FlightLease {
    fn drop(&mut self) {
        self.resolve(Err(TuneError::CacheIo(
            "in-flight search leader abandoned its flight".to_string(),
        )));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modality::Strategy;
    use crate::tuner::space::{Candidate, FrozenSetting};
    use crate::tuner::PlanSummary;

    fn fp() -> String {
        ClusterSpec::a40_default().with_devices(16).fingerprint()
    }

    fn cluster() -> ClusterSpec {
        ClusterSpec::a40_default().with_devices(16)
    }

    fn entry(sig: &str, llm_pp: usize) -> CacheEntry {
        CacheEntry {
            signature: sig.to_string(),
            cluster: fp(),
            frontier: vec![PlanSummary {
                candidate: Candidate {
                    strategy: Strategy::Cornstarch,
                    enc_pps: vec![1, 2],
                    llm_pp,
                    tp: 1,
                    cp: 1,
                    num_microbatches: 24,
                    frozen: FrozenSetting::Paper,
                    chain_groups: vec![0, 0, 0],
                },
                iteration_ms: 10.0 + llm_pp as f64,
                throughput_per_gpu: 0.1,
                n_gpus: 8,
                peak_mem_bytes: 1_000_000,
                cp_algorithm: "none".to_string(),
            }],
            top_k: 1,
            evaluated: 9,
        }
    }

    fn tmp(name: &str) -> String {
        let mut p = std::env::temp_dir();
        p.push(format!(
            "cornstarch-store-test-{name}-{}.json",
            std::process::id()
        ));
        p.to_string_lossy().into_owned()
    }

    #[test]
    fn private_stores_share_nothing() {
        let a = PlanStore::private();
        a.publish(entry("s", 2)).unwrap();
        assert!(a.lookup("s", &fp(), &cluster(), 1).is_some());
        let b = PlanStore::private();
        assert!(b.lookup("s", &fp(), &cluster(), 1).is_none());
    }

    #[test]
    fn for_path_returns_the_same_store_for_every_spelling() {
        let path = tmp("alias");
        let a = PlanStore::for_path(&path);
        let b = PlanStore::for_path(&path);
        assert!(Arc::ptr_eq(&a.inner, &b.inner));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn publish_then_lookup_round_trips_and_hits_memory() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let store = PlanStore::for_path(&path);
        store.publish(entry("sig-mem", 3)).unwrap();
        let hit = store
            .lookup("sig-mem", &fp(), &cluster(), 1)
            .expect("published entry must be visible");
        assert_eq!(hit.best().candidate.llm_pp, 3);
        // and it reached the disk tier too
        let disk = PlanCache::load(std::path::Path::new(&path));
        assert!(disk.lookup("sig-mem", &fp()).is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn keys_on_the_full_signature_cluster_pair() {
        let store = PlanStore::private();
        let mut other = entry("shared", 7);
        other.cluster = "some-other-pool".to_string();
        store.publish(entry("shared", 3)).unwrap();
        store.publish(other).unwrap();
        let hit = store.lookup("shared", &fp(), &cluster(), 1).unwrap();
        assert_eq!(hit.best().candidate.llm_pp, 3, "wrong pool's entry");
        assert!(store
            .lookup("shared", "a-third-pool", &cluster(), 1)
            .is_none());
    }

    #[test]
    fn invalidate_path_forces_a_re_read() {
        let path = tmp("invalidate");
        let _ = std::fs::remove_file(&path);
        let store = PlanStore::for_path(&path);
        store.publish(entry("inv", 2)).unwrap();
        assert!(store.lookup("inv", &fp(), &cluster(), 1).is_some());
        // an "external writer" empties the file behind our back; the
        // store keeps serving its image until told otherwise
        std::fs::write(&path, "{}").unwrap();
        assert!(store.lookup("inv", &fp(), &cluster(), 1).is_some());
        PlanStore::invalidate_path(&path);
        assert!(
            store.lookup("inv", &fp(), &cluster(), 1).is_none(),
            "invalidation must drop the in-memory image"
        );
        // unknown paths are a no-op
        PlanStore::invalidate_path("/definitely/not/registered.json");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn followers_receive_the_leaders_outcome() {
        let store = PlanStore::private();
        let FlightRole::Leader(lease) = store.lead_or_join("f", 1) else {
            panic!("first request must lead");
        };
        let followers: Vec<_> = (0..3)
            .map(|_| {
                let store = store.clone();
                std::thread::spawn(move || {
                    match store.lead_or_join("f", 1) {
                        FlightRole::Follower(f) => f.wait_outcome(),
                        FlightRole::Leader(_) => {
                            panic!("flight already led")
                        }
                    }
                })
            })
            .collect();
        // let the followers join before completing
        std::thread::sleep(std::time::Duration::from_millis(30));
        let outcome = TuneOutcome {
            entry: entry("f", 4),
            cache_hit: false,
            total_candidates: 5,
            evaluated: 5,
            pruned: 0,
        };
        lease.complete(Ok(outcome));
        for f in followers {
            let got = f.join().unwrap().unwrap();
            assert_eq!(got.entry.best().candidate.llm_pp, 4);
        }
        // flight retired: the next identical request leads anew
        assert!(matches!(
            store.lead_or_join("f", 1),
            FlightRole::Leader(_)
        ));
    }

    #[test]
    fn different_top_depths_do_not_coalesce() {
        let store = PlanStore::private();
        let FlightRole::Leader(a) = store.lead_or_join("t", 1) else {
            panic!("must lead");
        };
        assert!(
            matches!(store.lead_or_join("t", 3), FlightRole::Leader(_)),
            "a deeper request wants a deeper frontier — its own search"
        );
        a.complete(Err(TuneError::CacheIo("test teardown".into())));
    }

    #[test]
    fn abandoned_leader_fails_followers_instead_of_hanging_them() {
        let store = PlanStore::private();
        let lease = match store.lead_or_join("panic", 1) {
            FlightRole::Leader(l) => l,
            FlightRole::Follower(_) => panic!("must lead"),
        };
        let follower = {
            let store = store.clone();
            std::thread::spawn(move || match store.lead_or_join("panic", 1)
            {
                FlightRole::Follower(f) => f.wait_outcome(),
                FlightRole::Leader(_) => panic!("flight already led"),
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(30));
        drop(lease); // leader unwound without completing
        let got = follower.join().unwrap();
        assert!(matches!(got, Err(TuneError::CacheIo(_))));
    }
}
