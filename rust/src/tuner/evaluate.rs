//! Parallel candidate evaluation — the tuner's hot path.
//!
//! A candidate is scored by building its plan (partitioning via
//! [`crate::pipeline`]) and replaying the 1F1B task graph through the
//! discrete-event simulator ([`crate::sim::simulate`], reached through
//! [`Plan::simulate`]). Simulation dominates the cost, so batches of
//! candidates fan out over `std::thread` workers pulling from a shared
//! atomic cursor; results come back in candidate order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::api::ClusterSpec;
use crate::bam;
use crate::cp::{makespan, Algorithm};
use crate::modality::{
    planner, MultimodalModule, MultimodalParallelSpec, Plan,
};
use crate::model::MllmSpec;
use crate::util::rng::Rng;

use super::space::Candidate;

/// A fully-scored candidate.
#[derive(Clone, Debug)]
pub struct Evaluation {
    pub candidate: Candidate,
    pub iteration_ms: f64,
    pub throughput_per_gpu: f64,
    pub n_gpus: usize,
    /// Modeled peak per-GPU bytes ([`crate::memory`]) — reported next to
    /// the makespan so consumers see the headroom a plan leaves.
    pub peak_mem_bytes: u64,
}

/// Materialize the module tree a candidate plans against (frozen policy
/// applied).
pub fn module_for(spec: &MllmSpec, cand: &Candidate) -> MultimodalModule {
    let mut mm = MultimodalModule::from_spec(spec);
    cand.frozen.apply(&mut mm);
    mm
}

/// The parallel spec a candidate denotes on `cluster` (comm hops priced
/// off the cluster's interconnect bandwidth).
pub fn spec_for(
    cand: &Candidate,
    cluster: &ClusterSpec,
) -> MultimodalParallelSpec {
    let mut ps = MultimodalParallelSpec::for_cluster(
        &cand.enc_pps,
        cand.llm_pp,
        cand.tp,
        cand.cp,
        cluster,
    );
    ps.num_microbatches = cand.num_microbatches;
    ps
}

/// Build the stage DAG for one candidate without simulating it. A
/// candidate carrying a heterogeneous group assignment
/// ([`Candidate::chain_groups`]) is planned with each chain priced on
/// its assigned group's device and link; otherwise the homogeneous
/// single-class path is used (byte-for-byte the pre-hetero plan).
pub fn build_plan(
    spec: &MllmSpec,
    cand: &Candidate,
    cluster: &ClusterSpec,
) -> Plan {
    let mm = module_for(spec, cand);
    let ps = spec_for(cand, cluster);
    if cand.chain_groups.is_empty() && !cluster.is_heterogeneous() {
        planner::plan(cand.strategy, &mm, &ps, cluster.device_model())
    } else {
        planner::plan_assigned(
            cand.strategy,
            &mm,
            &ps,
            cluster,
            &cand.chain_groups,
        )
    }
}

/// The tuner's two lower bounds on a plan's 1F1B makespan, `(device_busy,
/// critical_path)`:
///
/// * **device-busy** — the bottleneck device must run all `m` of its
///   microbatches' fwd+bwd serially;
/// * **critical-path** — one microbatch must traverse the longest stage
///   path (fwd down, bwd back up, plus a comm hop each way per
///   cross-device edge, priced per edge on heterogeneous links).
///
/// Each is individually a valid lower bound; the search prunes on their
/// max ([`lower_bound_ms`]), and the property harness in
/// `tests/hetero_checks.rs` holds the simulator to both.
pub fn bounds_ms(plan: &Plan) -> (f64, f64) {
    let m = plan.num_microbatches as f64;
    // Per-device serial work (stages sharing a device accumulate).
    let n_dev = plan.graph.n_devices();
    let mut dev_work = vec![0.0f64; n_dev];
    for node in &plan.graph.nodes {
        dev_work[node.device] += node.cost.total();
    }
    let busy_lb = m * dev_work.iter().cloned().fold(0.0, f64::max);

    // Critical path of one microbatch: longest fwd chain into each node,
    // then the symmetric bwd walk back — equivalently twice the one-way
    // path with fwd+bwd costs and doubled comm.
    let n = plan.graph.nodes.len();
    let mut path = vec![0.0f64; n];
    let mut critical: f64 = 0.0;
    for (i, node) in plan.graph.nodes.iter().enumerate() {
        let mut best = 0.0f64;
        for &p in &node.preds {
            let comm =
                2.0 * plan.graph.hop_ms(plan.graph.nodes[p].device, node.device);
            best = best.max(path[p] + comm);
        }
        path[i] = best + node.cost.total();
        critical = critical.max(path[i]);
    }
    (busy_lb, critical)
}

/// Cheap lower bound on the plan's iteration time, used by the search to
/// prune without simulating: the max of the two bounds of [`bounds_ms`].
pub fn lower_bound_ms(plan: &Plan) -> f64 {
    let (busy, critical) = bounds_ms(plan);
    busy.max(critical)
}

/// Simulate an already-built plan. Runs on the search's worker threads:
/// the span (when tracing) lands on the worker's own trace lane, and the
/// name is only built when the sink is live — off-path otherwise.
fn evaluation_of(cand: &Candidate, plan: &Plan) -> Evaluation {
    let _sim_span = crate::telemetry::trace_enabled()
        .then(|| crate::telemetry::span(&format!("sim {}", cand.label())));
    let m = plan.simulate();
    Evaluation {
        candidate: cand.clone(),
        iteration_ms: m.iteration_ms,
        throughput_per_gpu: m.throughput_per_gpu,
        n_gpus: plan.n_gpus,
        peak_mem_bytes: plan.peak_device_bytes(),
    }
}

/// Score one candidate end-to-end (plan + simulate).
pub fn evaluate_one(
    spec: &MllmSpec,
    cand: &Candidate,
    cluster: &ClusterSpec,
) -> Evaluation {
    let plan = build_plan(spec, cand, cluster);
    evaluation_of(cand, &plan)
}

/// Simulate pre-built (candidate, plan) pairs across `threads` workers —
/// the search's wave path: plans were already constructed for bounding,
/// so they are not rebuilt here. Result `i` corresponds to `items[i]`.
pub fn simulate_plans_parallel(
    items: &[(Candidate, Plan)],
    threads: usize,
) -> Vec<Evaluation> {
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 {
        return items.iter().map(|(c, p)| evaluation_of(c, p)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Evaluation>>> =
        (0..items.len()).map(|_| Mutex::new(None)).collect();
    // Carry the calling request's telemetry scopes onto the workers,
    // so anything a worker counts is attributed to the right request.
    let scopes = crate::telemetry::current_scopes();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let _guards: Vec<_> = scopes
                    .iter()
                    .map(crate::telemetry::Scope::attach)
                    .collect();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let (c, p) = &items[i];
                    *slots[i].lock().unwrap() = Some(evaluation_of(c, p));
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// Score `candidates` across `threads` workers. Result `i` corresponds to
/// `candidates[i]`. `threads == 1` degenerates to a serial loop (used by
/// tests for determinism cross-checks).
pub fn evaluate_parallel(
    spec: &MllmSpec,
    candidates: &[Candidate],
    cluster: &ClusterSpec,
    threads: usize,
) -> Vec<Evaluation> {
    let threads = threads.max(1).min(candidates.len().max(1));
    if threads <= 1 {
        return candidates
            .iter()
            .map(|c| evaluate_one(spec, c, cluster))
            .collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Evaluation>>> =
        (0..candidates.len()).map(|_| Mutex::new(None)).collect();
    // Same scope hand-off as `simulate_plans_parallel`: per-request
    // accounting survives the hop onto the worker pool.
    let scopes = crate::telemetry::current_scopes();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let _guards: Vec<_> = scopes
                    .iter()
                    .map(crate::telemetry::Scope::attach)
                    .collect();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= candidates.len() {
                        break;
                    }
                    let ev = evaluate_one(spec, &candidates[i], cluster);
                    *slots[i].lock().unwrap() = Some(ev);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// Seed every cp-algorithm pick derives its sample mask from — pinned so
/// the tuner, the plan cache, and [`crate::profile`] all score the same
/// workload.
pub const CP_PICK_SEED: u64 = 0x7EAC_0DE5;

/// The blocked EE-style token workload cp algorithms are scored on:
/// deterministic in `(tokens, seed)`.
pub fn cp_block_workloads(tokens: usize, seed: u64) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    // Round up to a mask the generators accept comfortably.
    let t = tokens.max(256);
    let mask = bam::generators::random_ee(&mut rng, t, 3);
    bam::block_workloads(&mask.workloads(), 128)
}

/// Best of LPT / Zigzag / Ring on `w` by simulated max-rank workload
/// (first wins ties, so the pick is deterministic).
pub fn pick_cp_over(w: &[u64], cp: usize) -> Algorithm {
    let mut best = Algorithm::Lpt;
    let mut best_mk = u64::MAX;
    for alg in [Algorithm::Lpt, Algorithm::Zigzag, Algorithm::Ring] {
        let mk = makespan(w, &alg.assign(w, cp), cp);
        if mk < best_mk {
            best_mk = mk;
            best = alg;
        }
    }
    best
}

/// Pick the CP token-distribution algorithm for the tuned plan: sample an
/// EE-style multimodal mask at the workload's LLM sequence length and keep
/// the algorithm with the smallest simulated max-rank workload (§4.3.2).
/// With `cp == 1` there is nothing to distribute.
pub fn pick_cp_algorithm(tokens: usize, cp: usize, seed: u64) -> &'static str {
    if cp <= 1 {
        return "none";
    }
    pick_cp_over(&cp_block_workloads(tokens, seed), cp).name()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modality::Strategy;
    use crate::model::Size;
    use crate::tuner::space::FrozenSetting;

    fn cand(strategy: Strategy, enc_pps: Vec<usize>, llm_pp: usize) -> Candidate {
        Candidate {
            strategy,
            enc_pps,
            llm_pp,
            tp: 2,
            cp: 2,
            num_microbatches: 8,
            frozen: FrozenSetting::Paper,
            chain_groups: Vec::new(),
        }
    }

    #[test]
    fn lower_bound_never_exceeds_simulated_makespan() {
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let d = ClusterSpec::a40_default();
        for c in [
            cand(Strategy::Cornstarch, vec![1], 3),
            cand(Strategy::Cornstarch, vec![2], 4),
            cand(Strategy::Colocated, vec![1], 3),
            cand(Strategy::Replicated, vec![], 4),
        ] {
            let plan = build_plan(&spec, &c, &d);
            let lb = lower_bound_ms(&plan);
            let sim = plan.simulate().iteration_ms;
            assert!(
                lb <= sim + 1e-6,
                "{}: lb {lb:.2} > sim {sim:.2}",
                c.label()
            );
            assert!(lb > 0.0);
        }
    }

    #[test]
    fn assigned_candidate_builds_the_assigned_plan() {
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let cluster = ClusterSpec::a40_a100_demo();
        let mut c = cand(Strategy::Cornstarch, vec![1], 2);
        c.tp = 1;
        c.cp = 1;
        c.chain_groups = vec![0, 1];
        let plan = build_plan(&spec, &c, &cluster);
        assert_eq!(plan.stage_groups, vec![0, 1, 1]);
        // the lower bounds stay lower bounds under per-edge links
        let (busy, critical) = bounds_ms(&plan);
        let sim = plan.simulate().iteration_ms;
        assert!(busy <= sim + 1e-6);
        assert!(critical <= sim + 1e-6);
        assert_eq!(lower_bound_ms(&plan), busy.max(critical));
    }

    #[test]
    fn parallel_evaluation_matches_serial() {
        let spec = MllmSpec::vlm(Size::M, Size::S);
        let d = ClusterSpec::a40_default();
        let cands: Vec<Candidate> = (1..=4)
            .map(|pp| cand(Strategy::Cornstarch, vec![1], pp))
            .collect();
        let serial = evaluate_parallel(&spec, &cands, &d, 1);
        let par = evaluate_parallel(&spec, &cands, &d, 4);
        assert_eq!(serial.len(), par.len());
        for (s, p) in serial.iter().zip(&par) {
            assert_eq!(s.candidate, p.candidate);
            assert!((s.iteration_ms - p.iteration_ms).abs() < 1e-9);
            assert!(
                (s.throughput_per_gpu - p.throughput_per_gpu).abs() < 1e-12
            );
        }
    }

    #[test]
    fn frozen_setting_changes_the_score() {
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let d = ClusterSpec::a40_default();
        let mut a = cand(Strategy::Cornstarch, vec![1], 3);
        let mut b = a.clone();
        a.frozen = FrozenSetting::AllFrozen;
        b.frozen = FrozenSetting::AllTrainable;
        let ea = evaluate_one(&spec, &a, &d);
        let eb = evaluate_one(&spec, &b, &d);
        // full training must cost strictly more than pure frozen replay
        assert!(ea.iteration_ms < eb.iteration_ms);
    }

    #[test]
    fn candidate_gpu_accounting_matches_the_planner() {
        // Including the colocated case, where encoders share stages.
        let spec = MllmSpec::valm(Size::M, Size::M, Size::M);
        let d = ClusterSpec::a40_default();
        for c in [
            cand(Strategy::Cornstarch, vec![1, 2], 3),
            cand(Strategy::Colocated, vec![2, 2], 3),
            cand(Strategy::Replicated, vec![], 4),
        ] {
            let plan = build_plan(&spec, &c, &d);
            assert_eq!(plan.n_gpus, c.n_gpus(), "{}", c.label());
        }
    }

    #[test]
    fn cp_algorithm_pick_is_deterministic() {
        assert_eq!(
            pick_cp_algorithm(2774, 2, 7),
            pick_cp_algorithm(2774, 2, 7)
        );
        assert_eq!(pick_cp_algorithm(2774, 1, 7), "none");
    }
}
