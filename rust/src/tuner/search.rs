//! Bounded plan search: enumerate → bound → prune → simulate in waves.
//!
//! The search is a best-first beam over the enumerated candidate list.
//! Every candidate first gets a *cost-model lower bound* on its iteration
//! time ([`super::evaluate::lower_bound_ms`]) — orders of magnitude
//! cheaper than simulating the 1F1B schedule. Candidates are then visited
//! in ascending-bound order in waves of `threads` and simulated in
//! parallel; any candidate whose bound cannot beat the incumbent is
//! pruned unsimulated. Because bounds are true lower bounds and the
//! visit order is bound-ascending, once a wave's first bound exceeds the
//! incumbent the whole tail is pruned — the search is exact over the
//! enumerated space whenever the simulation budget is not exhausted.
//!
//! The search core is assignment-agnostic: on a heterogeneous pool the
//! enumeration ([`super::space::enumerate_with_plans`]) expands each
//! geometric candidate into its feasible chain→device-group placements,
//! and every (candidate, plan) pair flows through the same bound → prune
//! → simulate machinery — the lower bounds already price per-edge links
//! through [`crate::pipeline::StageGraph::hop_ms`].

use crate::api::ClusterSpec;
use crate::model::MllmSpec;
use crate::telemetry::{self, key as tkey};
use crate::util::json::Json;

use super::evaluate::{
    build_plan, lower_bound_ms, simulate_plans_parallel, Evaluation,
};
use super::space::{enumerate_with_plans, Candidate, SearchSpace};

/// What the tuner minimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Minimize simulated iteration time (makespan) — the default, and
    /// what the acceptance comparisons against the baseline planners use.
    Makespan,
    /// Maximize input/s/GPU (the paper's normalized metric); candidates
    /// that leave budget idle can win here.
    ThroughputPerGpu,
}

impl Objective {
    pub fn key(&self) -> &'static str {
        match self {
            Objective::Makespan => "makespan",
            Objective::ThroughputPerGpu => "tput-per-gpu",
        }
    }

    pub fn parse(s: &str) -> Option<Objective> {
        match s {
            "makespan" => Some(Objective::Makespan),
            "tput-per-gpu" | "tput" => Some(Objective::ThroughputPerGpu),
            _ => None,
        }
    }

    /// Scalar score — smaller is better under both objectives.
    pub fn score(&self, ev: &Evaluation) -> f64 {
        match self {
            Objective::Makespan => ev.iteration_ms,
            Objective::ThroughputPerGpu => -ev.throughput_per_gpu,
        }
    }

    /// Most optimistic achievable score for a candidate whose iteration
    /// time is at least `lb_ms`. Must never exceed the true score.
    fn optimistic_score(
        &self,
        lb_ms: f64,
        cand: &Candidate,
        samples: f64,
    ) -> f64 {
        match self {
            Objective::Makespan => lb_ms,
            Objective::ThroughputPerGpu => {
                let tput = samples / (lb_ms / 1e3);
                -(tput / cand.n_gpus() as f64)
            }
        }
    }
}

/// Search statistics + the winner and its runners-up.
#[derive(Clone, Debug)]
pub struct SearchReport {
    pub best: Evaluation,
    /// Ascending-objective frontier; `frontier[0]` is `best`. Length is
    /// at most the requested top-k. With an unlimited budget these are
    /// *exactly* the k best plans of the enumerated space (the prune
    /// threshold is the k-th incumbent, and bounds are true lower
    /// bounds).
    pub frontier: Vec<Evaluation>,
    /// Candidates enumerated from the space.
    pub total_candidates: usize,
    /// Candidates actually simulated.
    pub evaluated: usize,
    /// Candidates discarded on the lower bound alone.
    pub pruned: usize,
}

/// Run the search for the single best plan. `budget` caps how many
/// candidates may be simulated (0 means unlimited); `threads` sizes the
/// evaluation waves.
pub fn search(
    spec: &MllmSpec,
    space: &SearchSpace,
    objective: Objective,
    budget: usize,
    threads: usize,
    cluster: &ClusterSpec,
) -> Option<SearchReport> {
    search_top(spec, space, objective, budget, threads, cluster, 1)
}

/// Run the search keeping the `top_k` best plans (the frontier the plan
/// cache persists, so consumers can trade throughput against GPU count
/// and memory headroom without re-searching).
pub fn search_top(
    spec: &MllmSpec,
    space: &SearchSpace,
    objective: Objective,
    budget: usize,
    threads: usize,
    cluster: &ClusterSpec,
    top_k: usize,
) -> Option<SearchReport> {
    let mm = crate::modality::MultimodalModule::from_spec(spec);
    // The enumeration's memory filter had to build every candidate's
    // plan anyway; reuse those for bounding and simulation.
    let pairs = enumerate_with_plans(&mm, space, cluster);
    search_pairs(pairs, objective, budget, threads, top_k)
}

/// Search over an explicit candidate list (the entry point benches and
/// tests use to control the space exactly).
pub fn search_candidates(
    spec: &MllmSpec,
    candidates: Vec<Candidate>,
    objective: Objective,
    budget: usize,
    threads: usize,
    cluster: &ClusterSpec,
) -> Option<SearchReport> {
    search_candidates_top(
        spec, candidates, objective, budget, threads, cluster, 1,
    )
}

/// [`search_candidates`] with a `top_k` frontier.
#[allow(clippy::too_many_arguments)]
pub fn search_candidates_top(
    spec: &MllmSpec,
    candidates: Vec<Candidate>,
    objective: Objective,
    budget: usize,
    threads: usize,
    cluster: &ClusterSpec,
    top_k: usize,
) -> Option<SearchReport> {
    let pairs: Vec<(Candidate, crate::modality::Plan)> = candidates
        .into_iter()
        .map(|c| {
            let plan = build_plan(spec, &c, cluster);
            (c, plan)
        })
        .collect();
    search_pairs(pairs, objective, budget, threads, top_k)
}

/// The search core over pre-built (candidate, plan) pairs: bound → sort
/// → prune → simulate in waves. Every plan is constructed exactly once
/// (by [`crate::tuner::space::enumerate_with_plans`] or the caller) and
/// handed from bounding to the simulation wave.
fn search_pairs(
    pairs: Vec<(Candidate, crate::modality::Plan)>,
    objective: Objective,
    budget: usize,
    threads: usize,
    top_k: usize,
) -> Option<SearchReport> {
    if pairs.is_empty() {
        return None;
    }
    let _search_span = telemetry::span("search");
    let total = pairs.len();
    let budget = if budget == 0 { total } else { budget.min(total) };
    let threads = threads.max(1);
    let top_k = top_k.max(1);

    // Bound every candidate (cheap: a graph walk, no sim).
    let mut queue: std::collections::VecDeque<_> = {
        let _bound_span = telemetry::span("bound");
        let mut bounded: Vec<(f64, Candidate, crate::modality::Plan)> =
            pairs
                .into_iter()
                .map(|(c, plan)| {
                    let samples = (plan.num_microbatches
                        * plan.microbatch_size)
                        as f64;
                    let lb = lower_bound_ms(&plan);
                    (objective.optimistic_score(lb, &c, samples), c, plan)
                })
                .collect();
        bounded.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        bounded.into()
    };

    // Ascending-score frontier, capped at top_k.
    let mut frontier: Vec<(f64, Evaluation)> = Vec::new();
    let mut evaluated = 0usize;
    let mut pruned = 0usize;
    while let Some((head_bound, _, _)) = queue.front() {
        if evaluated >= budget {
            pruned += queue.len();
            telemetry::count(tkey::PRUNED_LOWER_BOUND, queue.len() as u64);
            break;
        }
        // Bound-ascending order: if this bound cannot beat the k-th
        // incumbent, neither can anything after it.
        if frontier.len() >= top_k {
            let worst_kept = frontier[frontier.len() - 1].0;
            if *head_bound >= worst_kept {
                pruned += queue.len();
                telemetry::count(
                    tkey::PRUNED_LOWER_BOUND,
                    queue.len() as u64,
                );
                break;
            }
        }
        let wave_n = queue.len().min(threads).min(budget - evaluated);
        let _wave_span = telemetry::span(&format!("wave n={wave_n}"));
        let wave: Vec<(Candidate, crate::modality::Plan)> =
            queue.drain(..wave_n).map(|(_, c, p)| (c, p)).collect();
        let evs = simulate_plans_parallel(&wave, threads);
        evaluated += evs.len();
        telemetry::count(tkey::EVALUATED, evs.len() as u64);
        let prev_best = frontier.first().map(|(s, _)| *s);
        for ev in evs {
            let s = objective.score(&ev);
            let pos = frontier.partition_point(|(fs, _)| *fs <= s);
            if pos < top_k {
                frontier.insert(pos, (s, ev));
                frontier.truncate(top_k);
            }
        }
        if let Some((s, ev)) = frontier.first() {
            // Best-so-far trajectory: one point per improving wave.
            if prev_best.is_none_or(|p| *s < p) {
                telemetry::instant(
                    "best_so_far",
                    vec![
                        ("score", Json::Num(*s)),
                        ("iteration_ms", Json::Num(ev.iteration_ms)),
                        ("label", Json::Str(ev.candidate.label())),
                        ("evaluated", Json::Int(evaluated as i64)),
                    ],
                );
                telemetry::debug(&format!(
                    "  search: best so far {:.1} ms ({}) after {} sims",
                    ev.iteration_ms,
                    ev.candidate.label(),
                    evaluated
                ));
            }
        }
    }
    if frontier.is_empty() {
        return None;
    }
    let frontier: Vec<Evaluation> =
        frontier.into_iter().map(|(_, e)| e).collect();
    Some(SearchReport {
        best: frontier[0].clone(),
        frontier,
        total_candidates: total,
        evaluated,
        pruned,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modality::{MultimodalModule, Strategy};
    use crate::model::{MllmSpec, Size};
    use crate::tuner::space::SearchSpace;

    fn run(
        spec: &MllmSpec,
        devices: usize,
        budget: usize,
        threads: usize,
    ) -> SearchReport {
        search(
            spec,
            &SearchSpace::paper_default(devices),
            Objective::Makespan,
            budget,
            threads,
            &ClusterSpec::a40_default(),
        )
        .expect("feasible space")
    }

    #[test]
    fn finds_a_plan_and_accounts_for_every_candidate() {
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let r = run(&spec, 16, 0, 4);
        assert!(r.best.iteration_ms > 0.0);
        assert_eq!(r.evaluated + r.pruned, r.total_candidates);
        assert!(r.evaluated >= 1);
    }

    #[test]
    fn unlimited_budget_matches_exhaustive_minimum() {
        let spec = MllmSpec::vlm(Size::M, Size::S);
        let space = SearchSpace::paper_default(12);
        let cl = ClusterSpec::a40_default();
        let mm = MultimodalModule::from_spec(&spec);
        let cands = crate::tuner::space::enumerate(&mm, &space);
        let exhaustive = crate::tuner::evaluate::evaluate_parallel(
            &spec, &cands, &cl, 4,
        )
        .into_iter()
        .map(|e| e.iteration_ms)
        .fold(f64::INFINITY, f64::min);
        let r =
            search(&spec, &space, Objective::Makespan, 0, 4, &cl).unwrap();
        assert!(
            (r.best.iteration_ms - exhaustive).abs() < 1e-9,
            "search {:.3} vs exhaustive {:.3}",
            r.best.iteration_ms,
            exhaustive
        );
        // pruning must have done something on a space this size
        assert!(r.pruned > 0, "no pruning over {} candidates", r.total_candidates);
    }

    #[test]
    fn top_k_frontier_matches_exhaustive_ranking() {
        let spec = MllmSpec::vlm(Size::M, Size::S);
        let space = SearchSpace::paper_default(12);
        let d = ClusterSpec::a40_default();
        let r = search_top(&spec, &space, Objective::Makespan, 0, 4, &d, 5)
            .unwrap();
        assert!(!r.frontier.is_empty() && r.frontier.len() <= 5);
        assert!(
            (r.frontier[0].iteration_ms - r.best.iteration_ms).abs()
                < 1e-12
        );
        assert!(r
            .frontier
            .windows(2)
            .all(|w| w[0].iteration_ms <= w[1].iteration_ms + 1e-12));
        // exhaustive cross-check: the frontier is exactly the k best
        let mm = MultimodalModule::from_spec(&spec);
        let cands = crate::tuner::space::enumerate(&mm, &space);
        let mut all: Vec<f64> = crate::tuner::evaluate::evaluate_parallel(
            &spec, &cands, &d, 4,
        )
        .into_iter()
        .map(|e| e.iteration_ms)
        .collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (i, ev) in r.frontier.iter().enumerate() {
            assert!(
                (ev.iteration_ms - all[i]).abs() < 1e-9,
                "frontier[{i}] {:.3} vs exhaustive {:.3}",
                ev.iteration_ms,
                all[i]
            );
        }
    }

    #[test]
    fn budget_caps_simulations() {
        let spec = MllmSpec::valm(Size::M, Size::M, Size::M);
        let r = run(&spec, 24, 10, 4);
        assert!(r.evaluated <= 10);
        assert_eq!(r.evaluated + r.pruned, r.total_candidates);
    }

    #[test]
    fn tuned_beats_every_fixed_baseline_at_same_budget() {
        // The acceptance property: the searched best is at least as fast
        // as each strategy's default configuration at the same budget.
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let d = crate::cost::Device::a40();
        let r = run(&spec, 16, 0, 4);
        let mm = MultimodalModule::from_spec(&spec);
        for (strategy, enc, llm) in [
            (Strategy::Cornstarch, vec![1usize], 3usize),
            (Strategy::Colocated, vec![1], 3),
            (Strategy::Replicated, vec![], 4),
        ] {
            let ps = crate::modality::MultimodalParallelSpec::paper_default(
                &enc, llm, 2, 2,
            );
            let base = crate::modality::planner::plan(strategy, &mm, &ps, d)
                .simulate()
                .iteration_ms;
            assert!(
                r.best.iteration_ms <= base + 1e-9,
                "tuned {:.1} ms vs {} baseline {:.1} ms",
                r.best.iteration_ms,
                strategy.name(),
                base
            );
        }
    }

    #[test]
    fn throughput_objective_prefers_denser_plans() {
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let space = SearchSpace::paper_default(16);
        let d = ClusterSpec::a40_default();
        let mk =
            search(&spec, &space, Objective::Makespan, 0, 4, &d).unwrap();
        let tp =
            search(&spec, &space, Objective::ThroughputPerGpu, 0, 4, &d)
                .unwrap();
        assert!(
            tp.best.throughput_per_gpu >= mk.best.throughput_per_gpu - 1e-12
        );
    }
}
