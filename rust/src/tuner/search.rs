//! Bounded plan search: enumerate → bound → prune → simulate in waves.
//!
//! The search is a best-first beam over the enumerated candidate list.
//! Every candidate first gets a *cost-model lower bound* on its iteration
//! time ([`super::evaluate::lower_bound_ms`]) — orders of magnitude
//! cheaper than simulating the 1F1B schedule. Candidates are then visited
//! in ascending-bound order in waves of `threads` and simulated in
//! parallel; any candidate whose bound cannot beat the incumbent is
//! pruned unsimulated. Because bounds are true lower bounds and the
//! visit order is bound-ascending, once a wave's first bound exceeds the
//! incumbent the whole tail is pruned — the search is exact over the
//! enumerated space whenever the simulation budget is not exhausted.

use crate::cost::Device;
use crate::model::MllmSpec;

use super::evaluate::{
    build_plan, lower_bound_ms, simulate_plans_parallel, Evaluation,
};
use super::space::{enumerate, Candidate, SearchSpace};

/// What the tuner minimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Minimize simulated iteration time (makespan) — the default, and
    /// what the acceptance comparisons against the baseline planners use.
    Makespan,
    /// Maximize input/s/GPU (the paper's normalized metric); candidates
    /// that leave budget idle can win here.
    ThroughputPerGpu,
}

impl Objective {
    pub fn key(&self) -> &'static str {
        match self {
            Objective::Makespan => "makespan",
            Objective::ThroughputPerGpu => "tput-per-gpu",
        }
    }

    pub fn parse(s: &str) -> Option<Objective> {
        match s {
            "makespan" => Some(Objective::Makespan),
            "tput-per-gpu" | "tput" => Some(Objective::ThroughputPerGpu),
            _ => None,
        }
    }

    /// Scalar score — smaller is better under both objectives.
    pub fn score(&self, ev: &Evaluation) -> f64 {
        match self {
            Objective::Makespan => ev.iteration_ms,
            Objective::ThroughputPerGpu => -ev.throughput_per_gpu,
        }
    }

    /// Most optimistic achievable score for a candidate whose iteration
    /// time is at least `lb_ms`. Must never exceed the true score.
    fn optimistic_score(
        &self,
        lb_ms: f64,
        cand: &Candidate,
        samples: f64,
    ) -> f64 {
        match self {
            Objective::Makespan => lb_ms,
            Objective::ThroughputPerGpu => {
                let tput = samples / (lb_ms / 1e3);
                -(tput / cand.n_gpus() as f64)
            }
        }
    }
}

/// Search statistics + the winner.
#[derive(Clone, Debug)]
pub struct SearchReport {
    pub best: Evaluation,
    /// Candidates enumerated from the space.
    pub total_candidates: usize,
    /// Candidates actually simulated.
    pub evaluated: usize,
    /// Candidates discarded on the lower bound alone.
    pub pruned: usize,
}

/// Run the search. `budget` caps how many candidates may be simulated
/// (0 means unlimited); `threads` sizes the evaluation waves.
pub fn search(
    spec: &MllmSpec,
    space: &SearchSpace,
    objective: Objective,
    budget: usize,
    threads: usize,
    device: Device,
) -> Option<SearchReport> {
    let mm = crate::modality::MultimodalModule::from_spec(spec);
    let candidates = enumerate(&mm, space);
    search_candidates(spec, candidates, objective, budget, threads, device)
}

/// Search over an explicit candidate list (the entry point benches and
/// tests use to control the space exactly).
pub fn search_candidates(
    spec: &MllmSpec,
    candidates: Vec<Candidate>,
    objective: Objective,
    budget: usize,
    threads: usize,
    device: Device,
) -> Option<SearchReport> {
    if candidates.is_empty() {
        return None;
    }
    let total = candidates.len();
    let budget = if budget == 0 { total } else { budget.min(total) };
    let threads = threads.max(1);

    // Bound every candidate (cheap: partition DP + a graph walk, no sim).
    // The plan built for bounding is kept and handed to the simulation
    // wave, so no candidate pays plan construction twice.
    let mut bounded: Vec<(f64, Candidate, crate::modality::Plan)> =
        candidates
            .into_iter()
            .map(|c| {
                let plan = build_plan(spec, &c, device);
                let samples =
                    (plan.num_microbatches * plan.microbatch_size) as f64;
                let lb = lower_bound_ms(&plan);
                (objective.optimistic_score(lb, &c, samples), c, plan)
            })
            .collect();
    bounded.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut queue: std::collections::VecDeque<_> = bounded.into();

    let mut best: Option<(f64, Evaluation)> = None;
    let mut evaluated = 0usize;
    let mut pruned = 0usize;
    while let Some((head_bound, _, _)) = queue.front() {
        if evaluated >= budget {
            pruned += queue.len();
            break;
        }
        // Bound-ascending order: if this bound cannot beat the incumbent,
        // neither can anything after it.
        if let Some((inc, _)) = &best {
            if *head_bound >= *inc {
                pruned += queue.len();
                break;
            }
        }
        let wave_n = queue.len().min(threads).min(budget - evaluated);
        let wave: Vec<(Candidate, crate::modality::Plan)> =
            queue.drain(..wave_n).map(|(_, c, p)| (c, p)).collect();
        let evs = simulate_plans_parallel(&wave, threads);
        evaluated += evs.len();
        for ev in evs {
            let s = objective.score(&ev);
            let better = match &best {
                None => true,
                Some((bs, _)) => s < *bs,
            };
            if better {
                best = Some((s, ev));
            }
        }
    }
    let (_, best) = best?;
    Some(SearchReport { best, total_candidates: total, evaluated, pruned })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::Device;
    use crate::modality::{MultimodalModule, Strategy};
    use crate::model::{MllmSpec, Size};
    use crate::tuner::space::SearchSpace;

    fn run(
        spec: &MllmSpec,
        devices: usize,
        budget: usize,
        threads: usize,
    ) -> SearchReport {
        search(
            spec,
            &SearchSpace::paper_default(devices),
            Objective::Makespan,
            budget,
            threads,
            Device::a40(),
        )
        .expect("feasible space")
    }

    #[test]
    fn finds_a_plan_and_accounts_for_every_candidate() {
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let r = run(&spec, 16, 0, 4);
        assert!(r.best.iteration_ms > 0.0);
        assert_eq!(r.evaluated + r.pruned, r.total_candidates);
        assert!(r.evaluated >= 1);
    }

    #[test]
    fn unlimited_budget_matches_exhaustive_minimum() {
        let spec = MllmSpec::vlm(Size::M, Size::S);
        let space = SearchSpace::paper_default(12);
        let mm = MultimodalModule::from_spec(&spec);
        let cands = crate::tuner::space::enumerate(&mm, &space);
        let exhaustive = crate::tuner::evaluate::evaluate_parallel(
            &spec,
            &cands,
            Device::a40(),
            4,
        )
        .into_iter()
        .map(|e| e.iteration_ms)
        .fold(f64::INFINITY, f64::min);
        let r = search(
            &spec,
            &space,
            Objective::Makespan,
            0,
            4,
            Device::a40(),
        )
        .unwrap();
        assert!(
            (r.best.iteration_ms - exhaustive).abs() < 1e-9,
            "search {:.3} vs exhaustive {:.3}",
            r.best.iteration_ms,
            exhaustive
        );
        // pruning must have done something on a space this size
        assert!(r.pruned > 0, "no pruning over {} candidates", r.total_candidates);
    }

    #[test]
    fn budget_caps_simulations() {
        let spec = MllmSpec::valm(Size::M, Size::M, Size::M);
        let r = run(&spec, 24, 10, 4);
        assert!(r.evaluated <= 10);
        assert_eq!(r.evaluated + r.pruned, r.total_candidates);
    }

    #[test]
    fn tuned_beats_every_fixed_baseline_at_same_budget() {
        // The acceptance property: the searched best is at least as fast
        // as each strategy's default configuration at the same budget.
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let d = Device::a40();
        let r = run(&spec, 16, 0, 4);
        let mm = MultimodalModule::from_spec(&spec);
        for (strategy, enc, llm) in [
            (Strategy::Cornstarch, vec![1usize], 3usize),
            (Strategy::Colocated, vec![1], 3),
            (Strategy::Replicated, vec![], 4),
        ] {
            let ps = crate::modality::MultimodalParallelSpec::paper_default(
                &enc, llm, 2, 2,
            );
            let base = crate::modality::planner::plan(strategy, &mm, &ps, d)
                .simulate()
                .iteration_ms;
            assert!(
                r.best.iteration_ms <= base + 1e-9,
                "tuned {:.1} ms vs {} baseline {:.1} ms",
                r.best.iteration_ms,
                strategy.name(),
                base
            );
        }
    }

    #[test]
    fn throughput_objective_prefers_denser_plans() {
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let space = SearchSpace::paper_default(16);
        let d = Device::a40();
        let mk = search(&spec, &space, Objective::Makespan, 0, 4, d).unwrap();
        let tp = search(&spec, &space, Objective::ThroughputPerGpu, 0, 4, d)
            .unwrap();
        assert!(
            tp.best.throughput_per_gpu >= mk.best.throughput_per_gpu - 1e-12
        );
    }
}
