//! Candidate enumeration: the joint configuration space the autotuner
//! searches.
//!
//! A [`Candidate`] fixes everything the planning layers need to produce an
//! executable plan: the parallelization policy ([`Strategy`]), the encoder
//! placement (per-encoder stage counts), the LLM pipeline depth, the TP
//! and CP degrees, the microbatch count, and the frozen policy. The
//! [`SearchSpace`] bounds each dimension; [`enumerate`] walks the cross
//! product and keeps only candidates that fit the device budget, the
//! per-module layer counts, and — when a per-GPU memory budget is set —
//! the capacity model of [`crate::memory`]: OOM-infeasible candidates are
//! rejected here, before the search ever simulates them.

use crate::api::ClusterSpec;
use crate::modality::{ModalityModule, MultimodalModule, Strategy};
use crate::telemetry::{self, key as tkey};

/// Which modules train — the §4.2 dimension DistTrain-style placement
/// search must be aware of, since it decides every stage's backward time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrozenSetting {
    /// The paper's recipe: encoders + LLM frozen, projectors trainable.
    Paper,
    /// Full fine-tuning: everything trainable.
    AllTrainable,
    /// Pure inference-style replay: nothing trainable anywhere.
    AllFrozen,
}

impl FrozenSetting {
    pub const ALL: [FrozenSetting; 3] = [
        FrozenSetting::Paper,
        FrozenSetting::AllTrainable,
        FrozenSetting::AllFrozen,
    ];

    pub fn key(&self) -> &'static str {
        match self {
            FrozenSetting::Paper => "paper",
            FrozenSetting::AllTrainable => "all",
            FrozenSetting::AllFrozen => "frozen",
        }
    }

    pub fn parse(s: &str) -> Option<FrozenSetting> {
        match s {
            "paper" => Some(FrozenSetting::Paper),
            "all" => Some(FrozenSetting::AllTrainable),
            "frozen" => Some(FrozenSetting::AllFrozen),
            _ => None,
        }
    }

    /// Rewrite a module tree's frozen flags in place.
    pub fn apply(&self, mm: &mut MultimodalModule) {
        let set = |m: &mut ModalityModule, frozen: bool, proj: bool| {
            m.frozen = frozen;
            m.projector_trainable = proj;
        };
        match self {
            // `MultimodalModule::from_spec` already builds the paper recipe.
            FrozenSetting::Paper => {}
            FrozenSetting::AllTrainable => {
                for e in &mut mm.encoders {
                    set(e, false, true);
                }
                mm.llm.frozen = false;
            }
            FrozenSetting::AllFrozen => {
                for e in &mut mm.encoders {
                    set(e, true, false);
                }
                mm.llm.frozen = true;
            }
        }
    }
}

/// One point of the joint configuration space.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Candidate {
    pub strategy: Strategy,
    /// Per-encoder stage counts in `MultimodalModule::encoders` order.
    /// Empty for [`Strategy::Replicated`] (encoders ride the LLM stages).
    pub enc_pps: Vec<usize>,
    pub llm_pp: usize,
    pub tp: usize,
    pub cp: usize,
    pub num_microbatches: usize,
    pub frozen: FrozenSetting,
    /// Cluster device-group per pipeline chain — the heterogeneous-pools
    /// dimension: one entry per encoder (in `enc_pps` order) followed by
    /// the LLM's, except [`Strategy::Replicated`] which has exactly one
    /// chain. Empty means "the single group of a homogeneous pool" —
    /// candidates enumerated against one-group clusters stay empty, so
    /// homogeneous labels, cache entries, and equality are unchanged.
    pub chain_groups: Vec<usize>,
}

impl Candidate {
    /// Total GPUs the candidate occupies (each stage is a `tp×cp` group).
    /// Colocated fuses every encoder into one shared chain of
    /// `enc_pps[0]` stages; Replicated reuses the LLM's groups for the
    /// encoders (`enc_pps` is empty).
    pub fn n_gpus(&self) -> usize {
        let groups = match self.strategy {
            Strategy::Colocated => {
                self.llm_pp + self.enc_pps.first().copied().unwrap_or(0)
            }
            _ => self.llm_pp + self.enc_pps.iter().sum::<usize>(),
        };
        groups * self.tp * self.cp
    }

    /// Compact human-readable form for tables and logs.
    pub fn label(&self) -> String {
        let groups = if self.chain_groups.is_empty() {
            String::new()
        } else {
            format!(" groups={:?}", self.chain_groups)
        };
        format!(
            "{} llm_pp={} enc_pp={:?} tp={} cp={} mb={} policy={}{}",
            self.strategy.key(),
            self.llm_pp,
            self.enc_pps,
            self.tp,
            self.cp,
            self.num_microbatches,
            self.frozen.key(),
            groups
        )
    }

    /// GPUs this candidate occupies in each of `n_groups` cluster
    /// groups, under its [`Candidate::chain_groups`] assignment (an
    /// empty assignment charges everything to group 0). Colocated fuses
    /// all encoders into one chain; Replicated has the LLM chain only.
    pub fn gpus_per_group(&self, n_groups: usize) -> Vec<usize> {
        let gps = self.tp * self.cp;
        let mut used = vec![0usize; n_groups.max(1)];
        let group_of = |chain: usize| -> usize {
            self.chain_groups.get(chain).copied().unwrap_or(0)
        };
        match self.strategy {
            Strategy::Replicated => {
                used[group_of(0)] += self.llm_pp * gps;
            }
            Strategy::Colocated => {
                if let Some(&enc_pp) = self.enc_pps.first() {
                    used[group_of(0)] += enc_pp * gps;
                }
                used[group_of(self.enc_pps.len())] += self.llm_pp * gps;
            }
            Strategy::Cornstarch => {
                for (i, &pp) in self.enc_pps.iter().enumerate() {
                    used[group_of(i)] += pp * gps;
                }
                used[group_of(self.enc_pps.len())] += self.llm_pp * gps;
            }
        }
        used
    }
}

/// Bounds of each search dimension.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// Total GPU budget; candidates must fit (they need not fill it).
    pub devices: usize,
    pub tp_choices: Vec<usize>,
    pub cp_choices: Vec<usize>,
    pub microbatch_choices: Vec<usize>,
    /// Cap on any single module's stage count (the paper caps at 6).
    pub max_pp: usize,
    pub strategies: Vec<Strategy>,
    pub frozen_choices: Vec<FrozenSetting>,
    /// Per-GPU memory budget in bytes; candidates whose modeled peak
    /// ([`crate::memory`]) exceeds it are rejected at enumeration time —
    /// never simulated. `None` disables the capacity filter.
    pub memory_budget_bytes: Option<u64>,
}

impl SearchSpace {
    /// The §6.1 defaults: tp/cp ∈ {1, 2}, 1 sample per microbatch, all
    /// three policies, the paper's frozen recipe, stages capped at 6, and
    /// the 40 GB A40 budget of Appendix D. Microbatch counts are swept
    /// around the paper's 24 — meaningful only because the memory filter
    /// prunes the counts whose 1F1B warm-up window cannot fit.
    pub fn paper_default(devices: usize) -> Self {
        assert!(devices >= 1);
        SearchSpace {
            devices,
            tp_choices: vec![1, 2],
            cp_choices: vec![1, 2],
            microbatch_choices: vec![8, 16, 24, 32],
            max_pp: 6,
            strategies: Strategy::ALL.to_vec(),
            frozen_choices: vec![FrozenSetting::Paper],
            memory_budget_bytes: Some(crate::api::cluster::A40_MEM_BYTES),
        }
    }

    /// The paper's search bounds sized to a cluster: the device pool and
    /// the per-GPU memory budget both come from the [`ClusterSpec`]
    /// instead of the hard-coded A40 testbed. For a heterogeneous pool
    /// `devices` is the total across groups and the scalar budget is the
    /// most permissive group's (enumeration then holds every stage to
    /// the budget of the group it actually lands on — the scalar only
    /// says "the capacity filter is on").
    pub fn for_cluster(cluster: &ClusterSpec) -> Self {
        let total = cluster.devices();
        let mut s = SearchSpace::paper_default(total.max(1));
        s.devices = total;
        s.memory_budget_bytes = Some(cluster.mem_budget_bytes());
        s
    }

    /// Stable fingerprint of the space bounds — part of the cache key, so
    /// a cache entry never answers for a differently-bounded search.
    pub fn fingerprint(&self) -> String {
        let keys: Vec<&str> =
            self.strategies.iter().map(|s| s.key()).collect();
        let frozen: Vec<&str> =
            self.frozen_choices.iter().map(|f| f.key()).collect();
        format!(
            "dev={}|tp={:?}|cp={:?}|mb={:?}|maxpp={}|strat={}|frozen={}|mem={:?}",
            self.devices,
            self.tp_choices,
            self.cp_choices,
            self.microbatch_choices,
            self.max_pp,
            keys.join(","),
            frozen.join(","),
            self.memory_budget_bytes
        )
    }
}

/// Max stage count of one encoder: its body layers plus the trailing
/// projector pseudo-layer (see `planner::encoder_layer_costs`).
fn enc_max_stages(e: &crate::modality::ModalityModule) -> usize {
    e.geom.n_layers + 1
}

/// Enumerate every candidate of `space` that is feasible for `mm`:
/// stage counts within layer counts, total GPUs within the budget, the
/// colocated policy's equal-encoder-stage constraint respected, and —
/// when the space carries a memory budget — a modeled peak per-GPU
/// footprint within capacity. The capacity filter is what makes the
/// joint microbatch sweep meaningful: a deep warm-up window at a high
/// microbatch count is rejected here instead of being simulated.
///
/// The memory verdicts are cluster-independent given the space's budget
/// (partition bounds only depend on relative layer costs, and peak bytes
/// do not depend on the time model), so the cluster used for the
/// internal plans cannot change which candidates survive.
pub fn enumerate(mm: &MultimodalModule, space: &SearchSpace) -> Vec<Candidate> {
    if space.memory_budget_bytes.is_none() {
        // No capacity filter: the cross product is the answer — skip
        // plan construction entirely.
        return raw_candidates(mm, space);
    }
    enumerate_with_plans(mm, space, &ClusterSpec::a40_default())
        .into_iter()
        .map(|(c, _)| c)
        .collect()
}

/// The geometric cross product (device budget + layer counts only).
fn raw_candidates(
    mm: &MultimodalModule,
    space: &SearchSpace,
) -> Vec<Candidate> {
    let mut raw = Vec::new();
    for &frozen in &space.frozen_choices {
        for &tp in &space.tp_choices {
            for &cp in &space.cp_choices {
                let groups = space.devices / (tp * cp);
                if groups == 0 {
                    continue;
                }
                for &mb in &space.microbatch_choices {
                    for &strategy in &space.strategies {
                        push_pp_splits(
                            mm, space, strategy, tp, cp, mb, frozen, groups,
                            &mut raw,
                        );
                    }
                }
            }
        }
    }
    telemetry::count(tkey::CANDIDATES_ENUMERATED, raw.len() as u64);
    raw
}

/// [`enumerate`], keeping the plan each candidate denotes (built on
/// `cluster`'s time model and comm pricing). This is the search's entry
/// point: the plan the memory filter had to build anyway is reused for
/// lower-bounding and simulation, so no candidate pays plan construction
/// twice.
///
/// On a heterogeneous cluster the group assignment is an extra search
/// dimension: every geometric candidate is expanded into the feasible
/// ways of placing its pipeline chains onto the cluster's device groups
/// (per-group GPU capacity respected), and each placement's stages are
/// held to the memory budget of the group they land on — so a frozen
/// encoder chain can survive on a 40 GB group while the LLM claims the
/// 80 GB one, and an OOM placement dies here, never simulated.
pub fn enumerate_with_plans(
    mm: &MultimodalModule,
    space: &SearchSpace,
    cluster: &ClusterSpec,
) -> Vec<(Candidate, crate::modality::Plan)> {
    let raw = raw_candidates(mm, space);
    // One frozen-rewritten module per policy, not one clone per
    // candidate.
    let variants: Vec<(FrozenSetting, MultimodalModule)> = space
        .frozen_choices
        .iter()
        .map(|&f| {
            let mut mm_f = mm.clone();
            f.apply(&mut mm_f);
            (f, mm_f)
        })
        .collect();
    let n_groups = cluster.groups.len();
    let mut out = Vec::with_capacity(raw.len());
    for c in raw {
        let (_, mm_f) = variants
            .iter()
            .find(|(f, _)| *f == c.frozen)
            .expect("candidate frozen setting comes from the space");
        if n_groups <= 1 {
            // Homogeneous pool: the assignment is trivial (and stays
            // empty, preserving pre-hetero candidates byte-for-byte).
            let plan = crate::modality::planner::plan(
                c.strategy,
                mm_f,
                &super::evaluate::spec_for(&c, cluster),
                cluster.device_model(),
            );
            if space
                .memory_budget_bytes
                .is_none_or(|budget| plan.peak_device_bytes() <= budget)
            {
                out.push((c, plan));
            } else {
                telemetry::incr(tkey::PRUNED_MEMORY);
            }
            continue;
        }
        for groups in assignment_choices(&c, n_groups) {
            let mut cand = c.clone();
            cand.chain_groups = groups;
            let demand = cand.gpus_per_group(n_groups);
            if demand
                .iter()
                .zip(&cluster.groups)
                .any(|(&used, g)| used > g.count)
            {
                telemetry::incr(tkey::PRUNED_GROUP_CAPACITY);
                continue;
            }
            let plan = crate::modality::planner::plan_assigned(
                cand.strategy,
                mm_f,
                &super::evaluate::spec_for(&cand, cluster),
                cluster,
                &cand.chain_groups,
            );
            // Each stage must fit min(space cap, its group's budget):
            // the group budget is the hardware truth, and a caller may
            // tighten the scalar cap below every group.
            if crate::memory::fits_assigned(
                &plan,
                cluster,
                space.memory_budget_bytes,
            ) {
                out.push((cand, plan));
            } else {
                telemetry::incr(tkey::PRUNED_MEMORY);
            }
        }
    }
    out
}

/// All group assignments of a candidate's chains onto `n_groups` cluster
/// groups, before capacity filtering: Replicated has one chain (the
/// LLM's), Colocated pins every encoder to one shared group (the fused
/// stages hold all encoders), Cornstarch assigns each chain freely.
fn assignment_choices(c: &Candidate, n_groups: usize) -> Vec<Vec<usize>> {
    let n_enc = c.enc_pps.len();
    match c.strategy {
        Strategy::Replicated => (0..n_groups).map(|g| vec![g]).collect(),
        Strategy::Colocated => {
            let mut out = Vec::with_capacity(n_groups * n_groups);
            for ge in 0..n_groups {
                for gl in 0..n_groups {
                    let mut v = vec![ge; n_enc];
                    v.push(gl);
                    out.push(v);
                }
            }
            out
        }
        Strategy::Cornstarch => {
            // Cartesian product over n_enc encoder chains + the LLM.
            let mut out: Vec<Vec<usize>> = vec![Vec::new()];
            for _ in 0..=n_enc {
                let mut next =
                    Vec::with_capacity(out.len() * n_groups);
                for base in &out {
                    for g in 0..n_groups {
                        let mut v = base.clone();
                        v.push(g);
                        next.push(v);
                    }
                }
                out = next;
            }
            out
        }
    }
}

/// Append all feasible (llm_pp, enc_pps) splits of `groups` device groups
/// for one (strategy, tp, cp, mb, frozen) combination.
#[allow(clippy::too_many_arguments)]
fn push_pp_splits(
    mm: &MultimodalModule,
    space: &SearchSpace,
    strategy: Strategy,
    tp: usize,
    cp: usize,
    mb: usize,
    frozen: FrozenSetting,
    groups: usize,
    out: &mut Vec<Candidate>,
) {
    let n_enc = mm.encoders.len();
    let llm_max = space.max_pp.min(mm.llm.geom.n_layers).min(groups);
    match strategy {
        Strategy::Replicated => {
            // Encoders are replicated into the LLM stages: the split is
            // the LLM depth alone.
            for llm_pp in 1..=llm_max {
                out.push(Candidate {
                    strategy,
                    enc_pps: Vec::new(),
                    llm_pp,
                    tp,
                    cp,
                    num_microbatches: mb,
                    frozen,
                    chain_groups: Vec::new(),
                });
            }
        }
        Strategy::Colocated => {
            // All encoders share one stage count (§6.3 constraint).
            if n_enc == 0 {
                return;
            }
            let enc_cap = space
                .max_pp
                .min(mm.encoders.iter().map(enc_max_stages).min().unwrap());
            for llm_pp in 1..=llm_max {
                for enc_pp in 1..=enc_cap {
                    if llm_pp + enc_pp <= groups {
                        out.push(Candidate {
                            strategy,
                            enc_pps: vec![enc_pp; n_enc],
                            llm_pp,
                            tp,
                            cp,
                            num_microbatches: mb,
                            frozen,
                            chain_groups: Vec::new(),
                        });
                    }
                }
            }
        }
        Strategy::Cornstarch => {
            if n_enc == 0 {
                return;
            }
            // Independent per-encoder depths: recurse over encoders.
            for llm_pp in 1..=llm_max {
                let left = match groups.checked_sub(llm_pp + n_enc) {
                    Some(slack) => slack,
                    None => continue, // not even 1 stage per encoder
                };
                let mut enc_pps = vec![1usize; n_enc];
                fill_encoders(
                    mm, space, 0, left, &mut enc_pps, &mut |pps: &[usize]| {
                        out.push(Candidate {
                            strategy,
                            enc_pps: pps.to_vec(),
                            llm_pp,
                            tp,
                            cp,
                            num_microbatches: mb,
                            frozen,
                            chain_groups: Vec::new(),
                        });
                    },
                );
            }
        }
    }
}

/// Recursively assign each encoder a stage count of `1 + extra` where the
/// `extra`s drawn across encoders never exceed `slack` spare groups.
fn fill_encoders(
    mm: &MultimodalModule,
    space: &SearchSpace,
    idx: usize,
    slack: usize,
    enc_pps: &mut Vec<usize>,
    emit: &mut dyn FnMut(&[usize]),
) {
    if idx == mm.encoders.len() {
        emit(enc_pps);
        return;
    }
    let cap = space.max_pp.min(enc_max_stages(&mm.encoders[idx]));
    for pp in 1..=cap.min(1 + slack) {
        enc_pps[idx] = pp;
        fill_encoders(mm, space, idx + 1, slack - (pp - 1), enc_pps, emit);
    }
    enc_pps[idx] = 1;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{MllmSpec, Size};

    fn vlm_mm() -> MultimodalModule {
        MultimodalModule::from_spec(&MllmSpec::vlm(Size::M, Size::M))
    }

    #[test]
    fn candidates_fit_the_budget() {
        let mm = vlm_mm();
        let space = SearchSpace::paper_default(16);
        let cands = enumerate(&mm, &space);
        assert!(!cands.is_empty());
        for c in &cands {
            assert!(c.n_gpus() <= 16, "{}", c.label());
            assert!(c.llm_pp >= 1 && c.llm_pp <= space.max_pp);
            for &pp in &c.enc_pps {
                assert!(pp >= 1 && pp <= space.max_pp);
            }
        }
    }

    #[test]
    fn all_three_strategies_appear() {
        let mm = vlm_mm();
        let cands = enumerate(&mm, &SearchSpace::paper_default(16));
        for s in Strategy::ALL {
            assert!(
                cands.iter().any(|c| c.strategy == s),
                "missing {}",
                s.name()
            );
        }
    }

    #[test]
    fn colocated_encoder_stages_are_equal() {
        let mm = MultimodalModule::from_spec(&MllmSpec::valm(
            Size::M,
            Size::M,
            Size::M,
        ));
        let cands = enumerate(&mm, &SearchSpace::paper_default(32));
        for c in cands.iter().filter(|c| c.strategy == Strategy::Colocated) {
            assert!(c.enc_pps.windows(2).all(|w| w[0] == w[1]));
        }
    }

    #[test]
    fn valm_cornstarch_splits_cover_both_encoders() {
        let mm = MultimodalModule::from_spec(&MllmSpec::valm(
            Size::M,
            Size::M,
            Size::M,
        ));
        let cands = enumerate(&mm, &SearchSpace::paper_default(24));
        let cs: Vec<_> = cands
            .iter()
            .filter(|c| c.strategy == Strategy::Cornstarch)
            .collect();
        assert!(!cs.is_empty());
        assert!(cs.iter().all(|c| c.enc_pps.len() == 2));
        // some candidate gives the two encoders different depths
        assert!(cs.iter().any(|c| c.enc_pps[0] != c.enc_pps[1]));
    }

    #[test]
    fn frozen_setting_rewrites_module_flags() {
        let mut mm = vlm_mm();
        FrozenSetting::AllTrainable.apply(&mut mm);
        assert!(!mm.llm.frozen);
        assert!(mm.encoders.iter().all(|e| !e.frozen));
        let mut mm2 = vlm_mm();
        FrozenSetting::AllFrozen.apply(&mut mm2);
        assert!(mm2.llm.frozen);
        assert!(!mm2.llm_has_trainable_upstream());
    }

    #[test]
    fn fingerprint_changes_with_budget() {
        let a = SearchSpace::paper_default(8).fingerprint();
        let b = SearchSpace::paper_default(16).fingerprint();
        assert_ne!(a, b);
    }

    #[test]
    fn for_cluster_takes_devices_and_memory_from_the_spec() {
        let a40 = ClusterSpec::a40_default();
        let s = SearchSpace::for_cluster(&a40);
        let d = SearchSpace::paper_default(16);
        assert_eq!(s.devices, 16);
        assert_eq!(s.memory_budget_bytes, d.memory_budget_bytes);
        assert_eq!(s.fingerprint(), d.fingerprint());
        let mut big = a40.clone().with_devices(8);
        big.groups[0].device.mem_bytes = 80_000_000_000;
        let s = SearchSpace::for_cluster(&big);
        assert_eq!(s.devices, 8);
        assert_eq!(s.memory_budget_bytes, Some(80_000_000_000));
        // heterogeneous: total pool, most permissive budget
        let hetero = ClusterSpec::a40_a100_demo();
        let s = SearchSpace::for_cluster(&hetero);
        assert_eq!(s.devices, 8);
        assert_eq!(s.memory_budget_bytes, Some(80_000_000_000));
    }

    #[test]
    fn hetero_enumeration_expands_and_prunes_assignments() {
        let cluster = ClusterSpec::a40_a100_demo();
        let mm = vlm_mm();
        let mut space = SearchSpace::for_cluster(&cluster);
        space.tp_choices = vec![2];
        space.cp_choices = vec![2];
        space.microbatch_choices = vec![8];
        space.strategies = vec![Strategy::Cornstarch];
        let pairs = enumerate_with_plans(&mm, &space, &cluster);
        assert!(!pairs.is_empty());
        for (c, plan) in &pairs {
            // every candidate carries a full assignment...
            assert_eq!(c.chain_groups.len(), c.enc_pps.len() + 1);
            assert!(c.chain_groups.iter().all(|&g| g < 2));
            // ...that respects per-group GPU capacity...
            let demand = c.gpus_per_group(2);
            assert!(demand[0] <= 4 && demand[1] <= 4, "{}", c.label());
            // ...and per-group memory where each stage lands
            for (sm, &g) in plan.stage_mem.iter().zip(&plan.stage_groups)
            {
                assert!(
                    sm.peak_bytes() <= cluster.group_mem_bytes(g),
                    "{}",
                    c.label()
                );
            }
            assert_eq!(plan.stage_groups.len(), plan.graph.nodes.len());
        }
        // both groups actually get used by some candidate
        assert!(pairs
            .iter()
            .any(|(c, _)| c.chain_groups.contains(&0)));
        assert!(pairs
            .iter()
            .any(|(c, _)| c.chain_groups.contains(&1)));
        // the same geometry appears under several assignments
        let geom_of = |c: &Candidate| {
            (c.enc_pps.clone(), c.llm_pp, c.num_microbatches)
        };
        let first = geom_of(&pairs[0].0);
        assert!(
            pairs.iter().filter(|(c, _)| geom_of(c) == first).count() > 1,
            "assignment expansion collapsed"
        );
    }

    // Assignment well-formedness (arity, index range, Colocated
    // uniformity) moved to the verifier's V005 lints — held by
    // `tests/verify_checks.rs::v005_assignment_rules_migrated_from_space`.

    #[test]
    fn hetero_filter_respects_a_tighter_scalar_cap() {
        // The space's scalar budget is a cap ON TOP of the per-group
        // budgets: a caller may tighten it below every group, and
        // heterogeneous enumeration must honor it (min of the two).
        let cluster = ClusterSpec::a40_a100_demo();
        let mm = vlm_mm();
        let mut space = SearchSpace::for_cluster(&cluster);
        space.tp_choices = vec![2];
        space.cp_choices = vec![2];
        space.microbatch_choices = vec![8];
        space.strategies = vec![Strategy::Cornstarch];
        let all = enumerate_with_plans(&mm, &space, &cluster);
        assert!(!all.is_empty());
        let max_peak = all
            .iter()
            .map(|(_, p)| p.peak_device_bytes())
            .max()
            .unwrap();
        space.memory_budget_bytes = Some(max_peak - 1);
        let capped = enumerate_with_plans(&mm, &space, &cluster);
        assert!(
            capped.len() < all.len(),
            "a cap below the worst surviving peak must prune something"
        );
        for (_, p) in &capped {
            assert!(p.peak_device_bytes() < max_peak);
        }
    }

    #[test]
    fn hetero_assignment_capacity_is_respected_per_group() {
        // A lopsided pool: 1 A40 + 4 A100. A 2-stage encoder chain can
        // never land on the single-device group at tp=cp=1.
        let mut cluster = ClusterSpec::a40_a100_demo();
        cluster.groups[0].count = 1;
        let mm = vlm_mm();
        let mut space = SearchSpace::for_cluster(&cluster);
        space.tp_choices = vec![1];
        space.cp_choices = vec![1];
        space.microbatch_choices = vec![8];
        space.strategies = vec![Strategy::Cornstarch];
        // capacity is the dimension under test, not memory
        space.memory_budget_bytes = None;
        let pairs = enumerate_with_plans(&mm, &space, &cluster);
        assert!(!pairs.is_empty());
        for (c, _) in &pairs {
            let demand = c.gpus_per_group(2);
            assert!(demand[0] <= 1, "over-packed group 0: {}", c.label());
            assert!(demand[1] <= 4, "over-packed group 1: {}", c.label());
        }
        // some multi-stage encoder chain exists and lands on the big
        // group — the single-device group cannot host it
        assert!(pairs.iter().any(|(c, _)| c.enc_pps == vec![2]
            && c.chain_groups[0] == 1));
        assert!(pairs
            .iter()
            .all(|(c, _)| !(c.enc_pps == vec![2] && c.chain_groups[0] == 0)));
    }

    #[test]
    fn memory_filter_prunes_oom_microbatch_counts() {
        // A deep tp=1 pipeline grows its 1F1B warm-up window with the
        // microbatch count; a budget between the best m=2 peak and the
        // best m=8 peak must keep m=2 candidates and reject every m=8
        // one — pruned at enumeration, never simulated.
        let spec = MllmSpec::vlm(Size::M, Size::M);
        let mm = MultimodalModule::from_spec(&spec);
        let mut space = SearchSpace::paper_default(8);
        space.tp_choices = vec![1];
        space.cp_choices = vec![1];
        space.strategies = vec![Strategy::Cornstarch];
        space.microbatch_choices = vec![2, 8];
        space.memory_budget_bytes = None;
        let all = enumerate(&mm, &space);
        let cl = ClusterSpec::a40_default();
        let peak = |c: &Candidate| {
            crate::tuner::evaluate::build_plan(&spec, c, &cl)
                .peak_device_bytes()
        };
        let min_of = |m: usize| {
            all.iter()
                .filter(|c| c.num_microbatches == m)
                .map(|c| peak(c))
                .min()
                .unwrap()
        };
        let (min2, min8) = (min_of(2), min_of(8));
        assert!(min2 < min8, "warm-up window must grow with m");
        space.memory_budget_bytes = Some(min8 - 1);
        let kept = enumerate(&mm, &space);
        assert!(!kept.is_empty());
        assert!(kept.iter().all(|c| c.num_microbatches == 2));
        assert!(kept.iter().all(|c| peak(c) < min8));
    }

    #[test]
    fn default_space_keeps_the_microbatch_sweep_live() {
        // The per-candidate budget assertion lives in
        // tests/tuner_checks.rs (the ISSUE's acceptance criterion); here
        // we only check the filter does not collapse the sweep.
        let mm = vlm_mm();
        let cands = enumerate(&mm, &SearchSpace::paper_default(16));
        assert!(!cands.is_empty());
        let mbs: std::collections::HashSet<usize> =
            cands.iter().map(|c| c.num_microbatches).collect();
        assert!(mbs.len() > 1, "microbatch sweep collapsed: {mbs:?}");
    }

    #[test]
    fn tiny_budget_yields_no_impossible_candidates() {
        // 1 GPU: only tp=cp=1, single-stage plans are geometrically
        // possible (memory filter off — a VLM-M does not fit one A40).
        let mm = vlm_mm();
        let mut space = SearchSpace::paper_default(1);
        space.memory_budget_bytes = None;
        let cands = enumerate(&mm, &space);
        assert!(!cands.is_empty());
        for c in &cands {
            assert_eq!(c.n_gpus(), 1, "{}", c.label());
        }
        // replicated with llm_pp=1 fits; cornstarch needs >= 2 groups.
        assert!(cands
            .iter()
            .all(|c| c.strategy != Strategy::Cornstarch));
        // ...and with the A40 budget on, nothing survives: the whole
        // model on one GPU is exactly the OOM the filter exists for.
        let filtered = enumerate(&mm, &SearchSpace::paper_default(1));
        assert!(filtered.is_empty());
    }
}
