//! Persistent plan cache: repeated tuning queries are O(1).
//!
//! Entries are keyed by a *signature* — a deterministic string over the
//! workload (MLLM composition, frozen policy, microbatching) and the
//! cluster/search bounds ([`super::space::SearchSpace::fingerprint`] plus
//! the objective and budget) — so a cached answer is only ever returned
//! for an identical query. The store is a single JSON file written
//! atomically (temp file + rename); a missing or corrupt file degrades to
//! an empty cache, never an error.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::modality::Strategy;
use crate::util::json::Json;

use super::space::{Candidate, FrozenSetting};

/// One cached tuning answer.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheEntry {
    pub signature: String,
    pub candidate: Candidate,
    pub iteration_ms: f64,
    pub throughput_per_gpu: f64,
    pub n_gpus: usize,
    /// Recommended CP token-distribution algorithm ("none" when cp = 1).
    pub cp_algorithm: String,
    /// How many candidates the original search simulated.
    pub evaluated: usize,
}

impl CacheEntry {
    fn to_json(&self) -> Json {
        let c = &self.candidate;
        Json::obj(vec![
            ("signature", Json::Str(self.signature.clone())),
            ("strategy", Json::Str(c.strategy.key().to_string())),
            (
                "enc_pps",
                Json::Arr(
                    c.enc_pps.iter().map(|&p| Json::Int(p as i64)).collect(),
                ),
            ),
            ("llm_pp", Json::Int(c.llm_pp as i64)),
            ("tp", Json::Int(c.tp as i64)),
            ("cp", Json::Int(c.cp as i64)),
            ("microbatches", Json::Int(c.num_microbatches as i64)),
            ("frozen", Json::Str(c.frozen.key().to_string())),
            ("iteration_ms", Json::Num(self.iteration_ms)),
            ("throughput_per_gpu", Json::Num(self.throughput_per_gpu)),
            ("n_gpus", Json::Int(self.n_gpus as i64)),
            ("cp_algorithm", Json::Str(self.cp_algorithm.clone())),
            ("evaluated", Json::Int(self.evaluated as i64)),
        ])
    }

    fn from_json(j: &Json) -> Option<CacheEntry> {
        let us = |k: &str| -> Option<usize> {
            j.get(k)?.as_i64().and_then(|v| usize::try_from(v).ok())
        };
        let enc_pps: Option<Vec<usize>> = j
            .get("enc_pps")?
            .as_arr()?
            .iter()
            .map(|v| v.as_i64().and_then(|x| usize::try_from(x).ok()))
            .collect();
        Some(CacheEntry {
            signature: j.get("signature")?.as_str()?.to_string(),
            candidate: Candidate {
                strategy: Strategy::from_key(j.get("strategy")?.as_str()?)?,
                enc_pps: enc_pps?,
                llm_pp: us("llm_pp")?,
                tp: us("tp")?,
                cp: us("cp")?,
                num_microbatches: us("microbatches")?,
                frozen: FrozenSetting::parse(j.get("frozen")?.as_str()?)?,
            },
            iteration_ms: j.get("iteration_ms")?.as_f64()?,
            throughput_per_gpu: j.get("throughput_per_gpu")?.as_f64()?,
            n_gpus: us("n_gpus")?,
            cp_algorithm: j.get("cp_algorithm")?.as_str()?.to_string(),
            evaluated: us("evaluated")?,
        })
    }
}

/// The on-disk store. `path = None` gives an in-memory cache (used when
/// the CLI runs without `--cache`).
#[derive(Debug, Default)]
pub struct PlanCache {
    path: Option<PathBuf>,
    entries: Vec<CacheEntry>,
}

/// Bumped when the entry schema or the scoring model changes
/// incompatibly; files with another version are ignored wholesale.
const CACHE_VERSION: i64 = 1;

impl PlanCache {
    pub fn in_memory() -> Self {
        PlanCache::default()
    }

    /// Load from `path`; missing or unreadable files yield an empty cache
    /// bound to that path (it will be created on the first `save`).
    pub fn load(path: &Path) -> Self {
        let entries = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .filter(|j| {
                j.get("version").and_then(Json::as_i64)
                    == Some(CACHE_VERSION)
            })
            .and_then(|j| {
                j.get("entries").and_then(Json::as_arr).map(|xs| {
                    xs.iter().filter_map(CacheEntry::from_json).collect()
                })
            })
            .unwrap_or_default();
        PlanCache { path: Some(path.to_path_buf()), entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn lookup(&self, signature: &str) -> Option<&CacheEntry> {
        self.entries.iter().find(|e| e.signature == signature)
    }

    /// Insert or replace the entry for its signature.
    pub fn insert(&mut self, entry: CacheEntry) {
        match self
            .entries
            .iter_mut()
            .find(|e| e.signature == entry.signature)
        {
            Some(slot) => *slot = entry,
            None => self.entries.push(entry),
        }
    }

    /// Persist to the bound path (no-op for in-memory caches). Atomic:
    /// write a sibling temp file, then rename over the target. Entries
    /// another process wrote since our load are re-read and kept (ours
    /// win per signature), so concurrent tuners sharing one file don't
    /// drop each other's results.
    pub fn save(&self) -> Result<()> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        let mut merged = PlanCache::load(path).entries;
        for e in &self.entries {
            match merged.iter_mut().find(|m| m.signature == e.signature) {
                Some(slot) => *slot = e.clone(),
                None => merged.push(e.clone()),
            }
        }
        let doc = Json::obj(vec![
            ("version", Json::Int(CACHE_VERSION)),
            (
                "entries",
                Json::Arr(merged.iter().map(|e| e.to_json()).collect()),
            ),
        ]);
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, doc.render())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(sig: &str, llm_pp: usize) -> CacheEntry {
        CacheEntry {
            signature: sig.to_string(),
            candidate: Candidate {
                strategy: Strategy::Cornstarch,
                enc_pps: vec![1, 2],
                llm_pp,
                tp: 2,
                cp: 2,
                num_microbatches: 24,
                frozen: FrozenSetting::Paper,
            },
            iteration_ms: 123.5,
            throughput_per_gpu: 0.042,
            n_gpus: 16,
            cp_algorithm: "LPT".to_string(),
            evaluated: 37,
        }
    }

    fn tmp_path(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cornstarch-cache-test-{name}-{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_through_disk() {
        let path = tmp_path("roundtrip");
        let _ = std::fs::remove_file(&path);
        let mut c = PlanCache::load(&path);
        assert!(c.is_empty());
        c.insert(entry("sig-a", 3));
        c.insert(entry("sig-b", 4));
        c.save().unwrap();
        let c2 = PlanCache::load(&path);
        assert_eq!(c2.len(), 2);
        assert_eq!(c2.lookup("sig-a"), Some(&entry("sig-a", 3)));
        assert_eq!(c2.lookup("sig-b"), Some(&entry("sig-b", 4)));
        assert!(c2.lookup("sig-c").is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn insert_replaces_same_signature() {
        let mut c = PlanCache::in_memory();
        c.insert(entry("s", 2));
        c.insert(entry("s", 5));
        assert_eq!(c.len(), 1);
        assert_eq!(c.lookup("s").unwrap().candidate.llm_pp, 5);
    }

    #[test]
    fn save_merges_entries_written_by_another_process() {
        let path = tmp_path("merge");
        let _ = std::fs::remove_file(&path);
        let mut a = PlanCache::load(&path);
        let mut b = PlanCache::load(&path);
        a.insert(entry("sig-a", 2));
        a.save().unwrap();
        b.insert(entry("sig-b", 3));
        b.save().unwrap(); // must not drop sig-a
        let c = PlanCache::load(&path);
        assert_eq!(c.len(), 2);
        assert!(c.lookup("sig-a").is_some());
        assert!(c.lookup("sig-b").is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_file_degrades_to_empty() {
        let path = tmp_path("corrupt");
        std::fs::write(&path, "not json at all {{{{").unwrap();
        let c = PlanCache::load(&path);
        assert!(c.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn version_mismatch_ignored() {
        let path = tmp_path("version");
        std::fs::write(&path, r#"{"version":999,"entries":[{}]}"#).unwrap();
        let c = PlanCache::load(&path);
        assert!(c.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn in_memory_save_is_noop() {
        let mut c = PlanCache::in_memory();
        c.insert(entry("x", 1));
        c.save().unwrap();
        assert_eq!(c.len(), 1);
    }
}
