//! Persistent plan cache: repeated tuning queries are O(1).
//!
//! Entries are keyed by a *signature* — a deterministic string over the
//! workload (MLLM composition, frozen policy, microbatching) and the
//! search bounds ([`super::space::SearchSpace::fingerprint`] plus the
//! objective and budget) — **and** by the cluster fingerprint
//! ([`crate::api::ClusterSpec::fingerprint`]) the plan was searched for,
//! stored on every entry: a lookup must match both, so an answer tuned
//! for one hardware pool can never serve another (a different memory
//! budget readmits different candidates; a different bandwidth prices
//! comm differently). An entry whose stored fingerprint is *absent* is
//! rejected at load, not defaulted — a pre-`ClusterSpec` entry must not
//! satisfy a v3 lookup. Each entry stores the search's **top-k
//! frontier** (best first), not just a single winner: consumers trade
//! throughput against GPU count and memory headroom without
//! re-searching. The store is a single JSON file written atomically
//! (temp file + rename); a missing, corrupt, or version-skewed file
//! (including the retired v2 layout) degrades to an empty cache, never
//! an error.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::modality::Strategy;
use crate::util::json::Json;

use super::space::{Candidate, FrozenSetting};

/// One ranked plan of a cached frontier.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanSummary {
    pub candidate: Candidate,
    pub iteration_ms: f64,
    pub throughput_per_gpu: f64,
    pub n_gpus: usize,
    /// Modeled peak per-GPU bytes ([`crate::memory`]).
    pub peak_mem_bytes: u64,
    /// Recommended CP token-distribution algorithm ("none" when cp = 1).
    pub cp_algorithm: String,
}

impl PlanSummary {
    fn to_json(&self) -> Json {
        let c = &self.candidate;
        Json::obj(vec![
            ("strategy", Json::Str(c.strategy.key().to_string())),
            (
                "enc_pps",
                Json::Arr(
                    c.enc_pps.iter().map(|&p| Json::Int(p as i64)).collect(),
                ),
            ),
            ("llm_pp", Json::Int(c.llm_pp as i64)),
            ("tp", Json::Int(c.tp as i64)),
            ("cp", Json::Int(c.cp as i64)),
            ("microbatches", Json::Int(c.num_microbatches as i64)),
            ("frozen", Json::Str(c.frozen.key().to_string())),
            ("iteration_ms", Json::Num(self.iteration_ms)),
            ("throughput_per_gpu", Json::Num(self.throughput_per_gpu)),
            ("n_gpus", Json::Int(self.n_gpus as i64)),
            ("peak_mem_bytes", Json::Int(self.peak_mem_bytes as i64)),
            ("cp_algorithm", Json::Str(self.cp_algorithm.clone())),
        ])
    }

    fn from_json(j: &Json) -> Option<PlanSummary> {
        let us = |k: &str| -> Option<usize> {
            j.get(k)?.as_i64().and_then(|v| usize::try_from(v).ok())
        };
        let enc_pps: Option<Vec<usize>> = j
            .get("enc_pps")?
            .as_arr()?
            .iter()
            .map(|v| v.as_i64().and_then(|x| usize::try_from(x).ok()))
            .collect();
        Some(PlanSummary {
            candidate: Candidate {
                strategy: Strategy::from_key(j.get("strategy")?.as_str()?)?,
                enc_pps: enc_pps?,
                llm_pp: us("llm_pp")?,
                tp: us("tp")?,
                cp: us("cp")?,
                num_microbatches: us("microbatches")?,
                frozen: FrozenSetting::parse(j.get("frozen")?.as_str()?)?,
            },
            iteration_ms: j.get("iteration_ms")?.as_f64()?,
            throughput_per_gpu: j.get("throughput_per_gpu")?.as_f64()?,
            n_gpus: us("n_gpus")?,
            peak_mem_bytes: j
                .get("peak_mem_bytes")?
                .as_i64()
                .and_then(|v| u64::try_from(v).ok())?,
            cp_algorithm: j.get("cp_algorithm")?.as_str()?.to_string(),
        })
    }
}

/// One cached tuning answer: the frontier the search kept, best first.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheEntry {
    pub signature: String,
    /// Fingerprint of the [`crate::api::ClusterSpec`] the entry was
    /// searched for. A lookup must present the same fingerprint; entries
    /// persisted without one (pre-v3 files) are rejected at load.
    pub cluster: String,
    /// Best-first frontier; never empty — `frontier[0]` is the winner.
    pub frontier: Vec<PlanSummary>,
    /// Frontier depth the writing query searched for. May exceed
    /// `frontier.len()` when the space held fewer plans — that is how a
    /// later, deeper query tells "the space ran out" (serve the hit)
    /// from "the writer asked for less" (re-search).
    pub top_k: usize,
    /// How many candidates the original search simulated.
    pub evaluated: usize,
}

impl CacheEntry {
    /// The winner.
    pub fn best(&self) -> &PlanSummary {
        &self.frontier[0]
    }

    /// Can this entry answer a query that wants a `top`-deep frontier?
    /// Yes when it stores that many plans, or when its own search
    /// already looked at least that deep (the space simply had fewer).
    pub fn satisfies_top(&self, top: usize) -> bool {
        self.frontier.len() >= top || self.top_k >= top
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("signature", Json::Str(self.signature.clone())),
            ("cluster", Json::Str(self.cluster.clone())),
            ("top_k", Json::Int(self.top_k as i64)),
            ("evaluated", Json::Int(self.evaluated as i64)),
            (
                "frontier",
                Json::Arr(
                    self.frontier.iter().map(|p| p.to_json()).collect(),
                ),
            ),
        ])
    }

    fn from_json(j: &Json) -> Option<CacheEntry> {
        let frontier: Option<Vec<PlanSummary>> = j
            .get("frontier")?
            .as_arr()?
            .iter()
            .map(PlanSummary::from_json)
            .collect();
        let frontier = frontier?;
        if frontier.is_empty() {
            return None;
        }
        Some(CacheEntry {
            signature: j.get("signature")?.as_str()?.to_string(),
            // Absent fingerprint => reject the entry (the `?`), never
            // default it: an entry that does not say what hardware it
            // was tuned for must not answer any lookup.
            cluster: j.get("cluster")?.as_str()?.to_string(),
            frontier,
            top_k: j
                .get("top_k")?
                .as_i64()
                .and_then(|v| usize::try_from(v).ok())?,
            evaluated: j
                .get("evaluated")?
                .as_i64()
                .and_then(|v| usize::try_from(v).ok())?,
        })
    }
}

/// The on-disk store. `path = None` gives an in-memory cache (used when
/// the CLI runs without `--cache`).
#[derive(Debug, Default)]
pub struct PlanCache {
    path: Option<PathBuf>,
    entries: Vec<CacheEntry>,
}

/// Bumped when the entry schema or the scoring model changes
/// incompatibly; files with another version are ignored wholesale.
/// v2: top-k `frontier` per signature (was a flat single winner) plus
/// per-plan `peak_mem_bytes` from the memory model.
/// v3: per-entry `cluster` fingerprint ([`crate::api::ClusterSpec`]);
/// entries without one are rejected at load, and v2 files degrade to an
/// empty cache.
const CACHE_VERSION: i64 = 3;

impl PlanCache {
    pub fn in_memory() -> Self {
        PlanCache::default()
    }

    /// Load from `path`; missing or unreadable files yield an empty cache
    /// bound to that path (it will be created on the first `save`).
    pub fn load(path: &Path) -> Self {
        let entries = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .filter(|j| {
                j.get("version").and_then(Json::as_i64)
                    == Some(CACHE_VERSION)
            })
            .and_then(|j| {
                j.get("entries").and_then(Json::as_arr).map(|xs| {
                    xs.iter().filter_map(CacheEntry::from_json).collect()
                })
            })
            .unwrap_or_default();
        PlanCache { path: Some(path.to_path_buf()), entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Find the entry for `signature` that was searched for `cluster`
    /// (a [`crate::api::ClusterSpec::fingerprint`]). Both must match: a
    /// plan tuned for one hardware pool never answers for another.
    pub fn lookup(
        &self,
        signature: &str,
        cluster: &str,
    ) -> Option<&CacheEntry> {
        self.entries
            .iter()
            .find(|e| e.signature == signature && e.cluster == cluster)
    }

    /// Insert or replace the entry for its signature.
    pub fn insert(&mut self, entry: CacheEntry) {
        assert!(
            !entry.frontier.is_empty(),
            "a cache entry must carry at least its winner"
        );
        match self
            .entries
            .iter_mut()
            .find(|e| e.signature == entry.signature)
        {
            Some(slot) => *slot = entry,
            None => self.entries.push(entry),
        }
    }

    /// Persist to the bound path (no-op for in-memory caches). Atomic:
    /// write a sibling temp file, then rename over the target. Entries
    /// another process wrote since our load are re-read and kept (ours
    /// win per signature), so concurrent tuners sharing one file don't
    /// drop each other's results.
    pub fn save(&self) -> Result<()> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        let mut merged = PlanCache::load(path).entries;
        for e in &self.entries {
            match merged.iter_mut().find(|m| m.signature == e.signature) {
                Some(slot) => *slot = e.clone(),
                None => merged.push(e.clone()),
            }
        }
        let doc = Json::obj(vec![
            ("version", Json::Int(CACHE_VERSION)),
            (
                "entries",
                Json::Arr(merged.iter().map(|e| e.to_json()).collect()),
            ),
        ]);
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, doc.render())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary(llm_pp: usize) -> PlanSummary {
        PlanSummary {
            candidate: Candidate {
                strategy: Strategy::Cornstarch,
                enc_pps: vec![1, 2],
                llm_pp,
                tp: 2,
                cp: 2,
                num_microbatches: 24,
                frozen: FrozenSetting::Paper,
            },
            iteration_ms: 123.5 + llm_pp as f64,
            throughput_per_gpu: 0.042,
            n_gpus: 16,
            peak_mem_bytes: 31_400_000_000,
            cp_algorithm: "LPT".to_string(),
        }
    }

    fn entry(sig: &str, llm_pp: usize) -> CacheEntry {
        CacheEntry {
            signature: sig.to_string(),
            cluster: "n=16|mem=40000000000".to_string(),
            frontier: vec![summary(llm_pp), summary(llm_pp + 1)],
            top_k: 2,
            evaluated: 37,
        }
    }

    const FP: &str = "n=16|mem=40000000000";

    fn tmp_path(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cornstarch-cache-test-{name}-{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_through_disk() {
        let path = tmp_path("roundtrip");
        let _ = std::fs::remove_file(&path);
        let mut c = PlanCache::load(&path);
        assert!(c.is_empty());
        c.insert(entry("sig-a", 3));
        c.insert(entry("sig-b", 4));
        c.save().unwrap();
        let c2 = PlanCache::load(&path);
        assert_eq!(c2.len(), 2);
        assert_eq!(c2.lookup("sig-a", FP), Some(&entry("sig-a", 3)));
        assert_eq!(c2.lookup("sig-b", FP), Some(&entry("sig-b", 4)));
        assert!(c2.lookup("sig-c", FP).is_none());
        // same signature, other hardware: never an answer
        assert!(c2.lookup("sig-a", "n=16|mem=80000000000").is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn frontier_order_survives_the_roundtrip() {
        let path = tmp_path("frontier");
        let _ = std::fs::remove_file(&path);
        let mut c = PlanCache::load(&path);
        c.insert(entry("s", 2));
        c.save().unwrap();
        let c2 = PlanCache::load(&path);
        let e = c2.lookup("s", FP).unwrap();
        assert_eq!(e.frontier.len(), 2);
        assert_eq!(e.best(), &e.frontier[0]);
        assert_eq!(e.best().candidate.llm_pp, 2);
        assert_eq!(e.frontier[1].candidate.llm_pp, 3);
        assert_eq!(e.best().peak_mem_bytes, 31_400_000_000);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn satisfies_top_distinguishes_shallow_writer_from_small_space() {
        let e = entry("s", 3); // 2 plans stored, searched top_k = 2
        assert!(e.satisfies_top(1));
        assert!(e.satisfies_top(2));
        assert!(!e.satisfies_top(3), "writer only looked 2 deep");
        let mut exhausted = entry("s", 3);
        exhausted.top_k = 10; // searched 10 deep, space held only 2
        assert!(exhausted.satisfies_top(5));
    }

    #[test]
    fn insert_replaces_same_signature() {
        let mut c = PlanCache::in_memory();
        c.insert(entry("s", 2));
        c.insert(entry("s", 5));
        assert_eq!(c.len(), 1);
        assert_eq!(c.lookup("s", FP).unwrap().best().candidate.llm_pp, 5);
    }

    #[test]
    fn save_merges_entries_written_by_another_process() {
        let path = tmp_path("merge");
        let _ = std::fs::remove_file(&path);
        let mut a = PlanCache::load(&path);
        let mut b = PlanCache::load(&path);
        a.insert(entry("sig-a", 2));
        a.save().unwrap();
        b.insert(entry("sig-b", 3));
        b.save().unwrap(); // must not drop sig-a
        let c = PlanCache::load(&path);
        assert_eq!(c.len(), 2);
        assert!(c.lookup("sig-a", FP).is_some());
        assert!(c.lookup("sig-b", FP).is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_file_degrades_to_empty() {
        let path = tmp_path("corrupt");
        std::fs::write(&path, "not json at all {{{{").unwrap();
        let c = PlanCache::load(&path);
        assert!(c.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn version_skew_is_ignored_wholesale() {
        // A future version, the retired v1 single-winner layout, and the
        // retired v2 cluster-less frontier layout all degrade to an
        // empty cache (and are rebuilt on the next save).
        let path = tmp_path("version");
        std::fs::write(&path, r#"{"version":999,"entries":[{}]}"#).unwrap();
        assert!(PlanCache::load(&path).is_empty());
        std::fs::write(
            &path,
            r#"{"version":1,"entries":[{"signature":"s","strategy":"cornstarch","enc_pps":[1],"llm_pp":3,"tp":2,"cp":2,"microbatches":24,"frozen":"paper","iteration_ms":1.0,"throughput_per_gpu":0.1,"n_gpus":16,"cp_algorithm":"LPT","evaluated":5}]}"#,
        )
        .unwrap();
        assert!(PlanCache::load(&path).is_empty());
        std::fs::write(
            &path,
            r#"{"version":2,"entries":[{"signature":"s","top_k":1,"evaluated":5,"frontier":[{"strategy":"cornstarch","enc_pps":[1],"llm_pp":3,"tp":2,"cp":2,"microbatches":24,"frozen":"paper","iteration_ms":1.0,"throughput_per_gpu":0.1,"n_gpus":16,"peak_mem_bytes":1000,"cp_algorithm":"LPT"}]}]}"#,
        )
        .unwrap();
        assert!(PlanCache::load(&path).is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn entry_without_cluster_fingerprint_is_rejected() {
        // A v3-versioned file whose entry lacks the stored cluster
        // fingerprint must drop that entry — never default it. (This is
        // exactly the shape a hand-migrated v2 entry would have.)
        let path = tmp_path("nocluster");
        let mut good = entry("kept", 3);
        good.cluster = "n=16|mem=40000000000".to_string();
        let mut store = PlanCache::load(&path);
        store.insert(good);
        store.save().unwrap();
        // strip the "cluster" field from the written JSON (the writer
        // renders compact `"k":v` pairs)
        let text = std::fs::read_to_string(&path).unwrap();
        let stripped =
            text.replace(r#""cluster":"n=16|mem=40000000000","#, "");
        assert_ne!(text, stripped, "fixture must actually strip the field");
        std::fs::write(&path, stripped).unwrap();
        assert!(
            PlanCache::load(&path).is_empty(),
            "a fingerprint-less entry satisfied a v3 load"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn entry_without_frontier_is_dropped_not_fatal() {
        let path = tmp_path("nofrontier");
        std::fs::write(
            &path,
            r#"{"version":3,"entries":[{"signature":"s","cluster":"n=16","evaluated":1,"frontier":[]}]}"#,
        )
        .unwrap();
        assert!(PlanCache::load(&path).is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn in_memory_save_is_noop() {
        let mut c = PlanCache::in_memory();
        c.insert(entry("x", 1));
        c.save().unwrap();
        assert_eq!(c.len(), 1);
    }
}
