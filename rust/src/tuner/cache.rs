//! Persistent plan cache: repeated tuning queries are O(1).
//!
//! Entries are keyed by a *signature* — a deterministic string over the
//! workload (MLLM composition, frozen policy, microbatching) and the
//! search bounds ([`super::space::SearchSpace::fingerprint`] plus the
//! objective and budget) — **and** by the cluster fingerprint
//! ([`crate::api::ClusterSpec::fingerprint`]) the plan was searched for,
//! stored on every entry: a lookup must match both, so an answer tuned
//! for one hardware pool can never serve another (a different memory
//! budget readmits different candidates; a different bandwidth prices
//! comm differently). Since schema v4 the fingerprint covers the **full
//! heterogeneous pool** (every device group's count, memory, flops/MFU,
//! and link), and each cached plan stores its chain→group assignment
//! (`groups`) — so a heterogeneous answer never aliases, or is served
//! to, a homogeneous query of the same size. An entry whose stored
//! fingerprint or assignment is *absent* is rejected at load, not
//! defaulted. Each entry stores the search's **top-k frontier** (best
//! first), not just a single winner: consumers trade throughput against
//! GPU count and memory headroom without re-searching. The store is a
//! single JSON file written atomically (unique temp file + rename)
//! under a process-wide per-path lock, merging entries other writers
//! persisted since our load — concurrent tuners sharing one file lose
//! nothing; a missing, corrupt, or version-skewed file (including the
//! retired v1–v3 layouts) degrades to an empty cache, never an error.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{Context, Result};

use crate::modality::Strategy;
use crate::util::json::Json;

use super::space::{Candidate, FrozenSetting};

/// One ranked plan of a cached frontier.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanSummary {
    pub candidate: Candidate,
    pub iteration_ms: f64,
    pub throughput_per_gpu: f64,
    pub n_gpus: usize,
    /// Modeled peak per-GPU bytes ([`crate::memory`]).
    pub peak_mem_bytes: u64,
    /// Recommended CP token-distribution algorithm ("none" when cp = 1).
    pub cp_algorithm: String,
}

impl PlanSummary {
    fn to_json(&self) -> Json {
        let c = &self.candidate;
        Json::obj(vec![
            ("strategy", Json::Str(c.strategy.key().to_string())),
            (
                "enc_pps",
                Json::Arr(
                    c.enc_pps.iter().map(|&p| Json::Int(p as i64)).collect(),
                ),
            ),
            (
                "groups",
                Json::Arr(
                    c.chain_groups
                        .iter()
                        .map(|&g| Json::Int(g as i64))
                        .collect(),
                ),
            ),
            ("llm_pp", Json::Int(c.llm_pp as i64)),
            ("tp", Json::Int(c.tp as i64)),
            ("cp", Json::Int(c.cp as i64)),
            ("microbatches", Json::Int(c.num_microbatches as i64)),
            ("frozen", Json::Str(c.frozen.key().to_string())),
            ("iteration_ms", Json::Num(self.iteration_ms)),
            ("throughput_per_gpu", Json::Num(self.throughput_per_gpu)),
            ("n_gpus", Json::Int(self.n_gpus as i64)),
            (
                // Checked, saturating: our JSON layer carries ints as
                // i64, and a modeled peak above i64::MAX (9.2 EB —
                // only a pathological model emits one) must not wrap
                // negative, which `as i64` did and which made
                // `from_json`'s u64 conversion silently drop the whole
                // entry on reload. Policy: saturate to i64::MAX and
                // keep the entry; the value is already nonsense, but a
                // nonsense *peak* still prices worse than any real
                // plan, while a dropped entry re-searches forever.
                "peak_mem_bytes",
                Json::Int(
                    i64::try_from(self.peak_mem_bytes).unwrap_or(i64::MAX),
                ),
            ),
            ("cp_algorithm", Json::Str(self.cp_algorithm.clone())),
        ])
    }

    fn from_json(j: &Json) -> Option<PlanSummary> {
        let us = |k: &str| -> Option<usize> {
            j.get(k)?.as_i64().and_then(|v| usize::try_from(v).ok())
        };
        let enc_pps: Option<Vec<usize>> = j
            .get("enc_pps")?
            .as_arr()?
            .iter()
            .map(|v| v.as_i64().and_then(|x| usize::try_from(x).ok()))
            .collect();
        // v4: the group assignment is load-bearing (it decides which
        // device prices each chain) — an entry without one is rejected,
        // never defaulted.
        let chain_groups: Option<Vec<usize>> = j
            .get("groups")?
            .as_arr()?
            .iter()
            .map(|v| v.as_i64().and_then(|x| usize::try_from(x).ok()))
            .collect();
        Some(PlanSummary {
            candidate: Candidate {
                strategy: Strategy::from_key(j.get("strategy")?.as_str()?)?,
                enc_pps: enc_pps?,
                llm_pp: us("llm_pp")?,
                tp: us("tp")?,
                cp: us("cp")?,
                num_microbatches: us("microbatches")?,
                frozen: FrozenSetting::parse(j.get("frozen")?.as_str()?)?,
                chain_groups: chain_groups?,
            },
            iteration_ms: j.get("iteration_ms")?.as_f64()?,
            throughput_per_gpu: j.get("throughput_per_gpu")?.as_f64()?,
            n_gpus: us("n_gpus")?,
            peak_mem_bytes: j
                .get("peak_mem_bytes")?
                .as_i64()
                .and_then(|v| u64::try_from(v).ok())?,
            cp_algorithm: j.get("cp_algorithm")?.as_str()?.to_string(),
        })
    }
}

/// One cached tuning answer: the frontier the search kept, best first.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheEntry {
    pub signature: String,
    /// Fingerprint of the [`crate::api::ClusterSpec`] the entry was
    /// searched for. A lookup must present the same fingerprint; entries
    /// persisted without one (pre-v3 files) are rejected at load.
    pub cluster: String,
    /// Best-first frontier; never empty — `frontier[0]` is the winner.
    pub frontier: Vec<PlanSummary>,
    /// Frontier depth the writing query searched for. May exceed
    /// `frontier.len()` when the space held fewer plans — that is how a
    /// later, deeper query tells "the space ran out" (serve the hit)
    /// from "the writer asked for less" (re-search).
    pub top_k: usize,
    /// How many candidates the original search simulated.
    pub evaluated: usize,
}

impl CacheEntry {
    /// The winner.
    pub fn best(&self) -> &PlanSummary {
        &self.frontier[0]
    }

    /// Can this entry answer a query that wants a `top`-deep frontier?
    /// Yes when it stores that many plans, or when its own search
    /// already looked at least that deep (the space simply had fewer).
    pub fn satisfies_top(&self, top: usize) -> bool {
        self.frontier.len() >= top || self.top_k >= top
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("signature", Json::Str(self.signature.clone())),
            ("cluster", Json::Str(self.cluster.clone())),
            ("top_k", Json::Int(self.top_k as i64)),
            ("evaluated", Json::Int(self.evaluated as i64)),
            (
                "frontier",
                Json::Arr(
                    self.frontier.iter().map(|p| p.to_json()).collect(),
                ),
            ),
        ])
    }

    fn from_json(j: &Json) -> Option<CacheEntry> {
        let frontier: Option<Vec<PlanSummary>> = j
            .get("frontier")?
            .as_arr()?
            .iter()
            .map(PlanSummary::from_json)
            .collect();
        let frontier = frontier?;
        if frontier.is_empty() {
            return None;
        }
        Some(CacheEntry {
            signature: j.get("signature")?.as_str()?.to_string(),
            // Absent fingerprint => reject the entry (the `?`), never
            // default it: an entry that does not say what hardware it
            // was tuned for must not answer any lookup.
            cluster: j.get("cluster")?.as_str()?.to_string(),
            frontier,
            top_k: j
                .get("top_k")?
                .as_i64()
                .and_then(|v| usize::try_from(v).ok())?,
            evaluated: j
                .get("evaluated")?
                .as_i64()
                .and_then(|v| usize::try_from(v).ok())?,
        })
    }
}

/// The on-disk store. `path = None` gives an in-memory cache (used when
/// the CLI runs without `--cache`).
#[derive(Debug, Default)]
pub struct PlanCache {
    path: Option<PathBuf>,
    entries: Vec<CacheEntry>,
}

/// Bumped when the entry schema or the scoring model changes
/// incompatibly; files with another version are ignored wholesale.
/// v2: top-k `frontier` per signature (was a flat single winner) plus
/// per-plan `peak_mem_bytes` from the memory model.
/// v3: per-entry `cluster` fingerprint ([`crate::api::ClusterSpec`]);
/// entries without one are rejected at load, and v2 files degrade to an
/// empty cache.
/// v4: heterogeneous pools — the cluster fingerprint covers every device
/// group of the pool (a mixed pool never aliases a homogeneous one of
/// the same size), and each cached plan stores its `groups` chain
/// assignment; plans without one are rejected at load, and v3 files
/// degrade to an empty cache.
const CACHE_VERSION: i64 = 4;

/// Process-wide per-path lock serializing [`PlanCache::save`]: two
/// threads saving different signatures to one file must not interleave
/// their load-merge-rename sequences (the later rename would silently
/// drop the earlier writer's entries). The key is canonicalized (or at
/// least absolutized for not-yet-existing files) so `plans.json` and
/// `./plans.json` take the same lock. Cross-*process* writers are
/// still best-effort merged by the re-read inside `save`.
fn save_lock(path: &Path) -> Arc<Mutex<()>> {
    static LOCKS: OnceLock<Mutex<HashMap<PathBuf, Arc<Mutex<()>>>>> =
        OnceLock::new();
    let map = LOCKS.get_or_init(|| Mutex::new(HashMap::new()));
    map.lock().unwrap().entry(lock_key(path)).or_default().clone()
}

/// The canonical registry key for a cache path: canonicalize the parent
/// directory (which exists even before the first save creates the file)
/// and rejoin the file name, so every spelling of one target —
/// relative, absolute, through symlinks — keys the same lock (and the
/// same [`super::store::PlanStore`]) on every use.
pub(crate) fn lock_key(path: &Path) -> PathBuf {
    match (path.parent(), path.file_name()) {
        (Some(dir), Some(file)) => {
            let dir = if dir.as_os_str().is_empty() {
                Path::new(".")
            } else {
                dir
            };
            dir.canonicalize()
                .map(|d| d.join(file))
                .unwrap_or_else(|_| path.to_path_buf())
        }
        _ => path.to_path_buf(),
    }
}

/// Delete `<stem>.tmp.<pid>.<seq>` staging siblings of `path` older
/// than `max_age` — the debris of writers that crashed between writing
/// their temp and renaming it into place. Called under the per-path
/// save lock; best-effort (a sweep failure never fails the save).
fn sweep_stale_temps(path: &Path, max_age: std::time::Duration) {
    let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else {
        return;
    };
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d,
        _ => Path::new("."),
    };
    let prefix = format!("{stem}.tmp.");
    let Ok(listing) = std::fs::read_dir(dir) else { return };
    for ent in listing.flatten() {
        let name = ent.file_name();
        let Some(name) = name.to_str() else { continue };
        if !name.starts_with(&prefix) {
            continue;
        }
        let stale = ent
            .metadata()
            .and_then(|m| m.modified())
            .ok()
            .and_then(|m| m.elapsed().ok())
            .is_some_and(|age| age >= max_age);
        if stale {
            let _ = std::fs::remove_file(ent.path());
        }
    }
}

impl PlanCache {
    pub fn in_memory() -> Self {
        PlanCache::default()
    }

    /// Load from `path`; missing or unreadable files yield an empty cache
    /// bound to that path (it will be created on the first `save`).
    pub fn load(path: &Path) -> Self {
        let _load_span = crate::telemetry::span("cache_load");
        let entries = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .filter(|j| {
                j.get("version").and_then(Json::as_i64)
                    == Some(CACHE_VERSION)
            })
            .and_then(|j| {
                j.get("entries").and_then(Json::as_arr).map(|xs| {
                    xs.iter().filter_map(CacheEntry::from_json).collect()
                })
            })
            .unwrap_or_default();
        PlanCache { path: Some(path.to_path_buf()), entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Surrender the entries (the warm-from-disk path of
    /// [`super::store::PlanStore`]).
    pub(crate) fn into_entries(self) -> Vec<CacheEntry> {
        self.entries
    }

    /// Find the entry for `signature` that was searched for `cluster`
    /// (a [`crate::api::ClusterSpec::fingerprint`]). Both must match: a
    /// plan tuned for one hardware pool never answers for another.
    pub fn lookup(
        &self,
        signature: &str,
        cluster: &str,
    ) -> Option<&CacheEntry> {
        self.entries
            .iter()
            .find(|e| e.signature == signature && e.cluster == cluster)
    }

    /// Insert or replace the entry for its `(signature, cluster)` pair
    /// — the same key [`PlanCache::lookup`] requires. Keying on the
    /// signature alone (as this once did) let an entry tuned for one
    /// hardware pool silently evict the same workload's entry for
    /// another pool whenever the signature did not happen to embed the
    /// cluster fingerprint.
    pub fn insert(&mut self, entry: CacheEntry) {
        assert!(
            !entry.frontier.is_empty(),
            "a cache entry must carry at least its winner"
        );
        match self.entries.iter_mut().find(|e| {
            e.signature == entry.signature && e.cluster == entry.cluster
        }) {
            Some(slot) => *slot = entry,
            None => self.entries.push(entry),
        }
    }

    /// Persist to the bound path (no-op for in-memory caches). Atomic:
    /// write a sibling temp file, then rename over the target. Entries
    /// another writer persisted since our load are re-read and kept
    /// (ours win per `(signature, cluster)`), so concurrent tuners
    /// sharing one file
    /// don't drop each other's results. The whole read-merge-rename
    /// sequence holds a process-wide per-path lock — without it, two
    /// in-process writers could both load the same base, and whichever
    /// renamed last would erase the other's entries — and the temp file
    /// name is unique per write so cross-process writers never clobber
    /// each other's staging file.
    pub fn save(&self) -> Result<()> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        let _save_span = crate::telemetry::span("cache_save");
        let lock = save_lock(path);
        let _guard = lock.lock().unwrap();
        // Under the lock: sweep staging files a crashed writer left
        // behind. The age threshold keeps a *live* cross-process
        // writer's temp safe (in-process writers are excluded by the
        // lock itself).
        sweep_stale_temps(path, std::time::Duration::from_secs(60));
        let mut merged = PlanCache::load(path).entries;
        for e in &self.entries {
            // Merge on the full (signature, cluster) key — mirroring
            // `insert` — so one pool's answer never erases another's.
            match merged.iter_mut().find(|m| {
                m.signature == e.signature && m.cluster == e.cluster
            }) {
                Some(slot) => *slot = e.clone(),
                None => merged.push(e.clone()),
            }
        }
        let doc = Json::obj(vec![
            ("version", Json::Int(CACHE_VERSION)),
            (
                "entries",
                Json::Arr(merged.iter().map(|e| e.to_json()).collect()),
            ),
        ]);
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, doc.render())
            .with_context(|| format!("writing {}", tmp.display()))?;
        if let Err(e) = std::fs::rename(&tmp, path) {
            // Don't leak the staging file on the error path — an
            // orphaned temp per failed save accumulates forever (the
            // sweep above only mops up after *crashed* writers).
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| {
                format!("renaming into {}", path.display())
            });
        }
        crate::telemetry::incr(crate::telemetry::key::CACHE_WRITE);
        crate::telemetry::debug(&format!(
            "  cache: wrote {} entries to {}",
            merged.len(),
            path.display()
        ));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary(llm_pp: usize) -> PlanSummary {
        PlanSummary {
            candidate: Candidate {
                strategy: Strategy::Cornstarch,
                enc_pps: vec![1, 2],
                llm_pp,
                tp: 2,
                cp: 2,
                num_microbatches: 24,
                frozen: FrozenSetting::Paper,
                chain_groups: vec![0, 0, 1],
            },
            iteration_ms: 123.5 + llm_pp as f64,
            throughput_per_gpu: 0.042,
            n_gpus: 16,
            peak_mem_bytes: 31_400_000_000,
            cp_algorithm: "LPT".to_string(),
        }
    }

    fn entry(sig: &str, llm_pp: usize) -> CacheEntry {
        CacheEntry {
            signature: sig.to_string(),
            cluster: "n=16|mem=40000000000".to_string(),
            frontier: vec![summary(llm_pp), summary(llm_pp + 1)],
            top_k: 2,
            evaluated: 37,
        }
    }

    const FP: &str = "n=16|mem=40000000000";

    fn tmp_path(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cornstarch-cache-test-{name}-{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_through_disk() {
        let path = tmp_path("roundtrip");
        let _ = std::fs::remove_file(&path);
        let mut c = PlanCache::load(&path);
        assert!(c.is_empty());
        c.insert(entry("sig-a", 3));
        c.insert(entry("sig-b", 4));
        c.save().unwrap();
        let c2 = PlanCache::load(&path);
        assert_eq!(c2.len(), 2);
        assert_eq!(c2.lookup("sig-a", FP), Some(&entry("sig-a", 3)));
        assert_eq!(c2.lookup("sig-b", FP), Some(&entry("sig-b", 4)));
        assert!(c2.lookup("sig-c", FP).is_none());
        // same signature, other hardware: never an answer
        assert!(c2.lookup("sig-a", "n=16|mem=80000000000").is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn frontier_order_survives_the_roundtrip() {
        let path = tmp_path("frontier");
        let _ = std::fs::remove_file(&path);
        let mut c = PlanCache::load(&path);
        c.insert(entry("s", 2));
        c.save().unwrap();
        let c2 = PlanCache::load(&path);
        let e = c2.lookup("s", FP).unwrap();
        assert_eq!(e.frontier.len(), 2);
        assert_eq!(e.best(), &e.frontier[0]);
        assert_eq!(e.best().candidate.llm_pp, 2);
        assert_eq!(e.frontier[1].candidate.llm_pp, 3);
        assert_eq!(e.best().peak_mem_bytes, 31_400_000_000);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn satisfies_top_distinguishes_shallow_writer_from_small_space() {
        let e = entry("s", 3); // 2 plans stored, searched top_k = 2
        assert!(e.satisfies_top(1));
        assert!(e.satisfies_top(2));
        assert!(!e.satisfies_top(3), "writer only looked 2 deep");
        let mut exhausted = entry("s", 3);
        exhausted.top_k = 10; // searched 10 deep, space held only 2
        assert!(exhausted.satisfies_top(5));
    }

    #[test]
    fn insert_replaces_same_signature() {
        let mut c = PlanCache::in_memory();
        c.insert(entry("s", 2));
        c.insert(entry("s", 5));
        assert_eq!(c.len(), 1);
        assert_eq!(c.lookup("s", FP).unwrap().best().candidate.llm_pp, 5);
    }

    #[test]
    fn save_merges_entries_written_by_another_process() {
        let path = tmp_path("merge");
        let _ = std::fs::remove_file(&path);
        let mut a = PlanCache::load(&path);
        let mut b = PlanCache::load(&path);
        a.insert(entry("sig-a", 2));
        a.save().unwrap();
        b.insert(entry("sig-b", 3));
        b.save().unwrap(); // must not drop sig-a
        let c = PlanCache::load(&path);
        assert_eq!(c.len(), 2);
        assert!(c.lookup("sig-a", FP).is_some());
        assert!(c.lookup("sig-b", FP).is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_file_degrades_to_empty() {
        let path = tmp_path("corrupt");
        std::fs::write(&path, "not json at all {{{{").unwrap();
        let c = PlanCache::load(&path);
        assert!(c.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn version_skew_is_ignored_wholesale() {
        // A future version and the retired v1 (flat single winner), v2
        // (cluster-less frontier), and v3 (assignment-less,
        // single-group-fingerprint) layouts all degrade to an empty
        // cache (and are rebuilt on the next save).
        let path = tmp_path("version");
        std::fs::write(&path, r#"{"version":999,"entries":[{}]}"#).unwrap();
        assert!(PlanCache::load(&path).is_empty());
        std::fs::write(
            &path,
            r#"{"version":1,"entries":[{"signature":"s","strategy":"cornstarch","enc_pps":[1],"llm_pp":3,"tp":2,"cp":2,"microbatches":24,"frozen":"paper","iteration_ms":1.0,"throughput_per_gpu":0.1,"n_gpus":16,"cp_algorithm":"LPT","evaluated":5}]}"#,
        )
        .unwrap();
        assert!(PlanCache::load(&path).is_empty());
        std::fs::write(
            &path,
            r#"{"version":2,"entries":[{"signature":"s","top_k":1,"evaluated":5,"frontier":[{"strategy":"cornstarch","enc_pps":[1],"llm_pp":3,"tp":2,"cp":2,"microbatches":24,"frozen":"paper","iteration_ms":1.0,"throughput_per_gpu":0.1,"n_gpus":16,"peak_mem_bytes":1000,"cp_algorithm":"LPT"}]}]}"#,
        )
        .unwrap();
        assert!(PlanCache::load(&path).is_empty());
        std::fs::write(
            &path,
            r#"{"version":3,"entries":[{"signature":"s","cluster":"n=16|mem=40000000000|flops=1.497000e14|mfu=0.67|bw=32","top_k":1,"evaluated":5,"frontier":[{"strategy":"cornstarch","enc_pps":[1],"llm_pp":3,"tp":2,"cp":2,"microbatches":24,"frozen":"paper","iteration_ms":1.0,"throughput_per_gpu":0.1,"n_gpus":16,"peak_mem_bytes":1000,"cp_algorithm":"LPT"}]}]}"#,
        )
        .unwrap();
        assert!(PlanCache::load(&path).is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn plan_without_group_assignment_is_rejected() {
        // A v4-versioned file whose plan lacks the `groups` assignment
        // must drop that entry — exactly the shape of a hand-migrated v3
        // plan, whose chains nothing says how to price.
        let path = tmp_path("nogroups");
        let mut store = PlanCache::load(&path);
        store.insert(entry("kept", 3));
        store.save().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let stripped = text.replace(r#""groups":[0,0,1],"#, "");
        assert_ne!(text, stripped, "fixture must actually strip the field");
        std::fs::write(&path, stripped).unwrap();
        assert!(
            PlanCache::load(&path).is_empty(),
            "an assignment-less plan satisfied a v4 load"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn concurrent_writers_lose_no_entries() {
        // The multi-writer regression the per-path save lock exists for:
        // many threads, each persisting a different signature to the
        // same file, racing load-merge-rename. Every signature must
        // survive and the file must stay valid JSON throughout.
        let path = tmp_path("concurrent");
        let _ = std::fs::remove_file(&path);
        let n_threads = 8;
        let writes_per_thread = 5;
        std::thread::scope(|scope| {
            for t in 0..n_threads {
                let path = path.clone();
                scope.spawn(move || {
                    for w in 0..writes_per_thread {
                        let mut c = PlanCache::load(&path);
                        c.insert(entry(&format!("sig-{t}-{w}"), t + 1));
                        c.save().unwrap();
                    }
                });
            }
        });
        let merged = PlanCache::load(&path);
        assert_eq!(
            merged.len(),
            n_threads * writes_per_thread,
            "concurrent saves dropped entries"
        );
        for t in 0..n_threads {
            for w in 0..writes_per_thread {
                let e = merged
                    .lookup(&format!("sig-{t}-{w}"), FP)
                    .unwrap_or_else(|| panic!("lost sig-{t}-{w}"));
                assert_eq!(e.best().candidate.llm_pp, t + 1);
            }
        }
        // and the surviving file is a single well-formed v4 document
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::util::json::Json::parse(&text).is_ok());
        assert!(text.contains("\"version\":4"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn entry_without_cluster_fingerprint_is_rejected() {
        // A v3-versioned file whose entry lacks the stored cluster
        // fingerprint must drop that entry — never default it. (This is
        // exactly the shape a hand-migrated v2 entry would have.)
        let path = tmp_path("nocluster");
        let mut good = entry("kept", 3);
        good.cluster = "n=16|mem=40000000000".to_string();
        let mut store = PlanCache::load(&path);
        store.insert(good);
        store.save().unwrap();
        // strip the "cluster" field from the written JSON (the writer
        // renders compact `"k":v` pairs)
        let text = std::fs::read_to_string(&path).unwrap();
        let stripped =
            text.replace(r#""cluster":"n=16|mem=40000000000","#, "");
        assert_ne!(text, stripped, "fixture must actually strip the field");
        std::fs::write(&path, stripped).unwrap();
        assert!(
            PlanCache::load(&path).is_empty(),
            "a fingerprint-less entry satisfied a v3 load"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn entry_without_frontier_is_dropped_not_fatal() {
        let path = tmp_path("nofrontier");
        std::fs::write(
            &path,
            r#"{"version":3,"entries":[{"signature":"s","cluster":"n=16","evaluated":1,"frontier":[]}]}"#,
        )
        .unwrap();
        assert!(PlanCache::load(&path).is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn in_memory_save_is_noop() {
        let mut c = PlanCache::in_memory();
        c.insert(entry("x", 1));
        c.save().unwrap();
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn same_signature_different_clusters_coexist() {
        // Regression: insert/save once merged by signature alone while
        // lookup required (signature, cluster) — so two pools sharing
        // a workload signature silently evicted each other's answers.
        let other_fp = "n=32|mem=80000000000";
        let mut on_other = entry("shared-sig", 7);
        on_other.cluster = other_fp.to_string();

        let mut c = PlanCache::in_memory();
        c.insert(entry("shared-sig", 3));
        c.insert(on_other.clone());
        assert_eq!(c.len(), 2, "second cluster's entry evicted the first");
        assert_eq!(
            c.lookup("shared-sig", FP).unwrap().best().candidate.llm_pp,
            3
        );
        assert_eq!(
            c.lookup("shared-sig", other_fp)
                .unwrap()
                .best()
                .candidate
                .llm_pp,
            7
        );
        // replacing still works, scoped to its own (sig, cluster)
        c.insert(entry("shared-sig", 5));
        assert_eq!(c.len(), 2);
        assert_eq!(
            c.lookup("shared-sig", FP).unwrap().best().candidate.llm_pp,
            5
        );

        // and the disk merge path keys the same way
        let path = tmp_path("two-clusters");
        let _ = std::fs::remove_file(&path);
        let mut a = PlanCache::load(&path);
        a.insert(entry("shared-sig", 3));
        a.save().unwrap();
        let mut b = PlanCache::load(&path);
        b.insert(on_other);
        b.save().unwrap();
        let merged = PlanCache::load(&path);
        assert_eq!(merged.len(), 2, "save() merged by signature alone");
        assert!(merged.lookup("shared-sig", FP).is_some());
        assert!(merged.lookup("shared-sig", other_fp).is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn failed_rename_cleans_up_its_temp() {
        // Force the final rename to fail by making the target path a
        // directory; the staging file must not be left behind.
        let path = tmp_path("rename-fail");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&path);
        std::fs::create_dir_all(&path).unwrap();
        let mut c = PlanCache::in_memory();
        c.insert(entry("s", 2));
        let mut bound = PlanCache { path: Some(path.clone()), entries: c.entries };
        assert!(bound.save().is_err(), "rename onto a directory must fail");
        bound.insert(entry("t", 3)); // a second failing save, same story
        assert!(bound.save().is_err());
        let stem = path.file_stem().unwrap().to_str().unwrap().to_string();
        let leaked: Vec<_> = std::fs::read_dir(path.parent().unwrap())
            .unwrap()
            .flatten()
            .filter(|e| {
                e.file_name()
                    .to_str()
                    .is_some_and(|n| n.starts_with(&format!("{stem}.tmp.")))
            })
            .collect();
        assert!(
            leaked.is_empty(),
            "failed saves leaked staging files: {leaked:?}"
        );
        let _ = std::fs::remove_dir(&path);
    }

    #[test]
    fn stale_temps_are_swept() {
        // Orphans from crashed writers (simulated by hand-creating the
        // staging names) are removed by the sweep; the target file and
        // unrelated siblings are untouched.
        let path = tmp_path("sweep");
        std::fs::write(&path, "target").unwrap();
        let stem = path.file_stem().unwrap().to_str().unwrap().to_string();
        let dir = path.parent().unwrap();
        let orphan_a = dir.join(format!("{stem}.tmp.99999.0"));
        let orphan_b = dir.join(format!("{stem}.tmp.99999.7"));
        let unrelated = dir.join(format!("{stem}-other.file"));
        std::fs::write(&orphan_a, "x").unwrap();
        std::fs::write(&orphan_b, "x").unwrap();
        std::fs::write(&unrelated, "x").unwrap();
        // age zero: everything matching the staging pattern is stale
        sweep_stale_temps(&path, std::time::Duration::ZERO);
        assert!(!orphan_a.exists(), "orphaned temp survived the sweep");
        assert!(!orphan_b.exists(), "orphaned temp survived the sweep");
        assert!(path.exists(), "sweep must never touch the target");
        assert!(unrelated.exists(), "sweep must not touch other siblings");
        // a generous age keeps fresh temps (live cross-process writers)
        std::fs::write(&orphan_a, "x").unwrap();
        sweep_stale_temps(&path, std::time::Duration::from_secs(3600));
        assert!(orphan_a.exists(), "fresh temp swept despite age gate");
        let _ = std::fs::remove_file(&orphan_a);
        let _ = std::fs::remove_file(&unrelated);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn peak_mem_saturates_at_i64_boundary_and_survives_reload() {
        // Regression: `as i64` wrapped a peak above i64::MAX negative,
        // and reload's u64 conversion then silently dropped the whole
        // entry. Policy now: saturate to i64::MAX, keep the entry.
        let path = tmp_path("peakmem");
        let boundary: &[u64] = &[
            0,
            31_400_000_000,
            i64::MAX as u64 - 1, // exact round-trip
            i64::MAX as u64,     // exact round-trip (last such value)
            i64::MAX as u64 + 1, // saturates
            u64::MAX,            // saturates
        ];
        for (i, &stored) in boundary.iter().enumerate() {
            let expect = stored.min(i64::MAX as u64);
            let _ = std::fs::remove_file(&path);
            let mut e = entry("peak", 2);
            for p in &mut e.frontier {
                p.peak_mem_bytes = stored;
            }
            let mut c = PlanCache::load(&path);
            c.insert(e);
            c.save().unwrap();
            let back = PlanCache::load(&path);
            let got = back.lookup("peak", FP).unwrap_or_else(|| {
                panic!("case {i}: entry with peak {stored} dropped on reload")
            });
            assert_eq!(got.best().peak_mem_bytes, expect, "case {i}");
        }
        let _ = std::fs::remove_file(&path);
    }
}
