//! The plan-search autotuner: given an MLLM composition and a device
//! budget, search the joint configuration space (policy × encoder
//! placement × LLM pipeline depth × TP/CP degrees × microbatch count ×
//! frozen policy) for the fastest plan, with a persistent cache so a
//! repeated query never re-simulates.
//!
//! The subsystem is four layers deep, mirroring its data flow:
//!
//! * [`space`] — [`space::Candidate`] enumeration under the device budget
//!   AND the per-GPU memory capacity of [`crate::memory`]: OOM-infeasible
//!   candidates (including microbatch counts whose 1F1B warm-up window
//!   cannot fit) are rejected before anything simulates them. On a
//!   heterogeneous pool the chain→device-group assignment is one more
//!   enumerated dimension, pruned by per-group GPU capacity and by the
//!   memory budget of the group each stage lands on;
//! * [`search`] — bounded best-first search with cost-model lower-bound
//!   pruning ([`search::Objective`] selects what is optimized), keeping a
//!   top-k frontier rather than a single winner;
//! * [`evaluate`] — plan construction ([`crate::modality::planner`] +
//!   [`crate::pipeline`]) and multi-threaded discrete-event simulation
//!   ([`crate::sim`]), plus the CP distribution pick ([`crate::cp`]);
//! * [`cache`] — the JSON-persisted plan cache keyed by a
//!   workload/cluster signature, storing the whole frontier so later
//!   queries can trade throughput against GPU count and memory headroom
//!   without re-searching — fronted by [`store`], the process-wide
//!   two-tier store: a sharded in-memory map (hits never touch disk)
//!   over the JSON tier, plus in-flight dedupe so concurrent identical
//!   queries coalesce onto one search.
//!
//! Entry point: [`tune`].

pub mod cache;
pub mod evaluate;
pub mod search;
pub mod space;
pub mod store;

pub use cache::{CacheEntry, PlanCache, PlanSummary};
pub use store::PlanStore;
pub use evaluate::{bounds_ms, build_plan, evaluate_parallel, Evaluation};
pub use search::{search, search_top, Objective, SearchReport};
pub use space::{enumerate, Candidate, FrozenSetting, SearchSpace};

use anyhow::Result;

use crate::api::ClusterSpec;
use crate::modality::Plan;
use crate::model::MllmSpec;

/// Frontier depth a search keeps (and the cache persists) by default.
pub const DEFAULT_TOP_K: usize = 5;

/// Default evaluation-worker count: every core, capped at 8 (simulation
/// waves saturate well before that on the paper-scale spaces).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// A tuning query.
#[derive(Clone, Debug)]
pub struct TuneRequest {
    pub spec: MllmSpec,
    /// The hardware truth: device pool size, per-device memory, the
    /// flops/MFU time model, and the interconnect the comm hops are
    /// priced off. Joins the cache signature (and is stored per entry),
    /// so a plan tuned for one cluster never answers for another.
    pub cluster: ClusterSpec,
    pub space: SearchSpace,
    pub objective: Objective,
    /// Max candidates to simulate; 0 = unlimited (exact over the space).
    pub budget: usize,
    pub threads: usize,
    /// Frontier depth to search for and persist (`--top N`). NOT part of
    /// the cache signature: the whole point of storing a frontier is
    /// answering later "show me the runners-up" queries without a
    /// re-search. A hit only counts when the stored entry satisfies this
    /// depth ([`CacheEntry::satisfies_top`]); a deeper request re-searches
    /// and overwrites the entry.
    pub top: usize,
    /// JSON cache path; `None` searches fresh every time (unless
    /// `shared_memory` opts into the process-wide in-memory tier).
    pub cache_path: Option<String>,
    /// With `cache_path: None`, share answers through the process-wide
    /// in-memory store ([`PlanStore::process_memory`]) instead of
    /// searching fresh every call — the long-lived-service mode
    /// (`cornstarch serve` without `--cache`,
    /// [`crate::api::CachePolicy::Memory`]). Ignored when `cache_path`
    /// is set (the file's store is process-shared already).
    pub shared_memory: bool,
}

impl TuneRequest {
    /// The paper's scenario: `devices` × A40.
    pub fn new(spec: MllmSpec, devices: usize) -> Self {
        TuneRequest::for_cluster(
            spec,
            ClusterSpec::a40_default().with_devices(devices),
        )
    }

    /// Tune for an arbitrary cluster; the search space is sized to it
    /// ([`SearchSpace::for_cluster`]).
    pub fn for_cluster(spec: MllmSpec, cluster: ClusterSpec) -> Self {
        TuneRequest {
            spec,
            space: SearchSpace::for_cluster(&cluster),
            cluster,
            objective: Objective::Makespan,
            budget: 0,
            threads: default_threads(),
            top: DEFAULT_TOP_K,
            cache_path: None,
            shared_memory: false,
        }
    }

    /// The cache key: everything that can change the answer, including
    /// the cluster fingerprint — a plan tuned for one hardware pool must
    /// not answer for another.
    pub fn signature(&self) -> String {
        format!(
            "mllm={}|llm={}|{}|obj={}|budget={}|{}",
            self.spec.name(),
            self.spec.llm.name,
            self.space.fingerprint(),
            self.objective.key(),
            self.budget,
            self.cluster.fingerprint(),
        )
    }
}

/// Why a tuning query failed — the typed form [`tune_with`] returns and
/// the planning facade ([`crate::api`]) maps onto
/// [`crate::api::PlanError`].
#[derive(Clone, Debug, PartialEq)]
pub enum TuneError {
    /// Enumeration produced no candidate that fits the device pool and
    /// the per-device memory budget.
    NoFeasiblePlan { mllm: String, devices: usize },
    /// The persistent cache could not be written.
    CacheIo(String),
}

impl std::fmt::Display for TuneError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TuneError::NoFeasiblePlan { mllm, devices } => write!(
                f,
                "no feasible plan for {mllm} on {devices} device(s)"
            ),
            TuneError::CacheIo(m) => write!(f, "plan cache: {m}"),
        }
    }
}

impl std::error::Error for TuneError {}

/// The tuner's answer.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    pub entry: CacheEntry,
    /// True when the answer came straight from the cache (no search, no
    /// simulation).
    pub cache_hit: bool,
    /// Search statistics — all zero on a cache hit.
    pub total_candidates: usize,
    pub evaluated: usize,
    pub pruned: usize,
}

impl TuneOutcome {
    /// Rebuild the executable stage DAG the cached winner denotes.
    pub fn instantiate(
        &self,
        spec: &MllmSpec,
        cluster: &ClusterSpec,
    ) -> Plan {
        build_plan(spec, &self.entry.best().candidate, cluster)
    }

    /// Rebuild the stage DAG of frontier entry `rank` (0 = winner).
    pub fn instantiate_ranked(
        &self,
        spec: &MllmSpec,
        cluster: &ClusterSpec,
        rank: usize,
    ) -> Option<Plan> {
        self.entry
            .frontier
            .get(rank)
            .map(|p| build_plan(spec, &p.candidate, cluster))
    }
}

/// The store a request's answers live in: the process-wide store of
/// its cache file, the process-wide in-memory store when it opted into
/// sharing without a file, or a private throwaway (the
/// `cache_path: None` "search every time" contract — a private store
/// can never hold a prior answer, and its flight table can never have
/// another request to coalesce with).
fn store_for(req: &TuneRequest) -> PlanStore {
    match (&req.cache_path, req.shared_memory) {
        (Some(p), _) => PlanStore::for_path(p),
        (None, true) => PlanStore::process_memory(),
        (None, false) => PlanStore::private(),
    }
}

/// Tune: consult the two-tier plan store, otherwise search (coalescing
/// with any identical in-flight search), then publish the top-k
/// frontier (best first) to both tiers. Typed-error core behind
/// [`tune`].
pub fn tune_with(req: &TuneRequest) -> Result<TuneOutcome, TuneError> {
    let _tune_span = crate::telemetry::span(&format!(
        "tune {} devices={}",
        req.spec.name(),
        req.space.devices
    ));
    let store = store_for(req);
    let sig = req.signature();
    let fingerprint = req.cluster.fingerprint();
    let top = req.top.max(1);
    // Fast path: a verified stored answer deep enough for this query.
    if let Some(entry) = store.lookup(&sig, &fingerprint, &req.cluster, top)
    {
        crate::telemetry::incr(crate::telemetry::key::CACHE_HIT);
        return Ok(TuneOutcome {
            entry,
            cache_hit: true,
            total_candidates: 0,
            evaluated: 0,
            pruned: 0,
        });
    }
    // Miss: lead a search, or join the identical one already running.
    match store.lead_or_join(&sig, top) {
        store::FlightRole::Follower(flight) => {
            crate::telemetry::incr(crate::telemetry::key::INFLIGHT_JOIN);
            let mut out = flight.wait_outcome()?;
            // To this request the answer is a hit: it searched nothing.
            out.cache_hit = true;
            out.total_candidates = 0;
            out.evaluated = 0;
            out.pruned = 0;
            crate::telemetry::incr(crate::telemetry::key::CACHE_HIT);
            Ok(out)
        }
        store::FlightRole::Leader(lease) => {
            // Re-check under the lead: a prior leader may have
            // published between our miss and our flight insertion.
            if let Some(entry) =
                store.lookup(&sig, &fingerprint, &req.cluster, top)
            {
                crate::telemetry::incr(crate::telemetry::key::CACHE_HIT);
                let out = TuneOutcome {
                    entry,
                    cache_hit: true,
                    total_candidates: 0,
                    evaluated: 0,
                    pruned: 0,
                };
                lease.complete(Ok(out.clone()));
                return Ok(out);
            }
            crate::telemetry::incr(crate::telemetry::key::CACHE_MISS);
            let result = search_and_publish(req, &store, sig, fingerprint, top);
            lease.complete(result.clone());
            result
        }
    }
}

/// The leader's slow path: search, summarize the frontier, publish to
/// both store tiers.
fn search_and_publish(
    req: &TuneRequest,
    store: &PlanStore,
    sig: String,
    fingerprint: String,
    top: usize,
) -> Result<TuneOutcome, TuneError> {
    let report = search_top(
        &req.spec,
        &req.space,
        req.objective,
        req.budget,
        req.threads,
        &req.cluster,
        top,
    )
    .ok_or_else(|| TuneError::NoFeasiblePlan {
        mllm: req.spec.name(),
        devices: req.space.devices,
    })?;
    let frontier: Vec<cache::PlanSummary> = report
        .frontier
        .iter()
        .map(|ev| cache::PlanSummary {
            candidate: ev.candidate.clone(),
            iteration_ms: ev.iteration_ms,
            throughput_per_gpu: ev.throughput_per_gpu,
            n_gpus: ev.n_gpus,
            peak_mem_bytes: ev.peak_mem_bytes,
            cp_algorithm: evaluate::pick_cp_algorithm(
                req.spec.llm_tokens(),
                ev.candidate.cp,
                evaluate::CP_PICK_SEED,
            )
            .to_string(),
        })
        .collect();
    let entry = CacheEntry {
        signature: sig,
        cluster: fingerprint,
        frontier,
        top_k: top,
        evaluated: report.evaluated,
    };
    store.publish(entry.clone())?;
    Ok(TuneOutcome {
        entry,
        cache_hit: false,
        total_candidates: report.total_candidates,
        evaluated: report.evaluated,
        pruned: report.pruned,
    })
}

/// [`tune_with`] with the error erased to `anyhow` for CLI-style callers.
pub fn tune(req: &TuneRequest) -> Result<TuneOutcome> {
    tune_with(req).map_err(anyhow::Error::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Size;

    fn req(devices: usize) -> TuneRequest {
        let mut r =
            TuneRequest::new(MllmSpec::vlm(Size::M, Size::S), devices);
        r.threads = 2;
        r
    }

    #[test]
    fn tune_without_cache_searches_every_time() {
        let a = tune(&req(8)).unwrap();
        assert!(!a.cache_hit);
        assert!(a.evaluated >= 1);
        let b = tune(&req(8)).unwrap();
        assert!(!b.cache_hit);
        assert_eq!(a.entry.best().candidate, b.entry.best().candidate);
    }

    #[test]
    fn frontier_is_sorted_and_capped_by_top() {
        let mut r = req(16);
        r.top = 3;
        let out = tune(&r).unwrap();
        let f = &out.entry.frontier;
        assert!(!f.is_empty() && f.len() <= 3);
        assert!(f
            .windows(2)
            .all(|w| w[0].iteration_ms <= w[1].iteration_ms + 1e-12));
        assert_eq!(out.entry.best(), &f[0]);
        // every frontier plan fits the modeled device budget
        let budget = r.space.memory_budget_bytes.unwrap();
        assert!(f.iter().all(|p| p.peak_mem_bytes <= budget));
        // runners-up instantiate too
        if f.len() > 1 {
            let plan =
                out.instantiate_ranked(&r.spec, &r.cluster, 1).unwrap();
            let m = plan.simulate();
            assert!(
                (m.iteration_ms - f[1].iteration_ms).abs() < 1e-6,
                "ranked plan {:.3} ms vs cached {:.3} ms",
                m.iteration_ms,
                f[1].iteration_ms
            );
        }
    }

    #[test]
    fn deeper_top_request_re_searches_and_deepens_the_cache() {
        let mut path = std::env::temp_dir();
        path.push(format!(
            "cornstarch-tune-deepen-{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let mut shallow = req(16);
        shallow.top = 1;
        shallow.cache_path = Some(path.to_string_lossy().into_owned());
        let first = tune(&shallow).unwrap();
        assert!(!first.cache_hit);
        assert_eq!(first.entry.frontier.len(), 1);
        let mut deep = shallow.clone();
        deep.top = 3;
        let second = tune(&deep).unwrap();
        assert!(!second.cache_hit, "shallow entry must not satisfy top=3");
        assert!(second.entry.frontier.len() > 1);
        assert_eq!(
            second.entry.best().candidate,
            first.entry.best().candidate
        );
        // the deepened entry now serves BOTH depths from the cache
        assert!(tune(&deep).unwrap().cache_hit);
        assert!(tune(&shallow).unwrap().cache_hit);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cache_hit_skips_search_and_preserves_the_plan() {
        let mut path = std::env::temp_dir();
        path.push(format!("cornstarch-tune-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut r = req(8);
        r.cache_path = Some(path.to_string_lossy().into_owned());
        let first = tune(&r).unwrap();
        assert!(!first.cache_hit);
        let second = tune(&r).unwrap();
        assert!(second.cache_hit);
        assert_eq!(second.evaluated, 0, "cache hit must not re-simulate");
        assert_eq!(first.entry, second.entry);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn different_budgets_get_different_signatures() {
        let mut a = req(8);
        let mut b = req(8);
        a.budget = 10;
        b.budget = 20;
        assert_ne!(a.signature(), b.signature());
    }

    #[test]
    fn different_clusters_get_different_signatures() {
        let a = req(8);
        let mut b = req(8);
        b.cluster.groups[0].device.mem_bytes = 80_000_000_000;
        assert_ne!(
            a.signature(),
            b.signature(),
            "a plan tuned for one memory budget must not answer another"
        );
        let mut c = req(8);
        c.cluster.groups[0].link_gbps /= 2.0;
        assert_ne!(a.signature(), c.signature());
        // a heterogeneous pool of the same total size never aliases a
        // homogeneous one
        let mut h = req(8);
        h.cluster = ClusterSpec::a40_a100_demo();
        h.space = SearchSpace::for_cluster(&h.cluster);
        assert_ne!(a.signature(), h.signature());
    }

    #[test]
    fn instantiate_rebuilds_a_consistent_plan() {
        let r = req(16);
        let out = tune(&r).unwrap();
        let plan = out.instantiate(&r.spec, &r.cluster);
        let m = plan.simulate();
        assert!(
            (m.iteration_ms - out.entry.best().iteration_ms).abs() < 1e-6,
            "instantiated plan {:.3} ms vs cached {:.3} ms",
            m.iteration_ms,
            out.entry.best().iteration_ms
        );
        assert_eq!(plan.n_gpus, out.entry.best().n_gpus);
        assert_eq!(
            plan.peak_device_bytes(),
            out.entry.best().peak_mem_bytes
        );
    }

    #[test]
    fn corrupt_group_assignment_in_cache_resurveys_instead_of_panicking() {
        let mut path = std::env::temp_dir();
        path.push(format!(
            "cornstarch-tune-badgroups-{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let mut r = req(8);
        r.cache_path = Some(path.to_string_lossy().into_owned());
        let first = tune(&r).unwrap();
        assert!(!first.cache_hit);
        // corrupt every cached plan's assignment to an out-of-range
        // group index (the A40 default has exactly one group, index 0)
        let text = std::fs::read_to_string(&path).unwrap();
        let bad = text.replace("\"groups\":[]", "\"groups\":[7]");
        assert_ne!(text, bad, "fixture must actually corrupt the file");
        std::fs::write(&path, bad).unwrap();
        // we just played "external writer": tell the process-wide
        // store its in-memory image of this path is stale, so the next
        // lookup re-reads the (corrupted) file
        PlanStore::invalidate_path(r.cache_path.as_deref().unwrap());
        let second = tune(&r).unwrap();
        assert!(
            !second.cache_hit,
            "an out-of-range assignment must not be served as a hit"
        );
        assert_eq!(first.entry.best().candidate, second.entry.best().candidate);
        // and the re-search healed the entry: next query hits again
        assert!(tune(&r).unwrap().cache_hit);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn infeasible_budget_is_an_error() {
        let mut r = req(8);
        r.space.devices = 0;
        r.space.tp_choices = vec![4];
        r.space.cp_choices = vec![4];
        assert!(tune(&r).is_err());
    }
}
