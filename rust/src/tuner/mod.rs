//! The plan-search autotuner: given an MLLM composition and a device
//! budget, search the joint configuration space (policy × encoder
//! placement × LLM pipeline depth × TP/CP degrees × microbatch count ×
//! frozen policy) for the fastest plan, with a persistent cache so a
//! repeated query never re-simulates.
//!
//! The subsystem is four layers deep, mirroring its data flow:
//!
//! * [`space`] — [`space::Candidate`] enumeration under the device budget;
//! * [`search`] — bounded best-first search with cost-model lower-bound
//!   pruning ([`search::Objective`] selects what is optimized);
//! * [`evaluate`] — plan construction ([`crate::modality::planner`] +
//!   [`crate::pipeline`]) and multi-threaded discrete-event simulation
//!   ([`crate::sim`]), plus the CP distribution pick ([`crate::cp`]);
//! * [`cache`] — the JSON-persisted plan cache keyed by a
//!   workload/cluster signature.
//!
//! Entry point: [`tune`].

pub mod cache;
pub mod evaluate;
pub mod search;
pub mod space;

pub use cache::{CacheEntry, PlanCache};
pub use evaluate::{build_plan, evaluate_parallel, Evaluation};
pub use search::{search, Objective, SearchReport};
pub use space::{enumerate, Candidate, FrozenSetting, SearchSpace};

use anyhow::{anyhow, Result};

use crate::cost::Device;
use crate::modality::Plan;
use crate::model::MllmSpec;

/// A tuning query.
#[derive(Clone, Debug)]
pub struct TuneRequest {
    pub spec: MllmSpec,
    pub space: SearchSpace,
    pub objective: Objective,
    /// Max candidates to simulate; 0 = unlimited (exact over the space).
    pub budget: usize,
    pub threads: usize,
    /// JSON cache path; `None` searches fresh every time.
    pub cache_path: Option<String>,
    pub device: Device,
}

impl TuneRequest {
    pub fn new(spec: MllmSpec, devices: usize) -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8);
        TuneRequest {
            spec,
            space: SearchSpace::paper_default(devices),
            objective: Objective::Makespan,
            budget: 0,
            threads,
            cache_path: None,
            device: Device::a40(),
        }
    }

    /// The cache key: everything that can change the answer (including
    /// the device model — a plan tuned for one throughput profile must
    /// not answer for another).
    pub fn signature(&self) -> String {
        format!(
            "mllm={}|llm={}|{}|obj={}|budget={}|flops={:.4e}|mfu={}",
            self.spec.name(),
            self.spec.llm.name,
            self.space.fingerprint(),
            self.objective.key(),
            self.budget,
            self.device.peak_flops,
            self.device.mfu,
        )
    }
}

/// The tuner's answer.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    pub entry: CacheEntry,
    /// True when the answer came straight from the cache (no search, no
    /// simulation).
    pub cache_hit: bool,
    /// Search statistics — all zero on a cache hit.
    pub total_candidates: usize,
    pub evaluated: usize,
    pub pruned: usize,
}

impl TuneOutcome {
    /// Rebuild the executable stage DAG the cached candidate denotes.
    pub fn instantiate(&self, spec: &MllmSpec, device: Device) -> Plan {
        build_plan(spec, &self.entry.candidate, device)
    }
}

/// Tune: consult the cache, otherwise search, then persist the winner.
pub fn tune(req: &TuneRequest) -> Result<TuneOutcome> {
    let mut cache = match &req.cache_path {
        Some(p) => PlanCache::load(std::path::Path::new(p)),
        None => PlanCache::in_memory(),
    };
    let sig = req.signature();
    if let Some(entry) = cache.lookup(&sig) {
        return Ok(TuneOutcome {
            entry: entry.clone(),
            cache_hit: true,
            total_candidates: 0,
            evaluated: 0,
            pruned: 0,
        });
    }
    let report = search(
        &req.spec,
        &req.space,
        req.objective,
        req.budget,
        req.threads,
        req.device,
    )
    .ok_or_else(|| {
        anyhow!(
            "no feasible plan for {} on {} device(s)",
            req.spec.name(),
            req.space.devices
        )
    })?;
    let best = report.best;
    let cp_algorithm = evaluate::pick_cp_algorithm(
        req.spec.llm_tokens(),
        best.candidate.cp,
        0x7EAC_0DE5,
    )
    .to_string();
    let entry = CacheEntry {
        signature: sig,
        candidate: best.candidate.clone(),
        iteration_ms: best.iteration_ms,
        throughput_per_gpu: best.throughput_per_gpu,
        n_gpus: best.n_gpus,
        cp_algorithm,
        evaluated: report.evaluated,
    };
    cache.insert(entry.clone());
    cache.save()?;
    Ok(TuneOutcome {
        entry,
        cache_hit: false,
        total_candidates: report.total_candidates,
        evaluated: report.evaluated,
        pruned: report.pruned,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Size;

    fn req(devices: usize) -> TuneRequest {
        let mut r =
            TuneRequest::new(MllmSpec::vlm(Size::M, Size::S), devices);
        r.threads = 2;
        r
    }

    #[test]
    fn tune_without_cache_searches_every_time() {
        let a = tune(&req(8)).unwrap();
        assert!(!a.cache_hit);
        assert!(a.evaluated >= 1);
        let b = tune(&req(8)).unwrap();
        assert!(!b.cache_hit);
        assert_eq!(a.entry.candidate, b.entry.candidate);
    }

    #[test]
    fn cache_hit_skips_search_and_preserves_the_plan() {
        let mut path = std::env::temp_dir();
        path.push(format!("cornstarch-tune-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut r = req(8);
        r.cache_path = Some(path.to_string_lossy().into_owned());
        let first = tune(&r).unwrap();
        assert!(!first.cache_hit);
        let second = tune(&r).unwrap();
        assert!(second.cache_hit);
        assert_eq!(second.evaluated, 0, "cache hit must not re-simulate");
        assert_eq!(first.entry, second.entry);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn different_budgets_get_different_signatures() {
        let mut a = req(8);
        let mut b = req(8);
        a.budget = 10;
        b.budget = 20;
        assert_ne!(a.signature(), b.signature());
    }

    #[test]
    fn instantiate_rebuilds_a_consistent_plan() {
        let r = req(16);
        let out = tune(&r).unwrap();
        let plan = out.instantiate(&r.spec, r.device);
        let m = plan.simulate();
        assert!(
            (m.iteration_ms - out.entry.iteration_ms).abs() < 1e-6,
            "instantiated plan {:.3} ms vs cached {:.3} ms",
            m.iteration_ms,
            out.entry.iteration_ms
        );
        assert_eq!(plan.n_gpus, out.entry.n_gpus);
    }

    #[test]
    fn infeasible_budget_is_an_error() {
        let mut r = req(8);
        r.space.devices = 0;
        r.space.tp_choices = vec![4];
        r.space.cp_choices = vec![4];
        assert!(tune(&r).is_err());
    }
}
