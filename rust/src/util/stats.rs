//! Summary statistics used by benches, the simulator, and CP imbalance
//! metrics.

/// Summary of a sample of f64 values.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Max/mean imbalance ratio — 1.0 is perfectly balanced. This is the
/// metric behind the paper's Figure 12 discussion.
pub fn imbalance(loads: &[f64]) -> f64 {
    assert!(!loads.is_empty());
    let mean = loads.iter().sum::<f64>() / loads.len() as f64;
    if mean == 0.0 {
        return 1.0;
    }
    loads.iter().cloned().fold(f64::MIN, f64::max) / mean
}

/// Coefficient of variation (std/mean).
pub fn cv(loads: &[f64]) -> f64 {
    let s = Summary::of(loads);
    if s.mean == 0.0 {
        0.0
    } else {
        s.std / s.mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_constant() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_simple() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 2.5);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 3.0);
        assert_eq!(percentile_sorted(&xs, 0.5), 2.0);
    }

    #[test]
    fn imbalance_balanced_is_one() {
        assert_eq!(imbalance(&[3.0, 3.0, 3.0]), 1.0);
    }

    #[test]
    fn imbalance_detects_skew() {
        let r = imbalance(&[1.0, 1.0, 6.0]);
        assert!((r - 6.0 / (8.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn cv_zero_for_constant() {
        assert_eq!(cv(&[2.0, 2.0]), 0.0);
    }
}
