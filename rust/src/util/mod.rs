//! Self-contained utilities.
//!
//! The offline vendor set has no `rand`, `serde`, `criterion`, or
//! `proptest`, so this module provides the small pieces we need:
//! deterministic RNGs ([`rng`]), summary statistics ([`stats`]),
//! ASCII table rendering ([`table`]), a minimal JSON writer ([`json`]),
//! and a shrinking property-test harness ([`check`]).

pub mod rng;
pub mod stats;
pub mod table;
pub mod json;
pub mod check;
