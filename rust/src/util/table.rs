//! ASCII table rendering for the `reproduce` harness — prints the same
//! rows the paper's tables/figures report.

/// A simple left/right-aligned ASCII table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width"
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for i in 0..ncol {
                s.push_str(&format!("| {:w$} ", cells[i], w = widths[i]));
            }
            s.push('|');
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        crate::telemetry::report(self.render().trim_end());
    }
}

/// Format a float with 2 decimals (the paper's table style).
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bee"]);
        t.row_strs(&["1", "22"]);
        t.row_strs(&["333", "4"]);
        let s = t.render();
        assert!(s.contains("| a   | bee |"));
        assert!(s.contains("| 333 | 4   |"));
        let widths: Vec<usize> =
            s.lines().filter(|l| l.starts_with('|')).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a"]);
        t.row_strs(&["1", "2"]);
    }

    #[test]
    fn f2_format() {
        assert_eq!(f2(1.567), "1.57");
    }
}
