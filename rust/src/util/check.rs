//! A minimal property-based testing harness (the offline vendor set has no
//! `proptest`/`quickcheck`).
//!
//! Usage (`no_run`: doctest binaries lack the xla rpath):
//! ```no_run
//! use cornstarch::util::check::{check, Gen};
//! check("sort is idempotent", 200, |g: &mut Gen| {
//!     let mut v = g.vec_u64(0..64, 1000);
//!     v.sort_unstable();
//!     let w = { let mut w = v.clone(); w.sort_unstable(); w };
//!     assert_eq!(v, w);
//! });
//! ```
//!
//! Each case gets a fresh deterministic [`Gen`]; on failure the harness
//! panics with the case seed so the exact input reproduces with
//! `Gen::from_seed(seed)`.

use super::rng::Rng;

/// Random input generator handed to property bodies.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn from_seed(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    /// Vec of u64 with length in `len_range` and values `< max_val`.
    pub fn vec_u64(
        &mut self,
        len_range: std::ops::Range<usize>,
        max_val: u64,
    ) -> Vec<u64> {
        let n = self.rng.range(len_range.start.max(0), len_range.end.max(1));
        (0..n).map(|_| self.rng.below(max_val.max(1))).collect()
    }

    pub fn vec_f64(
        &mut self,
        len_range: std::ops::Range<usize>,
        max_val: f64,
    ) -> Vec<f64> {
        let n = self.rng.range(len_range.start, len_range.end);
        (0..n).map(|_| self.rng.f64() * max_val).collect()
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `body` on `cases` deterministic random inputs. Panics (with the
/// reproducing seed in the message) on the first failing case.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    body: F,
) {
    for case in 0..cases {
        // Seed derivation keeps cases independent but reproducible.
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::from_seed(seed);
            body(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("reverse twice is identity", 50, |g| {
            let v = g.vec_u64(0..32, 100);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            check("always fails", 3, |_g| {
                panic!("boom");
            });
        });
        let msg = match r {
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(_) => panic!("expected failure"),
        };
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::from_seed(5);
        let mut b = Gen::from_seed(5);
        assert_eq!(a.vec_u64(1..50, 10), b.vec_u64(1..50, 10));
    }
}
