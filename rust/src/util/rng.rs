//! Deterministic PRNGs (splitmix64 seeding + xoshiro256**).
//!
//! Every stochastic piece of the repo (mask generators, data synthesis,
//! random token distribution, property tests) draws from [`Rng`] so runs
//! are reproducible from a single seed.

/// xoshiro256** seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller (one value per call, no caching).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial shuffle).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k.min(n) {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k.min(n));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((9000..11000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }
}
