//! Minimal JSON writer + reader. The writer dumps metrics / loss curves
//! for plotting; the reader exists for the tuner's persistent plan cache
//! (the offline vendor set has no `serde`, so round-tripping is done with
//! this hand-rolled recursive-descent parser).

use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Parse a JSON document. Strict enough for our own output; rejects
    /// trailing garbage.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => {
                kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {} of {}",
                c as char,
                self.pos,
                self.bytes.len()
            ))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(format!("bad object at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("bad array at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self
                .peek()
                .ok_or_else(|| "unterminated string".to_string())?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| "bad escape".to_string())?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos..self.pos + 4],
                            )
                            .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            self.pos += 4;
                            // Surrogate pairs are not needed by our own
                            // output; map unpaired surrogates to U+FFFD.
                            out.push(
                                char::from_u32(code).unwrap_or('\u{FFFD}'),
                            );
                        }
                        _ => return Err("bad escape".into()),
                    }
                }
                _ => {
                    // Re-decode the UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    if start + len > self.bytes.len() {
                        return Err("bad utf-8".into());
                    }
                    let s = std::str::from_utf8(
                        &self.bytes[start..start + len],
                    )
                    .map_err(|_| "bad utf-8".to_string())?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "bad number".to_string())?;
        if is_float {
            text.parse::<f64>()
                .map(Json::Num)
                .map_err(|_| format!("bad number {text:?}"))
        } else {
            text.parse::<i64>()
                .map(Json::Int)
                .map_err(|_| format!("bad number {text:?}"))
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj(vec![
            ("a", Json::Int(1)),
            ("b", Json::Arr(vec![Json::Num(1.5), Json::Null])),
            ("s", Json::Str("x\"y".into())),
        ]);
        assert_eq!(j.render(), r#"{"a":1,"b":[1.5,null],"s":"x\"y"}"#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn escapes_control_chars() {
        assert_eq!(Json::Str("\u{1}".into()).render(), "\"\\u0001\"");
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let j = Json::obj(vec![
            ("a", Json::Int(-3)),
            ("b", Json::Arr(vec![Json::Num(1.5), Json::Null, Json::Bool(true)])),
            ("s", Json::Str("x\"y\nz\u{1}é".into())),
            ("o", Json::obj(vec![("k", Json::Str("v".into()))])),
        ]);
        let text = j.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.render(), text);
    }

    #[test]
    fn parse_accessors() {
        let j = Json::parse(
            r#" { "n": 42, "x": 2.5, "s": "hi", "b": false,
                 "xs": [1, 2, 3] } "#,
        )
        .unwrap();
        assert_eq!(j.get("n").and_then(Json::as_i64), Some(42));
        assert_eq!(j.get("x").and_then(Json::as_f64), Some(2.5));
        assert_eq!(j.get("s").and_then(Json::as_str), Some("hi"));
        assert_eq!(j.get("b").and_then(Json::as_bool), Some(false));
        assert_eq!(j.get("xs").and_then(Json::as_arr).unwrap().len(), 3);
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""a\u0041\u00e9""#).unwrap();
        assert_eq!(j.as_str(), Some("aAé"));
    }
}
