//! Minimal JSON *writer* (no parser needed in-repo; the artifact manifest
//! uses a line format). Used to dump metrics / loss curves for plotting.

use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj(vec![
            ("a", Json::Int(1)),
            ("b", Json::Arr(vec![Json::Num(1.5), Json::Null])),
            ("s", Json::Str("x\"y".into())),
        ]);
        assert_eq!(j.render(), r#"{"a":1,"b":[1.5,null],"s":"x\"y"}"#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn escapes_control_chars() {
        assert_eq!(Json::Str("\u{1}".into()).render(), "\"\\u0001\"");
    }
}
