//! Bench: context-parallel token distribution (paper Table 4 + Figure 12
//! + the §4.3.2 "1 M tokens in under a millisecond" claim).
//!
//! Prints (a) the Table 4 reproduction (model-predicted attention step
//! times per algorithm/mask/length), (b) Figure 12's per-rank balance
//! sample, and (c) measured wall times of the distribution algorithms
//! themselves at 64k and 1M tokens.

use cornstarch::bam;
use cornstarch::bench::Bencher;
use cornstarch::coordinator::experiments;
use cornstarch::cp::Algorithm;
use cornstarch::util::rng::Rng;

fn main() {
    // (a) Table 4 — the paper's numbers are ms per attention layer step.
    let (table4, rows) = experiments::table4(20);
    println!("{}", table4.render());
    // Sanity: LPT never loses to zigzag on EE/MP (the paper's claim).
    for (len, mt, alg, ms) in &rows {
        if *mt == experiments::MaskType::Ee && alg == "LPT" {
            let zz = rows
                .iter()
                .find(|(l, m, a, _)| l == len && *m == *mt && a == "Zigzag")
                .unwrap()
                .3;
            assert!(
                *ms <= zz * 1.02,
                "{len}/EE: LPT {ms:.2} vs zigzag {zz:.2}"
            );
        }
    }

    // (b) Figure 12 — per-rank execution times, one 64k sample.
    println!("{}", experiments::fig12().render());

    // (c) algorithm wall time: the paper claims LPT distributes 1M tokens
    // (128-token blocks) in < 1 ms.
    let mut b = Bencher::new("distribution algorithm wall time");
    for &(t, label) in
        &[(65_536usize, "64k"), (1_048_576usize, "1M")]
    {
        let mut rng = Rng::new(7);
        let mask = bam::generators::random_ee(&mut rng, t, 3);
        let w = bam::block_workloads(&mask.workloads(), 128);
        for alg in [
            Algorithm::Lpt,
            Algorithm::Random { seed: 3 },
            Algorithm::Zigzag,
            Algorithm::Ring,
        ] {
            b.bench(&format!("{} {} tokens", alg.name(), label), || {
                std::hint::black_box(alg.assign(&w, 8));
            });
        }
        // workload computation itself (O(T·V), never materializes [T,T])
        b.bench(&format!("BAM workloads {label}"), || {
            std::hint::black_box(mask.workloads());
        });
    }
    b.report();

    // The paper's <1 ms claim for 1M-token LPT distribution.
    if let Some(ms) = b.median_of("LPT 1M tokens") {
        println!("LPT @ 1M tokens, 128-block: {ms:.3} ms (paper: < 1 ms)");
        assert!(ms < 10.0, "LPT at 1M tokens took {ms:.1} ms");
    }
}
