//! Bench: the autotuner's search wall time — enumeration + lower-bound
//! pruning + multi-threaded simulation — across compositions, budgets,
//! and worker counts, plus the cache's O(1) repeated-query path.

use cornstarch::api::ClusterSpec;
use cornstarch::bench::{median, Bencher};
use cornstarch::model::{MllmSpec, Size};
use cornstarch::telemetry::{self, key as tkey};
use cornstarch::tuner::{
    enumerate, search, tune, Objective, SearchSpace, TuneRequest,
};
use cornstarch::util::json::Json;

fn main() {
    let d = ClusterSpec::a40_default();

    // ---- space sizes, for context ----
    for (name, spec, devices) in [
        ("VLM-M", MllmSpec::vlm(Size::M, Size::M), 16usize),
        ("ALM-L", MllmSpec::alm(Size::M, Size::L), 16),
        ("VALM-MM", MllmSpec::valm(Size::M, Size::M, Size::M), 24),
    ] {
        let mm = cornstarch::modality::MultimodalModule::from_spec(&spec);
        let n = enumerate(&mm, &SearchSpace::paper_default(devices)).len();
        telemetry::info(&format!("{name} on {devices} GPUs: {n} candidates"));
    }
    telemetry::info("");

    let mut b = Bencher::new("autotuner search wall time");
    for (name, spec, devices) in [
        ("VLM-M @16", MllmSpec::vlm(Size::M, Size::M), 16usize),
        ("VALM-MM @24", MllmSpec::valm(Size::M, Size::M, Size::M), 24),
    ] {
        for threads in [1usize, 4] {
            b.bench(&format!("{name} exhaustive t={threads}"), || {
                std::hint::black_box(search(
                    &spec,
                    &SearchSpace::paper_default(devices),
                    Objective::Makespan,
                    0,
                    threads,
                    &d,
                ));
            });
        }
        b.bench(&format!("{name} budget=16 t=4"), || {
            std::hint::black_box(search(
                &spec,
                &SearchSpace::paper_default(devices),
                Objective::Makespan,
                16,
                4,
                &d,
            ));
        });
    }

    // ---- cache hit path: answered by the in-process tier of the
    // two-tier store (first query warms it from the file) — must be
    // map-read-bound, not search- or even file-read-bound ----
    let mut path = std::env::temp_dir();
    path.push(format!("cornstarch-tuner-bench-{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let mut req = TuneRequest::new(MllmSpec::vlm(Size::M, Size::M), 16);
    req.cache_path = Some(path.to_string_lossy().into_owned());
    tune(&req).expect("warm the cache");
    b.bench("VLM-M @16 cached query", || {
        let out = tune(&req).expect("cached");
        assert!(out.cache_hit);
        std::hint::black_box(out);
    });
    let _ = std::fs::remove_file(&path);

    // ---- the ROADMAP perf point: VLM-L on the mixed 4×A40 + 4×A100
    // pool, counted by the telemetry registry (candidates/s, prune
    // rate, end-to-end tune wall time) and written to BENCH_tuner.json
    // so the trajectory is diffable across PRs.
    let mut hetero = TuneRequest::for_cluster(
        MllmSpec::vlm(Size::M, Size::L),
        ClusterSpec::a40_a100_demo(),
    );
    hetero.threads = 4;
    let before = telemetry::snapshot();
    let mut walls = Vec::new();
    b.bench("VLM-L @ a40x4-a100x4 t=4", || {
        let t0 = std::time::Instant::now();
        std::hint::black_box(tune(&hetero).expect("hetero tune"));
        walls.push(t0.elapsed().as_secs_f64() * 1e3);
    });
    let fired = telemetry::snapshot().delta_since(&before);
    let runs = fired.get(tkey::CACHE_MISS).max(1);
    let enumerated = fired.get(tkey::CANDIDATES_ENUMERATED) / runs;
    let pruned = (fired.get(tkey::PRUNED_LOWER_BOUND)
        + fired.get(tkey::PRUNED_MEMORY)
        + fired.get(tkey::PRUNED_GROUP_CAPACITY))
        / runs;
    let evaluated = fired.get(tkey::EVALUATED) / runs;
    let wall_ms = median(&walls);
    let candidates_per_s = enumerated as f64 / (wall_ms / 1e3);
    let prune_rate = pruned as f64 / enumerated.max(1) as f64;
    telemetry::report(&format!(
        "VLM-L @ a40x4-a100x4: {enumerated} candidates ({evaluated} \
         simulated, {pruned} pruned = {:.0}% prune rate), {:.0} \
         candidates/s, {wall_ms:.1} ms/tune",
        prune_rate * 100.0,
        candidates_per_s
    ));
    // The winner's simulated bubble fraction — deterministic (same tune
    // answer every run), tracked so BENCH trajectories catch schedule
    // regressions, not just wall-time noise.
    let outcome = tune(&hetero).expect("hetero tune for winner");
    let winner_sim =
        outcome.instantiate(&hetero.spec, &hetero.cluster).simulate();
    let winner_bubble = cornstarch::sim::bubble_fraction(&winner_sim.sim);
    let bench_json = Json::obj(vec![
        // `schema`/`case_id` are the stable keys BENCH trajectory tooling
        // joins runs on PR-over-PR; no timestamps — emission order and
        // every non-timing field are deterministic.
        ("schema", Json::Str("cornstarch-bench/v1".to_string())),
        ("case_id", Json::Str("tuner.vlm_l.a40x4-a100x4.t4".to_string())),
        ("bench", Json::Str("tuner".to_string())),
        ("case", Json::Str("VLM-L @ a40x4-a100x4".to_string())),
        ("winner_bubble_fraction", Json::Num(winner_bubble)),
        ("candidates_enumerated", Json::Int(enumerated as i64)),
        ("candidates_evaluated", Json::Int(evaluated as i64)),
        ("candidates_pruned", Json::Int(pruned as i64)),
        ("prune_rate", Json::Num(prune_rate)),
        ("candidates_per_s", Json::Num(candidates_per_s)),
        ("tune_wall_ms", Json::Num(wall_ms)),
        ("threads", Json::Int(4)),
    ]);
    let out = std::env::var("CORNSTARCH_BENCH_OUT")
        .unwrap_or_else(|_| "../BENCH_tuner.json".to_string());
    match std::fs::write(&out, bench_json.render()) {
        Ok(()) => telemetry::info(&format!("wrote {out}")),
        Err(e) => telemetry::error(&format!(
            "error: writing {out}: {e}"
        )),
    }

    b.report();
}
