//! Bench: the autotuner's search wall time — enumeration + lower-bound
//! pruning + multi-threaded simulation — across compositions, budgets,
//! and worker counts, plus the cache's O(1) repeated-query path.

use cornstarch::api::ClusterSpec;
use cornstarch::bench::Bencher;
use cornstarch::model::{MllmSpec, Size};
use cornstarch::tuner::{
    enumerate, search, tune, Objective, SearchSpace, TuneRequest,
};

fn main() {
    let d = ClusterSpec::a40_default();

    // ---- space sizes, for context ----
    for (name, spec, devices) in [
        ("VLM-M", MllmSpec::vlm(Size::M, Size::M), 16usize),
        ("ALM-L", MllmSpec::alm(Size::M, Size::L), 16),
        ("VALM-MM", MllmSpec::valm(Size::M, Size::M, Size::M), 24),
    ] {
        let mm = cornstarch::modality::MultimodalModule::from_spec(&spec);
        let n = enumerate(&mm, &SearchSpace::paper_default(devices)).len();
        println!("{name} on {devices} GPUs: {n} candidates");
    }
    println!();

    let mut b = Bencher::new("autotuner search wall time");
    for (name, spec, devices) in [
        ("VLM-M @16", MllmSpec::vlm(Size::M, Size::M), 16usize),
        ("VALM-MM @24", MllmSpec::valm(Size::M, Size::M, Size::M), 24),
    ] {
        for threads in [1usize, 4] {
            b.bench(&format!("{name} exhaustive t={threads}"), || {
                std::hint::black_box(search(
                    &spec,
                    &SearchSpace::paper_default(devices),
                    Objective::Makespan,
                    0,
                    threads,
                    &d,
                ));
            });
        }
        b.bench(&format!("{name} budget=16 t=4"), || {
            std::hint::black_box(search(
                &spec,
                &SearchSpace::paper_default(devices),
                Objective::Makespan,
                16,
                4,
                &d,
            ));
        });
    }

    // ---- cache hit path: must be file-read-bound, not search-bound ----
    let mut path = std::env::temp_dir();
    path.push(format!("cornstarch-tuner-bench-{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let mut req = TuneRequest::new(MllmSpec::vlm(Size::M, Size::M), 16);
    req.cache_path = Some(path.to_string_lossy().into_owned());
    tune(&req).expect("warm the cache");
    b.bench("VLM-M @16 cached query", || {
        let out = tune(&req).expect("cached");
        assert!(out.cache_hit);
        std::hint::black_box(out);
    });
    let _ = std::fs::remove_file(&path);

    b.report();
}
