//! Bench: REAL training steps over PJRT artifacts — single-process vs the
//! thread-per-stage pipeline executor (modality parallelism made
//! measurable: the pipeline executor overlaps encoder work across threads
//! and should not be slower than sequential once per-step overheads are
//! amortized).

use cornstarch::bench::Bencher;
use cornstarch::runtime::Manifest;
use cornstarch::train::{
    FrozenPolicy, PipelineTrainer, SyntheticDataset, Trainer,
};

fn main() {
    let manifest = match Manifest::load(Manifest::default_root()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping train bench (no artifacts): {e:#}");
            return;
        }
    };
    let fast = std::env::var_os("CORNSTARCH_BENCH_FAST").is_some();
    let steps = if fast { 3 } else { 10 };

    for model in ["tiny", "tiny_va"] {
        let mm = manifest.model(model).unwrap().clone();
        let ds = SyntheticDataset::new(&mm, 42);
        let batch: Vec<_> = (0..4).map(|i| ds.sample(i)).collect();
        let mut b = Bencher::new(&format!("train step — {model} (4 microbatches)"));

        let mut single =
            Trainer::new(&manifest, model, FrozenPolicy::paper(), 1e-3)
                .unwrap();
        let mut samples = Vec::new();
        for _ in 0..steps {
            let s = single.train_step(&batch).unwrap();
            samples.push(s.wall_ms);
        }
        b.record("single-process", samples);

        let mut pipe =
            PipelineTrainer::new(&manifest, model, FrozenPolicy::paper(), 1e-3)
                .unwrap();
        let mut samples = Vec::new();
        for _ in 0..steps {
            let s = pipe.train_step(&batch).unwrap();
            samples.push(s.wall_ms);
        }
        b.record("pipeline (thread/stage)", samples);

        // all-trainable: the 2x backward path everywhere
        let mut full =
            Trainer::new(&manifest, model, FrozenPolicy::all_trainable(), 1e-3)
                .unwrap();
        let mut samples = Vec::new();
        for _ in 0..steps {
            let s = full.train_step(&batch).unwrap();
            samples.push(s.wall_ms);
        }
        b.record("single, all-trainable (2x bwd)", samples);

        b.report();
    }
}
