//! Bench: pipeline-parallel planning + discrete-event simulation for every
//! end-to-end table/figure of the paper (Figures 2, 9, 10, 13, 14, 15 and
//! Tables 2, 3, 7, 8, 10, 11), plus wall-time of the planner and the
//! simulator themselves.

use cornstarch::bench::Bencher;
use cornstarch::coordinator::experiments;
use cornstarch::cost::Device;
use cornstarch::modality::{
    planner, MultimodalModule, MultimodalParallelSpec, Strategy,
};
use cornstarch::model::{MllmSpec, Size};

fn main() {
    // ---- the paper's tables/figures, printed in full ----
    println!("{}", experiments::fig2().0.render());
    for s in Size::ALL {
        let (t, rows) = experiments::fig9_13_14(s);
        println!("{}", t.render());
        let best = rows
            .iter()
            .map(|r| r.speedup_vs_best_baseline())
            .fold(0.0f64, f64::max);
        println!("  max Cornstarch speedup (LLM-{}): {best:.2}x\n", s.letter());
    }
    for s in Size::ALL {
        println!("{}", experiments::fig10_15(s).0.render());
    }
    for s in Size::ALL {
        println!("{}", experiments::table2_7_8(s).0.render());
    }
    for s in Size::ALL {
        println!("{}", experiments::table3_10_11(s).0.render());
    }

    // ---- wall time of plan + simulate (the L3 "control plane") ----
    let mut b = Bencher::new("planner + 1F1B simulation wall time");
    let spec = MllmSpec::valm(Size::M, Size::M, Size::M);
    let mm = MultimodalModule::from_spec(&spec);
    for (name, strategy, enc, llm) in [
        ("cornstarch VALM-MM", Strategy::Cornstarch, 1usize, 4usize),
        ("colocated VALM-MM", Strategy::Colocated, 3, 3),
        ("replicated VALM-MM", Strategy::Replicated, 1, 6),
    ] {
        let ps = MultimodalParallelSpec::paper_default(&[enc, enc], llm, 2, 2);
        b.bench(name, || {
            let p = planner::plan(strategy, &mm, &ps, Device::a40());
            std::hint::black_box(p.simulate());
        });
    }
    // Algorithm 1 search
    b.bench("auto-parallelize VALM-MM (6 groups)", || {
        std::hint::black_box(cornstarch::modality::auto_parallelize(
            &mm,
            6,
            2,
            2,
            6,
            Device::a40(),
        ));
    });
    b.report();
}
