//! Bench: `cornstarch serve` under concurrent clients — mixed
//! warm-hit / cold-miss request streams over a real TCP socket, with
//! the cold one-shot tune as the baseline the warm path must beat.
//!
//! The headline numbers (written to `BENCH_serve.json`): per-request
//! latency p50/p99 for the mixed stream, the warm-hit-only p50 (served
//! from the plan store's in-process tier, no disk, no search), and the
//! aggregate requests/s across 8 client threads. The service claim is
//! `speedup_warm_vs_cold` ≥ 10: a warm repeat must be at least an
//! order of magnitude cheaper than re-running the search.

use std::io::{BufRead, BufReader, Write as _};
use std::net::TcpStream;

use cornstarch::api::{PlanRequest, PlanningService};
use cornstarch::bench::{median, Bencher};
use cornstarch::model::{MllmSpec, Size};
use cornstarch::serve::{ServeOpts, Server};
use cornstarch::telemetry;
use cornstarch::util::json::Json;

const CLIENTS: usize = 8;
/// Per-client mixed stream: hits to the warm set + unique-signature
/// misses (distinct budgets force distinct cache signatures).
const HITS_PER_CLIENT: usize = 15;
const MISSES_PER_CLIENT: usize = 5;

/// The warm set every client re-requests (small spaces keep the cold
/// fills fast; the warm path cost is independent of model size anyway).
const WARM: &[&str] = &[
    r#"{"mllm":"VLM-S","llm":"S","budget":8,"threads":2}"#,
    r#"{"mllm":"ALM-S","llm":"S","budget":8,"threads":2}"#,
    r#"{"mllm":"VLM-M","llm":"S","budget":8,"threads":2}"#,
];

fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

/// One request/response round-trip; returns (latency_ms, cache_hit).
fn roundtrip(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    line: &str,
) -> (f64, bool) {
    let t0 = std::time::Instant::now();
    stream.write_all(line.as_bytes()).expect("send");
    stream.write_all(b"\n").expect("send");
    let mut resp = String::new();
    reader.read_line(&mut resp).expect("recv");
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    let j = Json::parse(resp.trim()).expect("response is JSON");
    assert_eq!(
        j.get("ok").and_then(Json::as_bool),
        Some(true),
        "request failed: {resp}"
    );
    (ms, j.get("cache_hit").and_then(Json::as_bool) == Some(true))
}

fn main() {
    // ---- baseline: the cold one-shot tune the warm path must beat ----
    let cold_req = PlanRequest::default_for(MllmSpec::vlm(Size::S, Size::S))
        .budget(8)
        .threads(2);
    let mut cold_walls = Vec::new();
    for _ in 0..9 {
        let t0 = std::time::Instant::now();
        std::hint::black_box(
            PlanningService::new().plan(&cold_req).expect("cold tune"),
        );
        cold_walls.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let cold_tune_ms = median(&cold_walls);

    // ---- the server under test (in-memory store: the service mode) ----
    let server = Server::bind(
        "127.0.0.1:0",
        ServeOpts { threads: 2, ..ServeOpts::default() },
    )
    .expect("bind");
    let addr = server.local_addr();
    let handle = server.handle();
    let runner = std::thread::spawn(move || server.run().expect("serve"));

    let connect = || {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        (stream, reader)
    };

    // Warm the store once so the hit set is hot before anyone times it.
    {
        let (mut s, mut r) = connect();
        for line in WARM {
            let (_, hit) = roundtrip(&mut s, &mut r, line);
            assert!(!hit, "warm fill should be the miss");
        }
    }

    // ---- warm-hit-only latency: one client, store answers from memory
    let warm_hit_samples: Vec<f64> = {
        let (mut s, mut r) = connect();
        let mut out = Vec::new();
        for i in 0..60 {
            let (ms, hit) = roundtrip(&mut s, &mut r, WARM[i % WARM.len()]);
            assert!(hit, "warm set must hit");
            out.push(ms);
        }
        out
    };

    // ---- mixed stream: 8 clients, hits + unique-signature misses ----
    let t0 = std::time::Instant::now();
    let per_client: Vec<Vec<(f64, bool)>> = std::thread::scope(|scope| {
        let workers: Vec<_> = (0..CLIENTS)
            .map(|c| {
                scope.spawn(move || {
                    let (mut s, mut r) = connect();
                    let mut out = Vec::new();
                    for i in 0..HITS_PER_CLIENT {
                        out.push(roundtrip(
                            &mut s,
                            &mut r,
                            WARM[(c + i) % WARM.len()],
                        ));
                    }
                    for i in 0..MISSES_PER_CLIENT {
                        // budget is part of the cache signature: a
                        // never-seen budget is a guaranteed cold miss.
                        let line = format!(
                            r#"{{"mllm":"VLM-S","llm":"S","budget":{},"threads":2}}"#,
                            100 + c * MISSES_PER_CLIENT + i
                        );
                        out.push(roundtrip(&mut s, &mut r, &line));
                    }
                    out
                })
            })
            .collect();
        workers.into_iter().map(|w| w.join().expect("client")).collect()
    });
    let mixed_wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    let all: Vec<(f64, bool)> =
        per_client.into_iter().flatten().collect();
    let hits = all.iter().filter(|(_, h)| *h).count();
    let misses = all.len() - hits;
    let mut mixed: Vec<f64> = all.iter().map(|(ms, _)| *ms).collect();
    mixed.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut warm_sorted = warm_hit_samples.clone();
    warm_sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());

    handle.shutdown();
    let served = runner.join().expect("server thread");

    let p50 = percentile(&mixed, 0.50);
    let p99 = percentile(&mixed, 0.99);
    let warm_p50 = percentile(&warm_sorted, 0.50);
    let warm_p99 = percentile(&warm_sorted, 0.99);
    let requests_per_s = all.len() as f64 / (mixed_wall_ms / 1e3);
    let speedup = cold_tune_ms / warm_p50.max(1e-6);

    let mut b = Bencher::new("cornstarch serve");
    b.record("cold one-shot tune", cold_walls);
    b.record("warm hit (1 client)", warm_hit_samples);
    b.record("mixed stream (8 clients)", mixed);
    b.report();
    telemetry::report(&format!(
        "{served} served | {hits} hit / {misses} miss | p50 {p50:.3} ms, \
         p99 {p99:.3} ms | {requests_per_s:.0} req/s | warm hit p50 \
         {warm_p50:.3} ms vs cold tune {cold_tune_ms:.2} ms = {speedup:.1}x"
    ));
    if speedup < 10.0 {
        telemetry::error(&format!(
            "error: warm-hit speedup {speedup:.1}x is under the 10x \
             service claim"
        ));
    }

    let bench_json = Json::obj(vec![
        // `schema`/`case_id` are the stable keys BENCH trajectory tooling
        // joins runs on PR-over-PR; no timestamps — emission order and
        // every non-timing field are deterministic.
        ("schema", Json::Str("cornstarch-bench/v1".to_string())),
        ("case_id", Json::Str("serve.mixed.8clients".to_string())),
        ("bench", Json::Str("serve".to_string())),
        ("case", Json::Str("mixed hit/miss stream over TCP".to_string())),
        ("clients", Json::Int(CLIENTS as i64)),
        ("requests_total", Json::Int(all.len() as i64)),
        ("hit_requests", Json::Int(hits as i64)),
        ("miss_requests", Json::Int(misses as i64)),
        ("p50_ms", Json::Num(p50)),
        ("p99_ms", Json::Num(p99)),
        ("requests_per_s", Json::Num(requests_per_s)),
        ("warm_hit_p50_ms", Json::Num(warm_p50)),
        ("warm_hit_p99_ms", Json::Num(warm_p99)),
        ("cold_tune_ms", Json::Num(cold_tune_ms)),
        ("speedup_warm_vs_cold", Json::Num(speedup)),
    ]);
    let out = std::env::var("CORNSTARCH_BENCH_OUT")
        .unwrap_or_else(|_| "../BENCH_serve.json".to_string());
    match std::fs::write(&out, bench_json.render()) {
        Ok(()) => telemetry::info(&format!("wrote {out}")),
        Err(e) => telemetry::error(&format!("error: writing {out}: {e}")),
    }
}
