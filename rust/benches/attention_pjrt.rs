//! Bench: REAL PJRT execution of the L1 BAM-attention kernel artifact
//! (the cross-check behind Table 4's workload model — interpret-mode
//! Pallas on CPU, so absolute times are not TPU times, but the *ordering*
//! across mask types must track unmasked-pair counts).

use cornstarch::bench::Bencher;
use cornstarch::coordinator::experiments::MaskType;
use cornstarch::runtime::{AttnRuntime, Manifest};
use cornstarch::util::rng::Rng;

fn main() {
    let manifest = match Manifest::load(Manifest::default_root()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping attention bench (no artifacts): {e:#}");
            return;
        }
    };
    for art in ["attn128", "attn512"] {
        let rt = match AttnRuntime::load(&manifest, art) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("skipping {art}: {e:#}");
                continue;
            }
        };
        let t = rt.spec.tokens;
        let n = t * rt.spec.heads * rt.spec.head_dim;
        let mut rng = Rng::new(1);
        let mk = |rng: &mut Rng| -> Vec<f32> {
            (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.2).collect()
        };
        let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));

        let mut b = Bencher::new(&format!(
            "PJRT BAM attention {art} (T={t}, H={}, D={})",
            rt.spec.heads, rt.spec.head_dim
        ));
        let mut pair_counts = Vec::new();
        for mt in MaskType::ALL {
            let mut mrng = Rng::new(0x5EED ^ t as u64);
            let mask = mt.random(&mut mrng, t);
            let mut bits = mask.bits.clone();
            bits.resize(t, *bits.last().unwrap());
            let bam = cornstarch::bam::Bam::new(bits, mask.text_mask);
            let pairs: u64 = bam.workloads().iter().sum();
            pair_counts.push((mt.name(), pairs));
            let bi = bam.bits_i32();
            let pi = bam.pos_i32();
            b.bench(mt.name(), || {
                let (_, _ms) = rt.run(&q, &k, &v, &bi, &pi).unwrap();
            });
        }
        b.report();
        println!("unmasked (q,k) pairs per mask: {pair_counts:?}\n");
    }
}
